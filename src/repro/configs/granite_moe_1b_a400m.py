"""Granite-3.0-1B-A400M [hf:ibm-granite]: 32-expert top-8 MoE."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    num_experts=32, experts_per_token=8,
    mlp_activation="silu", mlp_gated=True, rope_theta=10000.0,
)
