from .base import (
    ModelConfig, ShapeCell, SHAPE_CELLS, ARCH_IDS, get_config, cell_applicable, all_cells,
)
