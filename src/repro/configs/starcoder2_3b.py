"""StarCoder2-3B [arXiv:2402.19173]: GQA kv=2, RoPE, ungated GELU MLP."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    mlp_activation="gelu", mlp_gated=False, norm="layernorm",
    rope_theta=100000.0,
)
