"""Hymba-1.5B [arXiv:2411.13676]: parallel attention + Mamba heads per layer,
sliding-window attention (global-attention layers simplified to SWA — DESIGN.md)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    mlp_activation="silu", mlp_gated=True,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    sliding_window=2048, rope_theta=10000.0,
)
