"""Qwen2-VL-2B [arXiv:2409.12191]: M-RoPE; vision frontend STUBBED — inputs
include precomputed patch embeddings prepended to the token stream."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    mlp_activation="silu", mlp_gated=True,
    mrope_sections=(16, 24, 24), rope_theta=1000000.0,
    frontend="vision_stub", num_prefix_embeds=256,
)
