"""Whisper-tiny [arXiv:2212.04356]: enc-dec; conv frontend STUBBED — the
dry-run/smoke inputs are precomputed frame embeddings (brief: frontend stub)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    num_layers=4, encoder_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, head_dim=64,
    mlp_activation="gelu", mlp_gated=False, norm="layernorm",
    use_rope=False, frontend="audio_stub",
)
