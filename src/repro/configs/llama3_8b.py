"""Llama-3-8B [arXiv:2407.21783]: dense GQA, 128k vocab, SwiGLU."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    mlp_activation="silu", mlp_gated=True, rope_theta=500000.0,
)
