"""Model/config system: architecture configs, input-shape cells, registry.

Every assigned architecture is a frozen :class:`ModelConfig`; the four
shape cells (train_4k / prefill_32k / decode_32k / long_500k) are global
:class:`ShapeCell` entries.  ``reduced()`` derives the CPU-smoke-test
variant of any config (same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    # MLP
    mlp_activation: str = "silu"  # silu | gelu | relu2
    mlp_gated: bool = True
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # attention
    sliding_window: int | None = None
    use_rope: bool = True
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE
    attention_schedule: str = "rect"  # rect | tri  (see §Perf)
    # enc-dec (whisper)
    encoder_layers: int = 0
    frontend: str | None = None  # audio_stub | vision_stub
    num_prefix_embeds: int = 0  # vlm: precomputed patch embeds prepended
    # numerics / misc
    remat_policy: str = "full"  # full | dots (save MXU outputs, skip bwd recompute)
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    logical_rules_overrides: tuple[tuple[str, str | None], ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve a 500k-token context (sub-quadratic cache)?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return self.replace(
            num_layers=2,
            encoder_layers=min(self.encoder_layers, 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=2,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            sliding_window=32 if self.sliding_window else None,
            mrope_sections=(2, 3, 3) if self.mrope_sections else None,  # sums to head_dim/2
            num_prefix_embeds=8 if self.num_prefix_embeds else 0,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "nemotron_4_340b",
    "llama3_8b",
    "deepseek_67b",
    "starcoder2_3b",
    "whisper_tiny",
    "mixtral_8x22b",
    "granite_moe_1b_a400m",
    "qwen2_vl_2b",
    "mamba2_1_3b",
    "hymba_1_5b",
]


def get_config(arch: str) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch × shape) cell."""
    if cell.name == "long_500k" and not cfg.is_subquadratic:
        return False, "pure full attention: 500k decode needs sub-quadratic cache (DESIGN.md §6)"
    return True, ""


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in SHAPE_CELLS.values():
            yield cfg, cell, *cell_applicable(cfg, cell)
