"""Pallas kernel: paged-KV decode attention — the serving engine's gather path.

The paged serving engine stores each slot's KV cache as fixed-size pages
scattered through a shared pool (``repro.serve.paged.PagePool``) instead of
one dense ``(max_len)`` row per slot.  Decode attention must therefore
*resolve the page table inside the kernel*: one grid program per batch row
walks the row's page table, gathers its pages into a contiguous
``(num_pages * page_size)`` KV view, and runs exactly the single-chunk
masked-softmax math of :func:`repro.models.common.attention`.

Like every kernel in this package it ships with a pure-jnp mirror
(:func:`paged_decode_attention_ref`) it must match **bitwise**, and traces
to exactly ONE ``pallas_call`` (asserted via
``repro.utils.hlo.primitive_count`` in tests/test_paged.py).

Bitwise contract with the dense decode path: the gathered view has the same
length as the dense cache row (``num_pages * page_size == max_len``), page
slots past the row's live length are masked to ``MASK_VALUE`` whose
``exp(MASK - m)`` underflows to exact 0, and unallocated page-table entries
(``-1``) gather zeros — so a paged serve is bitwise-identical per request
to a dense-slot serve (tests/test_paged.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.models.common import MASK_VALUE

__all__ = ["paged_decode_attention", "paged_decode_attention_ref"]


def _row_attention(q_row, ks, vs, length):
    """Single-row decode attention: the exact op sequence of the single-chunk
    branch of :func:`repro.models.common.attention` (b=1, sq=1), so the paged
    path stays bitwise-identical to the dense engine's per-row attention.

    q_row: (H, Dh); ks/vs: (Sc, KV, Dh); length: scalar int32 (live tokens).
    Returns (H * Dh,) in q_row.dtype.
    """
    h, dh = q_row.shape
    sc, kvh, _ = ks.shape
    rep = h // kvh
    qg = q_row.reshape(1, 1, kvh, rep, dh).transpose(0, 2, 3, 1, 4)
    scale = dh**-0.5
    s = jnp.einsum(
        "bgrqd,bkgd->bgrqk", qg, ks[None], preferred_element_type=jnp.float32
    )
    s = s * scale
    # contiguous paged rows: kv position j is valid iff j < length, which is
    # exactly the dense path's (pos >= 0) & (pos <= cur) mask
    mask = jnp.arange(sc, dtype=jnp.int32) < length
    s = jnp.where(mask[None, None, None, None, :], s, MASK_VALUE)
    m = jnp.maximum(s.max(-1), -1e25)
    p = jnp.exp(s - m[..., None])
    out = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vs.dtype), vs[None])
    out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None].astype(out.dtype)
    out = out.transpose(0, 3, 1, 2, 4).reshape(1, 1, h * dh)
    return out[0, 0].astype(q_row.dtype)


def _paged_attn_kernel(q_ref, pt_ref, len_ref, kp_ref, vp_ref, o_ref, *, num_row_pages: int):
    """One batch row: gather the row's pages, then single-chunk attention.

    q_ref (1, H, Dh); pt_ref (1, NP) int32 page table row (−1 = unallocated);
    len_ref (1, 1) int32; kp/vp_ref (P, page, KV, Dh) full pool; o (1, H·Dh).
    """
    full = (slice(None), slice(None), slice(None))
    ks_parts, vs_parts = [], []
    for j in range(num_row_pages):
        pid = pt_ref[0, j]
        safe = jnp.maximum(pid, 0)
        pk = pl.load(kp_ref, (pl.dslice(safe, 1),) + full)[0]
        pv = pl.load(vp_ref, (pl.dslice(safe, 1),) + full)[0]
        hole = pid < 0
        ks_parts.append(jnp.where(hole, jnp.zeros_like(pk), pk))
        vs_parts.append(jnp.where(hole, jnp.zeros_like(pv), pv))
    ks = jnp.concatenate(ks_parts, axis=0)  # (NP * page, KV, Dh)
    vs = jnp.concatenate(vs_parts, axis=0)
    o_ref[0] = _row_attention(q_ref[0], ks, vs, len_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(
    q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
    page_table: jax.Array, lengths: jax.Array, *, interpret: bool | None = None,
) -> jax.Array:
    """Decode attention over a paged KV pool; grid over the batch.

    q: (B, H, Dh) current-token queries; k_pages/v_pages: (P, page, KV, Dh)
    shared page pool; page_table: (B, NP) int32, −1 = unallocated slot;
    lengths: (B,) int32 live tokens per row (the current position + 1).
    Returns (B, H * Dh) attention outputs in q.dtype.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, h, dh = q.shape
    p, page, kvh, _ = k_pages.shape
    np_ = page_table.shape[1]
    lens2 = jnp.asarray(lengths, jnp.int32).reshape(b, 1)
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, num_row_pages=np_),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((p, page, kvh, dh), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((p, page, kvh, dh), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h * dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h * dh), q.dtype),
        interpret=interpret,
    )(q, page_table, lens2, k_pages, v_pages)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """Pure-jnp mirror of :func:`paged_decode_attention` (bitwise twin)."""
    b, np_ = page_table.shape
    page, kvh, dh = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    safe = jnp.maximum(page_table, 0)
    hole = (page_table < 0)[..., None, None, None]
    ks = jnp.where(hole, 0, k_pages[safe]).reshape(b, np_ * page, kvh, dh)
    vs = jnp.where(hole, 0, v_pages[safe]).reshape(b, np_ * page, kvh, dh)
    return jax.vmap(_row_attention)(q, ks, vs, jnp.asarray(lengths, jnp.int32))
