"""Pallas kernel: batched small-matrix EbV LU (+solve) — the optimizer path.

The EbV-preconditioned optimizer factors many independent (n, n) systems
(one per parameter factor / expert).  On TPU the natural mapping is one
grid program per matrix: each (n, n) system is VMEM-resident and the grid
runs the batch — equalized trivially (every work unit is one identical
factorization, the paper's invariant by construction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ebv_lu import _lu_body

__all__ = ["batched_lu_vmem", "batched_lu_solve_vmem"]


def _batched_lu_kernel(a_ref, o_ref, *, steps: int):
    a = a_ref[0]
    o_ref[0] = jax.lax.fori_loop(0, steps, _lu_body(*a.shape), a)


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_lu_vmem(a: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """(B, n, n) → packed LU per matrix; grid over the batch."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, n, _ = a.shape
    return pl.pallas_call(
        functools.partial(_batched_lu_kernel, steps=n - 1),
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a)


def _batched_solve_kernel(lu_ref, b_ref, x_ref, *, n: int):
    lu = lu_ref[0]
    y = b_ref[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def fwd(k, y):
        lk = jnp.where(rows > k, jax.lax.dynamic_slice(lu, (0, k), (n, 1)), 0.0)
        return y - lk * jax.lax.dynamic_slice(y, (k, 0), (1, y.shape[1]))

    y = jax.lax.fori_loop(0, n - 1, fwd, y)

    def bwd(j, x):
        k = n - 1 - j
        pivot = jax.lax.dynamic_slice(lu, (k, k), (1, 1))
        xk = jax.lax.dynamic_slice(x, (k, 0), (1, x.shape[1])) / pivot
        x = jax.lax.dynamic_update_slice(x, xk, (k, 0))
        uk = jnp.where(rows < k, jax.lax.dynamic_slice(lu, (0, k), (n, 1)), 0.0)
        return x - uk * xk

    x_ref[0] = jax.lax.fori_loop(0, n, bwd, y)


@functools.partial(jax.jit, static_argnames=("interpret",))
def batched_lu_solve_vmem(lu: jax.Array, b: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """lu: (B, n, n) packed; b: (B, n, m) → x: (B, n, m)."""
    lu = getattr(lu, "packed", lu)  # accept Factorization artifacts
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, n, _ = lu.shape
    m = b.shape[-1]
    return pl.pallas_call(
        functools.partial(_batched_solve_kernel, n=n),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=interpret,
    )(lu, b)
