"""Jit'd public wrappers over the Pallas kernels.

``impl`` dispatch:
  * ``"pallas_vmem"``    — whole-matrix VMEM kernel (n ≲ 4096 fp32).
  * ``"pallas_blocked"`` — blocked driver: panel kernel + fused bi-vector
                           step kernel per block column (rank-k updates).
  * ``"xla"``            — the pure-jnp blocked path from :mod:`repro.core`.

On CPU (this container) the Pallas paths run in interpret mode automatically;
on TPU they lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import blocked as _core_blocked
from repro.core import solve as _core_solve
from repro.core import banded as _core_banded
from . import ebv_lu as _k
from . import trsm as _trsm
from . import banded as _kbanded

__all__ = ["lu", "lu_solve", "linear_solve", "banded_lu"]


def _pallas_blocked_lu(a: jax.Array, *, block: int, col_tile: int, interpret: bool | None) -> jax.Array:
    n = a.shape[-1]
    block = min(block, n)
    for k0 in range(0, n, block):
        b = min(block, n - k0)
        pan = _k.panel(a[k0:, k0 : k0 + b], interpret=interpret)
        a = a.at[k0:, k0 : k0 + b].set(pan)
        w = n - k0 - b
        if w > 0:
            ct = min(col_tile, w)
            while w % ct:
                ct //= 2
            u12, trail = _k.fused_step(
                pan, a[k0 : k0 + b, k0 + b :], a[k0 + b :, k0 + b :],
                col_tile=ct, interpret=interpret,
            )
            a = a.at[k0 : k0 + b, k0 + b :].set(u12)
            a = a.at[k0 + b :, k0 + b :].set(trail)
    return a


@functools.partial(jax.jit, static_argnames=("impl", "block", "col_tile", "interpret"))
def lu(
    a: jax.Array,
    *,
    impl: str = "pallas_blocked",
    block: int = 256,
    col_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed EbV LU factorization (no pivoting — paper contract)."""
    if impl == "pallas_vmem":
        return _k.lu_vmem(a, interpret=interpret)
    if impl == "pallas_blocked":
        return _pallas_blocked_lu(a, block=block, col_tile=col_tile, interpret=interpret)
    if impl == "xla":
        return _core_blocked.blocked_lu(a, block=block)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def lu_solve(lu_packed: jax.Array, b: jax.Array, *, impl: str = "pallas", interpret: bool | None = None) -> jax.Array:
    if impl == "pallas":
        return _trsm.solve_vmem(lu_packed, b, interpret=interpret)
    if impl == "xla":
        return _core_solve.lu_solve(lu_packed, b)
    raise ValueError(f"unknown impl {impl!r}")


def linear_solve(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    return lu_solve(lu(a, **{k: v for k, v in kw.items() if k in ("impl", "block", "col_tile", "interpret")}), b)


@functools.partial(jax.jit, static_argnames=("bw", "impl", "interpret"))
def banded_lu(arow: jax.Array, *, bw: int, impl: str = "pallas", interpret: bool | None = None) -> jax.Array:
    if impl == "pallas":
        return _kbanded.banded_lu_kernelized(arow, bw=bw, interpret=interpret)
    if impl == "xla":
        return _core_banded.banded_lu(arow, bw=bw)
    raise ValueError(f"unknown impl {impl!r}")
