"""Jit'd public wrappers over the Pallas kernels.

``lu`` impl dispatch:
  * ``"pallas_fused"``   — DEFAULT: single-dispatch EbV LU megakernel — one
                           ``pallas_call`` for the whole factorization, matrix
                           carried in place in HBM (see
                           :func:`repro.kernels.ebv_lu.lu_fused`; small
                           matrices run its VMEM-resident variant).  Non-fp32
                           inputs fall back to the op-identical ``"xla"``
                           mirror with a one-time warning naming the dtype.
  * ``"pallas_blocked"`` — legacy multi-launch blocked driver: one panel
                           kernel + one fused bi-vector step kernel per block
                           column (kept as the fallback/baseline; see
                           README.md for the launch/traffic comparison).
  * ``"pallas_vmem"``    — whole-matrix VMEM kernel (n ≲ 4096 fp32).
  * ``"xla"``            — pure-jnp mirror of the fused driver
                           (:func:`repro.core.blocked.fused_blocked_lu`):
                           identical op shapes/ordering, bitwise-identical
                           output — the transparent reference.

``lu_solve`` impl dispatch:
  * ``"pallas"``         — DEFAULT: auto — ``solve_vmem`` while the packed LU
                           fits VMEM comfortably, ``solve_tiled`` beyond.
  * ``"pallas_vmem"`` / ``"pallas_tiled"`` — force either driver.
  * ``"xla"``            — pure-jnp substitution from :mod:`repro.core`.

``banded_lu`` impl dispatch (band row-aligned, see :mod:`repro.core.banded`):
  * ``"pallas"``         — DEFAULT: auto — the VMEM blocked megakernel while
                           the padded band fits VMEM, the HBM-streaming tiled
                           kernel beyond.
  * ``"pallas_blocked"`` / ``"pallas_tiled"`` — force either blocked driver.
  * ``"pallas_scalar"``  — legacy scalar-sequential kernel (n−1 rank-1 steps).
  * ``"xla"``            — pure-jnp mirror of the blocked kernels
                           (:func:`repro.core.banded.banded_lu_blocked`),
                           bitwise-identical to both.
  * ``"xla_scalar"``     — legacy scalar jnp loop.

``banded_solve`` mirrors the table: ``"pallas"`` (blocked kernel), ``"xla"``
(blocked mirror), ``"xla_scalar"`` (scalar jnp loop).

On CPU (this container) the Pallas paths run in interpret mode automatically;
on TPU they lower to Mosaic.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import blocked as _core_blocked
from repro.core import solve as _core_solve
from repro.core import banded as _core_banded
from . import ebv_lu as _k
from . import trsm as _trsm
from . import banded as _kbanded

__all__ = [
    "lu",
    "lu_solve",
    "linear_solve",
    "banded_lu",
    "banded_solve",
    "banded_linear_solve",
]

# Above this order the packed (n, n) LU no longer comfortably shares VMEM
# with an RHS tile, so the auto solve dispatch switches to the tiled driver.
_SOLVE_VMEM_MAX_N = 2048

# Above this many skewed-band bytes the auto banded dispatch switches from
# the VMEM-resident blocked kernel to the HBM-streaming tiled kernel (the
# VMEM kernel holds the skewed band twice — in and out — on real TPUs).
_BANDED_VMEM_MAX_BYTES = 6 * 2**20

_FUSED_FALLBACK_WARNED: set[str] = set()


def _warn_fused_dtype_fallback(dtype) -> None:
    """One-time (per dtype) warning when the fp32-only fused kernel falls
    back to its op-identical pure-jnp mirror."""
    key = str(dtype)
    if key not in _FUSED_FALLBACK_WARNED:
        _FUSED_FALLBACK_WARNED.add(key)
        warnings.warn(
            f"lu(impl='pallas_fused') supports float32 only; got {key} — "
            "falling back to the op-identical 'xla' mirror "
            "(repro.core.blocked.fused_blocked_lu)",
            UserWarning,
            stacklevel=3,
        )


def _pallas_blocked_lu(a: jax.Array, *, block: int, col_tile: int, interpret: bool | None) -> jax.Array:
    n = a.shape[-1]
    block = min(block, n)
    for k0 in range(0, n, block):
        b = min(block, n - k0)
        pan = _k.panel(a[k0:, k0 : k0 + b], interpret=interpret)
        a = a.at[k0:, k0 : k0 + b].set(pan)
        w = n - k0 - b
        if w > 0:
            ct = min(col_tile, w)
            if w % ct:
                # Pad the trailing width to the next tile multiple (tiles
                # capped at 128 lanes) instead of halving the tile — odd
                # widths used to degrade to 1-column tiles.  Zero columns are
                # inert through trsm and the rank-b update.
                ct = min(col_tile, 128)
                wp = -(-w // ct) * ct
                top = jnp.pad(a[k0 : k0 + b, k0 + b :], ((0, 0), (0, wp - w)))
                trail = jnp.pad(a[k0 + b :, k0 + b :], ((0, 0), (0, wp - w)))
                u12, new_trail = _k.fused_step(pan, top, trail, col_tile=ct, interpret=interpret)
                u12, new_trail = u12[:, :w], new_trail[:, :w]
            else:
                u12, new_trail = _k.fused_step(
                    pan, a[k0 : k0 + b, k0 + b :], a[k0 + b :, k0 + b :],
                    col_tile=ct, interpret=interpret,
                )
            a = a.at[k0 : k0 + b, k0 + b :].set(u12)
            a = a.at[k0 + b :, k0 + b :].set(new_trail)
    return a


@functools.partial(jax.jit, static_argnames=("impl", "block", "col_tile", "interpret"))
def lu(
    a: jax.Array,
    *,
    impl: str = "pallas_fused",
    block: int = 256,
    col_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed EbV LU factorization (no pivoting — paper contract)."""
    if impl == "pallas_fused":
        if a.dtype == jnp.float32:
            return _k.lu_fused(a, block=block, interpret=interpret)
        # The fused kernel is fp32-only.  Fall back to its bitwise mirror
        # (as fast as fused at n=1024 per BENCH_kernels.json) rather than
        # the ~9x-slower multi-launch blocked driver.
        _warn_fused_dtype_fallback(a.dtype)
        impl = "xla"
    if impl == "pallas_vmem":
        return _k.lu_vmem(a, interpret=interpret)
    if impl == "pallas_blocked":
        return _pallas_blocked_lu(a, block=block, col_tile=col_tile, interpret=interpret)
    if impl == "xla":
        return _core_blocked.fused_blocked_lu(a, block=block)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("impl", "block", "rhs_tile", "interpret"))
def lu_solve(
    lu_packed: jax.Array,
    b: jax.Array,
    *,
    impl: str = "pallas",
    block: int = 256,
    rhs_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    n = lu_packed.shape[-1]
    if impl == "pallas":
        impl = "pallas_vmem" if n <= _SOLVE_VMEM_MAX_N else "pallas_tiled"
    if impl == "pallas_vmem":
        return _trsm.solve_vmem(lu_packed, b, rhs_tile=rhs_tile, interpret=interpret)
    if impl == "pallas_tiled":
        return _trsm.solve_tiled(lu_packed, b, block=block, rhs_tile=rhs_tile, interpret=interpret)
    if impl == "xla":
        return _core_solve.lu_solve(lu_packed, b)
    raise ValueError(f"unknown impl {impl!r}")


def linear_solve(a: jax.Array, b: jax.Array, *, solve_impl: str | None = None, **kw) -> jax.Array:
    """Factor + solve.  ``impl`` routes BOTH phases: the factor phase gets it
    verbatim; the solve phase runs ``"xla"`` when the factor does and the
    Pallas auto driver otherwise (``impl="xla"`` used to silently solve with
    the default Pallas path).  Pass ``solve_impl`` to mix phases
    deliberately (any :func:`lu_solve` impl name)."""
    lu_kw = {k: v for k, v in kw.items() if k in ("impl", "block", "col_tile", "interpret")}
    solve_kw = {k: v for k, v in kw.items() if k in ("block", "rhs_tile", "interpret")}
    if solve_impl is None and "impl" in kw:
        solve_impl = "xla" if kw["impl"] == "xla" else "pallas"
    if solve_impl is not None:
        solve_kw["impl"] = solve_impl
    return lu_solve(lu(a, **lu_kw), b, **solve_kw)


def _banded_auto_impl(n: int, bw: int, block: int | None, itemsize: int) -> str:
    c = _core_banded.band_block_size(n, bw, block)
    skew_bytes = _core_banded.skew_rows(n, bw, c) * (c + 2 * bw) * itemsize
    return "pallas_blocked" if skew_bytes <= _BANDED_VMEM_MAX_BYTES else "pallas_tiled"


@functools.partial(jax.jit, static_argnames=("bw", "impl", "block", "interpret"))
def banded_lu(
    arow: jax.Array,
    *,
    bw: int,
    impl: str = "pallas",
    block: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed band LU on the row-aligned band (no pivoting)."""
    if impl == "pallas":
        impl = _banded_auto_impl(arow.shape[0], bw, block, jnp.dtype(arow.dtype).itemsize)
    if impl == "pallas_blocked":
        return _kbanded.banded_lu_blocked(arow, bw=bw, block=block, interpret=interpret)
    if impl == "pallas_tiled":
        return _kbanded.banded_lu_tiled(arow, bw=bw, block=block, interpret=interpret)
    if impl == "pallas_scalar":
        return _kbanded.banded_lu_kernelized(arow, bw=bw, interpret=interpret)
    if impl == "xla":
        return _core_banded.banded_lu_blocked(arow, bw=bw, block=block)
    if impl == "xla_scalar":
        return _core_banded.banded_lu(arow, bw=bw)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("bw", "impl", "block", "rhs_tile", "interpret"))
def banded_solve(
    lu_band: jax.Array,
    b: jax.Array,
    *,
    bw: int,
    impl: str = "pallas",
    block: int | None = None,
    rhs_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Forward+backward substitution on packed band factors.

    The default targets TPU residency (single-dispatch blocked kernel,
    factors streamed strip-by-strip from HBM); on this CPU container the
    interpret-mode DMA emulation makes ``impl="xla_scalar"`` the faster
    choice for one-off solves — see ``BENCH_kernels.json``
    (``banded_solve_n16384_*``)."""
    if impl == "pallas":
        return _kbanded.banded_solve_kernelized(
            lu_band, b, bw=bw, block=block, rhs_tile=rhs_tile, interpret=interpret
        )
    if impl == "xla":
        return _core_banded.banded_solve_blocked(lu_band, b, bw=bw, block=block)
    if impl == "xla_scalar":
        return _core_banded.banded_solve(lu_band, b, bw=bw)
    raise ValueError(f"unknown impl {impl!r}")


def banded_linear_solve(
    arow: jax.Array,
    b: jax.Array,
    *,
    bw: int,
    impl: str = "pallas",
    solve_impl: str | None = None,
    block: int | None = None,
    rhs_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Banded factor + solve with ``impl`` routed to BOTH phases (the same
    contract :func:`linear_solve` honours): ``"xla*"`` factor impls solve
    through the matching jnp path, Pallas factor impls solve through the
    blocked solve kernel.  ``solve_impl`` overrides the solve phase."""
    if solve_impl is None:
        solve_impl = impl if impl in ("xla", "xla_scalar") else "pallas"
    lub = banded_lu(arow, bw=bw, impl=impl, block=block, interpret=interpret)
    return banded_solve(
        lub, b, bw=bw, impl=solve_impl, block=block, rhs_tile=rhs_tile, interpret=interpret
    )
