"""Public solver ops — a thin compatibility shim over ``repro.solvers``.

Every call builds a :class:`repro.solvers.Problem` from its array arguments
and routes through the registry's selection engine
(:func:`repro.solvers.select`): capability filter → measured autotune cache
(``scripts/autotune.py`` / the smoke bench) → static heuristics that
reproduce the historical hardcoded dispatch.  The ``impl=`` kwarg is kept
as a **forced-backend override** — every historical name still routes to
the same implementation:

``lu``: ``"pallas_fused"`` (single-dispatch EbV megakernel, fp32; non-fp32
falls back to the op-identical ``"xla"`` mirror with a one-time warning),
``"pallas_blocked"`` (legacy multi-launch driver), ``"pallas_vmem"``,
``"xla"`` (bitwise mirror).  ``impl=None`` (the default) is the registry
auto path; with no cache it picks ``"pallas_fused"`` for fp32 — exactly the
old default.

``lu_solve``: ``"pallas_vmem"`` / ``"pallas_tiled"`` / ``"xla"`` forced;
``"pallas"`` = auto restricted to the Pallas drivers (the old meaning);
``None`` = full auto (old threshold: VMEM ≤ 2048, tiled beyond).

``banded_lu``: ``"pallas_blocked"`` / ``"pallas_tiled"`` / ``"pallas_scalar"``
/ ``"xla"`` / ``"xla_scalar"`` forced; ``"pallas"`` = Pallas-only auto (the
old 6 MB skewed-band VMEM rule); ``None`` = full auto.

``banded_solve``: ``"pallas"`` (blocked kernel) / ``"xla"`` (blocked mirror)
/ ``"xla_scalar"`` forced; ``None`` = auto — statically the blocked kernel,
but the smoke bench seeds the cache with the measured shootout
(``BENCH_kernels.json``), so on this container the auto path picks the
measured winner (``xla_scalar`` at n=16384) instead of losing 3.4x to it.

Batching: a leading batch axis on the matrix operand — or ``jax.vmap`` over
these ops — reroutes to the batched grid kernels
(:mod:`repro.kernels.batched_lu`, ``batched_banded_*_vmem``) instead of
unrolling per-sample kernels.

Multi-device: ``lu(a, mesh=mesh)`` / ``linear_solve(a, b, mesh=mesh)``
dispatch to the shard_map EbV LU (:mod:`repro.core.distributed`) via the
registry's ``devices > 1`` capability slot.

On CPU (this container) the Pallas paths run in interpret mode
automatically; on TPU they lower to Mosaic.
"""
from __future__ import annotations

import warnings

import importlib

import jax
import jax.numpy as jnp

from repro.core import health as _chealth
from repro.core.factorization import Factorization, factorize_banded, factorize_dense
from repro.core.pivoted import PivotedFactors
from repro.core.randomized import RankKFactors
from repro.core.spike import SpikeFactors

__all__ = [
    "lu",
    "lu_solve",
    "linear_solve",
    "banded_lu",
    "banded_solve",
    "banded_linear_solve",
]


def _sol():
    """Deferred import of the registry: ``repro.solvers.backends`` imports
    this module's siblings, so a module-level import here would cycle."""
    return importlib.import_module("repro.solvers")


def __getattr__(name: str):
    # Backward-compatible re-exports of the static thresholds, whose home is
    # now repro.solvers.backends (deferred for the same cycle reason).
    if name == "_SOLVE_VMEM_MAX_N":
        return _sol().backends.SOLVE_VMEM_MAX_N
    if name == "_BANDED_VMEM_MAX_BYTES":
        return _sol().backends.BANDED_VMEM_MAX_BYTES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

_FUSED_FALLBACK_WARNED: set[str] = set()


def _warn_fused_dtype_fallback(dtype) -> None:
    """One-time (per dtype) warning when the fp32-only fused kernel falls
    back to its op-identical pure-jnp mirror."""
    key = str(dtype)
    if key not in _FUSED_FALLBACK_WARNED:
        _FUSED_FALLBACK_WARNED.add(key)
        warnings.warn(
            f"lu(impl='pallas_fused') supports float32 only; got {key} — "
            "falling back to the op-identical 'xla' mirror "
            "(repro.core.blocked.fused_blocked_lu)",
            UserWarning,
            stacklevel=3,
        )


def _screen(health):
    """Normalize the ``health=`` kwarg: ``None``/``False`` → no screening,
    ``True`` → default thresholds, a :class:`HealthThresholds` → itself."""
    if health is None or health is False:
        return None
    return _chealth.DEFAULT_THRESHOLDS if health is True else health


def _health_validator(thresholds, ref_max, bw: int = 0):
    """Dispatch validator screening each candidate's factors — an unhealthy
    result rejects the backend and feeds the registry's escalation funnel."""

    def validate(problem, backend, result):
        rec = _chealth.factor_health(result, ref_max=ref_max, bw=bw)
        if not rec.verdict(thresholds):
            return (f"unhealthy factor from {backend.name}: "
                    f"{rec.report(thresholds)}", rec)
        return None

    return validate


def _banded_auto_impl(n: int, bw: int, block: int | None, itemsize: int) -> str:
    """Historical banded auto rule (kept for callers/tests; the registry's
    static priorities encode the same threshold)."""
    return _sol().backends.banded_static_impl(n, bw, block, itemsize)


def _batched_impl(op: str, structure: str, impl: str | None) -> str | None:
    """Map an unbatched impl name to its batched analog (Pallas names →
    the batched VMEM grid kernel, xla names → the vmapped mirror), after
    validating the name against the unbatched slot."""
    if impl is None:
        return None
    if impl != "pallas":  # legacy auto alias has no unbatched backend record
        _sol().get_backend(op, structure, impl)  # raises "unknown impl ..."
    if impl == "pallas_inverted":  # has a batched slot of its own (vmapped)
        return impl
    return "xla" if impl.startswith("xla") else "pallas_vmem"


def _as_artifact(packed, *, structure: str, bw: int = 0, block=None,
                 tier: float = 0.0, health_rec=None, enrich: bool = False):
    """Wrap an eager packed factor into the :class:`Factorization` artifact
    (the new factor→solve contract).  Special factor layouts (pivoted,
    rank-k), traced values (artifacts are a Python-level cache object) and
    already-wrapped results pass through unchanged."""
    if isinstance(packed, (Factorization, PivotedFactors, RankKFactors,
                           SpikeFactors, jax.core.Tracer)):
        return packed
    if packed.ndim > 3:  # deep-batched stacks stay raw (no batched enrichment)
        return packed
    if structure == "dense":
        return factorize_dense(packed, block=block or 256, tier=tier,
                               health=health_rec, enrich=enrich)
    return factorize_banded(packed, bw=bw, block=block, tier=tier,
                            health=health_rec, enrich=enrich)


def _with_batch_rule(unbatched_fn, batched_fn):
    """Wrap ``unbatched_fn`` so ``jax.vmap`` reroutes to ``batched_fn``
    (one batched grid kernel) instead of unrolling/lifting the unbatched
    kernels.  Unbatched operands are broadcast along the batch axis."""
    inner = jax.custom_batching.custom_vmap(unbatched_fn)

    @inner.def_vmap
    def _rule(axis_size, in_batched, *args):
        args = tuple(
            a if batched else jnp.broadcast_to(a[None], (axis_size,) + a.shape)
            for a, batched in zip(args, in_batched)
        )
        return batched_fn(*args), True

    return inner


# ---------------------------------------------------------------------------
# dense LU
# ---------------------------------------------------------------------------
def _lu_2d(a: jax.Array, *, impl, block, col_tile, interpret, tolerance=0.0,
           rank=None, oversample=8, rng_key=None, validate=None) -> jax.Array:
    if impl in (None, "pallas_fused") and a.dtype != jnp.float32:
        # The fused kernel is fp32-only.  Fall back to its bitwise mirror
        # (as fast as fused at n=1024 per BENCH_kernels.json) rather than
        # the ~9x-slower multi-launch blocked driver.
        _warn_fused_dtype_fallback(a.dtype)
        impl = "xla"
    if rank is not None and impl is None:
        impl = "rand_lu"  # an explicit rank is a request for the rank-k tier
    problem = _sol().Problem.from_arrays("factor", a, tolerance=tolerance)
    return _sol().dispatch(
        problem, a, impl=impl, validate=validate,
        block=block, col_tile=col_tile, interpret=interpret,
        rank=rank, oversample=oversample, rng_key=rng_key,
    )


def _lu_batched(a: jax.Array, *, impl, block, interpret, tolerance=0.0,
                validate=None) -> jax.Array:
    problem = _sol().Problem.from_arrays("factor", a, tolerance=tolerance)
    return _sol().dispatch(
        problem, a, impl=_batched_impl("factor", "dense", impl),
        validate=validate, block=block, interpret=interpret,
    )


def lu(
    a: jax.Array,
    *,
    impl: str | None = None,
    block: int = 256,
    col_tile: int = 256,
    interpret: bool | None = None,
    mesh=None,
    mesh_axis: str = "model",
    placement: str = "ebv_folded",
    tolerance: float = 0.0,
    rank: int | None = None,
    oversample: int = 8,
    rng_key=None,
    health=None,
    enrich: bool = False,
) -> jax.Array:
    """Packed EbV LU factorization (no pivoting — paper contract).

    2-D input → dense backends; a leading batch axis (or ``jax.vmap``) →
    the batched grid kernels; ``mesh=`` → the multi-chip shard_map LU.

    ``tolerance`` (largest acceptable relative residual of downstream
    solves) keys the selection funnel and the autotune cache; 0.0 keeps the
    exact tier bitwise-identical to a tolerance-less call.  ``rank=`` routes
    to the randomized rank-k tier (``impl="rand_lu"``) and returns
    :class:`repro.core.randomized.RankKFactors` instead of a packed square
    factor (``lu_solve`` recognises them).

    ``health=`` turns on post-factor screening: ``True`` (default
    thresholds) or a :class:`repro.core.health.HealthThresholds` makes the
    op return ``(factors, FactorHealth)``.  On eager auto dispatches the
    screen also *validates*: a backend whose factors fail the verdict is
    demoted and the registry escalates down the capable candidates (ending
    at the partial-pivoting ``pivoted`` fallback for dense operands),
    raising :class:`repro.solvers.SolveFailure` only when every candidate
    fails.  ``health=None`` (the default) is bitwise-identical to the
    pre-screening op.

    Eager calls return a :class:`repro.core.factorization.Factorization`
    artifact wrapping the packed factors (it quacks like the packed array —
    the one-release shim); ``enrich=True`` additionally pre-inverts the
    solve blocks at factor time so downstream solves can take the
    inverted-diagonal GEMM path with zero layout work.  Traced calls and
    special factor layouts (pivoted, rank-k, distributed) return their
    legacy values unchanged."""
    thresholds = _screen(health)
    ref_max = jnp.max(jnp.abs(a)) if thresholds is not None else None

    def _record(factors, bw=0):
        return _chealth.factor_health(factors, ref_max=ref_max, bw=bw)

    if mesh is not None and mesh.shape[mesh_axis] > 1:
        if impl not in (None, "distributed"):
            raise ValueError(
                f"impl={impl!r} is a single-device backend and cannot honour "
                "mesh=; only 'distributed' spans devices (drop mesh= or impl=)"
            )
        problem = _sol().Problem.from_arrays(
            "factor", a, devices=mesh.shape[mesh_axis], tolerance=tolerance
        )
        packed = _sol().dispatch(
            problem, a, impl=impl, mesh=mesh, axis=mesh_axis,
            block=block, placement=placement, interpret=interpret,
        )
        return packed if thresholds is None else (packed, _record(packed))
    eager = not isinstance(a, jax.core.Tracer)
    validate = (
        _health_validator(thresholds, ref_max)
        if thresholds is not None and eager else None
    )
    if a.ndim >= 3:
        if rank is not None:
            raise ValueError("rank= (the randomized tier) supports 2-D operands only")
        lead, tail = a.shape[:-2], a.shape[-2:]
        out = _lu_batched(
            a.reshape((-1,) + tail), impl=impl, block=block, interpret=interpret,
            tolerance=tolerance, validate=validate,
        )
        out = out.reshape(lead + tail)
        rec = None if thresholds is None else _record(out)
        out = _as_artifact(out, structure="dense", block=block, tier=tolerance,
                           health_rec=rec, enrich=enrich)
        return out if thresholds is None else (out, rec)

    if validate is not None:
        # Screened eager call: go straight to the 2-D dispatch — the vmap
        # wrapper traces its wrapped function, which would blind the
        # validator (it only runs on concrete factors).
        out = _lu_2d(a, impl=impl, block=block, col_tile=col_tile, interpret=interpret,
                     tolerance=tolerance, rank=rank, oversample=oversample,
                     rng_key=rng_key, validate=validate)
        rec = _record(out)
        return _as_artifact(out, structure="dense", block=block, tier=tolerance,
                            health_rec=rec, enrich=enrich), rec
    out = _with_batch_rule(
        lambda x: _lu_2d(x, impl=impl, block=block, col_tile=col_tile, interpret=interpret,
                         tolerance=tolerance, rank=rank, oversample=oversample, rng_key=rng_key),
        lambda xs: _lu_batched(xs, impl=impl, block=block, interpret=interpret,
                               tolerance=tolerance),
    )(a)
    rec = None if thresholds is None else _record(out)
    out = _as_artifact(out, structure="dense", block=block, tier=tolerance,
                       health_rec=rec, enrich=enrich)
    return out if thresholds is None else (out, rec)


# ---------------------------------------------------------------------------
# substitution (solve) on packed factors
# ---------------------------------------------------------------------------
def _lu_solve_2d(lu_packed, b, *, impl, block, rhs_tile, interpret, tolerance=0.0):
    problem = _sol().Problem.from_arrays("solve", lu_packed, b, tolerance=tolerance)
    allow = None
    if impl == "pallas":  # old meaning: auto restricted to the Pallas drivers
        impl, allow = None, lambda be: be.name.startswith("pallas")
    return _sol().dispatch(
        problem, lu_packed, b, impl=impl, allow=allow,
        block=block, rhs_tile=rhs_tile, interpret=interpret,
    )


def _lu_solve_batched(lu_packed, b, *, impl, block, interpret, tolerance=0.0):
    squeeze = b.ndim == 2  # (B, n) vector RHS
    bm = b[..., None] if squeeze else b
    problem = _sol().Problem.from_arrays("solve", lu_packed, bm, tolerance=tolerance)
    x = _sol().dispatch(
        problem, lu_packed, bm, impl=_batched_impl("solve", "dense", impl),
        block=block, interpret=interpret,
    )
    return x[..., 0] if squeeze else x


def lu_solve(
    lu_packed,
    b: jax.Array,
    *,
    impl: str | None = None,
    block: int = 256,
    rhs_tile: int = 256,
    interpret: bool | None = None,
    tolerance: float = 0.0,
) -> jax.Array:
    if isinstance(lu_packed, PivotedFactors):
        # row-permuted factors from the partial-pivoting last resort — only
        # the pivoted backend applies the permutation, so force it
        problem = _sol().Problem(
            op="solve", structure="dense", n=int(lu_packed.lu.shape[0]),
            dtype=jnp.dtype(lu_packed.lu.dtype).name,
            rhs=1 if b.ndim == 1 else int(b.shape[-1]),
            tolerance=float(tolerance),
        )
        return _sol().dispatch(problem, lu_packed, b, impl="pivoted")
    if isinstance(lu_packed, RankKFactors):
        # rank-k factors from lu(rank=...) — only the randomized backend
        # can consume them, so this is a forced dispatch by construction
        problem = _sol().Problem(
            op="solve", structure="dense", n=int(lu_packed.l.shape[0]),
            dtype=jnp.dtype(lu_packed.l.dtype).name,
            rhs=1 if b.ndim == 1 else int(b.shape[-1]),
            tolerance=float(tolerance),
        )
        return _sol().dispatch(problem, lu_packed, b, impl="rand_lu")
    if isinstance(lu_packed, Factorization):
        # The artifact is a Python-level pytree, not a jax array: it must
        # not flow through the custom_vmap wrapper (which traces even on
        # eager calls).  Dispatch directly — Problem.from_arrays reads its
        # duck-typed shape/dtype and the ``enriched`` capability flag, and
        # backends unwrap via ``packed_of`` (the one-release shim).
        if lu_packed.ndim >= 3:
            return _lu_solve_batched(
                lu_packed, b, impl=impl, block=block, interpret=interpret,
                tolerance=tolerance,
            )
        return _lu_solve_2d(
            lu_packed, b, impl=impl, block=block, rhs_tile=rhs_tile,
            interpret=interpret, tolerance=tolerance,
        )
    if lu_packed.ndim >= 3:
        if lu_packed.ndim > 3:  # fold extra leading batch dims, like lu()
            lead, tail = lu_packed.shape[:-2], lu_packed.shape[-2:]
            bf = b.reshape((-1,) + b.shape[len(lead):])
            x = _lu_solve_batched(
                lu_packed.reshape((-1,) + tail), bf,
                impl=impl, block=block, interpret=interpret, tolerance=tolerance,
            )
            return x.reshape(lead + x.shape[1:])
        return _lu_solve_batched(
            lu_packed, b, impl=impl, block=block, interpret=interpret, tolerance=tolerance
        )
    return _with_batch_rule(
        lambda l, r: _lu_solve_2d(l, r, impl=impl, block=block, rhs_tile=rhs_tile,
                                  interpret=interpret, tolerance=tolerance),
        lambda ls, rs: _lu_solve_batched(ls, rs, impl=impl, block=block,
                                         interpret=interpret, tolerance=tolerance),
    )(lu_packed, b)


# linear_solve slot backends that fuse factor+solve (the approximate tiers
# need the full operand — bf16_ir refines against it, rand_lu sketches it)
_FUSED_LINEAR_IMPLS = ("bf16_ir", "bf16_ir_xla", "rand_lu")


def linear_solve(
    a: jax.Array,
    b: jax.Array,
    *,
    solve_impl: str | None = None,
    mesh=None,
    mesh_axis: str = "model",
    placement: str = "ebv_folded",
    tolerance: float = 0.0,
    rank: int | None = None,
    oversample: int = 8,
    rng_key=None,
    verify_residual: bool = False,
    **kw,
) -> jax.Array:
    """Factor + solve.  ``impl`` routes BOTH phases: the factor phase gets it
    verbatim; the solve phase runs ``"xla"`` when the factor does and the
    Pallas auto driver otherwise (``impl="xla"`` used to silently solve with
    the default Pallas path).  Pass ``solve_impl`` to mix phases
    deliberately (any :func:`lu_solve` impl name).  With ``mesh=`` the whole
    factor+substitution pipeline runs distributed
    (:func:`repro.core.distributed.distributed_lu_solve`).

    ``tolerance`` (largest acceptable relative residual) opens the
    approximate tiers: the call first consults the fused ``linear_solve``
    slot, where the tolerance gate admits backends whose guaranteed
    residual bound it covers (``bf16_ir`` — bf16 factor + f32 iterative
    refinement — at ≥ 1e-6); with no admitted backend it composes the exact
    factor+solve as before.  ``rank=`` (or ``impl="rand_lu"``) forces the
    randomized rank-k tier.  ``tolerance=0.0`` (default) is
    bitwise-identical to the pre-tolerance call.

    ``verify_residual=True`` measures the relative residual ``|Ax-b|/|b|``
    of every eager dispatch against the declared bound (``tolerance`` when
    set, else ``repro.solvers.VERIFY_RESIDUAL_DEFAULT_BOUND``): fused-tier
    dispatches that miss the bound feed the registry's escalation funnel,
    and the composed exact path falls over to the partial-pivoting
    ``pivoted`` backend once before raising
    :class:`repro.solvers.SolveFailure`.  Off (the default) and under
    tracing, behaviour is unchanged."""
    if mesh is not None and mesh.shape[mesh_axis] > 1:
        if kw.get("impl") not in (None, "distributed"):
            raise ValueError(
                f"impl={kw['impl']!r} is a single-device backend and cannot "
                "honour mesh=; only 'distributed' spans devices"
            )
        problem = _sol().Problem.from_arrays(
            "linear_solve", a, b, devices=mesh.shape[mesh_axis], tolerance=tolerance
        )
        return _sol().dispatch(
            problem, a, b, impl=kw.get("impl"), mesh=mesh, axis=mesh_axis,
            block=kw.get("block", 64), placement=placement,
            interpret=kw.get("interpret"),
        )
    impl = kw.get("impl")
    if rank is not None and impl is None:
        impl = "rand_lu"
    if impl in _FUSED_LINEAR_IMPLS or (impl is None and tolerance > 0):
        bm = b[..., None] if b.ndim == a.ndim - 1 else b
        problem = _sol().Problem.from_arrays(
            "linear_solve", a, bm, tolerance=tolerance,
            verify_residual=verify_residual,
        )
        if impl is not None or _sol().candidates(problem):
            squeeze = bm is not b
            x = _sol().dispatch(
                problem, a, bm, impl=impl,
                block=kw.get("block", 256), interpret=kw.get("interpret"),
                rank=rank, oversample=oversample, rng_key=rng_key,
            )
            return x[..., 0] if squeeze else x
        # tolerance too tight for every approximate tier: compose the exact
        # factor+solve below (tolerance still keys their cache rows)
    lu_kw = {k: v for k, v in kw.items()
             if k in ("impl", "block", "col_tile", "interpret", "enrich")}
    solve_kw = {k: v for k, v in kw.items() if k in ("block", "rhs_tile", "interpret")}
    lu_kw["tolerance"] = solve_kw["tolerance"] = tolerance
    if solve_impl is None and kw.get("impl") is not None:
        solve_impl = "xla" if kw["impl"] == "xla" else "pallas"
    if solve_impl is not None:
        solve_kw["impl"] = solve_impl
    x = lu_solve(lu(a, **lu_kw), b, **solve_kw)
    if verify_residual and not isinstance(a, jax.core.Tracer) \
            and not isinstance(b, jax.core.Tracer):
        return _verify_composed(a, b, x, tolerance=tolerance)
    return x


def _verify_composed(a, b, x, *, tolerance: float, bw: int = 0):
    """Post-hoc residual gate for the composed factor+solve path (the
    check spans two dispatches, so the registry's in-dispatch validator
    can't host it).  A miss escalates once to the partial-pivoting last
    resort (dense only) before raising :class:`SolveFailure`."""
    sol = _sol()
    bound = tolerance if tolerance > 0 else sol.VERIFY_RESIDUAL_DEFAULT_BOUND
    rel = float(_chealth.relative_residual(a, b, x, bw=bw))
    if rel <= bound:  # NaN compares False and falls through to escalation
        return x
    problem = sol.Problem.from_arrays(
        "linear_solve", a, b, bw=bw, tolerance=tolerance, verify_residual=True
    )
    reason = f"residual {rel:.3e} > bound {bound:.1e} from composed exact solve"
    chain = [{"backend": "composed", "reason": reason}]
    if bw == 0:
        sol.registry._notify_escalation(problem, "composed", "pivoted", reason)
        xp = lu_solve(lu(a, impl="pivoted"), b)
        relp = float(_chealth.relative_residual(a, b, xp))
        if relp <= bound:
            return xp
        chain.append({
            "backend": "pivoted",
            "reason": f"residual {relp:.3e} > bound {bound:.1e}",
        })
        sol.registry._notify_escalation(problem, "pivoted", None, chain[-1]["reason"])
    else:
        sol.registry._notify_escalation(problem, "composed", None, reason)
    raise sol.SolveFailure(
        "verified linear solve failed for "
        f"{problem}: " + " -> ".join(f"{c['backend']} ({c['reason']})" for c in chain),
        problem=problem, chain=chain,
    )


# ---------------------------------------------------------------------------
# banded (row-aligned band, see repro.core.banded)
# ---------------------------------------------------------------------------
def _banded_lu_2d(arow, *, bw, impl, block, interpret, tolerance=0.0, validate=None):
    problem = _sol().Problem.from_arrays("factor", arow, bw=bw, tolerance=tolerance)
    allow = None
    if impl == "pallas":  # old meaning: Pallas-only auto (6 MB VMEM rule)
        impl, allow = None, lambda be: be.name in ("pallas_blocked", "pallas_tiled")
    return _sol().dispatch(
        problem, arow, impl=impl, allow=allow, validate=validate,
        bw=bw, block=block, interpret=interpret,
    )


def _banded_lu_batched(arow, *, bw, impl, block, interpret, tolerance=0.0,
                       validate=None):
    problem = _sol().Problem.from_arrays("factor", arow, bw=bw, tolerance=tolerance)
    return _sol().dispatch(
        problem, arow, impl=_batched_impl("factor", "banded", impl),
        validate=validate, bw=bw, block=block, interpret=interpret,
    )


def banded_lu(
    arow: jax.Array,
    *,
    bw: int,
    impl: str | None = None,
    block: int | None = None,
    interpret: bool | None = None,
    tolerance: float = 0.0,
    health=None,
    enrich: bool = False,
    mesh=None,
    mesh_axis: str = "model",
) -> jax.Array:
    """Packed band LU on the row-aligned band (no pivoting).  ``tolerance``
    keys selection/cache like the dense ops (no approximate banded tier
    exists yet, so it only partitions cache rows).  ``health=`` (``True``
    or a :class:`HealthThresholds`) returns ``(factors, FactorHealth)`` and
    screens eager auto dispatches exactly like :func:`lu` — the band has no
    pivoted last resort, so an unhealthy band factor escalates through the
    remaining band backends and then fails structurally.

    Eager calls return a :class:`repro.core.factorization.Factorization`
    artifact (array-duck-typed shim over the packed band); ``enrich=True``
    pre-inverts the (C, C) diagonal blocks and pre-couples the off-band
    strips at factor time, unlocking the two-phase inverted-diagonal solve
    (``banded_solve`` impl ``"pallas_inverted"``).

    With ``mesh=`` the band spans ``mesh.shape[mesh_axis]`` devices: the
    registry's multi-device banded slot selects between the SPIKE split
    solver (:mod:`repro.core.spike` — returns a
    :class:`~repro.core.spike.SpikeFactors` artifact) and the replicated
    fallback, with ``health=`` screening feeding the escalation funnel so
    an operand outside SPIKE's class demotes to replication."""
    thresholds = _screen(health)
    ref_max = jnp.max(jnp.abs(arow)) if thresholds is not None else None

    def _record(factors):
        return _chealth.factor_health(factors, ref_max=ref_max, bw=bw)

    eager = not isinstance(arow, jax.core.Tracer)
    validate = (
        _health_validator(thresholds, ref_max, bw=bw)
        if thresholds is not None and eager else None
    )
    if mesh is not None and mesh.shape[mesh_axis] > 1:
        if impl not in (None, "spike", "replicated"):
            raise ValueError(
                f"impl={impl!r} is a single-device backend and cannot honour "
                "mesh=; only 'spike'/'replicated' span devices "
                "(drop mesh= or impl=)"
            )
        problem = _sol().Problem.from_arrays(
            "factor", arow, bw=bw, devices=mesh.shape[mesh_axis],
            tolerance=tolerance,
        )
        out = _sol().dispatch(
            problem, arow, impl=impl, validate=validate,
            bw=bw, block=block, interpret=interpret, mesh=mesh, axis=mesh_axis,
        )
        rec = None if thresholds is None else _record(out)
        # SpikeFactors pass _as_artifact unchanged; a replicated (local)
        # factor wraps into the ordinary Factorization artifact.
        out = _as_artifact(out, structure="banded", bw=bw, block=block,
                           tier=tolerance, health_rec=rec, enrich=enrich)
        return out if thresholds is None else (out, rec)
    if arow.ndim >= 3:
        lead, tail = arow.shape[:-2], arow.shape[-2:]
        out = _banded_lu_batched(
            arow.reshape((-1,) + tail), bw=bw, impl=impl, block=block,
            interpret=interpret, tolerance=tolerance, validate=validate,
        )
        out = out.reshape(lead + out.shape[1:])
        rec = None if thresholds is None else _record(out)
        out = _as_artifact(out, structure="banded", bw=bw, block=block,
                           tier=tolerance, health_rec=rec, enrich=enrich)
        return out if thresholds is None else (out, rec)
    if validate is not None:
        # screened eager call: skip the vmap wrapper (it traces, which
        # would blind the validator) and dispatch the 2-D band directly
        out = _banded_lu_2d(arow, bw=bw, impl=impl, block=block,
                            interpret=interpret, tolerance=tolerance,
                            validate=validate)
        rec = _record(out)
        return _as_artifact(out, structure="banded", bw=bw, block=block,
                            tier=tolerance, health_rec=rec, enrich=enrich), rec
    out = _with_batch_rule(
        lambda x: _banded_lu_2d(x, bw=bw, impl=impl, block=block, interpret=interpret,
                                tolerance=tolerance),
        lambda xs: _banded_lu_batched(xs, bw=bw, impl=impl, block=block,
                                      interpret=interpret, tolerance=tolerance),
    )(arow)
    rec = None if thresholds is None else _record(out)
    out = _as_artifact(out, structure="banded", bw=bw, block=block,
                       tier=tolerance, health_rec=rec, enrich=enrich)
    return out if thresholds is None else (out, rec)


def _banded_solve_2d(lu_band, b, *, bw, impl, block, rhs_tile, interpret, tolerance=0.0):
    problem = _sol().Problem.from_arrays("solve", lu_band, b, bw=bw, tolerance=tolerance)
    return _sol().dispatch(
        problem, lu_band, b, impl=impl,
        bw=bw, block=block, rhs_tile=rhs_tile, interpret=interpret,
    )


def _banded_solve_batched(lu_band, b, *, bw, impl, block, interpret, tolerance=0.0):
    problem = _sol().Problem.from_arrays("solve", lu_band, b, bw=bw, tolerance=tolerance)
    return _sol().dispatch(
        problem, lu_band, b, impl=_batched_impl("solve", "banded", impl),
        bw=bw, block=block, interpret=interpret,
    )


def banded_solve(
    lu_band: jax.Array,
    b: jax.Array,
    *,
    bw: int,
    impl: str | None = None,
    block: int | None = None,
    rhs_tile: int = 256,
    interpret: bool | None = None,
    tolerance: float = 0.0,
    mesh=None,
    mesh_axis: str = "model",
) -> jax.Array:
    """Forward+backward substitution on packed band factors.

    ``impl=None`` consults the measured cache first: the smoke bench seeds
    it with the ``banded_solve_n16384_*`` shootout, so the auto path picks
    whatever actually won on this host (``xla_scalar`` beats the blocked
    kernel 2.4 ms vs 8.1 ms under interpret-mode DMA emulation on this CPU
    container; on a real TPU the measurement flips back).  An *enriched*
    :class:`Factorization` operand additionally admits the two-phase
    inverted-diagonal path (``"pallas_inverted"``), which wins the n=16384
    shootout outright on this container."""
    if isinstance(lu_band, SpikeFactors):
        # split-band factors from banded_lu(mesh=...) — only the spike
        # backend can consume them, so this is a forced dispatch by
        # construction (the pivoted / rank-k pattern).  ``mesh=`` runs the
        # local g-solves shard_map'd; without it the mirror loop runs.
        problem = _sol().Problem(
            op="solve", structure="banded", n=lu_band.n,
            dtype=jnp.dtype(lu_band.dtype).name, bw=lu_band.bw,
            rhs=1 if b.ndim == 1 else int(b.shape[-1]),
            devices=lu_band.devices, tolerance=float(tolerance),
        )
        return _sol().dispatch(
            problem, lu_band, b, impl="spike",
            bw=lu_band.bw, block=block, interpret=interpret,
            mesh=mesh, axis=mesh_axis,
        )
    if mesh is not None and mesh.shape[mesh_axis] > 1:
        raise ValueError(
            "banded_solve(mesh=...) expects SpikeFactors from "
            "banded_lu(mesh=...); local factors solve without a mesh"
        )
    if isinstance(lu_band, Factorization):
        # bypass the custom_vmap wrapper — see lu_solve
        if lu_band.ndim >= 3:
            return _banded_solve_batched(
                lu_band, b, bw=bw, impl=impl, block=block, interpret=interpret,
                tolerance=tolerance,
            )
        return _banded_solve_2d(
            lu_band, b, bw=bw, impl=impl, block=block, rhs_tile=rhs_tile,
            interpret=interpret, tolerance=tolerance,
        )
    if lu_band.ndim >= 3:
        if lu_band.ndim > 3:  # fold extra leading batch dims, like banded_lu()
            lead, tail = lu_band.shape[:-2], lu_band.shape[-2:]
            bf = b.reshape((-1,) + b.shape[len(lead):])
            x = _banded_solve_batched(
                lu_band.reshape((-1,) + tail), bf,
                bw=bw, impl=impl, block=block, interpret=interpret, tolerance=tolerance,
            )
            return x.reshape(lead + x.shape[1:])
        return _banded_solve_batched(
            lu_band, b, bw=bw, impl=impl, block=block, interpret=interpret,
            tolerance=tolerance,
        )
    return _with_batch_rule(
        lambda l, r: _banded_solve_2d(
            l, r, bw=bw, impl=impl, block=block, rhs_tile=rhs_tile,
            interpret=interpret, tolerance=tolerance,
        ),
        lambda ls, rs: _banded_solve_batched(
            ls, rs, bw=bw, impl=impl, block=block, interpret=interpret,
            tolerance=tolerance,
        ),
    )(lu_band, b)


def banded_linear_solve(
    arow: jax.Array,
    b: jax.Array,
    *,
    bw: int,
    impl: str | None = None,
    solve_impl: str | None = None,
    block: int | None = None,
    rhs_tile: int = 256,
    interpret: bool | None = None,
    tolerance: float = 0.0,
    verify_residual: bool = False,
    mesh=None,
    mesh_axis: str = "model",
) -> jax.Array:
    """Banded factor + solve with ``impl`` routed to BOTH phases (the same
    contract :func:`linear_solve` honours): ``"xla*"`` factor impls solve
    through the matching jnp path, Pallas factor impls solve through the
    blocked solve kernel.  ``solve_impl`` overrides the solve phase.
    ``verify_residual=True`` gates eager results on the relative residual
    like :func:`linear_solve` (there is no banded pivoted fallback, so a
    miss raises :class:`repro.solvers.SolveFailure` directly).

    With ``mesh=`` the fused multi-device banded slot selects SPIKE vs
    replication (measured cache keyed on ``devices``, static priorities
    otherwise); ``verify_residual=True`` then runs inside the registry
    funnel, so a SPIKE residual miss demotes to the replicated path."""
    if mesh is not None and mesh.shape[mesh_axis] > 1:
        if impl not in (None, "spike", "replicated"):
            raise ValueError(
                f"impl={impl!r} is a single-device backend and cannot honour "
                "mesh=; only 'spike'/'replicated' span devices "
                "(drop mesh= or impl=)"
            )
        problem = _sol().Problem.from_arrays(
            "linear_solve", arow, b[..., None] if b.ndim == 1 else b,
            bw=bw, devices=mesh.shape[mesh_axis], tolerance=tolerance,
            verify_residual=verify_residual,
        )
        return _sol().dispatch(
            problem, arow, b, impl=impl,
            bw=bw, block=block, interpret=interpret, mesh=mesh, axis=mesh_axis,
        )
    if solve_impl is None and impl is not None:
        solve_impl = impl if impl in ("xla", "xla_scalar") else "pallas"
    lub = banded_lu(arow, bw=bw, impl=impl, block=block, interpret=interpret,
                    tolerance=tolerance)
    x = banded_solve(
        lub, b, bw=bw, impl=solve_impl, block=block, rhs_tile=rhs_tile,
        interpret=interpret, tolerance=tolerance,
    )
    if verify_residual and not isinstance(arow, jax.core.Tracer) \
            and not isinstance(b, jax.core.Tracer):
        return _verify_composed(arow, b, x, tolerance=tolerance, bw=bw)
    return x
