"""Jit'd public wrappers over the Pallas kernels.

``lu`` impl dispatch:
  * ``"pallas_fused"``   — DEFAULT: single-dispatch EbV LU megakernel — one
                           ``pallas_call`` for the whole factorization, matrix
                           carried in place in HBM (see
                           :func:`repro.kernels.ebv_lu.lu_fused`).  Falls back
                           to ``"pallas_blocked"`` for non-float32 dtypes.
  * ``"pallas_blocked"`` — legacy multi-launch blocked driver: one panel
                           kernel + one fused bi-vector step kernel per block
                           column (kept as the fallback/baseline; see
                           README.md for the launch/traffic comparison).
  * ``"pallas_vmem"``    — whole-matrix VMEM kernel (n ≲ 4096 fp32).
  * ``"xla"``            — pure-jnp mirror of the fused driver
                           (:func:`repro.core.blocked.fused_blocked_lu`):
                           identical op shapes/ordering, bitwise-identical
                           output — the transparent reference.

``lu_solve`` impl dispatch:
  * ``"pallas"``         — DEFAULT: auto — ``solve_vmem`` while the packed LU
                           fits VMEM comfortably, ``solve_tiled`` beyond.
  * ``"pallas_vmem"`` / ``"pallas_tiled"`` — force either driver.
  * ``"xla"``            — pure-jnp substitution from :mod:`repro.core`.

On CPU (this container) the Pallas paths run in interpret mode automatically;
on TPU they lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import blocked as _core_blocked
from repro.core import solve as _core_solve
from repro.core import banded as _core_banded
from . import ebv_lu as _k
from . import trsm as _trsm
from . import banded as _kbanded

__all__ = ["lu", "lu_solve", "linear_solve", "banded_lu"]

# Above this order the packed (n, n) LU no longer comfortably shares VMEM
# with an RHS tile, so the auto solve dispatch switches to the tiled driver.
_SOLVE_VMEM_MAX_N = 2048


def _pallas_blocked_lu(a: jax.Array, *, block: int, col_tile: int, interpret: bool | None) -> jax.Array:
    n = a.shape[-1]
    block = min(block, n)
    for k0 in range(0, n, block):
        b = min(block, n - k0)
        pan = _k.panel(a[k0:, k0 : k0 + b], interpret=interpret)
        a = a.at[k0:, k0 : k0 + b].set(pan)
        w = n - k0 - b
        if w > 0:
            ct = min(col_tile, w)
            if w % ct:
                # Pad the trailing width to the next tile multiple (tiles
                # capped at 128 lanes) instead of halving the tile — odd
                # widths used to degrade to 1-column tiles.  Zero columns are
                # inert through trsm and the rank-b update.
                ct = min(col_tile, 128)
                wp = -(-w // ct) * ct
                top = jnp.pad(a[k0 : k0 + b, k0 + b :], ((0, 0), (0, wp - w)))
                trail = jnp.pad(a[k0 + b :, k0 + b :], ((0, 0), (0, wp - w)))
                u12, new_trail = _k.fused_step(pan, top, trail, col_tile=ct, interpret=interpret)
                u12, new_trail = u12[:, :w], new_trail[:, :w]
            else:
                u12, new_trail = _k.fused_step(
                    pan, a[k0 : k0 + b, k0 + b :], a[k0 + b :, k0 + b :],
                    col_tile=ct, interpret=interpret,
                )
            a = a.at[k0 : k0 + b, k0 + b :].set(u12)
            a = a.at[k0 + b :, k0 + b :].set(new_trail)
    return a


@functools.partial(jax.jit, static_argnames=("impl", "block", "col_tile", "interpret"))
def lu(
    a: jax.Array,
    *,
    impl: str = "pallas_fused",
    block: int = 256,
    col_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed EbV LU factorization (no pivoting — paper contract)."""
    if impl == "pallas_fused":
        if a.dtype == jnp.float32:
            return _k.lu_fused(a, block=block, interpret=interpret)
        impl = "pallas_blocked"  # fused kernel is fp32-only; fall back
    if impl == "pallas_vmem":
        return _k.lu_vmem(a, interpret=interpret)
    if impl == "pallas_blocked":
        return _pallas_blocked_lu(a, block=block, col_tile=col_tile, interpret=interpret)
    if impl == "xla":
        return _core_blocked.fused_blocked_lu(a, block=block)
    raise ValueError(f"unknown impl {impl!r}")


@functools.partial(jax.jit, static_argnames=("impl", "block", "rhs_tile", "interpret"))
def lu_solve(
    lu_packed: jax.Array,
    b: jax.Array,
    *,
    impl: str = "pallas",
    block: int = 256,
    rhs_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    n = lu_packed.shape[-1]
    if impl == "pallas":
        impl = "pallas_vmem" if n <= _SOLVE_VMEM_MAX_N else "pallas_tiled"
    if impl == "pallas_vmem":
        return _trsm.solve_vmem(lu_packed, b, rhs_tile=rhs_tile, interpret=interpret)
    if impl == "pallas_tiled":
        return _trsm.solve_tiled(lu_packed, b, block=block, rhs_tile=rhs_tile, interpret=interpret)
    if impl == "xla":
        return _core_solve.lu_solve(lu_packed, b)
    raise ValueError(f"unknown impl {impl!r}")


def linear_solve(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    lu_kw = {k: v for k, v in kw.items() if k in ("impl", "block", "col_tile", "interpret")}
    solve_kw = {k: v for k, v in kw.items() if k in ("block", "rhs_tile", "interpret")}
    return lu_solve(lu(a, **lu_kw), b, **solve_kw)


@functools.partial(jax.jit, static_argnames=("bw", "impl", "interpret"))
def banded_lu(arow: jax.Array, *, bw: int, impl: str = "pallas", interpret: bool | None = None) -> jax.Array:
    if impl == "pallas":
        return _kbanded.banded_lu_kernelized(arow, bw=bw, interpret=interpret)
    if impl == "xla":
        return _core_banded.banded_lu(arow, bw=bw)
    raise ValueError(f"unknown impl {impl!r}")
