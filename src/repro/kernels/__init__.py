"""Pallas TPU kernels for the paper's compute hot-spot (LU factorization).

``<name>.py`` kernels + ``ops.py`` jit'd wrappers + ``ref.py`` numpy oracles.
Validated in interpret mode on CPU; target is TPU v5e Mosaic.
"""
from . import ebv_lu, trsm, banded, ops, paged_attn, ref  # noqa: F401
