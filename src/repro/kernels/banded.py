"""Pallas kernels for the banded ("sparse") EbV path.

The band is the paper's *naturally equalized* workload (DESIGN.md §4): every
elimination step touches exactly ``bw`` L and ``bw`` U elements.  Four
kernels, all single-dispatch (one ``pallas_call`` per factorization/solve):

* :func:`banded_lu_blocked`     — **blocked band LU megakernel**: the whole
                                  band VMEM-resident in the window-aligned
                                  skewed layout, one ``fori_loop`` step per
                                  ``C``-row block.  Each step assembles its
                                  dense ``(C+bw, C+bw)`` working window from
                                  two contiguous slices and retires ``C``
                                  pivots via ``(bw+1, bw+1)``-confined
                                  bi-vector updates
                                  (:func:`repro.core.banded.band_block_step`)
                                  — replacing the ``n−1`` scalar-sequential
                                  steps of the old kernel with ``⌈n/C⌉``
                                  equal-work block steps.
* :func:`banded_lu_tiled`       — HBM-streaming variant: the skewed band
                                  stays in HBM (``ANY`` memspace, carried in
                                  place via ``input_output_aliases``) and
                                  each grid step DMAs one ``(C+bw, C+2bw)``
                                  slab through a bounded VMEM buffer — ``n``
                                  is no longer capped by band-fits-VMEM.
* :func:`banded_solve_kernelized` — blocked forward/backward substitution on
                                  the packed band factors (HBM-resident,
                                  one ``(C, C+2bw)`` coupling strip DMA'd
                                  per block), mirroring ``trsm.py``'s
                                  strip-recurrence + rank-``C2`` retirement;
                                  RHS column tiles across the grid.
* :func:`batched_banded_lu_vmem` / :func:`batched_banded_solve_vmem` — the
                                  optimizer's many-small-systems path: one
                                  grid program per system (equalized
                                  trivially — every program factors one
                                  identical-shape band).

All blocked kernels trace the exact window-helper jaxprs of the pure-jnp
mirrors in :mod:`repro.core.banded`, so kernel and mirror produce
**bitwise-identical** packed band factors.  The legacy scalar kernel
(:func:`banded_lu_kernelized`) is kept as the measured baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.banded import (
    band_block_size,
    band_block_step,
    band_to_skewed,
    pad_band_identity,
    skew_pad,
    skewed_to_band,
    unit_lower_window_solve,
    upper_window_solve,
)
from repro.core.factorization import equalized_rhs_tile, inverted_band_sweeps

__all__ = [
    "banded_lu_kernelized",
    "banded_lu_blocked",
    "banded_lu_tiled",
    "banded_solve_kernelized",
    "banded_solve_inverted",
    "batched_banded_lu_vmem",
    "batched_banded_solve_vmem",
]


# ---------------------------------------------------------------------------
# legacy scalar-sequential kernel (kept as the measured baseline)
# ---------------------------------------------------------------------------
def _banded_kernel(ap_ref, out_ref, *, n: int, bw: int):
    w = 2 * bw + 1
    ap = ap_ref[...]  # (n + bw, w), zero-padded rows at the bottom
    s = jax.lax.broadcasted_iota(jnp.int32, (bw, w), 0) + 1  # row offset 1..bw
    c = jax.lax.broadcasted_iota(jnp.int32, (bw, w), 1)
    src = c - (bw + 1 - s)  # index into the pivot row's upper tail
    valid = (src >= 0) & (src < bw)
    anti_mask = c == (bw - s)  # where the L element sits in the window
    t = jax.lax.broadcasted_iota(jnp.int32, (bw, w, bw), 2)
    onehot = ((src[..., None] == t) & valid[..., None]).astype(ap.dtype)

    def body(k, ap):
        pivot = jax.lax.dynamic_slice(ap, (k, bw), (1, 1))
        window = jax.lax.dynamic_slice(ap, (k + 1, 0), (bw, w))
        u_tail = jax.lax.dynamic_slice(ap, (k, bw + 1), (1, bw))[0]  # (bw,)
        l = jnp.sum(jnp.where(anti_mask, window, 0.0), axis=1, keepdims=True) / pivot
        shifted = jnp.sum(onehot * u_tail[None, None, :], axis=2)  # (bw, w)
        window = window - l * shifted
        window = jnp.where(anti_mask, l, window)
        return jax.lax.dynamic_update_slice(ap, window, (k + 1, 0))

    out_ref[...] = jax.lax.fori_loop(0, n - 1, body, ap)


@functools.partial(jax.jit, static_argnames=("bw", "interpret"))
def banded_lu_kernelized(arow: jax.Array, *, bw: int, interpret: bool | None = None) -> jax.Array:
    """Row-aligned band (n, 2bw+1) → packed band LU, one scalar-sequential
    Pallas kernel (``n−1`` rank-1 ``fori_loop`` steps — the pre-blocked
    baseline; see :func:`banded_lu_blocked` for the fast path)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = arow.shape[0]
    ap = jnp.concatenate([arow, jnp.zeros((bw, arow.shape[1]), arow.dtype)], axis=0)
    out = pl.pallas_call(
        functools.partial(_banded_kernel, n=n, bw=bw),
        out_shape=jax.ShapeDtypeStruct(ap.shape, ap.dtype),
        interpret=interpret,
    )(ap)
    return out[:n]


# ---------------------------------------------------------------------------
# blocked band LU — VMEM-resident megakernel
# ---------------------------------------------------------------------------
def _banded_blocked_kernel(g_ref, out_ref, *, num_steps: int, block: int, bw: int):
    step = functools.partial(band_block_step, block=block, bw=bw)
    out_ref[...] = jax.lax.fori_loop(
        0, num_steps, lambda s, g: step(g, s * block), g_ref[...]
    )


@functools.partial(jax.jit, static_argnames=("bw", "block", "interpret"))
def banded_lu_blocked(
    arow: jax.Array, *, bw: int, block: int | None = None, interpret: bool | None = None
) -> jax.Array:
    """Blocked band LU in ONE ``pallas_call``, whole band VMEM-resident.

    The identity-padded band is re-laid into the window-aligned skewed form
    (:func:`repro.core.banded.band_to_skewed`); each of the ``S``
    ``fori_loop`` steps assembles its dense ``(C+bw, C+bw)`` window from two
    static slices and retires ``C`` pivot rows.  Bitwise-identical to the
    :func:`repro.core.banded.banded_lu_blocked` mirror."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = arow.shape[0]
    c = band_block_size(n, bw, block)
    g, s = skew_pad(arow, bw, c)
    out = pl.pallas_call(
        functools.partial(_banded_blocked_kernel, num_steps=s, block=c, bw=bw),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=interpret,
    )(g)
    return skewed_to_band(out, bw, c)[:n]


# ---------------------------------------------------------------------------
# blocked band LU — HBM-streaming variant
# ---------------------------------------------------------------------------
def _banded_tiled_kernel(g_any, o_any, slab_buf, sem, *, block: int, bw: int):
    """One grid step: DMA the ``(C+bw, C+2bw)`` skewed slab HBM→VMEM, factor
    its window, DMA it back.  TPU grid steps run sequentially, so step
    ``s+1`` observes the ``bw`` carry rows step ``s`` just wrote."""
    del g_any  # aliased to o_any; all traffic goes through the output ref
    s = pl.program_id(0)
    c = block
    hbm = o_any.at[pl.ds(s * c, c + bw), :]
    load = pltpu.make_async_copy(hbm, slab_buf, sem)
    load.start()
    load.wait()
    slab_buf[...] = band_block_step(slab_buf[...], 0, block=c, bw=bw)
    store = pltpu.make_async_copy(slab_buf, hbm, sem)
    store.start()
    store.wait()


@functools.partial(jax.jit, static_argnames=("bw", "block", "interpret"))
def banded_lu_tiled(
    arow: jax.Array, *, bw: int, block: int | None = None, interpret: bool | None = None
) -> jax.Array:
    """Blocked band LU in ONE ``pallas_call`` with the band HBM-resident.

    The skewed band is carried in place through ``input_output_aliases``;
    VMEM holds only one ``(C+bw, C+2bw)`` slab regardless of ``n``, so the
    factorization scales past the band-fits-VMEM wall of
    :func:`banded_lu_blocked`.  Bitwise-identical to the blocked mirror
    (same window helpers)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = arow.shape[0]
    c = band_block_size(n, bw, block)
    g, s = skew_pad(arow, bw, c)
    out = pl.pallas_call(
        functools.partial(_banded_tiled_kernel, block=c, bw=bw),
        grid=(s,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        scratch_shapes=[
            pltpu.VMEM((c + bw, g.shape[1]), g.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(g)
    return skewed_to_band(out, bw, c)[:n]


# ---------------------------------------------------------------------------
# blocked band solve
# ---------------------------------------------------------------------------
def _banded_solve_sweeps(read_strip, xp, *, num_steps: int, block: int, bw: int):
    """Blocked forward then backward band substitution on a carried RHS
    value.  ``read_strip(k)`` yields the skewed factors' dense coupling
    strip ``F`` ``(C, C+2bw)`` of the block at row ``k`` (a DMA'd copy or a
    value slice — both exact, so the bitwise mirror contract holds).  The
    carried RHS has ``bw`` zero margin rows at both ends so every block
    reads its above/below coupling window without branching."""
    c = block
    rt = xp.shape[1]

    def fwd(i, xp):
        k = i * c
        f = read_strip(k)
        yblk = jax.lax.dynamic_slice(xp, (bw + k, 0), (c, rt)) - jnp.dot(
            f[:, :bw], jax.lax.dynamic_slice(xp, (k, 0), (bw, rt)),
            preferred_element_type=jnp.float32,
        ).astype(xp.dtype)
        yblk = unit_lower_window_solve(f[:, bw : bw + c], yblk, bw)
        return jax.lax.dynamic_update_slice(xp, yblk, (bw + k, 0))

    xp = jax.lax.fori_loop(0, num_steps, fwd, xp)

    def bwd(ii, xp):
        k = (num_steps - 1 - ii) * c
        f = read_strip(k)
        xblk = jax.lax.dynamic_slice(xp, (bw + k, 0), (c, rt)) - jnp.dot(
            f[:, bw + c :], jax.lax.dynamic_slice(xp, (bw + k + c, 0), (bw, rt)),
            preferred_element_type=jnp.float32,
        ).astype(xp.dtype)
        xblk = upper_window_solve(f[:, bw : bw + c], xblk, bw)
        return jax.lax.dynamic_update_slice(xp, xblk, (bw + k, 0))

    return jax.lax.fori_loop(0, num_steps, bwd, xp)


def _banded_solve_kernel(g_any, b_ref, x_ref, fbuf, sem, *, num_steps: int, block: int, bw: int):
    """One RHS-tile program.  The skewed factors stay in HBM (``ANY``
    memspace); only one ``(C, C+2bw)`` coupling strip is DMA'd to VMEM
    scratch at a time — per-program VMEM is ``(2bw+S·C+...)·rt + C·(C+2bw)``
    floats, the band analogue of ``trsm.py:solve_tiled``'s footprint."""

    def read_strip(k):
        dma = pltpu.make_async_copy(g_any.at[pl.ds(k, block), :], fbuf, sem)
        dma.start()
        dma.wait()
        return fbuf[...]

    x_ref[...] = _banded_solve_sweeps(
        read_strip, b_ref[...], num_steps=num_steps, block=block, bw=bw
    )


@functools.partial(jax.jit, static_argnames=("bw", "block", "rhs_tile", "interpret"))
def banded_solve_kernelized(
    lu_band: jax.Array,
    b: jax.Array,
    *,
    bw: int,
    block: int | None = None,
    rhs_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Solve ``(LU) x = b`` on packed band factors in ONE ``pallas_call``:
    blocked forward/backward sweeps (strip recurrence + rank-``C2``
    retirement per block, the band analogue of ``trsm.py``), RHS column
    tiles across the grid, factors HBM-resident and streamed strip-by-strip
    so the solve is not capped by factors-fit-VMEM.  Bitwise-identical to
    :func:`repro.core.banded.banded_solve_blocked`."""
    lu_band = getattr(lu_band, "packed", lu_band)  # accept artifacts
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = lu_band.shape[0]
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    m = bm.shape[1]
    c = band_block_size(n, bw, block)
    s = -(-n // c)
    np_rows = s * c
    g = band_to_skewed(pad_band_identity(lu_band, bw, np_rows), bw, c)
    rt = min(rhs_tile, m)
    m_pad = -(-m // rt) * rt
    p_rows = bw + np_rows + bw
    xp = jnp.zeros((p_rows, m_pad), bm.dtype).at[bw : bw + n, :m].set(bm)
    x = pl.pallas_call(
        functools.partial(_banded_solve_kernel, num_steps=s, block=c, bw=bw),
        grid=(m_pad // rt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((p_rows, rt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((p_rows, rt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((p_rows, m_pad), bm.dtype),
        scratch_shapes=[
            pltpu.VMEM((c, g.shape[1]), g.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(g, xp)
    x = x[bw : bw + n, :m]
    return x[:, 0] if squeeze else x


# ---------------------------------------------------------------------------
# inverted-diagonal blocked band solve (Factorization artifact fast path)
# ---------------------------------------------------------------------------
def _banded_solve_inv_kernel(linv_ref, uinv_ref, tlo_ref, tup_ref, b_ref, x_ref, *, bw: int):
    """One RHS-tile program of the inverted-diagonal band solve: the
    VMEM-resident inverse / transfer stacks drive the two-phase batched-GEMM
    substitution (:func:`repro.core.factorization.inverted_band_sweeps`).
    The whole program is GEMM + one associative tail scan — equal
    contribution across all solve blocks, no per-block loop."""
    x_ref[...] = inverted_band_sweeps(
        linv_ref[...], uinv_ref[...], tlo_ref[...], tup_ref[...], b_ref[...], bw=bw
    )


@functools.partial(jax.jit, static_argnames=("n", "bw", "rhs_tile", "interpret"))
def banded_solve_inverted(
    linv: jax.Array,
    uinv: jax.Array,
    tlo: jax.Array,
    tup: jax.Array,
    b: jax.Array,
    *,
    n: int,
    bw: int,
    rhs_tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Solve ``(LU) x = b`` from a :class:`~repro.core.factorization
    .Factorization` artifact's enrichments: the pre-inverted in-window
    diagonal blocks and the pre-coupled transfer blocks, both derived ONCE
    at factor time — no per-solve re-skew, no sequential strip recurrence.
    Each sweep is two batched GEMMs over all ``S`` blocks plus an
    associative scan over the ``(bw, rt)`` tail states.  RHS columns run in
    equalized tiles (:func:`repro.core.factorization.equalized_rhs_tile`).
    Bitwise-identical to
    :func:`repro.core.factorization.banded_inverted_solve`.

    Like ``banded_lu_blocked``, this is the VMEM-resident variant: the
    ``(S, C, C)`` inverse stacks live in VMEM for the whole program (the
    artifact payload the registry's VMEM estimate accounts for); an
    HBM-streaming phase-split variant is the escape hatch past that wall."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    s, c = linv.shape[0], linv.shape[1]
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    out_dtype = bm.dtype
    compute = linv.dtype
    m = bm.shape[1]
    rt = equalized_rhs_tile(m, rhs_tile)
    m_pad = -(-m // rt) * rt
    xb = (
        jnp.zeros((s * c, m_pad), compute)
        .at[:n, :m]
        .set(bm.astype(compute))
        .reshape(s, c, m_pad)
    )
    x = pl.pallas_call(
        functools.partial(_banded_solve_inv_kernel, bw=bw),
        grid=(m_pad // rt,),
        in_specs=[
            pl.BlockSpec((s, c, c), lambda j: (0, 0, 0)),
            pl.BlockSpec((s, c, c), lambda j: (0, 0, 0)),
            pl.BlockSpec((s, c, bw), lambda j: (0, 0, 0)),
            pl.BlockSpec((s, c, bw), lambda j: (0, 0, 0)),
            pl.BlockSpec((s, c, rt), lambda j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((s, c, rt), lambda j: (0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((s, c, m_pad), compute),
        interpret=interpret,
    )(linv, uinv, tlo, tup, xb)
    x = x.reshape(s * c, m_pad)[:n, :m].astype(out_dtype)
    return x[:, 0] if squeeze else x


# ---------------------------------------------------------------------------
# batched band grid path (optimizer: many small independent systems)
# ---------------------------------------------------------------------------
def _batched_banded_lu_kernel(g_ref, o_ref, *, num_steps: int, block: int, bw: int):
    step = functools.partial(band_block_step, block=block, bw=bw)
    o_ref[0] = jax.lax.fori_loop(0, num_steps, lambda s, g: step(g, s * block), g_ref[0])


@functools.partial(jax.jit, static_argnames=("bw", "block", "interpret"))
def batched_banded_lu_vmem(
    arow: jax.Array, *, bw: int, block: int | None = None, interpret: bool | None = None
) -> jax.Array:
    """(B, n, 2bw+1) → packed band LU per system; one grid program per
    system, each running the blocked window steps on its VMEM-resident band
    (equal work per program by construction — every system is one identical
    factorization)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, n, w = arow.shape
    c = band_block_size(n, bw, block)
    g = jax.vmap(lambda ap: skew_pad(ap, bw, c)[0])(arow)
    s = -(-n // c)
    rows, gw = g.shape[1], g.shape[2]
    out = pl.pallas_call(
        functools.partial(_batched_banded_lu_kernel, num_steps=s, block=c, bw=bw),
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, rows, gw), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, rows, gw), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=interpret,
    )(g)
    return jax.vmap(lambda gi: skewed_to_band(gi, bw, c))(out)[:, :n]


def _batched_banded_solve_kernel(lu_ref, b_ref, x_ref, *, num_steps: int, block: int, bw: int):
    g = lu_ref[0]  # small per-system factors stay VMEM-resident

    def read_strip(k):
        return jax.lax.dynamic_slice(g, (k, 0), (block, g.shape[1]))

    x_ref[0] = _banded_solve_sweeps(
        read_strip, b_ref[0], num_steps=num_steps, block=block, bw=bw
    )


@functools.partial(jax.jit, static_argnames=("bw", "block", "interpret"))
def batched_banded_solve_vmem(
    lu_band: jax.Array, b: jax.Array, *, bw: int, block: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """lu_band: (B, n, 2bw+1) packed; b: (B, n) or (B, n, m) → x, same shape
    as ``b``; one grid program per system."""
    lu_band = getattr(lu_band, "packed", lu_band)  # accept artifacts
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, n, w = lu_band.shape
    squeeze = b.ndim == 2
    bm = b[..., None] if squeeze else b
    m = bm.shape[-1]
    c = band_block_size(n, bw, block)
    s = -(-n // c)
    np_rows = s * c
    g = jax.vmap(
        lambda lb: band_to_skewed(pad_band_identity(lb, bw, np_rows), bw, c)
    )(lu_band)
    gw = g.shape[2]
    p_rows = bw + np_rows + bw
    xp = jnp.zeros((bsz, p_rows, m), bm.dtype).at[:, bw : bw + n].set(bm)
    x = pl.pallas_call(
        functools.partial(_batched_banded_solve_kernel, num_steps=s, block=c, bw=bw),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, np_rows, gw), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p_rows, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p_rows, m), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, p_rows, m), bm.dtype),
        interpret=interpret,
    )(g, xp)
    x = x[:, bw : bw + n]
    return x[..., 0] if squeeze else x
