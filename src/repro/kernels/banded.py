"""Pallas kernel for the banded ("sparse") EbV LU.

Whole band VMEM-resident (n=16384, bw=16 fp32 ≈ 2.2 MB).  Every elimination
step touches exactly ``bw`` L elements and ``bw`` U elements — the naturally
equalized case (DESIGN.md §4).  The shifted-window gather is expressed as a
one-hot contraction (elementwise + reduce only) so it lowers on Mosaic
without general gather support.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["banded_lu_kernelized"]


def _banded_kernel(ap_ref, out_ref, *, n: int, bw: int):
    w = 2 * bw + 1
    ap = ap_ref[...]  # (n + bw, w), zero-padded rows at the bottom
    s = jax.lax.broadcasted_iota(jnp.int32, (bw, w), 0) + 1  # row offset 1..bw
    c = jax.lax.broadcasted_iota(jnp.int32, (bw, w), 1)
    src = c - (bw + 1 - s)  # index into the pivot row's upper tail
    valid = (src >= 0) & (src < bw)
    anti_mask = c == (bw - s)  # where the L element sits in the window
    t = jax.lax.broadcasted_iota(jnp.int32, (bw, w, bw), 2)
    onehot = ((src[..., None] == t) & valid[..., None]).astype(ap.dtype)

    def body(k, ap):
        pivot = jax.lax.dynamic_slice(ap, (k, bw), (1, 1))
        window = jax.lax.dynamic_slice(ap, (k + 1, 0), (bw, w))
        u_tail = jax.lax.dynamic_slice(ap, (k, bw + 1), (1, bw))[0]  # (bw,)
        l = jnp.sum(jnp.where(anti_mask, window, 0.0), axis=1, keepdims=True) / pivot
        shifted = jnp.sum(onehot * u_tail[None, None, :], axis=2)  # (bw, w)
        window = window - l * shifted
        window = jnp.where(anti_mask, l, window)
        return jax.lax.dynamic_update_slice(ap, window, (k + 1, 0))

    out_ref[...] = jax.lax.fori_loop(0, n - 1, body, ap)


@functools.partial(jax.jit, static_argnames=("bw", "interpret"))
def banded_lu_kernelized(arow: jax.Array, *, bw: int, interpret: bool | None = None) -> jax.Array:
    """Row-aligned band (n, 2bw+1) → packed band LU, via one Pallas kernel."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = arow.shape[0]
    ap = jnp.concatenate([arow, jnp.zeros((bw, arow.shape[1]), arow.dtype)], axis=0)
    out = pl.pallas_call(
        functools.partial(_banded_kernel, n=n, bw=bw),
        out_shape=jax.ShapeDtypeStruct(ap.shape, ap.dtype),
        interpret=interpret,
    )(ap)
    return out[:n]
