"""Pure oracles for every Pallas kernel (numpy float64, loop-level naive).

These are deliberately the dumbest correct implementations — independent of
both the Pallas kernels and the vectorized :mod:`repro.core` paths — so the
allclose sweeps in ``tests/test_kernels.py`` anchor three implementations
against each other.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "lu_ref",
    "panel_ref",
    "solve_ref",
    "forward_ref",
    "backward_ref",
    "banded_lu_ref",
    "update_ref",
    "fused_step_ref",
]


def lu_ref(a) -> np.ndarray:
    """Doolittle LU, no pivoting, packed (unit lower implicit)."""
    a = np.array(a, dtype=np.float64)
    n = a.shape[0]
    for k in range(n - 1):
        a[k + 1 :, k] /= a[k, k]
        a[k + 1 :, k + 1 :] -= np.outer(a[k + 1 :, k], a[k, k + 1 :])
    return a


def panel_ref(p) -> np.ndarray:
    """Tall-panel LU: pivots in the top b rows."""
    p = np.array(p, dtype=np.float64)
    m, b = p.shape
    for k in range(min(b, m - 1)):
        p[k + 1 :, k] /= p[k, k]
        p[k + 1 :, k + 1 : b] -= np.outer(p[k + 1 :, k], p[k, k + 1 : b])
    return p


def forward_ref(lu, b) -> np.ndarray:
    lu = np.asarray(lu, dtype=np.float64)
    y = np.array(b, dtype=np.float64)
    n = lu.shape[0]
    for i in range(n):
        y[i] = y[i] - lu[i, :i] @ y[:i]
    return y


def backward_ref(lu, y) -> np.ndarray:
    lu = np.asarray(lu, dtype=np.float64)
    x = np.array(y, dtype=np.float64)
    n = lu.shape[0]
    for i in range(n - 1, -1, -1):
        x[i] = (x[i] - lu[i, i + 1 :] @ x[i + 1 :]) / lu[i, i]
    return x


def solve_ref(lu, b) -> np.ndarray:
    return backward_ref(lu, forward_ref(lu, b))


def update_ref(l21, u12, a22) -> np.ndarray:
    return np.asarray(a22, np.float64) - np.asarray(l21, np.float64) @ np.asarray(u12, np.float64)


def fused_step_ref(panel, a_top, a_trail):
    """U12 = L11^{-1} A12 (unit-lower) then A22 - L21 @ U12."""
    panel = np.asarray(panel, np.float64)
    b = panel.shape[1]
    l11 = np.tril(panel[:b], -1) + np.eye(b)
    u12 = np.linalg.solve(l11, np.asarray(a_top, np.float64))
    return u12, update_ref(panel[b:], u12, a_trail)


def banded_lu_ref(arow, bw: int) -> np.ndarray:
    """Band LU by densifying, factoring with :func:`lu_ref`, re-banding."""
    arow = np.asarray(arow, np.float64)
    n = arow.shape[0]
    dense = np.zeros((n, n))
    for i in range(n):
        for t in range(2 * bw + 1):
            j = i - bw + t
            if 0 <= j < n:
                dense[i, j] = arow[i, t]
    lu = lu_ref(dense)
    out = np.zeros_like(arow)
    for i in range(n):
        for t in range(2 * bw + 1):
            j = i - bw + t
            if 0 <= j < n:
                out[i, t] = lu[i, j]
    return out
