"""Pallas kernels for the substitution (solve) phases.

Column-oriented vectorized substitution: once pivot ``k`` resolves, one
masked axpy retires its contribution from every remaining row — the solve
phase analogue of the bi-vectorized elimination step.

Two drivers:

* :func:`solve_vmem`  — the packed LU stays VMEM-resident per program and the
                        RHS block is tiled over the grid.  Simple and fast
                        while ``(n, n)`` fits in VMEM (n ≲ 4096 fp32).
* :func:`solve_tiled` — blocked substitution that never materializes the
                        whole LU on-chip: the factor stays in HBM (``ANY``
                        memory space) and only one ``(block, block)`` tile is
                        DMA'd to VMEM scratch at a time, so solves scale past
                        the VMEM wall.  Forward phase walks diagonal blocks
                        left→right (unit-lower tile solve, then one GEMM per
                        lower off-diagonal tile); backward phase mirrors it
                        right→left against U.  VMEM footprint per program:
                        ``N·rhs_tile + block²`` floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.blocked import pad_identity_tail as _pad_identity_tail
from repro.core.blocked import strip_trsm as _strip_trsm
from repro.core.factorization import equalized_rhs_tile, inverted_dense_sweeps

__all__ = ["solve_vmem", "solve_tiled", "solve_inverted"]


def _solve_kernel(lu_ref, b_ref, x_ref, *, n: int):
    lu = lu_ref[...]
    y = b_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def fwd(k, y):
        lk = jnp.where(rows > k, jax.lax.dynamic_slice(lu, (0, k), (n, 1)), 0.0)
        yk = jax.lax.dynamic_slice(y, (k, 0), (1, y.shape[1]))
        return y - lk * yk

    y = jax.lax.fori_loop(0, n - 1, fwd, y)

    def bwd(j, x):
        k = n - 1 - j
        pivot = jax.lax.dynamic_slice(lu, (k, k), (1, 1))
        xk = jax.lax.dynamic_slice(x, (k, 0), (1, x.shape[1])) / pivot
        x = jax.lax.dynamic_update_slice(x, xk, (k, 0))
        uk = jnp.where(rows < k, jax.lax.dynamic_slice(lu, (0, k), (n, 1)), 0.0)
        return x - uk * xk

    x_ref[...] = jax.lax.fori_loop(0, n, bwd, y)


@functools.partial(jax.jit, static_argnames=("rhs_tile", "interpret"))
def solve_vmem(
    lu: jax.Array, b: jax.Array, *, rhs_tile: int = 256, interpret: bool | None = None
) -> jax.Array:
    """Solve ``(LU) x = b`` for packed ``lu`` (n, n) and RHS ``b`` (n,) or
    (n, m); the RHS columns are tiled across the grid.  RHS widths that do
    not divide ``rhs_tile`` are zero-padded to the next tile multiple and
    sliced back (zero columns solve to zero, so padding is inert)."""
    lu = getattr(lu, "packed", lu)  # accept Factorization artifacts
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    n, m = bm.shape
    rt = min(rhs_tile, m)
    m_pad = -(-m // rt) * rt
    if m_pad != m:
        bm = jnp.pad(bm, ((0, 0), (0, m_pad - m)))
    x = pl.pallas_call(
        functools.partial(_solve_kernel, n=n),
        grid=(m_pad // rt,),
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((n, rt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, rt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, m_pad), bm.dtype),
        interpret=interpret,
    )(lu, bm)
    x = x[:, :m] if m_pad != m else x
    return x[:, 0] if squeeze else x


def _solve_tiled_kernel(lu_any, b_ref, x_ref, ltile, sem, *, num_steps: int, block: int):
    """One RHS tile program: blocked forward then backward substitution with
    the LU factor streamed tile-by-tile from HBM."""
    S, B = num_steps, block
    rt = b_ref.shape[1]
    x_ref[...] = b_ref[...]
    rows_b = jax.lax.broadcasted_iota(jnp.int32, (B, 1), 0)
    acc_dtype = jnp.promote_types(jnp.float32, b_ref.dtype)  # f32, or f64 under x64

    def load(i, j):
        dma = pltpu.make_async_copy(
            lu_any.at[pl.ds(i * B, B), pl.ds(j * B, B)], ltile, sem
        )
        dma.start()
        dma.wait()

    def fwd_outer(i, _):
        load(i, i)
        yi = _strip_trsm(ltile[...], x_ref[pl.ds(i * B, B), :])
        x_ref[pl.ds(i * B, B), :] = yi

        def off(r, _):
            load(r, i)
            blk = x_ref[pl.ds(r * B, B), :]
            x_ref[pl.ds(r * B, B), :] = blk - jnp.dot(
                ltile[...], yi, preferred_element_type=acc_dtype
            ).astype(blk.dtype)
            return 0

        jax.lax.fori_loop(i + 1, S, off, 0)
        return 0

    jax.lax.fori_loop(0, S, fwd_outer, 0)

    def bwd_outer(jj, _):
        i = (S - 1) - jj
        load(i, i)
        u11 = ltile[...]
        xi = x_ref[pl.ds(i * B, B), :]

        def bwd_in(kk, x):
            k = (B - 1) - kk
            pivot = jax.lax.dynamic_slice(u11, (k, k), (1, 1))
            xk = jax.lax.dynamic_slice(x, (k, 0), (1, rt)) / pivot
            x = jax.lax.dynamic_update_slice(x, xk, (k, 0))
            uk = jnp.where(rows_b < k, jax.lax.dynamic_slice(u11, (0, k), (B, 1)), 0.0)
            return x - uk * xk

        xi = jax.lax.fori_loop(0, B, bwd_in, xi)
        x_ref[pl.ds(i * B, B), :] = xi

        def off(r, _):
            load(r, i)
            blk = x_ref[pl.ds(r * B, B), :]
            x_ref[pl.ds(r * B, B), :] = blk - jnp.dot(
                ltile[...], xi, preferred_element_type=acc_dtype
            ).astype(blk.dtype)
            return 0

        jax.lax.fori_loop(0, i, off, 0)
        return 0

    jax.lax.fori_loop(0, S, bwd_outer, 0)


@functools.partial(jax.jit, static_argnames=("block", "rhs_tile", "interpret"))
def solve_tiled(
    lu: jax.Array,
    b: jax.Array,
    *,
    block: int = 256,
    rhs_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked ``(LU) x = b`` solve with the factor HBM-resident.

    Pads ``n`` to a multiple of ``block`` with an identity tail (inert: unit
    diagonal, zero coupling) and the RHS with zero rows/columns, then runs one
    program per RHS column tile.  Only one ``(block, block)`` LU tile is
    on-chip at a time, so the solve scales to matrices far past what
    :func:`solve_vmem` can hold (~4096² fp32)."""
    lu = getattr(lu, "packed", lu)  # accept Factorization artifacts
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    out_dtype = bm.dtype
    # substitution runs at (at least) f32: lower-precision factors/RHS are
    # solved in f32 and cast back (more accurate than bf16 math); f64 inputs
    # keep f64 scratch and full accuracy
    compute_dtype = jnp.promote_types(jnp.float32, jnp.promote_types(lu.dtype, out_dtype))
    lu = lu.astype(compute_dtype)
    bm = bm.astype(compute_dtype)
    n, m = bm.shape
    B = min(block, n)
    S = -(-n // B)
    N = S * B
    rt = min(rhs_tile, m)
    M = -(-m // rt) * rt
    lu = _pad_identity_tail(lu, N)
    if (N, M) != (n, m):
        bm = jnp.pad(bm, ((0, N - n), (0, M - m)))
    x = pl.pallas_call(
        functools.partial(_solve_tiled_kernel, num_steps=S, block=B),
        grid=(M // rt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((N, rt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((N, rt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), bm.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, B), compute_dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(lu, bm)
    x = x[:n, :m].astype(out_dtype)
    return x[:, 0] if squeeze else x


def _solve_inverted_kernel(
    lu_any, linv_any, uinv_any, b_ref, x_ref, ltile, ibuf, sem, isem,
    *, num_steps: int, block: int,
):
    """One RHS-tile program of the inverted-diagonal blocked solve: the
    factor and the ``(S, B, B)`` inverse stacks stay in HBM; per step one
    off-diagonal tile or one inverse block is DMA'd to VMEM and every
    diagonal step is pure GEMM
    (:func:`repro.core.factorization.inverted_dense_sweeps`)."""
    B = block

    def read_tile(r, i):
        dma = pltpu.make_async_copy(
            lu_any.at[pl.ds(r * B, B), pl.ds(i * B, B)], ltile, sem
        )
        dma.start()
        dma.wait()
        return ltile[...]

    def _read_inv(src, i):
        dma = pltpu.make_async_copy(src.at[pl.ds(i, 1)], ibuf, isem)
        dma.start()
        dma.wait()
        return ibuf[0]

    x_ref[...] = inverted_dense_sweeps(
        read_tile,
        functools.partial(_read_inv, linv_any),
        functools.partial(_read_inv, uinv_any),
        b_ref[...],
        num_steps=num_steps,
        block=B,
    )


@functools.partial(jax.jit, static_argnames=("rhs_tile", "interpret"))
def solve_inverted(
    lu: jax.Array,
    linv: jax.Array,
    uinv: jax.Array,
    b: jax.Array,
    *,
    rhs_tile: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Blocked ``(LU) x = b`` solve consuming a
    :class:`~repro.core.factorization.Factorization` artifact's pre-inverted
    ``(S, B, B)`` diagonal blocks: the per-diagonal-block ``strip_trsm``
    recurrence and the scalar backward loop of :func:`solve_tiled` are
    replaced by one GEMM against the stored inverse — the whole sweep is
    GEMM + rank-``B`` retirement.  RHS columns run in *equalized* tiles
    (:func:`repro.core.factorization.equalized_rhs_tile`), sized for the
    wide stacked-RHS dispatches the solve service coalesces.
    Bitwise-identical to
    :func:`repro.core.factorization.dense_inverted_solve`."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    out_dtype = bm.dtype
    compute_dtype = jnp.promote_types(jnp.float32, jnp.promote_types(lu.dtype, out_dtype))
    n, m = bm.shape
    S, B = linv.shape[0], linv.shape[1]
    N = S * B
    rt = equalized_rhs_tile(m, rhs_tile)
    M = -(-m // rt) * rt
    lup = _pad_identity_tail(lu.astype(compute_dtype), N)
    bm = bm.astype(compute_dtype)
    if (N, M) != (n, m):
        bm = jnp.pad(bm, ((0, N - n), (0, M - m)))
    x = pl.pallas_call(
        functools.partial(_solve_inverted_kernel, num_steps=S, block=B),
        grid=(M // rt,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((N, rt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((N, rt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), bm.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, B), compute_dtype),
            pltpu.VMEM((1, B, B), linv.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(lup, linv, uinv, bm)
    x = x[:n, :m].astype(out_dtype)
    return x[:, 0] if squeeze else x
