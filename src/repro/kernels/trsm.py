"""Pallas kernels for the substitution (solve) phases.

Column-oriented vectorized substitution: once pivot ``k`` resolves, one
masked axpy retires its contribution from every remaining row — the solve
phase analogue of the bi-vectorized elimination step.  The RHS block is
tiled over the grid; the packed LU stays VMEM-resident per program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["solve_vmem"]


def _solve_kernel(lu_ref, b_ref, x_ref, *, n: int):
    lu = lu_ref[...]
    y = b_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)

    def fwd(k, y):
        lk = jnp.where(rows > k, jax.lax.dynamic_slice(lu, (0, k), (n, 1)), 0.0)
        yk = jax.lax.dynamic_slice(y, (k, 0), (1, y.shape[1]))
        return y - lk * yk

    y = jax.lax.fori_loop(0, n - 1, fwd, y)

    def bwd(j, x):
        k = n - 1 - j
        pivot = jax.lax.dynamic_slice(lu, (k, k), (1, 1))
        xk = jax.lax.dynamic_slice(x, (k, 0), (1, x.shape[1])) / pivot
        x = jax.lax.dynamic_update_slice(x, xk, (k, 0))
        uk = jnp.where(rows < k, jax.lax.dynamic_slice(lu, (0, k), (n, 1)), 0.0)
        return x - uk * xk

    x_ref[...] = jax.lax.fori_loop(0, n, bwd, y)


@functools.partial(jax.jit, static_argnames=("rhs_tile", "interpret"))
def solve_vmem(
    lu: jax.Array, b: jax.Array, *, rhs_tile: int = 256, interpret: bool | None = None
) -> jax.Array:
    """Solve ``(LU) x = b`` for packed ``lu`` (n, n) and RHS ``b`` (n,) or
    (n, m); the RHS columns are tiled across the grid."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    n, m = bm.shape
    rt = min(rhs_tile, m)
    assert m % rt == 0, (m, rt)
    x = pl.pallas_call(
        functools.partial(_solve_kernel, n=n),
        grid=(m // rt,),
        in_specs=[
            pl.BlockSpec((n, n), lambda j: (0, 0)),
            pl.BlockSpec((n, rt), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, rt), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), bm.dtype),
        interpret=interpret,
    )(lu, bm)
    return x[:, 0] if squeeze else x
