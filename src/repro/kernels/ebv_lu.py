"""Pallas TPU kernels for EbV LU factorization.

Kernels, mirroring DESIGN.md §2's GPU→TPU adaptation:

* :func:`lu_fused`      — **single-dispatch blocked EbV LU megakernel**: one
                          ``pallas_call`` for the whole factorization.  The
                          packed matrix stays in HBM (``ANY`` memory space)
                          and is carried *in place* via
                          ``input_output_aliases``; the grid iterates
                          (block-step × equalized tile program) and each
                          program DMAs its panel/tiles through double-buffered
                          VMEM scratch, fusing panel factorization, unit-lower
                          trsm and the rank-b trailing update per step.
                          Tile→program assignment is the paper's eq. 7 fold
                          (:func:`repro.core.ebv.equalized_tile_schedule`):
                          program ``p`` owns trailing tiles ``p+1`` and
                          ``S-1-p`` whose lifetime work sums to the constant
                          ``S``.  See ``src/repro/kernels/README.md`` for the
                          launch-count / HBM-traffic math vs the legacy
                          multi-launch driver.
* :func:`lu_vmem`       — paper-faithful bi-vectorized LU with the whole
                          matrix VMEM-resident; every ``fori_loop`` step is a
                          fixed-shape masked rank-1 update (equal work/step).
* :func:`panel`         — tall (m, b) panel factorization (the unblocked
                          bi-vectorized steps confined to a VMEM panel).
* :func:`fused_step`    — the *fused bi-vector step*: unit-lower trsm
                          (U-row block) and the rank-b trailing update in a
                          single VMEM pass, grid over column tiles.
* :func:`update`        — standalone rank-k update GEMM (2-D tile grid) for
                          trailing blocks too tall for the fused kernel.

All kernels run under ``interpret=True`` on CPU (how we validate here) and
lower to Mosaic on real TPUs.  MXU alignment: tile sizes default to multiples
of 128; iotas are 2-D (TPU requirement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.blocked import (
    factor_diag_strip,
    fused_block_size,
    fused_lu_steps,
    pad_identity_tail,
    solve_below_strip,
    strip_trsm,
    sub_block_width,
)

__all__ = ["lu_fused", "lu_vmem", "panel", "fused_step", "update"]

# Padded orders at or below this run the fused LU as a VMEM-resident value
# kernel (no HBM scratch streaming).  The HBM megakernel's interpret-mode
# DMA emulation and per-strip scratch-ref copies made it *slower* than its
# own pure-jnp mirror at n=256 (3460 vs 3166 µs, BENCH_kernels.json seed);
# on a VMEM value the kernel traces exactly the mirror's ops.  2·N²·4 bytes
# of VMEM at N=512 is 2 MB — comfortable on real TPUs too.
_FUSED_VMEM_MAX_N = 512


def _rows_cols(m: int, n: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    return rows, cols


def _lu_body(m: int, n: int):
    """Shared bi-vectorized elimination step on a VMEM-resident value."""
    rows, cols = _rows_cols(m, n)

    def body(k, a):
        pivot = jax.lax.dynamic_slice(a, (k, k), (1, 1))
        col = jax.lax.dynamic_slice(a, (0, k), (m, 1))
        row = jax.lax.dynamic_slice(a, (k, 0), (1, n))
        l_col = jnp.where(rows > k, col / pivot, 0.0)
        u_row = jnp.where(cols > k, row, 0.0)
        a = a - l_col * u_row  # rank-1 Schur update (masked to trailing block)
        new_col = jnp.where(rows > k, l_col, col)
        return jax.lax.dynamic_update_slice(a, new_col, (0, k))

    return body


def _lu_vmem_kernel(a_ref, o_ref, *, steps: int):
    a = a_ref[...]
    m, n = a.shape
    o_ref[...] = jax.lax.fori_loop(0, steps, _lu_body(m, n), a)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lu_vmem(a: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Whole-matrix VMEM-resident EbV LU (paper-faithful kernel).

    Fits matrices up to ~4096² fp32 in v5e VMEM; larger inputs should use the
    blocked driver in :mod:`repro.kernels.ops`.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = a.shape[-1]
    return pl.pallas_call(
        functools.partial(_lu_vmem_kernel, steps=n - 1),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a)


def _panel_kernel(p_ref, o_ref, *, steps: int):
    p = p_ref[...]
    m, b = p.shape
    o_ref[...] = jax.lax.fori_loop(0, steps, _lu_body(m, b), p)


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel(p: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Tall (m, b) panel factorization, pivots in the top b rows."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b = p.shape[-1]
    return pl.pallas_call(
        functools.partial(_panel_kernel, steps=b),
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        interpret=interpret,
    )(p)


def _fused_step_kernel(panel_ref, top_ref, trail_ref, u12_ref, new_trail_ref):
    """Per column tile: forward-substitute U12 against the unit-lower L11 of
    the packed panel, then immediately apply the rank-b update to the trailing
    rows — one VMEM round-trip for the whole bi-vector step."""
    pan = panel_ref[...]  # (m, b) packed panel (L11 top, L21 below)
    b = pan.shape[1]
    y = top_ref[...]  # (b, ct)
    rows, _ = _rows_cols(b, 1)

    def solve_body(k, y):
        lk = jnp.where(rows > k, jax.lax.dynamic_slice(pan, (0, k), (b, 1)), 0.0)
        yk = jax.lax.dynamic_slice(y, (k, 0), (1, y.shape[1]))
        return y - lk * yk

    y = jax.lax.fori_loop(0, b, solve_body, y)
    u12_ref[...] = y
    l21 = pan[b:, :]
    new_trail_ref[...] = trail_ref[...] - jnp.dot(
        l21, y, preferred_element_type=jnp.float32
    ).astype(trail_ref.dtype)


@functools.partial(jax.jit, static_argnames=("col_tile", "interpret"))
def fused_step(
    pan: jax.Array,
    a_top: jax.Array,
    a_trail: jax.Array,
    *,
    col_tile: int = 256,
    interpret: bool | None = None,
):
    """Fused bi-vector step.  ``pan``: (m, b) factored packed panel;
    ``a_top``: (b, W) A12 rows; ``a_trail``: (m-b, W) A22.
    Returns (U12, updated A22)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, b = pan.shape
    w = a_top.shape[1]
    ct = min(col_tile, w)
    assert w % ct == 0, (w, ct)
    grid = (w // ct,)
    return pl.pallas_call(
        _fused_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, b), lambda j: (0, 0)),
            pl.BlockSpec((b, ct), lambda j: (0, j)),
            pl.BlockSpec((m - b, ct), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((b, ct), lambda j: (0, j)),
            pl.BlockSpec((m - b, ct), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, w), a_top.dtype),
            jax.ShapeDtypeStruct((m - b, w), a_trail.dtype),
        ],
        interpret=interpret,
    )(pan, a_top, a_trail)


def _fused_lu_kernel(a_any, o_any, panel_buf, tile1_buf, tile2_buf, sems, *, num_steps: int, block: int):
    """One (step ``s``, program ``p``) grid point of the single-dispatch LU.

    Grid iteration on TPU is sequential with the last axis fastest, so within
    a step program 0 factorizes the panel first and every program of that step
    then consumes it from the persistent ``panel_buf`` scratch.  The matrix
    itself never moves through the pipeline: it stays in HBM (``o_any`` is
    aliased to the input) and only (N, B) column slabs are DMA'd to VMEM.

    Panel factorization and trsm are two-level blocked: sequential masked
    axpys are confined to ``C2``-wide strips and everything beyond the strip
    is retired by rank-``C2`` GEMMs — O(B/C2) instead of O(B) passes over the
    slab, which is what makes the megakernel decisively faster than the
    multi-launch driver even at equal FLOPs.
    """
    del a_any  # aliased to o_any; all traffic goes through the output ref
    s = pl.program_id(0)
    p = pl.program_id(1)
    S, B = num_steps, block
    N = S * B
    C2 = sub_block_width(B)  # shared with the pure-jnp mirror (bitwise twin)

    def copy_live_rows(buf, sem, src_cols, to_hbm):
        """DMA a column slab one (B, B) row block at a time, rows ``s*B``
        down only — rows above the current step hold final U values and
        never move."""

        def blk_copy(r, _):
            hbm = o_any.at[pl.ds(r * B, B), pl.ds(src_cols, B)]
            vmem = buf.at[pl.ds(r * B, B), :]
            dma = pltpu.make_async_copy(*((vmem, hbm) if to_hbm else (hbm, vmem)), sem)
            dma.start()
            dma.wait()
            return 0

        jax.lax.fori_loop(s, S, blk_copy, 0)

    @pl.when(p == 0)
    def _factor_panel():
        copy_live_rows(panel_buf, sems.at[0], s * B, to_hbm=False)
        base = s * B

        # All sequential recurrences run on small array carries through the
        # shared core.blocked strip helpers (the pure-jnp mirror traces the
        # same jaxprs — bitwise equality by construction) and write scratch
        # back once per strip: interpret-mode ref writes copy the whole
        # scratch buffer, and on TPU fewer, larger stores pipeline better.
        for j in range(0, B, C2):
            # (1) bi-vectorized factorization of the diagonal-block strip
            diag = factor_diag_strip(panel_buf[pl.ds(base, B), pl.ds(j, C2)], j)
            panel_buf[pl.ds(base, B), pl.ds(j, C2)] = diag

            # (2) unit-lower trsm: U rows of the strip vs the remaining cols
            w = B - j - C2
            if w:
                u = strip_trsm(diag[j : j + C2, :], panel_buf[pl.ds(base + j, C2), pl.ds(j + C2, w)])
                panel_buf[pl.ds(base + j, C2), pl.ds(j + C2, w)] = u
                lpart = diag[j + C2 :, :]
                blk = panel_buf[pl.ds(base + j + C2, w), pl.ds(j + C2, w)]
                panel_buf[pl.ds(base + j + C2, w), pl.ds(j + C2, w)] = (
                    blk - jnp.dot(lpart, u, preferred_element_type=jnp.float32)
                ).astype(blk.dtype)

            # (3) row blocks below: multipliers via right-solve against the
            # factored strip, then the rank-C2 GEMM retirement
            def rblk(r, _):
                off = r * B
                strip = solve_below_strip(diag, panel_buf[pl.ds(off, B), pl.ds(j, C2)], j)
                panel_buf[pl.ds(off, B), pl.ds(j, C2)] = strip
                if w:
                    blkr = panel_buf[pl.ds(off, B), pl.ds(j + C2, w)]
                    panel_buf[pl.ds(off, B), pl.ds(j + C2, w)] = (
                        blkr - jnp.dot(strip, u, preferred_element_type=jnp.float32)
                    ).astype(blkr.dtype)
                return 0

            jax.lax.fori_loop(s + 1, S, rblk, 0)
        copy_live_rows(panel_buf, sems.at[0], s * B, to_hbm=True)

    if S == 1:
        return  # no trailing tiles — the panel was the whole matrix

    # Equalized fold (paper eq. 7 at tile granularity): program p owns the
    # long-lived tile p+1 and the short-lived tile S-1-p; their lifetime work
    # sums to the constant S (see core.ebv.equalized_tile_schedule).
    t1 = p + 1
    t2 = (S - 1) - p
    act1 = t1 > s
    act2 = jnp.logical_and(t2 > s, t2 != t1)

    def tile_load(tbuf, sem, t):
        return pltpu.make_async_copy(o_any.at[:, pl.ds(t * B, B)], tbuf, sem)

    # Double buffering: both owned tiles start streaming in before the first
    # is consumed, so tile t2's HBM→VMEM load overlaps tile t1's update.
    @pl.when(act1)
    def _():
        tile_load(tile1_buf, sems.at[1], t1).start()

    @pl.when(act2)
    def _():
        tile_load(tile2_buf, sems.at[2], t2).start()

    def process(tbuf, sem, t):
        tile_load(tbuf, sem, t).wait()
        base = s * B

        # Unit-lower trsm of the U12 tile, two-level: per C2-strip a short
        # sequential axpy solve, then one rank-C2 GEMM retires the strip —
        # all on a (B, B) array carry, written back to scratch once.
        y = tbuf[pl.ds(base, B), :]
        for j in range(0, B, C2):
            ldiag = panel_buf[pl.ds(base + j, C2), pl.ds(j, C2)]
            strip = strip_trsm(ldiag, y[j : j + C2, :])
            y = jax.lax.dynamic_update_slice(y, strip, (j, 0))
            w = B - j - C2
            if w:
                lpart = panel_buf[pl.ds(base + j + C2, w), pl.ds(j, C2)]
                tail = (
                    y[j + C2 :, :] - jnp.dot(lpart, strip, preferred_element_type=jnp.float32)
                ).astype(y.dtype)
                y = jax.lax.dynamic_update_slice(y, tail, (j + C2, 0))
        tbuf[pl.ds(base, B), :] = y  # U12 tile

        def row_body(r, _):
            off = r * B
            blk = tbuf[pl.ds(off, B), :]
            lblk = panel_buf[pl.ds(off, B), :]  # L21 row block of this step
            tbuf[pl.ds(off, B), :] = blk - jnp.dot(
                lblk, y, preferred_element_type=jnp.float32
            ).astype(blk.dtype)
            return 0

        jax.lax.fori_loop(s + 1, S, row_body, 0)
        # Writeback moves live rows only — rows above s*B are final U values
        # the kernel never touched (the load stays one full-slab async copy
        # so the second owned tile's stream can overlap the first's update).
        copy_live_rows(tbuf, sem, t * B, to_hbm=True)

    @pl.when(act1)
    def _():
        process(tile1_buf, sems.at[1], t1)

    @pl.when(act2)
    def _():
        process(tile2_buf, sems.at[2], t2)


def _fused_vmem_lu_kernel(a_ref, o_ref, *, num_steps: int, block: int):
    """Small-n fused LU: the padded matrix is one VMEM block and the kernel
    runs the mirror's exact value-level step sequence — no DMA, no scratch
    refs, still one ``pallas_call`` (and still bitwise-equal to the mirror
    by construction)."""
    o_ref[...] = fused_lu_steps(a_ref[...], block=block, num_steps=num_steps)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def lu_fused(a: jax.Array, *, block: int = 256, interpret: bool | None = None) -> jax.Array:
    """Single-dispatch blocked EbV LU: the whole factorization in ONE
    ``pallas_call``.

    The matrix is padded to a multiple of ``block`` with an identity tail
    (inert under no-pivot elimination), kept in HBM for the whole kernel and
    mutated in place through ``input_output_aliases`` — no functional
    ``a.at[...].set`` copies and no per-block-column dispatches remain.
    VMEM footprint is 3·N·B floats (one panel slab + two double-buffered tile
    slabs), independent of the matrix being square-resident.

    Padded orders ≤ ``_FUSED_VMEM_MAX_N`` skip the HBM streaming entirely and
    run the same step sequence on a VMEM-resident value — the small-n fast
    path (see ``_fused_vmem_lu_kernel``).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = a.shape[-1]
    if a.dtype not in (jnp.float32, jnp.bfloat16):
        raise TypeError(f"lu_fused supports float32/bfloat16 only, got {a.dtype}")
    B = fused_block_size(n, block)  # padding- and VMEM-aware; mirror uses it too
    S = -(-n // B)
    N = S * B
    a = pad_identity_tail(a, N)
    if N <= _FUSED_VMEM_MAX_N:
        out = pl.pallas_call(
            functools.partial(_fused_vmem_lu_kernel, num_steps=S, block=B),
            out_shape=jax.ShapeDtypeStruct((N, N), a.dtype),
            input_output_aliases={0: 0},  # carried in place, like the HBM path
            interpret=interpret,
        )(a)
        return out[:n, :n] if N != n else out
    num_programs = max(1, S // 2)
    out = pl.pallas_call(
        functools.partial(_fused_lu_kernel, num_steps=S, block=B),
        grid=(S, num_programs),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((N, N), a.dtype),
        scratch_shapes=[
            pltpu.VMEM((N, B), a.dtype),
            pltpu.VMEM((N, B), a.dtype),
            pltpu.VMEM((N, B), a.dtype),
            pltpu.SemaphoreType.DMA((3,)),
        ],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(a)
    return out[:n, :n] if N != n else out


def _update_kernel(l_ref, u_ref, c_ref, o_ref):
    o_ref[...] = c_ref[...] - jnp.dot(
        l_ref[...], u_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("row_tile", "col_tile", "interpret"))
def update(
    l21: jax.Array,
    u12: jax.Array,
    a22: jax.Array,
    *,
    row_tile: int = 256,
    col_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Rank-k trailing update ``A22 − L21 @ U12`` on a 2-D tile grid (for
    trailing blocks too tall for :func:`fused_step`)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, b = l21.shape
    w = u12.shape[1]
    rt, ct = min(row_tile, m), min(col_tile, w)
    assert m % rt == 0 and w % ct == 0, (m, rt, w, ct)
    return pl.pallas_call(
        _update_kernel,
        grid=(m // rt, w // ct),
        in_specs=[
            pl.BlockSpec((rt, b), lambda i, j: (i, 0)),
            pl.BlockSpec((b, ct), lambda i, j: (0, j)),
            pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, w), a22.dtype),
        interpret=interpret,
    )(l21, u12, a22)
