"""Pallas TPU kernels for EbV LU factorization.

Three kernels, mirroring DESIGN.md §2's GPU→TPU adaptation:

* :func:`lu_vmem`       — paper-faithful bi-vectorized LU with the whole
                          matrix VMEM-resident; every ``fori_loop`` step is a
                          fixed-shape masked rank-1 update (equal work/step).
* :func:`panel`         — tall (m, b) panel factorization (the unblocked
                          bi-vectorized steps confined to a VMEM panel).
* :func:`fused_step`    — the *fused bi-vector step*: unit-lower trsm
                          (U-row block) and the rank-b trailing update in a
                          single VMEM pass, grid over column tiles.
* :func:`update`        — standalone rank-k update GEMM (2-D tile grid) for
                          trailing blocks too tall for the fused kernel.

All kernels run under ``interpret=True`` on CPU (how we validate here) and
lower to Mosaic on real TPUs.  MXU alignment: tile sizes default to multiples
of 128; iotas are 2-D (TPU requirement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lu_vmem", "panel", "fused_step", "update"]


def _rows_cols(m: int, n: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    return rows, cols


def _lu_body(m: int, n: int):
    """Shared bi-vectorized elimination step on a VMEM-resident value."""
    rows, cols = _rows_cols(m, n)

    def body(k, a):
        pivot = jax.lax.dynamic_slice(a, (k, k), (1, 1))
        col = jax.lax.dynamic_slice(a, (0, k), (m, 1))
        row = jax.lax.dynamic_slice(a, (k, 0), (1, n))
        l_col = jnp.where(rows > k, col / pivot, 0.0)
        u_row = jnp.where(cols > k, row, 0.0)
        a = a - l_col * u_row  # rank-1 Schur update (masked to trailing block)
        new_col = jnp.where(rows > k, l_col, col)
        return jax.lax.dynamic_update_slice(a, new_col, (0, k))

    return body


def _lu_vmem_kernel(a_ref, o_ref, *, steps: int):
    a = a_ref[...]
    m, n = a.shape
    o_ref[...] = jax.lax.fori_loop(0, steps, _lu_body(m, n), a)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lu_vmem(a: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Whole-matrix VMEM-resident EbV LU (paper-faithful kernel).

    Fits matrices up to ~4096² fp32 in v5e VMEM; larger inputs should use the
    blocked driver in :mod:`repro.kernels.ops`.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = a.shape[-1]
    return pl.pallas_call(
        functools.partial(_lu_vmem_kernel, steps=n - 1),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a)


def _panel_kernel(p_ref, o_ref, *, steps: int):
    p = p_ref[...]
    m, b = p.shape
    o_ref[...] = jax.lax.fori_loop(0, steps, _lu_body(m, b), p)


@functools.partial(jax.jit, static_argnames=("interpret",))
def panel(p: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Tall (m, b) panel factorization, pivots in the top b rows."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b = p.shape[-1]
    return pl.pallas_call(
        functools.partial(_panel_kernel, steps=b),
        out_shape=jax.ShapeDtypeStruct(p.shape, p.dtype),
        interpret=interpret,
    )(p)


def _fused_step_kernel(panel_ref, top_ref, trail_ref, u12_ref, new_trail_ref):
    """Per column tile: forward-substitute U12 against the unit-lower L11 of
    the packed panel, then immediately apply the rank-b update to the trailing
    rows — one VMEM round-trip for the whole bi-vector step."""
    pan = panel_ref[...]  # (m, b) packed panel (L11 top, L21 below)
    b = pan.shape[1]
    y = top_ref[...]  # (b, ct)
    rows, _ = _rows_cols(b, 1)

    def solve_body(k, y):
        lk = jnp.where(rows > k, jax.lax.dynamic_slice(pan, (0, k), (b, 1)), 0.0)
        yk = jax.lax.dynamic_slice(y, (k, 0), (1, y.shape[1]))
        return y - lk * yk

    y = jax.lax.fori_loop(0, b, solve_body, y)
    u12_ref[...] = y
    l21 = pan[b:, :]
    new_trail_ref[...] = trail_ref[...] - jnp.dot(
        l21, y, preferred_element_type=jnp.float32
    ).astype(trail_ref.dtype)


@functools.partial(jax.jit, static_argnames=("col_tile", "interpret"))
def fused_step(
    pan: jax.Array,
    a_top: jax.Array,
    a_trail: jax.Array,
    *,
    col_tile: int = 256,
    interpret: bool | None = None,
):
    """Fused bi-vector step.  ``pan``: (m, b) factored packed panel;
    ``a_top``: (b, W) A12 rows; ``a_trail``: (m-b, W) A22.
    Returns (U12, updated A22)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, b = pan.shape
    w = a_top.shape[1]
    ct = min(col_tile, w)
    assert w % ct == 0, (w, ct)
    grid = (w // ct,)
    return pl.pallas_call(
        _fused_step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, b), lambda j: (0, 0)),
            pl.BlockSpec((b, ct), lambda j: (0, j)),
            pl.BlockSpec((m - b, ct), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((b, ct), lambda j: (0, j)),
            pl.BlockSpec((m - b, ct), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, w), a_top.dtype),
            jax.ShapeDtypeStruct((m - b, w), a_trail.dtype),
        ],
        interpret=interpret,
    )(pan, a_top, a_trail)


def _update_kernel(l_ref, u_ref, c_ref, o_ref):
    o_ref[...] = c_ref[...] - jnp.dot(
        l_ref[...], u_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("row_tile", "col_tile", "interpret"))
def update(
    l21: jax.Array,
    u12: jax.Array,
    a22: jax.Array,
    *,
    row_tile: int = 256,
    col_tile: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Rank-k trailing update ``A22 − L21 @ U12`` on a 2-D tile grid (for
    trailing blocks too tall for :func:`fused_step`)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, b = l21.shape
    w = u12.shape[1]
    rt, ct = min(row_tile, m), min(col_tile, w)
    assert m % rt == 0 and w % ct == 0, (m, rt, w, ct)
    return pl.pallas_call(
        _update_kernel,
        grid=(m // rt, w // ct),
        in_specs=[
            pl.BlockSpec((rt, b), lambda i, j: (i, 0)),
            pl.BlockSpec((b, ct), lambda i, j: (0, j)),
            pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rt, ct), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, w), a22.dtype),
        interpret=interpret,
    )(l21, u12, a22)
