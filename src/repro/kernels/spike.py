"""Sharded SPIKE entry: per-partition Pallas local work under ``shard_map``.

The multi-device realization of :mod:`repro.core.spike`: the stacked
per-partition operands (leading ``devices`` axis) are laid over a mesh axis
with ``shard_map``, each device runs the existing single-dispatch Pallas
megakernels locally — :func:`repro.kernels.banded.banded_lu_blocked` for the
block factor, :func:`repro.kernels.banded.banded_solve_kernelized` for the
spike/``g`` solves — and everything *around* the local work (partitioning,
coupling extraction, reduced-system assembly and tip solve, recovery) is the
exact shared code from :mod:`repro.core.spike`.  Kernel-vs-mirror bitwise
equality therefore reduces to the established per-partition kernel/mirror
twin contract: same shapes, same blocked schedule, same window jaxprs.

Communication pattern per solve: the local ``g`` solves run embarrassingly
parallel, the ``2·d·bw``-row tips gather once for the reduced solve (the
only cross-device traffic — O(d·bw·k) floats), and the recovery GEMMs are
local again.
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import spike as core_spike
from repro.dist.sharding import shard_map

from . import banded as kbanded

__all__ = [
    "spike_lu_sharded",
    "spike_solve_sharded",
    "spike_linear_solve_sharded",
]


# The jitted shard_map entries are cached per (mesh, axis, kernel params):
# defining the local fn inside each public call would hand jax.jit a fresh
# function object every time, so every solve would re-trace and re-compile
# (~30x the actual substitution cost at the bench shape).  jax.jit still
# specializes per operand shape underneath each cached entry.
@functools.lru_cache(maxsize=None)
def _factor_entry(mesh, axis: str, bw: int, block: int | None,
                  interpret: bool | None):
    def local_fn(p, r):
        p = p[0] if p.ndim == 3 else p
        r = r[0] if r.ndim == 3 else r
        lu = kbanded.banded_lu_blocked(p, bw=bw, block=block, interpret=interpret)
        wv = kbanded.banded_solve_kernelized(
            lu, r, bw=bw, block=block, interpret=interpret
        )
        return lu[None], wv[None]

    return jax.jit(
        shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(P(axis, None, None), P(axis, None, None)),
            out_specs=(P(axis, None, None), P(axis, None, None)),
            check_vma=False,
        )
    )


@functools.lru_cache(maxsize=None)
def _solve_entry(mesh, axis: str, bw: int, block: int | None,
                 interpret: bool | None):
    def local_fn(lu, fj):
        lu = lu[0] if lu.ndim == 3 else lu
        fj = fj[0] if fj.ndim == 3 else fj
        g = kbanded.banded_solve_kernelized(
            lu, fj, bw=bw, block=block, interpret=interpret
        )
        return g[None]

    return jax.jit(
        shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(P(axis, None, None), P(axis, None, None)),
            out_specs=P(axis, None, None),
            check_vma=False,
        )
    )


def spike_lu_sharded(
    arow: jax.Array,
    *,
    bw: int,
    mesh,
    axis: str = "model",
    block: int | None = None,
    interpret: bool | None = None,
) -> core_spike.SpikeFactors:
    """SPIKE factorization with the per-partition factor + spike solve
    sharded over ``mesh.shape[axis]`` devices.  Returns the same
    :class:`repro.core.spike.SpikeFactors` artifact as the mirror."""
    devices = mesh.shape[axis]
    parts, rhs, _m = core_spike.partition_band(arow, bw=bw, devices=devices)
    fn = _factor_entry(mesh, axis, bw, block, interpret)
    local_lu, wv = fn(parts, rhs)
    # canonicalize placement before the shared eager tail: the recovery and
    # assembly ops lower differently over mesh-sharded operands than over
    # single-device ones, which would break the kernel≡mirror bitwise
    # contract.  The solve entry re-shards ``local_lu`` through its own
    # in_specs, so nothing is lost (a real accelerator mesh would instead
    # keep the recovery under shard_map and relax the placement).
    local_lu, wv = jax.device_put((local_lu, wv), jax.devices()[0])
    return core_spike.assemble_spike_factors(
        local_lu, wv, n=arow.shape[0], bw=bw, devices=devices
    )


def spike_solve_sharded(
    factors: core_spike.SpikeFactors,
    b: jax.Array,
    *,
    mesh,
    axis: str = "model",
    block: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """SPIKE substitution with the local ``g`` solves sharded over the mesh;
    the reduced tip solve and recovery run on the gathered result via the
    shared :mod:`repro.core.spike` tail."""
    f, squeeze = core_spike._solve_rhs_parts(factors, b)
    bw = factors.bw
    fn = _solve_entry(mesh, axis, bw, block, interpret)
    sharded = NamedSharding(mesh, P(axis, None, None))
    g = fn(
        jax.device_put(factors.local_lu, sharded), jax.device_put(f, sharded)
    )
    # same placement canonicalization as the factor entry: the shared
    # reduced-solve/recovery tail must see single-device operands to stay
    # bitwise with the mirror.
    g = jax.device_put(g, jax.devices()[0])
    return core_spike._finish_solve(factors, g, squeeze)


def spike_linear_solve_sharded(
    arow: jax.Array,
    b: jax.Array,
    *,
    bw: int,
    mesh,
    axis: str = "model",
    block: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Factor + solve through the sharded path."""
    factors = spike_lu_sharded(
        arow, bw=bw, mesh=mesh, axis=axis, block=block, interpret=interpret
    )
    return spike_solve_sharded(
        factors, b, mesh=mesh, axis=axis, block=block, interpret=interpret
    )
