"""HLO-text analysis for the roofline: collective-op byte accounting.

``cost_analysis()`` has no collective-bytes entry, so we parse the compiled
HLO module text.  Optimized HLO references operands by name (no inline
shapes), so per-op bytes are derived from the *result* shape + the replica
group size ``g``:

    op                  operand bytes        wire bytes/device (ring)
    all-gather          result / g           result · (g−1)/g
    reduce-scatter      result · g           result · (g−1)   [operand=result·g]
    all-reduce          result               2 · result · (g−1)/g
    all-to-all          result               result · (g−1)/g
    collective-permute  result               result

"operand bytes" is the paper-brief accounting (sum of operand sizes);
"wire bytes" is the per-device transported estimate used for the roofline
collective term.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_SPLIT_RE = re.compile(r"\s(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(tail: str, num_devices: int) -> int:
    m = _GROUPS_ITOTA_RE.search(tail)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(tail)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return num_devices


_COMP_NAME_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")


def computation_multipliers(hlo_text: str) -> tuple[dict, dict]:
    """Execution count of each HLO computation, derived from while
    ``known_trip_count`` annotations (scan bodies execute trip-count times —
    XLA's static cost analysis counts them once).

    Computation headers sit at column 0 and end with '{'; instructions are
    indented.  Returns (multiplier per computation name, lines per comp)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        if not raw.strip():
            continue
        s = raw.strip()
        if not raw[0].isspace():
            if s.rstrip().endswith("{"):
                m = _COMP_NAME_RE.match(s)
                if m and m.group(2) != "HloModule":
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
                continue
            if s == "}":
                cur = None
                continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)

    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for cname, lines in comps.items():
        for l in lines:
            n = 1
            tm = _TRIP_RE.search(l)
            if " while(" in l and tm:
                n = int(tm.group(1))
            for rex in (_BODY_RE, _COND_RE, _CALLS_RE):
                for target in rex.findall(l):
                    edges[cname].append((target, n))

    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        entry = next(iter(comps), None)
    if entry is not None:
        mult[entry] = 1.0
        # relax in passes (call graph is a DAG; few levels deep)
        for _ in range(32):
            changed = False
            new = defaultdict(float)
            new[entry] = 1.0
            for parent, targets in edges.items():
                for child, n in targets:
                    new[child] += mult[parent] * n
            if dict(new) != dict(mult):
                mult = new
                changed = True
            if not changed:
                break
    return dict(mult), comps


def collective_bytes(hlo_text: str, *, num_devices: int = 1, weighted: bool = True) -> dict:
    """Per-collective-kind operand bytes + per-device wire-byte estimate.
    With ``weighted=True`` each op is multiplied by its computation's
    execution count (scan trip counts)."""
    operand: dict = defaultdict(float)
    wire: dict = defaultdict(float)
    counts: dict = defaultdict(float)
    if weighted:
        mult, comps = computation_multipliers(hlo_text)
        items = [(l, mult.get(c, 1.0)) for c, lines in comps.items() for l in lines]
    else:
        items = [(l.strip(), 1.0) for l in hlo_text.splitlines()]
    for stripped, weight in items:
        if "=" not in stripped or "-done(" in stripped:
            continue
        m = _OP_SPLIT_RE.search(stripped)
        if m is None:
            continue
        kind = m.group(1)
        left, tail = stripped[: m.start()], stripped[m.end() :]
        result = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(left))
        if result == 0:
            continue
        g = max(_group_size(tail, num_devices), 1)
        if kind == "all-gather":
            op_b, wire_b = result / g, result * (g - 1) / g
        elif kind == "reduce-scatter":
            op_b, wire_b = result * g, result * (g - 1)
        elif kind == "all-reduce":
            op_b, wire_b = result, 2 * result * (g - 1) / g
        elif kind == "all-to-all":
            op_b, wire_b = result, result * (g - 1) / g
        else:  # collective-permute
            op_b, wire_b = result, float(result)
        operand[kind] += op_b * weight
        wire[kind] += wire_b * weight
        counts[kind] += weight
    return {
        "operand_bytes": {k: round(v) for k, v in operand.items()},
        "wire_bytes": {k: round(v) for k, v in wire.items()},
        "counts": {k: round(v) for k, v in counts.items()},
        "total": round(sum(operand.values())),
        "total_wire": round(sum(wire.values())),
    }


def op_histogram(hlo_text: str, ops=("fusion", "custom-call", "while", "dot", "convolution")) -> dict:
    hist = defaultdict(int)
    for line in hlo_text.splitlines():
        for op in ops:
            if f" {op}(" in line:
                hist[op] += 1
    return dict(hist)


def primitive_count(jaxpr, name: str) -> int:
    """Count occurrences of primitive ``name`` in a (closed) jaxpr, recursing
    into sub-jaxprs (cond/scan/while/pjit bodies).  Used to assert dispatch
    counts — e.g. the single-dispatch LU driver must trace to exactly one
    ``pallas_call``."""
    from jax.core import Jaxpr, ClosedJaxpr  # local: keep module import-light

    if isinstance(jaxpr, ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    count = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            count += 1
        for v in eqn.params.values():
            for sub in v if isinstance(v, (list, tuple)) else (v,):
                if isinstance(sub, (Jaxpr, ClosedJaxpr)):
                    count += primitive_count(sub, name)
    return count


def cost_analysis_dict(compiled) -> dict:
    """jax-version-portable ``Compiled.cost_analysis()``: newer jax returns a
    flat dict, older releases a one-element list of dicts (per device
    assignment).  Always returns the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
