"""Shared utilities."""
