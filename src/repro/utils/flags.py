"""Process-local analysis flags.

``analysis_unroll()``: during roofline analysis the dry-run lowers reduced-
depth variants with every ``lax.scan`` fully unrolled, so XLA's static
``cost_analysis`` (which counts while bodies once) becomes exact; totals for
the real depth are recovered by linear two-point extrapolation
(EXPERIMENTS.md §Roofline).  Production lowering keeps rolled loops.
"""
from __future__ import annotations

import contextlib
import threading


class _Flags(threading.local):
    unroll_scans: bool = False


_FLAGS = _Flags()


def scan_unroll():
    """Value for lax.scan's ``unroll=``."""
    return True if _FLAGS.unroll_scans else 1


@contextlib.contextmanager
def analysis_unroll(on: bool = True):
    prev = _FLAGS.unroll_scans
    _FLAGS.unroll_scans = on
    try:
        yield
    finally:
        _FLAGS.unroll_scans = prev
