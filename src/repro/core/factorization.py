"""First-class ``Factorization`` artifact: packed factors + solve-ready
enrichments computed once at factor time.

The EbV paper's payoff lives in the solve phase, and the solve phase is
exactly where re-deriving state per call hurts: every
``banded_solve_kernelized`` dispatch used to re-skew the band into the
window-aligned layout, and every blocked sweep re-ran the sequential
``strip_trsm``/``strip_utrsm`` recurrences against the same diagonal
blocks.  Following the block-inversion structure of Chen, Liu & Yang
("Parallel Triangular Solvers on GPU", arXiv 1606.00541) and the
carry-solve-metadata-with-the-factors design of Li, Serban & Negrut
(arXiv 1509.07919), this module makes the factorization an *artifact*:

* ``packed``      — the legacy packed-LU layout (dense ``(…, n, n)`` or
                    row-aligned band ``(…, n, 2bw+1)``), unchanged, so
                    every pre-artifact consumer keeps working;
* ``linv``/``uinv`` — the **pre-inverted diagonal blocks**: for every
                    solve block the unit-lower and upper in-block windows
                    are inverted at factor time (one batched triangular
                    solve against the identity), so each solve sweep
                    becomes batched GEMM against the stored inverses — no
                    sequential recurrence remains on the solve path;
* ``tlo``/``tup`` — the **pre-coupled transfer blocks**
                    ``L^{-1}_i F_i^{above}`` / ``U^{-1}_i F_i^{below}``
                    (banded only): the skewed-band coupling columns
                    (:func:`repro.core.banded.band_to_skewed`), derived
                    once and already multiplied through the inverses, so
                    the solve never touches the band layout again and its
                    only sequential dependence is a ``bw``-row tail/head
                    recurrence resolved by associative scan;
* ``health``      — the embedded :class:`~repro.core.health.FactorHealth`
                    record, so cached artifacts are never re-screened;
* ``tier``/``fingerprint`` — accuracy-tier and cache-identity metadata
                    for the serving layer.

The artifact is a registered pytree (it crosses ``jit``/``vmap``
boundaries) and quacks like the packed array it wraps (``shape`` /
``ndim`` / ``dtype`` / ``__jax_array__``) — the one-release shim that
lets artifact and raw-ndarray call sites coexist.

Bitwise kernel≡mirror contract: the inverses are computed ONCE here (pure
jnp) and handed to both the Pallas kernels and the pure-jnp mirrors as
plain arrays; both sides then apply them through the *shared* sweep
helpers below (:func:`inverted_dense_sweeps` /
:func:`inverted_band_sweeps`), so the twins trace identical jaxprs and
stay bitwise-identical by construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .banded import band_block_size, band_to_skewed, pad_band_identity
from .blocked import pad_identity_tail
from .health import FactorHealth

__all__ = [
    "Factorization",
    "dense_block_inverses",
    "banded_block_inverses",
    "banded_skewed_layout",
    "inverted_dense_sweeps",
    "inverted_band_sweeps",
    "dense_inverted_solve",
    "banded_inverted_solve",
    "equalized_rhs_tile",
    "factorize_dense",
    "factorize_banded",
    "dense_artifact",
    "banded_artifact",
    "packed_of",
]


# ---------------------------------------------------------------------------
# factor-time enrichment: pre-inverted diagonal blocks
# ---------------------------------------------------------------------------
def _packed_block_inverses(diags: jax.Array) -> tuple[jax.Array, jax.Array]:
    """``L^{-1}`` / ``U^{-1}`` of a ``(S, B, B)`` stack of *packed* diagonal
    blocks (unit-lower L strictly below the diagonal, U on and above it) via
    batched triangular solves against the identity.  Entries outside each
    factor's triangle are ignored by construction, so the packed layout needs
    no unpacking.  This runs ONCE at factor time; the solve path then only
    ever GEMMs against the results."""
    s, b = diags.shape[0], diags.shape[1]
    eye = jnp.broadcast_to(jnp.eye(b, dtype=diags.dtype), (s, b, b))
    linv = jax.lax.linalg.triangular_solve(
        diags, eye, left_side=True, lower=True, unit_diagonal=True
    )
    uinv = jax.lax.linalg.triangular_solve(
        diags, eye, left_side=True, lower=False, unit_diagonal=False
    )
    return linv, uinv


def dense_block_inverses(lu: jax.Array, *, block: int) -> tuple[jax.Array, jax.Array]:
    """``(S, B, B)`` ``L^{-1}`` / ``U^{-1}`` stacks for the padded packed LU's
    diagonal blocks, computed once at factor time."""
    n = lu.shape[-1]
    b = min(block, n)
    s = -(-n // b)
    lup = pad_identity_tail(lu, s * b)
    diags = jax.vmap(
        lambda i: jax.lax.dynamic_slice(lup, (i * b, i * b), (b, b))
    )(jnp.arange(s))
    return _packed_block_inverses(diags)


def banded_skewed_layout(lu_band: jax.Array, *, bw: int, block: int | None = None):
    """Solve-layout skewed band ``G`` ``(S·C, C+2bw)`` of the packed band
    factors (the layout :func:`repro.core.banded.banded_solve_blocked`
    derives per call), plus its ``(C, S)`` blocking.  Derived ONCE at factor
    time and carried in the artifact."""
    n = lu_band.shape[-2]
    c = band_block_size(n, bw, block)
    s = -(-n // c)
    g = band_to_skewed(pad_band_identity(lu_band, bw, s * c), bw, c)
    return g, c, s


def banded_block_inverses(
    g: jax.Array, *, bw: int, block: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Banded solve enrichment from the skewed band ``G``: the in-window
    ``(S, C, C)`` ``L^{-1}`` / ``U^{-1}`` stacks plus the **pre-coupled**
    transfer blocks

    * ``tlo[i] = L^{-1}_i · F_i[:, :bw]``      (couples to the block above),
    * ``tup[i] = U^{-1}_i · F_i[:, bw+C:]``    (couples to the block below),

    each ``(S, C, bw)``.  With the coupling folded in at factor time the
    solve's only sequential dependence is the ``bw``-row tail/head
    recurrence (:func:`inverted_band_sweeps`) — everything else is one
    batched GEMM per sweep."""
    c = block
    gw = c + 2 * bw
    s = g.shape[-2] // c
    f = g.reshape(s, c, gw)
    linv, uinv = _packed_block_inverses(f[:, :, bw : bw + c])
    tlo = jnp.matmul(linv, f[:, :, :bw], preferred_element_type=jnp.float32).astype(g.dtype)
    tup = jnp.matmul(uinv, f[:, :, bw + c :], preferred_element_type=jnp.float32).astype(g.dtype)
    return linv, uinv, tlo, tup


# ---------------------------------------------------------------------------
# shared inverted-diagonal solve sweeps (kernel/mirror bitwise twins)
# ---------------------------------------------------------------------------
def inverted_dense_sweeps(read_tile, read_linv, read_uinv, x, *, num_steps: int, block: int):
    """Blocked forward+backward substitution where every diagonal step is one
    GEMM against the pre-inverted block — no ``strip_trsm`` recurrence on the
    solve path.  ``read_tile(r, i)`` yields the ``(B, B)`` factor tile,
    ``read_linv(i)`` / ``read_uinv(i)`` the stored inverses (DMA'd copies or
    value slices — both exact, so the bitwise mirror contract holds)."""
    s, b = num_steps, block
    rt = x.shape[1]

    def fwd(i, x):
        yi = jnp.dot(
            read_linv(i), jax.lax.dynamic_slice(x, (i * b, 0), (b, rt)),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, yi, (i * b, 0))

        def off(r, x):
            blk = jax.lax.dynamic_slice(x, (r * b, 0), (b, rt)) - jnp.dot(
                read_tile(r, i), yi, preferred_element_type=jnp.float32
            ).astype(x.dtype)
            return jax.lax.dynamic_update_slice(x, blk, (r * b, 0))

        return jax.lax.fori_loop(i + 1, s, off, x)

    x = jax.lax.fori_loop(0, s, fwd, x)

    def bwd(jj, x):
        i = s - 1 - jj
        xi = jnp.dot(
            read_uinv(i), jax.lax.dynamic_slice(x, (i * b, 0), (b, rt)),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, xi, (i * b, 0))

        def off(r, x):
            blk = jax.lax.dynamic_slice(x, (r * b, 0), (b, rt)) - jnp.dot(
                read_tile(r, i), xi, preferred_element_type=jnp.float32
            ).astype(x.dtype)
            return jax.lax.dynamic_update_slice(x, blk, (r * b, 0))

        return jax.lax.fori_loop(0, i, off, x)

    return jax.lax.fori_loop(0, s, bwd, x)


def _affine_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """All states ``y_i`` of the affine recurrence ``y_i = a_i @ y_{i-1} + b_i``
    (``y_{-1} = 0``) over a ``(S, k, k)`` / ``(S, k, m)`` stack, via
    associative composition of the affine maps — ``O(log S)`` batched GEMM
    levels instead of ``S`` sequential steps."""

    def combine(lo, hi):
        a_lo, b_lo = lo
        a_hi, b_hi = hi
        return (
            jnp.matmul(a_hi, a_lo, preferred_element_type=jnp.float32).astype(a_lo.dtype),
            jnp.matmul(a_hi, b_lo, preferred_element_type=jnp.float32).astype(b_lo.dtype)
            + b_hi,
        )

    return jax.lax.associative_scan(combine, (a, b), axis=0)[1]


def inverted_band_sweeps(
    linv: jax.Array, uinv: jax.Array, tlo: jax.Array, tup: jax.Array,
    xb: jax.Array, *, bw: int,
) -> jax.Array:
    """Two-phase banded substitution on pre-inverted factors.  ``xb`` is the
    RHS reshaped to solve blocks ``(S, C, m)``.

    Forward sweep ``L y = x``: the per-block solution is
    ``y_i = L^{-1}_i x_i − tlo_i · ytail_{i-1}`` where ``ytail`` is the last
    ``bw`` rows of the previous block — so phase 1 is ONE batched GEMM
    (``z = linv @ xb``), phase 2 resolves the tiny ``(bw, m)`` tail
    recurrence ``ytail_i = ztail_i − tlo^{tail}_i ytail_{i-1}`` with an
    associative scan, and phase 3 recovers every block with a second batched
    GEMM.  The backward sweep mirrors this on the first-``bw``-row heads.
    No sequential full-block recurrence remains anywhere on the solve path —
    this is the equal-contribution GEMM formulation of arXiv 1606.00541 with
    the SPIKE-style reduced tail system of arXiv 1509.07919."""
    s, c = linv.shape[0], linv.shape[1]
    m = xb.shape[-1]
    zero = jnp.zeros((1, bw, m), xb.dtype)

    z = jnp.matmul(linv, xb, preferred_element_type=jnp.float32).astype(xb.dtype)
    ytail = _affine_scan(-tlo[:, c - bw :, :], z[:, c - bw :, :])
    prev = jnp.concatenate([zero, ytail[:-1]], axis=0)
    y = z - jnp.matmul(tlo, prev, preferred_element_type=jnp.float32).astype(xb.dtype)

    w = jnp.matmul(uinv, y, preferred_element_type=jnp.float32).astype(xb.dtype)
    xhead = jnp.flip(
        _affine_scan(-jnp.flip(tup[:, :bw, :], 0), jnp.flip(w[:, :bw, :], 0)), 0
    )
    nxt = jnp.concatenate([xhead[1:], zero], axis=0)
    return w - jnp.matmul(tup, nxt, preferred_element_type=jnp.float32).astype(xb.dtype)


def equalized_rhs_tile(m: int, rhs_tile: int) -> int:
    """Equalized RHS tile width for stacked-RHS dispatches: instead of the
    legacy pad-to-``rhs_tile``-multiple (whose last tile is mostly padding),
    split the ``m`` columns into ``ceil(m / rhs_tile)`` *equal-width* tiles
    rounded up to a lane-friendly multiple of 8 — the paper's equalization
    idea applied to the solve grid."""
    tiles = max(1, -(-m // rhs_tile))
    rt = -(-m // tiles)
    if rt > 8:
        rt = -(-rt // 8) * 8
    return rt


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True, eq=False)
class Factorization:
    """Packed factors + solve-ready enrichments (see module docstring).

    Children (pytree leaves): ``packed``, ``linv``, ``uinv``, ``tlo``,
    ``tup``, ``health``.  Static aux: ``structure`` ("dense" | "banded"),
    ``bw``, ``block`` (the enrichment's solve-block size — the skewed-band
    layout descriptor), ``tier`` (accuracy tier the factors were produced
    under) and ``fingerprint`` (matrix identity for the serving cache; None
    for factors built under tracing)."""

    packed: Any
    linv: Any = None
    uinv: Any = None
    tlo: Any = None
    tup: Any = None
    health: FactorHealth | None = None
    structure: str = "dense"
    bw: int = 0
    block: int = 0
    tier: float = 0.0
    fingerprint: str | None = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.packed, self.linv, self.uinv, self.tlo, self.tup, self.health)
        aux = (self.structure, self.bw, self.block, self.tier, self.fingerprint)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, linv, uinv, tlo, tup, health = children
        structure, bw, block, tier, fingerprint = aux
        return cls(packed=packed, linv=linv, uinv=uinv, tlo=tlo, tup=tup,
                   health=health, structure=structure, bw=bw, block=block,
                   tier=tier, fingerprint=fingerprint)

    # -- array duck-typing (one-release legacy shim) ------------------------
    @property
    def shape(self):
        return self.packed.shape

    @property
    def ndim(self):
        return self.packed.ndim

    @property
    def dtype(self):
        return self.packed.dtype

    @property
    def n(self) -> int:
        return self.packed.shape[-2]

    @property
    def batched(self) -> bool:
        return self.packed.ndim > 2

    @property
    def enriched(self) -> bool:
        return self.linv is not None

    def __jax_array__(self):
        return self.packed

    def __array__(self, dtype=None):
        import numpy as np

        return np.asarray(self.packed, dtype=dtype)

    def __getitem__(self, idx):
        return self.packed[idx]

    def astype(self, dtype):
        return self.packed.astype(dtype)

    def with_meta(self, **kw) -> "Factorization":
        return dataclasses.replace(self, **kw)


def packed_of(x):
    """Artifact-or-array → the packed factor array (the legacy operand)."""
    return x.packed if isinstance(x, Factorization) else x


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------
def factorize_dense(
    packed: jax.Array,
    *,
    block: int = 256,
    tier: float = 0.0,
    health: FactorHealth | None = None,
    fingerprint: str | None = None,
    enrich: bool = True,
) -> Factorization:
    """Wrap packed dense LU factors ``(…, n, n)`` into an artifact,
    pre-inverting the diagonal blocks (in the ≥f32 compute dtype the tiled
    solve promotes to) unless ``enrich=False``."""
    if isinstance(packed, Factorization):
        return packed
    n = packed.shape[-1]
    b = min(block, n)
    linv = uinv = None
    if enrich:
        compute = jnp.promote_types(jnp.float32, packed.dtype)
        inv = functools.partial(dense_block_inverses, block=b)
        for _ in range(packed.ndim - 2):
            inv = jax.vmap(inv)
        linv, uinv = inv(packed.astype(compute))
    return Factorization(packed=packed, linv=linv, uinv=uinv, health=health,
                         structure="dense", bw=0, block=b, tier=tier,
                         fingerprint=fingerprint)


def factorize_banded(
    packed: jax.Array,
    *,
    bw: int,
    block: int | None = None,
    tier: float = 0.0,
    health: FactorHealth | None = None,
    fingerprint: str | None = None,
    enrich: bool = True,
) -> Factorization:
    """Wrap packed band LU factors ``(…, n, 2bw+1)`` into an artifact,
    deriving the skewed solve layout and pre-inverting the in-window
    diagonal blocks unless ``enrich=False``."""
    if isinstance(packed, Factorization):
        return packed
    n = packed.shape[-2]
    c = band_block_size(n, bw, block)
    linv = uinv = tlo = tup = None
    if enrich:
        compute = jnp.promote_types(jnp.float32, packed.dtype)

        def one(lb):
            g, _, _ = banded_skewed_layout(lb, bw=bw, block=c)
            return banded_block_inverses(g, bw=bw, block=c)

        fn = one
        for _ in range(packed.ndim - 2):
            fn = jax.vmap(fn)
        linv, uinv, tlo, tup = fn(packed.astype(compute))
    return Factorization(packed=packed, linv=linv, uinv=uinv, tlo=tlo, tup=tup,
                         health=health, structure="banded", bw=bw, block=c,
                         tier=tier, fingerprint=fingerprint)


def dense_artifact(x, *, block: int = 256) -> Factorization:
    """Artifact-or-array → *enriched* dense artifact (the legacy-array shim
    path: raw operands are wrapped and inverted on the fly)."""
    if isinstance(x, Factorization):
        if x.enriched:
            return x
        return factorize_dense(x.packed, block=x.block or block, tier=x.tier,
                               health=x.health, fingerprint=x.fingerprint)
    return factorize_dense(x, block=block)


def banded_artifact(x, *, bw: int, block: int | None = None) -> Factorization:
    """Artifact-or-array → *enriched* banded artifact (legacy-array shim)."""
    if isinstance(x, Factorization):
        if x.enriched:
            return x
        return factorize_banded(x.packed, bw=x.bw or bw, block=x.block or block,
                                tier=x.tier, health=x.health,
                                fingerprint=x.fingerprint)
    return factorize_banded(x, bw=bw, block=block)


# ---------------------------------------------------------------------------
# pure-jnp mirror drivers (op-identical twins of the Pallas kernels)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("block",))
def dense_inverted_solve(
    lu: jax.Array, linv: jax.Array, uinv: jax.Array, b: jax.Array, *, block: int = 256
) -> jax.Array:
    """Pure-jnp mirror of :func:`repro.kernels.trsm.solve_inverted` —
    identical math through the shared :func:`inverted_dense_sweeps`, so
    kernel and mirror stay bitwise-identical."""
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    out_dtype = bm.dtype
    compute = jnp.promote_types(jnp.float32, jnp.promote_types(lu.dtype, out_dtype))
    n, m = bm.shape
    s, bb = linv.shape[0], linv.shape[1]
    lup = pad_identity_tail(lu.astype(compute), s * bb)
    x = jnp.zeros((s * bb, m), compute).at[:n].set(bm.astype(compute))

    def read_tile(r, i):
        return jax.lax.dynamic_slice(lup, (r * bb, i * bb), (bb, bb))

    def read_linv(i):
        return jax.lax.dynamic_slice(linv, (i, 0, 0), (1, bb, bb))[0]

    def read_uinv(i):
        return jax.lax.dynamic_slice(uinv, (i, 0, 0), (1, bb, bb))[0]

    x = inverted_dense_sweeps(read_tile, read_linv, read_uinv, x,
                              num_steps=s, block=bb)
    x = x[:n].astype(out_dtype)
    return x[:, 0] if squeeze else x


@functools.partial(jax.jit, static_argnames=("n", "bw"))
def banded_inverted_solve(
    linv: jax.Array, uinv: jax.Array, tlo: jax.Array, tup: jax.Array,
    b: jax.Array, *, n: int, bw: int,
) -> jax.Array:
    """Pure-jnp mirror of
    :func:`repro.kernels.banded.banded_solve_inverted` — identical math
    through the shared :func:`inverted_band_sweeps`."""
    s, c = linv.shape[0], linv.shape[1]
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    out_dtype = bm.dtype
    compute = linv.dtype
    m = bm.shape[1]
    xb = jnp.zeros((s * c, m), compute).at[:n].set(bm.astype(compute))
    x = inverted_band_sweeps(linv, uinv, tlo, tup, xb.reshape(s, c, m), bw=bw)
    x = x.reshape(s * c, m)[:n].astype(out_dtype)
    return x[:, 0] if squeeze else x
