"""Batched EbV solvers (vmapped) — throughput path used by the
EbV-preconditioned optimizer (many small independent systems, one per
parameter factor / expert)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ebv as _ebv
from . import blocked as _blocked
from .solve import lu_solve

__all__ = [
    "batched_ebv_lu",
    "batched_lu_solve",
    "batched_linear_solve",
    "batched_linear_solve_many",
]

batched_ebv_lu = jax.vmap(_ebv.ebv_lu)
batched_lu_solve = jax.vmap(lu_solve)


@functools.partial(jax.jit, static_argnames=("method", "block"))
def batched_linear_solve(a: jax.Array, b: jax.Array, *, method: str = "ebv", block: int = 128) -> jax.Array:
    """Solve a batch of diagonally-dominant systems ``a[i] x[i] = b[i]``.

    ``method="auto"`` routes through the ``repro.solvers`` registry
    (capability filter → measured cache → static heuristics), which lands on
    the batched Pallas grid kernels for small fp32 systems; the named
    methods keep their historical vmapped-jnp meaning."""
    if method == "auto":
        from repro.kernels import ops as kops  # deferred: kernels imports core

        squeeze = b.ndim == 2  # (B, n) vector RHS per system
        bm = b[..., None] if squeeze else b
        x = kops.linear_solve(a, bm, block=block)
        return x[..., 0] if squeeze else x
    if method == "ebv":
        lu = batched_ebv_lu(a)
    elif method == "ebv_blocked":
        lu = jax.vmap(lambda m: _blocked.blocked_lu(m, block=block))(a)
    elif method == "jnp":
        return jnp.linalg.solve(a, b)
    else:
        raise ValueError(f"unknown method {method!r}")
    return batched_lu_solve(lu, b)


def batched_linear_solve_many(a: jax.Array, bs, *, method: str = "ebv", block: int = 128) -> list[jax.Array]:
    """Stacked-RHS path over a batch of systems: factor ``a`` ((B, n, n))
    once, solve every RHS in ``bs`` (each (B, n) or (B, n, m_i)) in one wide
    batched substitution, and split the columns back per request — the
    batched analogue of :func:`repro.core.solve.linear_solve_many`."""
    cols, widths, squeezes = [], [], []
    for b in bs:
        squeeze = b.ndim == 2  # (B, n) vector RHS per system
        bm = b[..., None] if squeeze else b
        cols.append(bm)
        widths.append(bm.shape[-1])
        squeezes.append(squeeze)
    stacked = jnp.concatenate(cols, axis=-1)
    x = batched_linear_solve(a, stacked, method=method, block=block)
    out, c0 = [], 0
    for w, squeeze in zip(widths, squeezes):
        blk = x[..., c0 : c0 + w]
        out.append(blk[..., 0] if squeeze else blk)
        c0 += w
    return out
