"""Batched EbV solvers (vmapped) — throughput path used by the
EbV-preconditioned optimizer (many small independent systems, one per
parameter factor / expert)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ebv as _ebv
from . import blocked as _blocked
from .solve import lu_solve

__all__ = ["batched_ebv_lu", "batched_lu_solve", "batched_linear_solve"]

batched_ebv_lu = jax.vmap(_ebv.ebv_lu)
batched_lu_solve = jax.vmap(lu_solve)


@functools.partial(jax.jit, static_argnames=("method", "block"))
def batched_linear_solve(a: jax.Array, b: jax.Array, *, method: str = "ebv", block: int = 128) -> jax.Array:
    """Solve a batch of diagonally-dominant systems ``a[i] x[i] = b[i]``.

    ``method="auto"`` routes through the ``repro.solvers`` registry
    (capability filter → measured cache → static heuristics), which lands on
    the batched Pallas grid kernels for small fp32 systems; the named
    methods keep their historical vmapped-jnp meaning."""
    if method == "auto":
        from repro.kernels import ops as kops  # deferred: kernels imports core

        squeeze = b.ndim == 2  # (B, n) vector RHS per system
        bm = b[..., None] if squeeze else b
        x = kops.linear_solve(a, bm, block=block)
        return x[..., 0] if squeeze else x
    if method == "ebv":
        lu = batched_ebv_lu(a)
    elif method == "ebv_blocked":
        lu = jax.vmap(lambda m: _blocked.blocked_lu(m, block=block))(a)
    elif method == "jnp":
        return jnp.linalg.solve(a, b)
    else:
        raise ValueError(f"unknown method {method!r}")
    return batched_lu_solve(lu, b)
