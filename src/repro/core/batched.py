"""Batched EbV solvers (vmapped) — throughput path used by the
EbV-preconditioned optimizer (many small independent systems, one per
parameter factor / expert)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ebv as _ebv
from . import blocked as _blocked
from .solve import lu_solve

__all__ = ["batched_ebv_lu", "batched_lu_solve", "batched_linear_solve"]

batched_ebv_lu = jax.vmap(_ebv.ebv_lu)
batched_lu_solve = jax.vmap(lu_solve)


@functools.partial(jax.jit, static_argnames=("method", "block"))
def batched_linear_solve(a: jax.Array, b: jax.Array, *, method: str = "ebv", block: int = 128) -> jax.Array:
    """Solve a batch of diagonally-dominant systems ``a[i] x[i] = b[i]``."""
    if method == "ebv":
        lu = batched_ebv_lu(a)
    elif method == "ebv_blocked":
        lu = jax.vmap(lambda m: _blocked.blocked_lu(m, block=block))(a)
    elif method == "jnp":
        return jnp.linalg.solve(a, b)
    else:
        raise ValueError(f"unknown method {method!r}")
    return batched_lu_solve(lu, b)
