"""Paper-faithful "Equal bi-Vectorized" (EbV) LU decomposition.

The paper (Hashemi/Lahooti/Shirani 2019) factorizes a diagonally-dominant
matrix without pivoting.  At elimination step ``r`` the *bi-vector* is the
pair (L-column ``A[r+1:, r]``, U-row ``A[r, r+1:]``): both are scaled by the
pivot and consumed by one rank-1 Schur update (paper eqs. 6-a..6-c).  Because
the vectors shrink with ``r``, the paper *equalizes* work units by pairing
vector ``r`` with vector ``n-2-r`` (eqs. 7-a..7-e) so every unit has total
length ``n``.

This module is the paper-faithful reference realization in pure JAX:

* :func:`ebv_lu` — unblocked bi-vectorized factorization.  Each
  ``lax.fori_loop`` step extracts the bi-vector, scales by the pivot and
  applies the rank-1 update as fixed-shape masked vector ops — on a vector
  machine every step costs the same, which is the in-step analogue of the
  paper's equal-thread-work property.
* :func:`equalized_pairing` / :func:`fold_index` — the r ↔ n-2-r pairing,
  reused by the Pallas kernels (paired-tile grids) and the distributed
  factorization (folded panel-owner schedule).

The packed format is Doolittle: ``L`` strictly below the diagonal with an
implicit unit diagonal, ``U`` on and above the diagonal — the paper's
eq. (3) storage with both factors packed into one square array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ebv_lu",
    "ebv_step",
    "equalized_pairing",
    "pair_lengths",
    "fold_index",
    "equalized_tile_schedule",
    "tile_schedule_work",
    "unpack_lu",
    "reconstruct",
]


def equalized_pairing(n: int) -> list[tuple[int, ...]]:
    """Pair elimination vectors ``r`` and ``n-2-r`` (paper eq. 7).

    Vector ``r`` (``0 <= r <= n-2``) has length ``n-1-r``.  Pairing first
    with last gives units of equal total length ``n``.  With an odd number
    of vectors the middle one forms a singleton unit.
    """
    if n < 2:
        return []
    pairs: list[tuple[int, ...]] = []
    lo, hi = 0, n - 2
    while lo < hi:
        pairs.append((lo, hi))
        lo += 1
        hi -= 1
    if lo == hi:
        pairs.append((lo,))
    return pairs


def pair_lengths(n: int) -> list[int]:
    """Total element count of each equalized work unit (all ``n`` except a
    possible middle singleton)."""
    out = []
    for unit in equalized_pairing(n):
        out.append(sum(n - 1 - r for r in unit))
    return out


def fold_index(i, count):
    """Fold ``i`` from the two ends towards the middle.

    ``0, 1, 2, ... -> 0, count-1, 1, count-2, ...``  Used to hand paired
    (wide, narrow) work items to the same executor so cumulative work is
    equal — the EbV assignment generalized to any executor count.
    Works on Python ints and traced arrays.
    """
    half = (i + 1) // 2
    from_front = i % 2 == 0
    return jnp.where(from_front, half, count - half) if not isinstance(i, int) else (
        half if from_front else count - half
    )


def equalized_tile_schedule(num_steps: int) -> list[tuple[int, ...]]:
    """Equalized owner schedule for the blocked single-dispatch LU driver.

    Block column ``t`` (``1 <= t <= num_steps-1``) is a *trailing tile* during
    steps ``s < t``, so its lifetime work (trsm + rank-b update passes) is
    proportional to ``t``.  Folding tile ``1+r`` with tile ``num_steps-1-r``
    (paper eq. 7 with tiles in place of vectors) gives every program a
    (long-lived, short-lived) tile pair with equal total lifetime work
    ``num_steps``.  Returns, per program, the tuple of owned tile indices;
    with an odd tile count the middle tile forms a singleton unit.

    The fused Pallas kernel realizes exactly this map as
    ``t1 = p + 1, t2 = num_steps - 1 - p`` for program ``p``.
    """
    return [
        tuple(sorted(num_steps - 1 - r for r in unit))
        for unit in equalized_pairing(num_steps)
    ]


def tile_schedule_work(num_steps: int) -> list[int]:
    """Lifetime work (total trailing-tile step count) per program of
    :func:`equalized_tile_schedule` — equals :func:`pair_lengths`."""
    return [sum(unit) for unit in equalized_tile_schedule(num_steps)]


def ebv_step(a: jax.Array, k, *, row_index=None) -> jax.Array:
    """One bi-vectorized elimination step on the packed array.

    Fixed-shape (masked) realization of paper eqs. 6-a..6-c:
    scale the L-column by the pivot, take the U-row, apply one rank-1
    Schur update, and write the scaled column back.
    """
    n = a.shape[-1]
    if row_index is None:
        row_index = jnp.arange(a.shape[-2])
    col_index = jnp.arange(n)
    pivot = a[..., k, k]
    # bi-vector: pivot-scaled L-column (rows > k) and U-row (cols > k).
    l_col = jnp.where(row_index > k, a[..., :, k] / pivot[..., None], 0.0)
    u_row = jnp.where(col_index > k, a[..., k, :], 0.0)
    # rank-1 Schur complement update; masks confine it to the trailing block.
    a = a - l_col[..., :, None] * u_row[..., None, :]
    # store the scaled L-column (paper keeps the factors packed, eq. 3).
    a = a.at[..., :, k].set(jnp.where(row_index > k, l_col, a[..., :, k]))
    return a


def ebv_lu(a: jax.Array) -> jax.Array:
    """Unblocked paper-faithful EbV LU (no pivoting).

    Returns the packed LU array (unit-lower L implicit).  Every loop step is
    the same fixed-shape bi-vectorized update — the equal-work invariant.
    """
    n = a.shape[-1]
    row_index = jnp.arange(a.shape[-2])
    body = lambda k, acc: ebv_step(acc, k, row_index=row_index)
    return jax.lax.fori_loop(0, n - 1, body, a)


def unpack_lu(lu: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split the packed array into explicit (L, U) with unit diagonal on L."""
    lu = getattr(lu, "packed", lu)  # accept Factorization artifacts
    n = lu.shape[-1]
    eye = jnp.eye(n, dtype=lu.dtype)
    l = jnp.tril(lu, -1) + eye
    u = jnp.triu(lu)
    return l, u


def reconstruct(lu: jax.Array) -> jax.Array:
    """``L @ U`` from the packed factorization (testing/validation)."""
    l, u = unpack_lu(lu)
    return l @ u


@functools.partial(jax.jit, static_argnames=())
def ebv_lu_jit(a: jax.Array) -> jax.Array:
    return ebv_lu(a)


def make_diagonally_dominant(key, n: int, dtype=jnp.float32, *, sparse_band: int | None = None):
    """Test-matrix factory matching the paper's contract (diagonal dominance).

    ``sparse_band`` limits off-diagonal support to a band — the paper's
    "sparse" (CFD stencil) matrices.
    """
    a = jax.random.uniform(key, (n, n), dtype=jnp.float32, minval=-1.0, maxval=1.0)
    if sparse_band is not None:
        i = np.arange(n)
        mask = np.abs(i[:, None] - i[None, :]) <= sparse_band
        a = a * jnp.asarray(mask, a.dtype)
    # strict row-wise diagonal dominance
    rowsum = jnp.sum(jnp.abs(a), axis=-1)
    a = a.at[jnp.arange(n), jnp.arange(n)].set(rowsum + 1.0)
    return a.astype(dtype)
