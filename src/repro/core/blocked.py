"""Blocked (rank-k) EbV LU — the TPU-adapted fast path.

The paper's rank-1 updates have O(1) FLOP/byte arithmetic intensity: fine for
a 2008 GPU's scalar ALUs, hopeless against an MXU.  The adaptation keeps the
paper's two invariants while blocking for the MXU:

* **bi-vectorization** → the *fused panel step*: the pivot-scaled L-column
  block and the trsm-produced U-row block of the same step are computed
  together and consumed by one rank-``b`` GEMM update (one pass over the
  trailing matrix instead of the paper's two vector passes per step).
* **equalization** → the tile/owner schedules exported here
  (:func:`ebv_folded_owners`) pair wide early panels with narrow late panels
  so per-executor work is equal — the r ↔ n-2-r pairing at block granularity.

Shapes shrink statically (Python loop under ``jit``), so no masking waste.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .solve import unit_lower_solve_packed

__all__ = [
    "panel_factor",
    "blocked_lu",
    "fused_blocked_lu",
    "fused_lu_steps",
    "fused_block_size",
    "sub_block_width",
    "strip_trsm",
    "strip_utrsm",
    "factor_diag_strip",
    "solve_below_strip",
    "pad_identity_tail",
    "ebv_folded_owners",
    "cyclic_owners",
]


def sub_block_width(block: int) -> int:
    """Strip width of the two-level (axpy-in-strip, GEMM-retire) panel/trsm
    scheme.  Shared by :func:`fused_blocked_lu` and the Pallas megakernel
    (:func:`repro.kernels.ebv_lu.lu_fused`) so both trace identical op
    shapes — the basis of their bitwise equality."""
    return next((c for c in (32, 16, 8) if block % c == 0), block)


def pad_identity_tail(a: jax.Array, n_to: int) -> jax.Array:
    """Embed square ``a`` in an (n_to, n_to) array with an identity tail —
    inert under no-pivot elimination and substitution (unit pivots, zero
    coupling).  Shared by the fused LU drivers and the tiled solve."""
    n = a.shape[-1]
    if n_to == n:
        return a
    pad_ix = jnp.arange(n, n_to)
    one = jnp.ones((), a.dtype)
    return jnp.zeros((n_to, n_to), a.dtype).at[:n, :n].set(a).at[pad_ix, pad_ix].set(one)


def strip_trsm(ldiag: jax.Array, rhs: jax.Array) -> jax.Array:
    """Unit-lower solve of a ``(C2, w)`` strip against the ``(C2, C2)``
    diagonal block, as a short sequential masked-axpy recurrence on an array
    carry.  Shared verbatim by the megakernel and its mirror — both sides
    trace this exact jaxpr, so their bitwise equality holds by construction."""
    c2 = ldiag.shape[0]
    w = rhs.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (c2, 1), 0)

    def body(k, u):
        lk = jnp.where(rows > k, jax.lax.dynamic_slice(ldiag, (0, k), (c2, 1)), 0.0)
        uk = jax.lax.dynamic_slice(u, (k, 0), (1, w))
        return u - lk * uk

    return jax.lax.fori_loop(0, c2 - 1, body, rhs)


def strip_utrsm(udiag: jax.Array, rhs: jax.Array) -> jax.Array:
    """Upper-triangular solve (diagonal division included) of a ``(C2, w)``
    strip against the ``(C2, C2)`` diagonal block, as a short backward
    masked-axpy recurrence on an array carry — the backward-sweep twin of
    :func:`strip_trsm`.  Shared verbatim by the banded solve kernel and its
    pure-jnp mirror, so their bitwise equality holds by construction."""
    c2 = udiag.shape[0]
    w = rhs.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (c2, 1), 0)

    def body(kk, x):
        k = c2 - 1 - kk
        pivot = jax.lax.dynamic_slice(udiag, (k, k), (1, 1))
        xk = jax.lax.dynamic_slice(x, (k, 0), (1, w)) / pivot
        x = jax.lax.dynamic_update_slice(x, xk, (k, 0))
        uk = jnp.where(rows < k, jax.lax.dynamic_slice(udiag, (0, k), (c2, 1)), 0.0)
        return x - uk * xk

    return jax.lax.fori_loop(0, c2, body, rhs)


def factor_diag_strip(dblk: jax.Array, j: int) -> jax.Array:
    """Bi-vectorized (rank-1) factorization of the ``(B, C2)`` diagonal-block
    strip whose pivot rows start at local row ``j``; rows above ``j+k`` are
    masked no-ops (they hold final U values).  Shared kernel/mirror code."""
    b, c2 = dblk.shape
    rows_b = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)
    cols_c2 = jax.lax.broadcasted_iota(jnp.int32, (1, c2), 1)

    def dstep(k, d):
        piv = jax.lax.dynamic_slice(d, (j + k, k), (1, 1))
        urow = jnp.where(cols_c2 > k, jax.lax.dynamic_slice(d, (j + k, 0), (1, c2)), 0.0)
        colb = jax.lax.dynamic_slice(d, (0, k), (b, 1))
        lb = jnp.where(rows_b > j + k, colb / piv, 0.0)
        d = d - lb * urow
        return jax.lax.dynamic_update_slice(d, jnp.where(rows_b > j + k, lb, colb), (0, k))

    return jax.lax.fori_loop(0, c2, dstep, dblk)


def solve_below_strip(diag: jax.Array, strip: jax.Array, j: int) -> jax.Array:
    """Multipliers of a below-diagonal ``(B, C2)`` strip: right-solve against
    the factored diagonal strip.  Operand values equal the rank-1 sequence's
    (pivot row ``j+k`` of ``diag`` is final by its iteration), so this is
    bitwise-identical to eliminating column-by-column.  Shared kernel/mirror
    code."""
    b, c2 = strip.shape
    cols_c2 = jax.lax.broadcasted_iota(jnp.int32, (1, c2), 1)

    def bstep(k, st):
        piv = jax.lax.dynamic_slice(diag, (j + k, k), (1, 1))
        urow = jnp.where(cols_c2 > k, jax.lax.dynamic_slice(diag, (j + k, 0), (1, c2)), 0.0)
        colb = jax.lax.dynamic_slice(st, (0, k), (b, 1))
        lb = colb / piv  # every row is below the pivot here
        st = st - lb * urow
        return jax.lax.dynamic_update_slice(st, lb, (0, k))

    return jax.lax.fori_loop(0, c2, bstep, strip)


def fused_block_size(n: int, block: int, *, vmem_budget_bytes: int = 12 * 2**20) -> int:
    """Effective block size of the fused LU driver for an (n, n) matrix.

    Shared by the megakernel and its mirror (same reasons as
    :func:`sub_block_width`).  Two adjustments over ``min(block, n)``:

    * **padding**: the fused driver pads n up to ``S·B``; for n just above a
      block multiple (n=257, block=256) that nearly doubles the matrix.  At
      the same step count ``S``, ``B = ceil(n/S)`` rounded up to a 32
      multiple gives minimal padding — pick whichever candidate pads less.
    * **VMEM**: the kernel holds three (N, B) fp32 scratch slabs; halve B
      until they fit the budget so the default path compiles on real TPUs
      for large n (e.g. n=8000 → B=128) instead of overflowing VMEM.
    """
    B = min(block, n)
    S = -(-n // B)
    balanced = min(block, -(-(-(-n // S)) // 32) * 32)  # ceil(n/S) up to a 32-multiple
    if balanced >= 32 and -(-n // balanced) * balanced < S * B:
        B = balanced
    while B > 32 and 3 * (-(-n // B) * B) * B * 4 > vmem_budget_bytes:
        B = max(32, B // 2)
    return B


def panel_factor(panel: jax.Array) -> jax.Array:
    """Unblocked bi-vectorized LU of a tall ``(m, b)`` panel (pivots in the
    top ``b`` rows, no pivoting — paper contract)."""
    m, bw = panel.shape
    rows = jnp.arange(m)
    cols = jnp.arange(bw)

    def body(k, p):
        pivot = p[k, k]
        l_col = jnp.where(rows > k, p[:, k] / pivot, 0.0)
        u_row = jnp.where(cols > k, p[k, :], 0.0)
        p = p - l_col[:, None] * u_row[None, :]
        return p.at[:, k].set(jnp.where(rows > k, l_col, p[:, k]))

    return jax.lax.fori_loop(0, bw, body, panel)


def blocked_lu(a: jax.Array, *, block: int = 256) -> jax.Array:
    """Right-looking blocked EbV LU on a packed square array."""
    n = a.shape[-1]
    block = min(block, n)
    for k0 in range(0, n, block):
        b = min(block, n - k0)
        panel = panel_factor(a[k0:, k0 : k0 + b])
        a = a.at[k0:, k0 : k0 + b].set(panel)
        if k0 + b < n:
            l11 = panel[:b]  # packed: unit-lower + U11
            # fused bi-vector step: U-row block via trsm against the unit-lower
            # panel factor, immediately consumed by the rank-b update.
            u12 = unit_lower_solve_packed(l11, a[k0 : k0 + b, k0 + b :])
            a = a.at[k0 : k0 + b, k0 + b :].set(u12)
            l21 = panel[b:]
            a = a.at[k0 + b :, k0 + b :].add(-(l21 @ u12))
    return a


def fused_lu_steps(a: jax.Array, *, block: int, num_steps: int) -> jax.Array:
    """Value-level body of the fused blocked LU on an already-padded
    ``(S·B, S·B)`` array: two-level panel factorization + trailing-tile
    trsm/update per step.  Shared verbatim by the pure-jnp mirror
    (:func:`fused_blocked_lu`) and the small-n VMEM megakernel
    (:func:`repro.kernels.ebv_lu.lu_fused`) — both trace these exact ops,
    which is what makes their packed factors bitwise-identical."""
    B, S = block, num_steps
    C2 = sub_block_width(B)
    for s in range(S):
        base = s * B
        # ---- panel: two-level factorization of the column slab
        for j in range(0, B, C2):
            r0 = base + j
            w = B - j - C2

            # (1) bi-vectorized factorization of the diagonal-block strip
            # (dynamic_update_slice, not .at[].set: when the strip covers the
            # whole array — S == 1 and C2 == B, i.e. n ≤ 32 — the full-slice
            # scatter lowers with an empty int32[0] index constant that the
            # Pallas kernel tracer rejects as a captured constant)
            diag = factor_diag_strip(a[base : base + B, r0 : r0 + C2], j)
            a = jax.lax.dynamic_update_slice(a, diag, (base, r0))

            # (2) unit-lower trsm: U rows of the strip vs the remaining cols
            if w:
                u = strip_trsm(diag[j : j + C2, :], a[r0 : r0 + C2, r0 + C2 : base + B])
                a = a.at[r0 : r0 + C2, r0 + C2 : base + B].set(u)
                lpart = diag[j + C2 :, :]
                blk = a[r0 + C2 : base + B, r0 + C2 : base + B]
                a = a.at[r0 + C2 : base + B, r0 + C2 : base + B].set(
                    (blk - jnp.dot(lpart, u, preferred_element_type=jnp.float32)).astype(a.dtype)
                )

            # (3) row blocks below: right-solve multipliers + GEMM retirement
            for r in range(s + 1, S):
                off = r * B
                strip = solve_below_strip(diag, a[off : off + B, r0 : r0 + C2], j)
                a = a.at[off : off + B, r0 : r0 + C2].set(strip)
                if w:
                    blkr = a[off : off + B, r0 + C2 : base + B]
                    a = a.at[off : off + B, r0 + C2 : base + B].set(
                        (blkr - jnp.dot(strip, u, preferred_element_type=jnp.float32)).astype(a.dtype)
                    )
        # ---- trailing tiles: two-level trsm + rank-B update per row block
        for t in range(s + 1, S):
            tb = t * B
            y = a[base : base + B, tb : tb + B]
            for j in range(0, B, C2):
                r0 = base + j
                strip = strip_trsm(a[r0 : r0 + C2, r0 : r0 + C2], y[j : j + C2, :])
                y = jax.lax.dynamic_update_slice(y, strip, (j, 0))
                w = B - j - C2
                if w:
                    lpart = a[r0 + C2 : base + B, r0 : r0 + C2]
                    tail = (
                        y[j + C2 :, :] - jnp.dot(lpart, strip, preferred_element_type=jnp.float32)
                    ).astype(y.dtype)
                    y = jax.lax.dynamic_update_slice(y, tail, (j + C2, 0))
            a = a.at[base : base + B, tb : tb + B].set(y)
            for r in range(s + 1, S):
                off = r * B
                lblk = a[off : off + B, base : base + B]
                blk = a[off : off + B, tb : tb + B]
                a = a.at[off : off + B, tb : tb + B].set(
                    (blk - jnp.dot(lblk, y, preferred_element_type=jnp.float32)).astype(a.dtype)
                )
    return a


def fused_blocked_lu(a: jax.Array, *, block: int = 256) -> jax.Array:
    """Pure-jnp mirror of the single-dispatch Pallas megakernel
    (:func:`repro.kernels.ebv_lu.lu_fused`) — op-for-op identical shapes and
    ordering, so the two produce bitwise-identical packed LU factors.

    Structure per step ``s`` (matrix padded to ``S·B`` with an inert identity
    tail): two-level panel factorization (``C2``-wide strip rank-1 loop, strip
    trsm, rank-``C2`` GEMM retirement per (B, C2) row block), then per
    trailing block-column tile a two-level unit-lower trsm and the rank-``B``
    trailing GEMM per row block.  This is also the fast ``impl="xla"`` path:
    O(B/C2) passes over each slab instead of the O(B) passes of
    :func:`blocked_lu`."""
    n = a.shape[-1]
    B = fused_block_size(n, block)
    S = -(-n // B)
    N = S * B
    a = pad_identity_tail(a, N)
    a = fused_lu_steps(a, block=B, num_steps=S)
    return a[:n, :n] if N != n else a


def cyclic_owners(num_blocks: int, num_executors: int) -> list[int]:
    """Standard block-cyclic owner schedule (ScaLAPACK-style baseline)."""
    return [k % num_executors for k in range(num_blocks)]


def ebv_folded_owners(num_blocks: int, num_executors: int) -> list[int]:
    """EbV-folded owner schedule: panels ``k`` and ``nb-1-k`` (whose trailing
    work sums to a constant) go to the same executor — equalized cumulative
    panel work, the paper's pairing at block granularity."""
    return [min(k, num_blocks - 1 - k) % num_executors for k in range(num_blocks)]
