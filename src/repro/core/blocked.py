"""Blocked (rank-k) EbV LU — the TPU-adapted fast path.

The paper's rank-1 updates have O(1) FLOP/byte arithmetic intensity: fine for
a 2008 GPU's scalar ALUs, hopeless against an MXU.  The adaptation keeps the
paper's two invariants while blocking for the MXU:

* **bi-vectorization** → the *fused panel step*: the pivot-scaled L-column
  block and the trsm-produced U-row block of the same step are computed
  together and consumed by one rank-``b`` GEMM update (one pass over the
  trailing matrix instead of the paper's two vector passes per step).
* **equalization** → the tile/owner schedules exported here
  (:func:`ebv_folded_owners`) pair wide early panels with narrow late panels
  so per-executor work is equal — the r ↔ n-2-r pairing at block granularity.

Shapes shrink statically (Python loop under ``jit``), so no masking waste.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .solve import unit_lower_solve_packed

__all__ = ["panel_factor", "blocked_lu", "ebv_folded_owners", "cyclic_owners"]


def panel_factor(panel: jax.Array) -> jax.Array:
    """Unblocked bi-vectorized LU of a tall ``(m, b)`` panel (pivots in the
    top ``b`` rows, no pivoting — paper contract)."""
    m, bw = panel.shape
    rows = jnp.arange(m)
    cols = jnp.arange(bw)

    def body(k, p):
        pivot = p[k, k]
        l_col = jnp.where(rows > k, p[:, k] / pivot, 0.0)
        u_row = jnp.where(cols > k, p[k, :], 0.0)
        p = p - l_col[:, None] * u_row[None, :]
        return p.at[:, k].set(jnp.where(rows > k, l_col, p[:, k]))

    return jax.lax.fori_loop(0, bw, body, panel)


def blocked_lu(a: jax.Array, *, block: int = 256) -> jax.Array:
    """Right-looking blocked EbV LU on a packed square array."""
    n = a.shape[-1]
    block = min(block, n)
    for k0 in range(0, n, block):
        b = min(block, n - k0)
        panel = panel_factor(a[k0:, k0 : k0 + b])
        a = a.at[k0:, k0 : k0 + b].set(panel)
        if k0 + b < n:
            l11 = panel[:b]  # packed: unit-lower + U11
            # fused bi-vector step: U-row block via trsm against the unit-lower
            # panel factor, immediately consumed by the rank-b update.
            u12 = unit_lower_solve_packed(l11, a[k0 : k0 + b, k0 + b :])
            a = a.at[k0 : k0 + b, k0 + b :].set(u12)
            l21 = panel[b:]
            a = a.at[k0 + b :, k0 + b :].add(-(l21 @ u12))
    return a


def cyclic_owners(num_blocks: int, num_executors: int) -> list[int]:
    """Standard block-cyclic owner schedule (ScaLAPACK-style baseline)."""
    return [k % num_executors for k in range(num_blocks)]


def ebv_folded_owners(num_blocks: int, num_executors: int) -> list[int]:
    """EbV-folded owner schedule: panels ``k`` and ``nb-1-k`` (whose trailing
    work sums to a constant) go to the same executor — equalized cumulative
    panel work, the paper's pairing at block granularity."""
    return [min(k, num_blocks - 1 - k) % num_executors for k in range(num_blocks)]
