"""Multi-chip EbV LU via ``jax.shard_map`` — block-cyclic / EbV-folded
column placement over one mesh axis.

The paper's equalization insight, lifted to chip granularity (DESIGN.md §2):
panel ``k``'s trailing work is ∝ ``n − k·b``, so *paired* placement — panels
``k`` and ``nb−1−k`` on the same chip — gives every chip an equal cumulative
panel load (``ebv_folded``), vs. the standard ScaLAPACK ``cyclic`` baseline.
Both placements are supported; the factorization math is placement-agnostic.

Communication pattern per panel step (all expressible in XLA collectives):
  1. owner's column panel is broadcast (masked ``psum``) — one (n, b) tensor;
  2. every chip trsm-solves its own U12 columns and applies the rank-b
     update to its local trailing tiles (no further communication).
XLA's latency-hiding scheduler overlaps the next panel broadcast with the
current trailing GEMM — the compute/comm overlap story for §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import shard_map
from .blocked import panel_factor, cyclic_owners, ebv_folded_owners
from .solve import unit_lower_solve_packed, backward_substitution, forward_substitution

__all__ = ["placement_tables", "distributed_blocked_lu", "distributed_lu_solve"]


def placement_tables(nb: int, num_devices: int, placement: str):
    """Static (owners, slots, col_perm) for a column-block placement."""
    if placement == "cyclic":
        owners = cyclic_owners(nb, num_devices)
    elif placement == "ebv_folded":
        owners = ebv_folded_owners(nb, num_devices)
    else:
        raise ValueError(f"unknown placement {placement!r}")
    counts = [owners.count(d) for d in range(num_devices)]
    if len(set(counts)) != 1:
        raise ValueError(
            f"placement {placement!r} with nb={nb}, P={num_devices} is not "
            f"load-balanced ({counts}); choose nb a multiple of "
            f"{2 * num_devices if placement == 'ebv_folded' else num_devices}"
        )
    slots = []
    used = [0] * num_devices
    for k in range(nb):
        slots.append(used[owners[k]])
        used[owners[k]] += 1
    return owners, slots, counts[0]


def _column_tables(n: int, block: int, num_devices: int, placement: str):
    nb = n // block
    owners, slots, per_dev = placement_tables(nb, num_devices, placement)
    n_local = per_dev * block
    # global column index of each (device, local column)
    col_table = np.zeros((num_devices, n_local), dtype=np.int32)
    for k in range(nb):
        col_table[owners[k], slots[k] * block : (slots[k] + 1) * block] = np.arange(
            k * block, (k + 1) * block, dtype=np.int32
        )
    perm = col_table.reshape(-1)  # device-major column permutation
    inv = np.argsort(perm)
    return nb, owners, slots, col_table, perm, inv


def _broadcast_panel(local, slot, block, owner, axis):
    """Masked-psum broadcast of the owner's (n, block) column panel."""
    cols = jax.lax.dynamic_slice_in_dim(local, slot * block, block, axis=1)
    is_owner = jax.lax.axis_index(axis) == owner
    return jax.lax.psum(jnp.where(is_owner, cols, 0.0), axis)


def distributed_blocked_lu(
    a: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "model",
    block: int = 64,
    placement: str = "ebv_folded",
) -> jax.Array:
    """Factorize a replicated (n, n) matrix across ``mesh[axis]``; returns the
    packed LU replicated (gathered + unpermuted) for validation-scale use."""
    n = a.shape[-1]
    num_devices = mesh.shape[axis]
    if n % block:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    nb, owners, slots, col_table, perm, inv = _column_tables(n, block, num_devices, placement)
    col_table_j = jnp.asarray(col_table)

    def local_fn(local):  # local: (n, n_local)
        local = local[0] if local.ndim == 3 else local
        gcol = col_table_j[jax.lax.axis_index(axis)]  # (n_local,)
        for k in range(nb):
            k0 = k * block
            panel = _broadcast_panel(local, slots[k], block, owners[k], axis)
            sub = panel_factor(panel[k0:])
            panel = panel.at[k0:].set(sub)
            # owner stores its factored panel
            mine = jax.lax.dynamic_slice_in_dim(local, slots[k] * block, block, axis=1)
            is_owner = jax.lax.axis_index(axis) == owners[k]
            local = jax.lax.dynamic_update_slice_in_dim(
                local, jnp.where(is_owner, panel, mine), slots[k] * block, axis=1
            )
            if k0 + block < n:
                l11 = sub[:block]
                colmask = (gcol >= k0 + block)[None, :]
                rhs = local[k0 : k0 + block, :]
                u12 = unit_lower_solve_packed(l11, rhs)
                local = local.at[k0 : k0 + block, :].set(jnp.where(colmask, u12, rhs))
                l21 = sub[block:]
                local = local.at[k0 + block :, :].add(-(l21 @ jnp.where(colmask, u12, 0.0)))
        return local[None]

    from jax.sharding import PartitionSpec as P

    fn = jax.jit(
        shard_map(
            local_fn,
            mesh=mesh,
            in_specs=P(axis, None, None),
            out_specs=P(axis, None, None),
            check_vma=False,
        )
    )
    a_perm = a[:, perm]
    # stack a device axis so shard_map distributes the permuted column groups
    local_all = fn(a_perm.reshape(n, num_devices, -1).transpose(1, 0, 2))
    out_perm = jnp.concatenate([local_all[d] for d in range(num_devices)], axis=1)
    return out_perm[:, inv]


def distributed_lu_solve(
    a: jax.Array,
    b: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "model",
    block: int = 64,
    placement: str = "ebv_folded",
) -> jax.Array:
    """Distributed factorization + distributed two-phase substitution."""
    n = a.shape[-1]
    num_devices = mesh.shape[axis]
    nb, owners, slots, col_table, perm, inv = _column_tables(n, block, num_devices, placement)

    def local_fn(local, y):
        local = local[0] if local.ndim == 3 else local
        gcol = jnp.asarray(col_table)[jax.lax.axis_index(axis)]
        # ---- factorization (same schedule as distributed_blocked_lu) ----
        for k in range(nb):
            k0 = k * block
            panel = _broadcast_panel(local, slots[k], block, owners[k], axis)
            sub = panel_factor(panel[k0:])
            panel = panel.at[k0:].set(sub)
            mine = jax.lax.dynamic_slice_in_dim(local, slots[k] * block, block, axis=1)
            is_owner = jax.lax.axis_index(axis) == owners[k]
            local = jax.lax.dynamic_update_slice_in_dim(
                local, jnp.where(is_owner, panel, mine), slots[k] * block, axis=1
            )
            if k0 + block < n:
                l11 = sub[:block]
                colmask = (gcol >= k0 + block)[None, :]
                rhs = local[k0 : k0 + block, :]
                u12 = unit_lower_solve_packed(l11, rhs)
                local = local.at[k0 : k0 + block, :].set(jnp.where(colmask, u12, rhs))
                l21 = sub[block:]
                local = local.at[k0 + block :, :].add(-(l21 @ jnp.where(colmask, u12, 0.0)))
        # ---- forward substitution (y replicated; one panel broadcast/step) --
        for k in range(nb):
            k0 = k * block
            panel = _broadcast_panel(local, slots[k], block, owners[k], axis)
            yk = forward_substitution(panel[k0 : k0 + block], y[k0 : k0 + block])
            y = y.at[k0 : k0 + block].set(yk)
            if k0 + block < n:
                y = y.at[k0 + block :].add(-(panel[k0 + block :] @ yk))
        # ---- backward substitution --------------------------------------
        for k in reversed(range(nb)):
            k0 = k * block
            panel = _broadcast_panel(local, slots[k], block, owners[k], axis)
            xk = backward_substitution(panel[k0 : k0 + block], y[k0 : k0 + block])
            y = y.at[k0 : k0 + block].set(xk)
            if k0 > 0:
                y = y.at[:k0].add(-(panel[:k0] @ xk))
        return y

    from jax.sharding import PartitionSpec as P

    fn = jax.jit(
        shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(P(axis, None, None), P()),
            out_specs=P(),
            check_vma=False,
        )
    )
    a_perm = a[:, perm].reshape(n, num_devices, -1).transpose(1, 0, 2)
    return fn(a_perm, b)
