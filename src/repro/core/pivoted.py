"""Partial-pivoting dense LU — the last-resort fallback tier.

The EbV contract is *no pivoting* (fixed elimination order is what makes
the bi-vector pairing equalizable), and every fast path in the repo honours
it.  But an operand with a vanishing leading pivot is simply outside the
no-pivot class: the fused kernel, its mirror, and the legacy drivers all
produce the same Inf/NaN factors for it.  This module is the escape hatch
the escalation funnel (:mod:`repro.solvers.registry`) reaches *after* the
no-pivot twins fail their health screen: classical row-partial-pivoting
LU, built in-house on ``fori_loop`` (no LAPACK — the repo's
no-external-factorization rule), registered at the lowest dense priority
so it can never win a default selection.

It is O(n) sequential steps with a rank-1 update each — the paper's
pre-blocking cost profile — which is exactly why it is a *fallback*: you
pay the slow path only for operands the fast path provably mangles.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .solve import lu_solve

__all__ = ["PivotedFactors", "pivoted_lu", "pivoted_solve", "pivoted_linear_solve"]


class PivotedFactors(NamedTuple):
    """Row-pivoted factorization ``P A = L U``: ``lu`` is the packed
    (n, n) L\\U of the permuted operand, ``perm`` the int32 row permutation
    (``(P A)[i] = A[perm[i]]``).  ``repro.kernels.ops.lu_solve`` recognises
    the type and forces the ``pivoted`` solve backend, mirroring the
    rank-k factor handling."""

    lu: jax.Array
    perm: jax.Array


@jax.jit
def pivoted_lu(a: jax.Array) -> PivotedFactors:
    """Row-partial-pivoting LU of a dense (n, n) operand.

    Each step swaps the max-|value| row of the active column into pivot
    position before the rank-1 elimination — the textbook growth bound
    (multipliers ≤ 1) the no-pivot contract gives up."""
    n = a.shape[-1]
    rows = jnp.arange(n)

    def body(k, carry):
        m, perm = carry
        col = jnp.where(rows >= k, jnp.abs(m[:, k]), -jnp.inf)
        p = jnp.argmax(col)
        # swap rows k and p (gather/scatter with traced indices)
        rk, rp = m[k], m[p]
        m = m.at[k].set(rp).at[p].set(rk)
        pk, pp = perm[k], perm[p]
        perm = perm.at[k].set(pp).at[p].set(pk)
        pivot = m[k, k]
        l_col = jnp.where(rows > k, m[:, k] / pivot, 0.0)
        u_row = jnp.where(rows > k, m[k], 0.0)
        m = m - l_col[:, None] * u_row[None, :]
        m = m.at[:, k].set(jnp.where(rows > k, l_col, m[:, k]))
        return m, perm

    m, perm = jax.lax.fori_loop(0, n, body, (a, rows.astype(jnp.int32)))
    return PivotedFactors(lu=m, perm=perm)


@jax.jit
def pivoted_solve(factors: PivotedFactors, b: jax.Array) -> jax.Array:
    """Substitution through row-pivoted factors: apply the row permutation
    to the RHS, then the standard packed forward/backward sweeps."""
    return lu_solve(factors.lu, b[factors.perm])


def pivoted_linear_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    return pivoted_solve(pivoted_lu(a), b)
