"""SPIKE split banded solve: the band partitioned into per-device blocks.

Following the splitting approach of Li, Serban & Negrut (arXiv 1509.07919),
the row-aligned band ``arow[i, t] = A[i, i - bw + t]`` is cut into
``d = devices`` diagonal blocks of ``m = ceil(n / d)`` rows.  Writing the
global system per partition ``j``::

    A_j x_j  +  B̂_j x_{j-1}^(b)  +  Ĉ_j x_{j+1}^(t)  =  f_j

where ``x^(t)``/``x^(b)`` are a partition's top/bottom ``bw`` entries,
``B̂_j`` is nonzero only in its first ``bw`` rows (the band's left overhang
into the previous partition) and ``Ĉ_j`` only in its last ``bw`` rows (the
right overhang into the next).  Multiplying through by ``A_j^{-1}`` defines
the *spikes*::

    W_j = A_j^{-1} B̂_j      V_j = A_j^{-1} Ĉ_j      g_j = A_j^{-1} f_j

(each ``(m, bw)``; ``W_0 = 0`` and ``V_{d-1} = 0`` fall out of the global
band mask — partition 0 has no left overhang, partition d-1 no right one).
Restricting the recovery identity ``x_j = g_j − W_j x_{j-1}^(b) − V_j
x_{j+1}^(t)`` to each partition's top/bottom ``bw`` rows closes a *reduced
spike system* of order ``2·d·bw`` in the tip unknowns alone — identity
diagonal plus the spike tip blocks.  Factor time computes the local LU, the
spikes (one ``(m, 2bw)`` multi-RHS local solve), and the reduced matrix;
solve time is one local solve for ``g``, one small reduced solve for the
tips, and two rank-``bw`` GEMMs per partition for the recovery.

Everything here is the **pure-jnp mirror** plus the helpers *shared* with
the shard_map'd kernel entry (:mod:`repro.kernels.spike`): partitioning,
coupling extraction, reduced-system assembly, tip solve, and recovery are
one code path for both, so kernel-vs-mirror bitwise equality reduces to the
established :mod:`repro.core.banded` / :mod:`repro.kernels.banded` twin
contract for the per-partition local factor/solve.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .banded import banded_lu_blocked, banded_solve_blocked, pad_band_identity

__all__ = [
    "SpikeFactors",
    "spike_supported",
    "partition_band",
    "assemble_spike_factors",
    "spike_reduced_rhs",
    "spike_recover",
    "spike_lu",
    "spike_solve",
    "spike_linear_solve",
]


def spike_supported(n: int, bw: int, devices: int) -> bool:
    """Shape capability predicate for the SPIKE split.

    Requires ``bw >= 1`` (a pure-diagonal band has no couplings to split)
    and ``2*bw <= ceil(n / devices)``: each partition must hold its top and
    bottom tips disjointly — when ``bw >= n/devices`` the spikes overlap and
    the reduced-system closure is invalid, so the predicate rejects instead
    of returning garbage (dispatch falls back to replication)."""
    if devices < 1 or bw < 1 or n < 1:
        return False
    m = -(-n // devices)
    return 2 * bw <= m


def _coupling_blocks(ap: jax.Array, *, bw: int, m: int) -> tuple[jax.Array, jax.Array]:
    """Extract the dense coupling blocks from the padded band ``ap``
    reshaped ``(d, m, 2bw+1)``.

    ``B_j[r, q] = A[j·m+r, j·m−bw+q]`` lives at band offset ``t = q − r``
    (valid iff ``q ≥ r``); ``C_j[r', q] = A[j·m+m−bw+r', (j+1)·m+q]`` at
    ``t = 2bw + q − r'`` (valid iff ``q ≤ r'``).  Partition 0's B entries
    and partition d−1's C entries index outside the matrix and are already
    zero from the global band mask — no special-casing."""
    parts = ap  # (d, m, w)
    head = parts[:, :bw, :]          # rows that reach the previous partition
    tail = parts[:, m - bw :, :]     # rows that reach the next partition
    r = jnp.arange(bw)[:, None]
    q = jnp.arange(bw)[None, :]
    tb = q - r
    bmat = jnp.where(
        tb >= 0,
        jnp.take_along_axis(head, jnp.clip(tb, 0, None)[None, :, :], axis=2),
        0.0,
    )
    tc = 2 * bw + q - r
    cmat = jnp.where(
        tc <= 2 * bw,
        jnp.take_along_axis(tail, jnp.clip(tc, None, 2 * bw)[None, :, :], axis=2),
        0.0,
    )
    return bmat, cmat


def partition_band(
    arow: jax.Array, *, bw: int, devices: int
) -> tuple[jax.Array, jax.Array, int]:
    """Split the row-aligned band into per-partition operands.

    Returns ``(parts, coupling_rhs, m)``:

    * ``parts`` ``(d, m, 2bw+1)`` — each partition's *local* band: entries
      reaching outside the partition's own ``m`` columns are zeroed (they
      move into the couplings), identity pad rows fill the last partition
      when ``d`` does not divide ``n``;
    * ``coupling_rhs`` ``(d, m, 2bw)`` — the dense ``[B̂_j | Ĉ_j]`` spike
      right-hand sides (``B`` in the first ``bw`` rows of columns ``:bw``,
      ``C`` in the last ``bw`` rows of columns ``bw:``), ready for one
      multi-RHS local solve per partition;
    * ``m`` — the per-partition row count.
    """
    n, w = arow.shape
    assert w == 2 * bw + 1, f"band width {w} != 2*bw+1 for bw={bw}"
    if not spike_supported(n, bw, devices):
        raise ValueError(
            f"SPIKE split unsupported for n={n} bw={bw} devices={devices} "
            f"(requires bw >= 1 and 2*bw <= ceil(n/devices))"
        )
    d = devices
    m = -(-n // d)
    # defensive global mask: entries whose global column falls outside the
    # matrix must be zero for the coupling extraction's edge cases (valid
    # operands — e.g. make_banded_dd — already satisfy this bitwise).
    i = jnp.arange(n)[:, None]
    t = jnp.arange(w)[None, :]
    col = i - bw + t
    masked = jnp.where((col >= 0) & (col < n), arow, 0.0)
    ap = pad_band_identity(masked, bw, d * m).reshape(d, m, w)
    bmat, cmat = _coupling_blocks(ap, bw=bw, m=m)
    # local mask: keep only entries whose column stays inside the partition
    r = jnp.arange(m)[:, None]
    lcol = r - bw + t
    parts = jnp.where((lcol >= 0) & (lcol < m), ap, 0.0)
    zeros = jnp.zeros((d, m, bw), arow.dtype)
    bhat = zeros.at[:, :bw, :].set(bmat)
    chat = zeros.at[:, m - bw :, :].set(cmat)
    return parts, jnp.concatenate([bhat, chat], axis=-1), m


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SpikeFactors:
    """Factor-time artifact of the SPIKE split: per-partition packed band
    factors, the pre-solved spikes, and the assembled reduced matrix.

    ``packed`` exposes the stacked local factors as one ``(d·m, 2bw+1)``
    packed band so :func:`repro.core.health.factor_health` screens it like
    any banded factor (identity pad rows factor to pivot 1 — inert)."""

    local_lu: jax.Array   # (d, m, 2bw+1) per-partition packed band factors
    w_spikes: jax.Array   # (d, m, bw)  W_j = A_j^{-1} B̂_j
    v_spikes: jax.Array   # (d, m, bw)  V_j = A_j^{-1} Ĉ_j
    reduced: jax.Array    # (2·d·bw, 2·d·bw) reduced spike matrix
    n: int
    bw: int
    devices: int

    @property
    def m(self) -> int:
        return self.local_lu.shape[1]

    @property
    def packed(self) -> jax.Array:
        return self.local_lu.reshape(-1, self.local_lu.shape[-1])

    @property
    def shape(self):
        return self.packed.shape

    @property
    def dtype(self):
        return self.local_lu.dtype

    def tree_flatten(self):
        return (
            (self.local_lu, self.w_spikes, self.v_spikes, self.reduced),
            (self.n, self.bw, self.devices),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def assemble_spike_factors(
    local_lu: jax.Array, wv: jax.Array, *, n: int, bw: int, devices: int
) -> SpikeFactors:
    """Shared factor-time tail: split the stacked spike solve ``wv``
    ``(d, m, 2bw)`` into W/V, take the tips, and assemble the reduced spike
    matrix — identity diagonal plus the tip blocks.

    Unknown layout ``u = [x_0^t; x_0^b; x_1^t; x_1^b; …]`` (``bw`` rows per
    tip).  Restricting the recovery identity to the tips gives, per ``j``::

        x_j^t + Wt_j x_{j-1}^b + Vt_j x_{j+1}^t = gt_j
        x_j^b + Wb_j x_{j-1}^b + Vb_j x_{j+1}^t = gb_j

    so block-row ``2j`` carries ``Wt_j`` at block-column ``2(j−1)+1`` and
    ``Vt_j`` at ``2(j+1)``; block-row ``2j+1`` carries ``Wb_j``/``Vb_j`` at
    the same columns."""
    d, m = devices, local_lu.shape[1]
    w_sp = wv[..., :bw]
    v_sp = wv[..., bw:]
    wt, wb = w_sp[:, :bw, :], w_sp[:, m - bw :, :]
    vt, vb = v_sp[:, :bw, :], v_sp[:, m - bw :, :]
    red = jnp.eye(2 * d * bw, dtype=local_lu.dtype)
    for j in range(d):
        rt = 2 * j * bw
        rb = (2 * j + 1) * bw
        if j > 0:
            c = (2 * (j - 1) + 1) * bw
            red = red.at[rt : rt + bw, c : c + bw].set(wt[j])
            red = red.at[rb : rb + bw, c : c + bw].set(wb[j])
        if j < d - 1:
            c = 2 * (j + 1) * bw
            red = red.at[rt : rt + bw, c : c + bw].set(vt[j])
            red = red.at[rb : rb + bw, c : c + bw].set(vb[j])
    return SpikeFactors(
        local_lu=local_lu, w_spikes=w_sp, v_spikes=v_sp, reduced=red,
        n=n, bw=bw, devices=d,
    )


def spike_reduced_rhs(g: jax.Array, bw: int) -> jax.Array:
    """Tip right-hand side in the reduced system's unknown layout:
    ``[gt_0; gb_0; gt_1; …]`` from the stacked local solves ``g (d, m, k)``."""
    d, m, k = g.shape
    tips = jnp.stack([g[:, :bw, :], g[:, m - bw :, :]], axis=1)  # (d, 2, bw, k)
    return tips.reshape(2 * d * bw, k)


def spike_recover(factors: SpikeFactors, g: jax.Array, tips: jax.Array) -> jax.Array:
    """Shared recovery: ``x_j = g_j − W_j x_{j-1}^b − V_j x_{j+1}^t``,
    unpadded back to ``n`` rows.  ``tips`` is the reduced-system solution
    ``(2·d·bw, k)``."""
    d, bw = factors.devices, factors.bw
    k = g.shape[-1]
    t = tips.reshape(d, 2, bw, k)
    xt, xb = t[:, 0], t[:, 1]
    prev_xb = jnp.concatenate([jnp.zeros_like(xb[:1]), xb[:-1]], axis=0)
    next_xt = jnp.concatenate([xt[1:], jnp.zeros_like(xt[:1])], axis=0)
    x = g - jnp.matmul(factors.w_spikes, prev_xb) - jnp.matmul(factors.v_spikes, next_xt)
    return x.reshape(d * factors.m, k)[: factors.n]


def spike_lu(
    arow: jax.Array, *, bw: int, devices: int, block: int | None = None
) -> SpikeFactors:
    """Pure-jnp mirror SPIKE factorization: per-partition
    :func:`repro.core.banded.banded_lu_blocked` plus one ``(m, 2bw)``
    multi-RHS spike solve, run as a Python loop over partitions (preserves
    the per-partition op order the shard_map'd kernel path replays)."""
    parts, rhs, _m = partition_band(arow, bw=bw, devices=devices)
    lus, wvs = [], []
    for j in range(devices):
        lu_j = banded_lu_blocked(parts[j], bw=bw, block=block)
        wvs.append(banded_solve_blocked(lu_j, rhs[j], bw=bw, block=block))
        lus.append(lu_j)
    return assemble_spike_factors(
        jnp.stack(lus), jnp.stack(wvs), n=arow.shape[0], bw=bw, devices=devices
    )


def _solve_rhs_parts(factors: SpikeFactors, b: jax.Array) -> tuple[jax.Array, bool]:
    """Normalize/pad the RHS into stacked per-partition columns ``(d, m, k)``."""
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    d, m = factors.devices, factors.m
    fp = jnp.zeros((d * m, bm.shape[1]), bm.dtype).at[: factors.n].set(bm)
    return fp.reshape(d, m, bm.shape[1]), squeeze


@jax.jit
def _finish_solve_compiled(factors: SpikeFactors, g: jax.Array) -> jax.Array:
    tips = jnp.linalg.solve(factors.reduced, spike_reduced_rhs(g, factors.bw))
    return spike_recover(factors, g, tips)


def _finish_solve(
    factors: SpikeFactors, g: jax.Array, squeeze: bool
) -> jax.Array:
    """Shared solve tail: reduced tip solve + recovery.  Jitted because the
    tail is a handful of small ops whose eager dispatch overhead would
    otherwise rival the local solves; kernel and mirror both land here, so
    the bitwise contract is unaffected."""
    x = _finish_solve_compiled(factors, g)
    return x[:, 0] if squeeze else x


def spike_solve(
    factors: SpikeFactors, b: jax.Array, *, block: int | None = None
) -> jax.Array:
    """Pure-jnp mirror SPIKE substitution: per-partition local solves for
    ``g`` (Python loop), then the shared reduced solve + recovery."""
    f, squeeze = _solve_rhs_parts(factors, b)
    g = jnp.stack([
        banded_solve_blocked(factors.local_lu[j], f[j], bw=factors.bw, block=block)
        for j in range(factors.devices)
    ])
    return _finish_solve(factors, g, squeeze)


def spike_linear_solve(
    arow: jax.Array, b: jax.Array, *, bw: int, devices: int, block: int | None = None
) -> jax.Array:
    """Factor + solve through the mirror path."""
    return spike_solve(spike_lu(arow, bw=bw, devices=devices, block=block), b, block=block)
