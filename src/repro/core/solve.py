"""Triangular solves and the public ``linear_solve`` API.

The substitution phases follow the paper's vectorized (column-oriented /
"right-looking") form: after pivot ``k`` resolves, one fixed-shape masked
axpy retires the whole remaining vector — the solve-phase analogue of the
bi-vectorized elimination step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ebv as _ebv

__all__ = [
    "forward_substitution",
    "backward_substitution",
    "unit_lower_solve_packed",
    "upper_solve_packed",
    "lu_solve",
    "linear_solve",
    "stack_rhs",
    "split_rhs",
    "lu_solve_stacked",
    "linear_solve_many",
]


def _as_matrix(b):
    if b.ndim == 1:
        return b[:, None], True
    return b, False


def forward_substitution(lu: jax.Array, b: jax.Array) -> jax.Array:
    """Solve ``L y = b`` with the packed factor's implicit unit diagonal.

    Column-oriented: once ``y[k]`` is final, a masked axpy eliminates its
    contribution from every later row in one vector op.
    """
    y, squeeze = _as_matrix(b)
    n = lu.shape[-1]
    rows = jnp.arange(n)

    def body(k, y):
        lk = jnp.where(rows > k, lu[:, k], 0.0)
        return y - lk[:, None] * y[k][None, :]

    y = jax.lax.fori_loop(0, n - 1, body, y)
    return y[:, 0] if squeeze else y


def backward_substitution(lu: jax.Array, y: jax.Array) -> jax.Array:
    """Solve ``U x = y`` (diagonal of U lives on the packed diagonal)."""
    x, squeeze = _as_matrix(y)
    n = lu.shape[-1]
    rows = jnp.arange(n)

    def body(j, x):
        k = n - 1 - j
        xk = x[k] / lu[k, k]
        x = x.at[k].set(xk)
        uk = jnp.where(rows < k, lu[:, k], 0.0)
        return x - uk[:, None] * xk[None, :]

    x = jax.lax.fori_loop(0, n, body, x)
    return x[:, 0] if squeeze else x


def unit_lower_solve_packed(l_packed: jax.Array, b: jax.Array) -> jax.Array:
    """Forward substitution against the strictly-lower part of a packed
    square block (unit diagonal implicit).  Used by the blocked driver's
    ``U12 = L11^{-1} A12`` step."""
    return forward_substitution(l_packed, b)


def upper_solve_packed(u_packed: jax.Array, b: jax.Array) -> jax.Array:
    return backward_substitution(u_packed, b)


def lu_solve(lu: jax.Array, b: jax.Array) -> jax.Array:
    """Both substitution phases against a packed EbV factorization."""
    lu = getattr(lu, "packed", lu)  # accept Factorization artifacts
    return backward_substitution(lu, forward_substitution(lu, b))


# ---------------------------------------------------------------------------
# stacked-RHS paths — the factor-once/solve-many serving shape.  Many
# requests against the SAME matrix coalesce into one wide substitution
# (columns are independent through both sweeps, so the stacked solve is
# bitwise-identical per column to the per-request solves it replaces).
# ---------------------------------------------------------------------------
def stack_rhs(bs) -> tuple[jax.Array, list[int], list[bool]]:
    """hstack a sequence of (n,) / (n, m_i) RHS into one (n, Σm_i) matrix.

    Returns (stacked, widths, squeezes) — feed the latter two to
    :func:`split_rhs` to recover the per-request results."""
    cols, widths, squeezes = [], [], []
    for b in bs:
        squeeze = b.ndim == 1
        bm = b[:, None] if squeeze else b
        cols.append(bm)
        widths.append(bm.shape[1])
        squeezes.append(squeeze)
    return jnp.concatenate(cols, axis=1), widths, squeezes


def split_rhs(x: jax.Array, widths: list[int], squeezes: list[bool]) -> list[jax.Array]:
    """Inverse of :func:`stack_rhs` on the solved columns."""
    out, c0 = [], 0
    for w, squeeze in zip(widths, squeezes):
        blk = x[:, c0 : c0 + w]
        out.append(blk[:, 0] if squeeze else blk)
        c0 += w
    return out


def lu_solve_stacked(lu: jax.Array, bs) -> list[jax.Array]:
    """Solve one packed factorization against many RHS in ONE wide
    substitution pass; returns per-request results."""
    stacked, widths, squeezes = stack_rhs(bs)
    return split_rhs(lu_solve(lu, stacked), widths, squeezes)


def linear_solve_many(a: jax.Array, bs, *, method: str = "ebv_blocked", block: int = 256) -> list[jax.Array]:
    """Factor ``a`` ONCE, then solve every RHS in ``bs`` via the stacked
    path (same ``method`` vocabulary as :func:`linear_solve`)."""
    if method == "auto":
        from repro.kernels import ops as _kops  # deferred: kernels imports core

        stacked, widths, squeezes = stack_rhs(bs)
        return split_rhs(_kops.linear_solve(a, stacked, block=block), widths, squeezes)
    if method == "jnp":
        stacked, widths, squeezes = stack_rhs(bs)
        return split_rhs(jnp.linalg.solve(a, stacked), widths, squeezes)
    if method == "ebv":
        lu = _ebv.ebv_lu(a)
    elif method == "ebv_blocked":
        from . import blocked as _blocked

        lu = _blocked.blocked_lu(a, block=block)
    else:
        raise ValueError(f"unknown method {method!r}")
    return lu_solve_stacked(lu, bs)


@functools.partial(jax.jit, static_argnames=("method", "block"))
def linear_solve(a: jax.Array, b: jax.Array, *, method: str = "ebv_blocked", block: int = 256) -> jax.Array:
    """Solve ``A x = b`` for diagonally-dominant ``A`` (paper contract, no
    pivoting).

    methods:
      * ``"ebv"``          — paper-faithful unblocked bi-vectorized LU.
      * ``"ebv_blocked"``  — TPU-adapted blocked (rank-k) EbV LU.
      * ``"jnp"``          — ``jnp.linalg.solve`` (cross-check baseline).
      * ``"auto"``         — the ``repro.solvers`` registry (measured cache
                             → static heuristics; lands on the Pallas
                             kernels, incl. batched inputs).
    """
    if method == "auto":
        from repro.kernels import ops as _kops  # deferred: kernels imports core

        return _kops.linear_solve(a, b, block=block)
    if method == "jnp":
        return jnp.linalg.solve(a, b)
    if method == "ebv":
        lu = _ebv.ebv_lu(a)
    elif method == "ebv_blocked":
        from . import blocked as _blocked

        lu = _blocked.blocked_lu(a, block=block)
    else:
        raise ValueError(f"unknown method {method!r}")
    return lu_solve(lu, b)
