"""EbV (Equal bi-Vectorized) LU decomposition — the paper's contribution.

Layers:
  * ``ebv``          — paper-faithful unblocked bi-vectorized LU + the
                       r ↔ n-2-r equalization schedule.
  * ``blocked``      — TPU-adapted rank-k (MXU) blocked LU.
  * ``solve``        — vectorized substitution phases + ``linear_solve`` API.
  * ``banded``       — the paper's "sparse" (CFD stencil) path.
  * ``batched``      — vmapped many-small-systems path (optimizer use).
  * ``distributed``  — multi-chip shard_map factorization with EbV-folded
                       block placement.
  * ``health``       — post-factor screening (min pivot, element growth,
                       finiteness) for the no-pivot contract.
  * ``pivoted``      — partial-pivoting last-resort fallback for operands
                       outside the no-pivot class.
"""
from .ebv import (
    ebv_lu,
    ebv_step,
    equalized_pairing,
    pair_lengths,
    fold_index,
    unpack_lu,
    reconstruct,
    make_diagonally_dominant,
)
from .blocked import blocked_lu, panel_factor, ebv_folded_owners, cyclic_owners
from .solve import (
    forward_substitution,
    backward_substitution,
    lu_solve,
    linear_solve,
)
from .banded import (
    to_banded,
    from_banded,
    banded_lu,
    banded_solve,
    banded_lu_solve,
    banded_lu_blocked,
    banded_solve_blocked,
    banded_linear_solve_blocked,
    make_banded_dd,
)
from .batched import batched_ebv_lu, batched_lu_solve, batched_linear_solve
from .distributed import distributed_blocked_lu, distributed_lu_solve, placement_tables
from .health import (
    DEFAULT_THRESHOLDS,
    FactorHealth,
    HealthThresholds,
    factor_health,
    relative_residual,
)
from .pivoted import PivotedFactors, pivoted_lu, pivoted_solve

__all__ = [
    "ebv_lu", "ebv_step", "equalized_pairing", "pair_lengths", "fold_index",
    "unpack_lu", "reconstruct", "make_diagonally_dominant",
    "blocked_lu", "panel_factor", "ebv_folded_owners", "cyclic_owners",
    "forward_substitution", "backward_substitution", "lu_solve", "linear_solve",
    "to_banded", "from_banded", "banded_lu", "banded_solve", "banded_lu_solve",
    "banded_lu_blocked", "banded_solve_blocked", "banded_linear_solve_blocked",
    "make_banded_dd",
    "batched_ebv_lu", "batched_lu_solve", "batched_linear_solve",
    "distributed_blocked_lu", "distributed_lu_solve", "placement_tables",
    "FactorHealth", "HealthThresholds", "DEFAULT_THRESHOLDS", "factor_health",
    "relative_residual", "PivotedFactors", "pivoted_lu", "pivoted_solve",
]
