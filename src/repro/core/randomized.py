"""Randomized low-rank LU solves (after Shabat, Shmueli & Averbuch,
arXiv 1310.7202), restructured for the repo's no-pivot contract.

The cheap tier of the accuracy axis: instead of the O(n³) exact EbV LU,
sketch the range with a Gaussian projection and factor only the sketch.
The paper factors the sketch with *partially-pivoted* LU; every kernel in
this repo is pivot-free (the EbV contract), and un-pivoted elimination of
a raw Gaussian sketch panel has erratic element growth at depth ≳100 that
corrupts the basis beyond repair (measured: max|L| up to 2e4 at k=128,
basis error 9e-2 *in f64*).  So the elimination is moved to the one place
where pivot-free LU is provably growth-free — the sketch's SPD Gram
matrix — giving a CholeskyQR whose triangular factor comes from the
repo's own blocked no-pivot LU:

    G    ~  N(0, 1)                 (n, k+p)  Gaussian test matrix
    Y    =  A @ G                   (n, k+p)  range sketch — one tall GEMM
    M    =  YᵀY + ridge·I           (k+p)²    SPD Gram (ridge absorbs the
                                              rank-deficient tail)
    LDLᵀ =  no-pivot-LU(M)          growth-free: SPD needs no pivoting
    Q    =  (Y L⁻ᵀ D^(-1/2))[:, :k] orthonormal range basis
    B    =  Qᵀ A                    (k, n)

so ``A ≈ l @ u`` with ``l = Q`` (n, k) orthonormal and ``u = B`` (k, n) —
O(n²k) total, dominated by two GEMMs, all inner factorizations through
``fused_blocked_lu`` / the Pallas megakernel (``lu_impl``), no LAPACK.

Solves exploit ``l⁺ = lᵀ``: min-norm least squares through the k×k SPD
system ``(u uᵀ) w = lᵀ b``, ``x = uᵀ w`` — conditioned by the operand's
*nonzero* spectrum only, never by the sketch-LU's growth.

**Operand class / residual guarantee** (what the registry's tolerance gate
advertises): operands of numerical rank ≤ k with range-consistent RHS.
For that class the relative residual is bounded by
``RAND_LU_RESIDUAL_BOUND`` in ``repro.solvers.backends`` (measured per run
by the ``rand_lu_n2048_k256`` bench row and gated in ``scripts/check.sh``;
observed ~5e-7 across sizes/seeds, bound 1e-3).
:func:`randomized_linear_solve` additionally polishes through
:func:`repro.core.refine.iterative_refinement` against the full operand,
so off-class drift is caught and reported, not silently returned.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .blocked import fused_blocked_lu
from .refine import iterative_refinement
from .solve import lu_solve, unit_lower_solve_packed

__all__ = [
    "RankKFactors",
    "randomized_lu",
    "randomized_solve",
    "randomized_linear_solve",
    "GRAM_RIDGE",
]

# Relative Tikhonov shift on the sketch Gram matrix: keeps the trailing
# pivots of a numerically rank-deficient sketch positive (oversample
# columns beyond the operand's rank) without perturbing the leading
# spectrum above f32 Gram round-off (which is ~1e-6 relative already).
GRAM_RIDGE = 1e-6


class RankKFactors(NamedTuple):
    """Rank-k factorization ``A ≈ l @ u``: ``l`` (n, k) orthonormal range
    basis (so ``l⁺ = lᵀ``), ``u`` (k, n) its coefficient rows ``lᵀ A``."""

    l: jax.Array
    u: jax.Array

    @property
    def rank(self) -> int:
        return self.l.shape[-1]


def _spd_solve(m: jax.Array, rhs: jax.Array, lu_impl: Callable) -> jax.Array:
    """k×k SPD system through the no-pivot blocked LU (growth-free class)."""
    return lu_solve(lu_impl(m), rhs)


def randomized_lu(
    a: jax.Array,
    *,
    rank: int,
    oversample: int = 8,
    key: jax.Array | None = None,
    lu_impl: Callable[[jax.Array], jax.Array] | None = None,
) -> RankKFactors:
    """Rank-``rank`` randomized factorization of ``a`` ((n, n), f32).

    ``lu_impl`` factors the (k+p, k+p) SPD Gram matrix — defaults to the
    pure-jnp :func:`repro.core.blocked.fused_blocked_lu`; the registry's
    kernel backend passes the Pallas megakernel instead.  ``oversample``
    widens the sketch for conditioning; the basis is truncated back to
    ``rank`` columns (left-to-right elimination means the kept columns
    never depend on the oversample tail).
    """
    n = a.shape[-1]
    k = min(int(rank), n)
    p = min(int(oversample), n - k)
    if key is None:
        key = jax.random.PRNGKey(0)
    if lu_impl is None:
        lu_impl = fused_blocked_lu

    g = jax.random.normal(key, (n, k + p), dtype=a.dtype)
    y = jnp.dot(a, g, preferred_element_type=jnp.float32).astype(a.dtype)
    gram = jnp.dot(y.T, y, preferred_element_type=jnp.float32).astype(a.dtype)
    ridge = GRAM_RIDGE * jnp.trace(gram) / (k + p)
    ldl = lu_impl(gram + ridge * jnp.eye(k + p, dtype=a.dtype))
    # packed no-pivot LU of SPD M is its LDLᵀ: unit-lower L below, D·Lᵀ
    # above, pivots D on the diagonal.  Q = Y L⁻ᵀ D^(-1/2) is the
    # CholeskyQR orthonormalization with an in-house factor.
    d = jnp.diagonal(ldl)
    wt = unit_lower_solve_packed(ldl, y.T)  # solves L Wᵀ = Yᵀ
    q = (wt.T * jax.lax.rsqrt(d)[None, :])[:, :k]
    b = jnp.dot(q.T, a, preferred_element_type=jnp.float32).astype(a.dtype)
    return RankKFactors(l=q, u=b)


def randomized_solve(factors: RankKFactors, b: jax.Array) -> jax.Array:
    """Min-norm least-squares solve against rank-k factors (vector or
    matrix RHS): ``x = uᵀ (u uᵀ)⁻¹ lᵀ b`` (``l`` orthonormal)."""
    l, u = factors.l, factors.u
    k = u.shape[0]
    z = l.T @ b
    w = _spd_solve(
        jnp.dot(u, u.T, preferred_element_type=jnp.float32).astype(u.dtype),
        z,
        lambda m: fused_blocked_lu(m, block=min(256, k)),
    )
    return u.T @ w


def randomized_linear_solve(
    a: jax.Array,
    b: jax.Array,
    *,
    rank: int,
    oversample: int = 8,
    key: jax.Array | None = None,
    lu_impl: Callable[[jax.Array], jax.Array] | None = None,
    tolerance: float = 1e-3,
    max_refine_iters: int = 4,
) -> jax.Array:
    """Factor + solve in one call (the ``linear_solve`` slot's adapter),
    polished by f32 iterative refinement against the full operand until
    ``tolerance`` (the iterations/residual reached surface through
    :func:`repro.core.refine.last_refinement`)."""
    factors = randomized_lu(a, rank=rank, oversample=oversample, key=key, lu_impl=lu_impl)
    x0 = randomized_solve(factors, b)
    x, _info = iterative_refinement(
        a,
        b,
        x0,
        lambda r: randomized_solve(factors, r),
        tolerance=tolerance,
        max_iters=max_refine_iters,
    )
    return x
