"""Post-factorization health screening for the no-pivot EbV contract.

Every factorization path in the repo is un-pivoted LU (the paper's
equalized scheme eliminates in fixed order), so a zero or tiny pivot
silently produces Inf/NaN factors — and un-pivoted elimination of an
off-class operand shows *element growth* (max|U| far above max|A|) long
before it overflows.  The randomized-LU work (arXiv 1310.7202) measured
exactly this signal: max|L| ~ 2e4 when a raw Gaussian panel is eliminated
pivot-free.  This module turns those observations into a cheap, on-device
screening record:

* **min |pivot|** — the smallest pivot magnitude actually divided by;
  compared *relative to max|A|* so the check is scale-invariant;
* **element growth** — ``max|U| / max|A|``, the classical stability ratio
  (bounded by 2^(n-1) for partial pivoting, unbounded without);
* **finiteness** — any Inf/NaN anywhere in the packed factors.

All three are plain ``jnp`` reductions over the packed factor layouts the
kernels already produce (dense ``(n, n)``, row-aligned band
``(n, 2bw+1)``, batched variants, rank-k and row-pivoted factor records),
so the Pallas kernels and their pure-jnp mirrors — whose packed factors
are bitwise-identical by the twin contract — produce bitwise-identical
:class:`FactorHealth` records too (asserted in ``tests/test_health.py``).

The record travels with the factors (``ops.lu(..., health=True)`` returns
``(factors, FactorHealth)``) and drives the registry's escalation funnel
and the solve service's cache-admission / quarantine decisions.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "HealthThresholds",
    "DEFAULT_THRESHOLDS",
    "FactorHealth",
    "factor_health",
    "relative_residual",
    "banded_matvec",
]

_TINY = 1e-30  # denominator floor: an all-zero operand is its own problem


@dataclasses.dataclass(frozen=True)
class HealthThresholds:
    """Configurable verdict bounds for a :class:`FactorHealth` record.

    ``min_pivot_ratio``  smallest acceptable ``min|pivot| / max|A|``.  The
                         default tolerates the benign pivot decay of
                         diagonally-dominant operands (pivots stay O(max|A|))
                         while catching exact/near-singularity.
    ``max_growth``       largest acceptable ``max|U| / max|A|``.  Healthy
                         no-pivot factorizations of the repo's operand class
                         stay O(1-10); runaway growth means the elimination
                         order was wrong for this operand.
    ``require_finite``   whether any Inf/NaN in the factors fails the verdict.
    """

    min_pivot_ratio: float = 1e-10
    max_growth: float = 1e6
    require_finite: bool = True


DEFAULT_THRESHOLDS = HealthThresholds()


class FactorHealth(NamedTuple):
    """On-device screening record for one factorization.

    All fields are scalars (``jnp`` on device, castable eagerly): batched
    factorizations reduce to the *worst member* — one bad system taints the
    batch record, which is the binding number for admission decisions.
    """

    min_pivot: jax.Array  # min |pivot| over every system in the dispatch
    growth: jax.Array     # max|U| / max|A|  (the element-growth ratio)
    finite: jax.Array     # bool: every packed factor entry finite
    ref_max: jax.Array    # max|A| of the operand (the screening reference)

    def ok(self, thresholds: HealthThresholds | None = None) -> jax.Array:
        """Device-side verdict (bool scalar).  NaN fields compare False, so
        a poisoned record can never pass."""
        t = thresholds or DEFAULT_THRESHOLDS
        good = self.min_pivot >= t.min_pivot_ratio * self.ref_max
        good = jnp.logical_and(good, self.growth <= t.max_growth)
        if t.require_finite:
            good = jnp.logical_and(good, self.finite)
        return good

    def verdict(self, thresholds: HealthThresholds | None = None) -> bool:
        """Eager verdict (host bool)."""
        return bool(self.ok(thresholds))

    def report(self, thresholds: HealthThresholds | None = None) -> str:
        """Eager one-line reason string for logs and failure records."""
        t = thresholds or DEFAULT_THRESHOLDS
        parts = []
        mp, gr, fin, rm = (
            float(self.min_pivot), float(self.growth),
            bool(self.finite), float(self.ref_max),
        )
        if t.require_finite and not fin:
            parts.append("non-finite factor entries")
        if not mp >= t.min_pivot_ratio * rm:  # NaN-safe: NaN comparisons are False
            parts.append(f"min|pivot|={mp:.3e} < {t.min_pivot_ratio:g}*max|A|={t.min_pivot_ratio * rm:.3e}")
        if not gr <= t.max_growth:
            parts.append(f"growth={gr:.3e} > {t.max_growth:g}")
        return "; ".join(parts) if parts else (
            f"healthy (min|pivot|={mp:.3e}, growth={gr:.3e})"
        )


def _dense_health(packed: jax.Array, ref_max: jax.Array) -> FactorHealth:
    diag = jnp.diagonal(packed, axis1=-2, axis2=-1)
    n = packed.shape[-1]
    umask = jnp.triu(jnp.ones((n, n), bool))
    umax = jnp.max(jnp.where(umask, jnp.abs(packed), 0.0))
    return FactorHealth(
        min_pivot=jnp.min(jnp.abs(diag)),
        growth=umax / jnp.maximum(ref_max, _TINY),
        finite=jnp.all(jnp.isfinite(packed)),
        ref_max=ref_max,
    )


def _banded_health(packed: jax.Array, ref_max: jax.Array, bw: int) -> FactorHealth:
    # row-aligned band: column bw is the diagonal (the pivots), columns
    # bw..2bw the U part; columns 0..bw-1 hold the L multipliers.
    pivots = packed[..., bw]
    umax = jnp.max(jnp.abs(packed[..., bw:]))
    return FactorHealth(
        min_pivot=jnp.min(jnp.abs(pivots)),
        growth=umax / jnp.maximum(ref_max, _TINY),
        finite=jnp.all(jnp.isfinite(packed)),
        ref_max=ref_max,
    )


def factor_health(factors, *, ref_max, bw: int = 0) -> FactorHealth:
    """Screening record for any factor object the repo produces.

    ``factors`` is a packed dense ``(..., n, n)`` array, a packed
    row-aligned band ``(..., n, 2bw+1)`` (``bw > 0``), a
    :class:`~repro.core.randomized.RankKFactors`, or a
    :class:`~repro.core.pivoted.PivotedFactors`.  Leading batch axes reduce
    to the worst member.  ``ref_max`` is ``max|A|`` of the operand that was
    factored (computed by the caller — the factors alone can't recover it).
    """
    from .pivoted import PivotedFactors
    from .randomized import RankKFactors

    # Factorization artifacts screen on their packed payload (attribute
    # access instead of an isinstance to keep this module import-cycle-free
    # with repro.core.factorization).
    factors = getattr(factors, "packed", factors)
    ref_max = jnp.asarray(ref_max, jnp.float32)
    if isinstance(factors, RankKFactors):
        # no square pivot sequence: the analogue of a vanished pivot is a
        # collapsed coefficient row of u (the basis column spans nothing)
        row_peak = jnp.max(jnp.abs(factors.u), axis=-1)
        amax = jnp.maximum(jnp.max(jnp.abs(factors.l)), jnp.max(jnp.abs(factors.u)))
        return FactorHealth(
            min_pivot=jnp.min(row_peak),
            growth=amax / jnp.maximum(ref_max, _TINY),
            finite=jnp.logical_and(
                jnp.all(jnp.isfinite(factors.l)), jnp.all(jnp.isfinite(factors.u))
            ),
            ref_max=ref_max,
        )
    if isinstance(factors, PivotedFactors):
        return _dense_health(factors.lu, ref_max)
    if bw:
        return _banded_health(factors, ref_max, bw)
    return _dense_health(factors, ref_max)


def banded_matvec(arow: jax.Array, x: jax.Array, *, bw: int) -> jax.Array:
    """``A @ x`` on the row-aligned band (``arow[i, t] = A[i, i-bw+t]``)
    without densifying: O(n·bw) work/memory.  ``x`` is ``(n,)`` or
    ``(n, m)``."""
    n = arow.shape[0]
    squeeze = x.ndim == 1
    xm = x[:, None] if squeeze else x
    pad = jnp.zeros((bw, xm.shape[1]), xm.dtype)
    xp = jnp.concatenate([pad, xm, pad], axis=0)  # (n + 2bw, m)
    y = jnp.zeros_like(xm)
    for t in range(2 * bw + 1):
        y = y + arow[:, t : t + 1] * jax.lax.dynamic_slice_in_dim(xp, t, n, 0)
    return y[:, 0] if squeeze else y


def relative_residual(a, b, x, *, bw: int = 0) -> jax.Array:
    """Frobenius relative residual ``|Ax - b| / |b|`` for a dense ``(n, n)``
    or row-aligned band operand — the same norm
    :func:`repro.core.refine.iterative_refinement` drives to tolerance, so
    verification and refinement agree on what "met" means."""
    a32 = jnp.asarray(a, jnp.float32)
    b32 = jnp.asarray(b, jnp.float32)
    x32 = jnp.asarray(x, jnp.float32)
    ax = banded_matvec(a32, x32, bw=bw) if bw else a32 @ x32
    return jnp.linalg.norm(b32 - ax) / jnp.maximum(jnp.linalg.norm(b32), _TINY)
