"""Banded ("sparse") EbV LU.

The paper's sparse matrices come from CFD stencils — banded systems.  For a
bandwidth-``bw`` matrix every elimination bi-vector has length exactly
``bw``: the vectors are *naturally equalized*, which is the EbV ideal case
(DESIGN.md §4).

Storage is row-aligned band form: ``arow[i, t] = A[i, i - bw + t]`` for
``t ∈ [0, 2bw]`` (zero outside the matrix).  Factorization costs
O(n·bw²) instead of O(n³).

Two realizations live here:

* the scalar-sequential reference (:func:`banded_lu` / :func:`banded_solve`):
  one ``fori_loop`` step per elimination row — the paper-faithful loop.
* the **blocked** path (:func:`banded_lu_blocked` /
  :func:`banded_solve_blocked`): ``C`` pivot rows retired per step through a
  dense ``(C+bw, C+bw)`` working *window*.  The band is first re-laid into a
  window-aligned skewed form (:func:`band_to_skewed`) in which every window
  assembles from two static slices — no per-step gather/shear — and each
  bi-vector elimination inside the window is confined to the ``(bw+1, bw+1)``
  sub-block the band can reach (the paper's naturally-equalized unit: every
  step identical shape and cost).  The window step collectively applies the
  rank-``C`` Schur update to the ``(bw, bw)`` carry corner that flows into
  the next step.  These pure-jnp drivers are the op-identical mirrors of the
  Pallas kernels in :mod:`repro.kernels.banded` — both sides trace the same
  window jaxprs, so their packed band factors are bitwise-identical (the
  dense path's PR-2 contract, extended to the band).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .blocked import strip_trsm, strip_utrsm, sub_block_width

__all__ = [
    "to_banded",
    "from_banded",
    "banded_lu",
    "banded_solve",
    "banded_lu_solve",
    "make_banded_dd",
    "band_block_size",
    "pad_band_identity",
    "band_to_skewed",
    "skewed_to_band",
    "skew_rows",
    "skew_pad",
    "band_window_from_slabs",
    "factor_band_window",
    "band_step_slabs",
    "band_step_writeback",
    "band_block_step",
    "unit_lower_window_solve",
    "upper_window_solve",
    "banded_lu_blocked",
    "banded_solve_blocked",
    "banded_linear_solve_blocked",
]


def to_banded(a: jax.Array, bw: int) -> jax.Array:
    """Dense (n, n) → row-aligned band (n, 2bw+1)."""
    n = a.shape[-1]
    i = jnp.arange(n)[:, None]
    t = jnp.arange(2 * bw + 1)[None, :]
    j = i - bw + t
    valid = (j >= 0) & (j < n)
    return jnp.where(valid, a[i, jnp.clip(j, 0, n - 1)], 0.0)


def from_banded(arow: jax.Array) -> jax.Array:
    """Row-aligned band (n, 2bw+1) → dense (n, n)."""
    n, w = arow.shape
    bw = (w - 1) // 2
    i = jnp.arange(n)[:, None]
    t = jnp.arange(w)[None, :]
    j = i - bw + t
    dense = jnp.zeros((n, n), arow.dtype)
    return dense.at[i, jnp.clip(j, 0, n - 1)].add(jnp.where((j >= 0) & (j < n), arow, 0.0))


def _update_indices(bw: int) -> tuple[np.ndarray, np.ndarray]:
    """Static gather map for the shifted-window rank-1 band update.

    For row offset ``s`` (1..bw) the touched columns of the row-band are
    ``t = bw+1-s .. 2bw-s`` and they consume ``u_tail[c - (bw+1-s)]``.
    """
    s = np.arange(1, bw + 1)[:, None]  # (bw, 1)
    c = np.arange(2 * bw + 1)[None, :]  # (1, 2bw+1)
    src = c - (bw + 1 - s)
    valid = (src >= 0) & (src < bw)
    return np.clip(src, 0, bw - 1), valid


@functools.partial(jax.jit, static_argnames=("bw",))
def banded_lu(arow: jax.Array, *, bw: int) -> jax.Array:
    """No-pivot LU on the row-aligned band; factors packed in place
    (``L`` strictly left of the centre diagonal, unit diagonal implicit)."""
    n = arow.shape[0]
    pad = jnp.zeros((bw, 2 * bw + 1), arow.dtype)
    ap = jnp.concatenate([arow, pad], axis=0)  # (n+bw, 2bw+1)
    src_idx, src_valid = _update_indices(bw)
    src_idx = jnp.asarray(src_idx)
    src_valid = jnp.asarray(src_valid)
    anti = (jnp.arange(bw), bw - 1 - jnp.arange(bw))  # L positions in the window

    def body(k, ap):
        pivot = ap[k, bw]
        window = jax.lax.dynamic_slice(ap, (k + 1, 0), (bw, 2 * bw + 1))
        # bi-vector: the L-column lives on the window's anti-diagonal …
        l = window[anti] / pivot
        # … and the U-row is the pivot row's upper tail.
        u_tail = jax.lax.dynamic_slice(ap, (k, bw + 1), (1, bw))[0]
        upd = l[:, None] * jnp.where(src_valid, u_tail[src_idx], 0.0)
        window = window - upd
        window = window.at[anti].set(l)
        return jax.lax.dynamic_update_slice(ap, window, (k + 1, 0))

    ap = jax.lax.fori_loop(0, n - 1, body, ap)
    return ap[:n]


@functools.partial(jax.jit, static_argnames=("bw",))
def banded_solve(lu_band: jax.Array, b: jax.Array, *, bw: int) -> jax.Array:
    """Forward+backward substitution on the packed band factors."""
    lu_band = getattr(lu_band, "packed", lu_band)
    n = lu_band.shape[0]

    # forward: y_i = b_i − Σ_t L[i, i-bw+t] · y_{i-bw+t}
    ypad = jnp.concatenate([jnp.zeros((bw,), b.dtype), b])

    def fwd(i, ypad):
        window = jax.lax.dynamic_slice(ypad, (i,), (bw,))  # y_{i-bw} … y_{i-1}
        yi = ypad[i + bw] - jnp.dot(lu_band[i, :bw], window)
        return ypad.at[i + bw].set(yi)

    ypad = jax.lax.fori_loop(0, n, fwd, ypad)

    # backward: x_i = (y_i − Σ_t U[i, i+t] · x_{i+t}) / U[i, i]
    xpad = jnp.concatenate([ypad[bw:], jnp.zeros((bw,), b.dtype)])

    def bwd(j, xpad):
        i = n - 1 - j
        window = jax.lax.dynamic_slice(xpad, (i + 1,), (bw,))  # x_{i+1} … x_{i+bw}
        xi = (xpad[i] - jnp.dot(lu_band[i, bw + 1 :], window)) / lu_band[i, bw]
        return xpad.at[i].set(xi)

    xpad = jax.lax.fori_loop(0, n, bwd, xpad)
    return xpad[:n]


def banded_lu_solve(arow: jax.Array, b: jax.Array, *, bw: int) -> jax.Array:
    return banded_solve(banded_lu(arow, bw=bw), b, bw=bw)


# ---------------------------------------------------------------------------
# blocked band path — shared helpers (kernel/mirror bitwise twins)
# ---------------------------------------------------------------------------
def make_banded_dd(key, n: int, bw: int, dtype=jnp.float32) -> jax.Array:
    """Diagonally-dominant row-aligned band factory, built directly in band
    form — no dense ``(n, n)`` detour, so it scales to the paper's n=16384
    (where the dense matrix alone would be 1 GB)."""
    w = 2 * bw + 1
    a = jax.random.uniform(key, (n, w), jnp.float32, minval=-1.0, maxval=1.0)
    i = jnp.arange(n)[:, None]
    t = jnp.arange(w)[None, :]
    j = i - bw + t
    a = jnp.where((j >= 0) & (j < n), a, 0.0)
    offsum = jnp.sum(jnp.abs(a), axis=1) - jnp.abs(a[:, bw])
    return a.at[:, bw].set(offsum + 1.0).astype(dtype)


def band_block_size(n: int, bw: int, block: int | None = None) -> int:
    """Pivot rows ``C`` retired per blocked band step.

    ``C ≈ 8·bw`` (clamped to [32, 256]) amortizes the per-step window
    assembly over many pivots while keeping the ``(C+bw)²`` dense window
    small.  ``C ≥ bw`` is enforced so a step's ``bw`` carry rows never span
    more than one following block (the skewed layout's contract); ``C ≤ n``
    caps the degenerate bw ≥ n case at one step.  Shared by the Pallas
    kernels and the pure-jnp mirrors so both sides block identically
    (bitwise contract)."""
    if block is None:
        block = max(32, min(256, 8 * bw))
    return min(max(block, bw), n)


def pad_band_identity(arow: jax.Array, bw: int, rows_to: int) -> jax.Array:
    """Pad the band with identity rows (centre diagonal 1, zero coupling) —
    inert under no-pivot elimination and substitution, the band analogue of
    :func:`repro.core.blocked.pad_identity_tail`."""
    n, w = arow.shape
    if rows_to == n:
        return arow
    pad = jnp.zeros((rows_to - n, w), arow.dtype).at[:, bw].set(1.0)
    return jnp.concatenate([arow, pad], axis=0)


def band_to_skewed(ap: jax.Array, bw: int, block: int) -> jax.Array:
    """Re-lay the row-aligned band ``(R, 2bw+1)`` (``R`` a multiple of
    ``block``) into the window-aligned skewed form ``G`` ``(R, C+2bw)``:
    ``G[i, c] = A[i, k(i) - bw + c]`` with ``k(i) = (i // C)·C``.

    In this layout the blocked drivers assemble every dense working window
    from two *contiguous static slices* of ``G`` — the per-step gather that
    a row-aligned shear would need never happens.  The skew itself is the
    classic flat-reshape trick: shifting row ``r0`` of a block right by
    ``r0`` is the identity on flattened indices once rows are padded to
    width ``C+2bw+1``, so the whole conversion is one pad + two reshapes +
    one slice.  Pure data movement (exact), so it never perturbs bitwise
    comparisons."""
    r, w = ap.shape
    c = block
    gw = c + 2 * bw
    # rows padded to gw+1: flat index r0·(gw+1) + t  ==  r0·gw + (r0 + t),
    # i.e. exactly the skewed row-of-gw layout.
    padded = jnp.pad(ap.reshape(r // c, c, w), ((0, 0), (0, 0), (0, gw + 1 - w)))
    flat = padded.reshape(r // c, c * (gw + 1))[:, : c * gw]
    return flat.reshape(r, gw)


def skewed_to_band(g: jax.Array, bw: int, block: int) -> jax.Array:
    """Inverse of :func:`band_to_skewed`: skewed ``(R, C+2bw)`` → row-aligned
    band ``(R, 2bw+1)`` (the same flat-reshape identity, run backwards)."""
    r, gw = g.shape
    c = block
    w = 2 * bw + 1
    flat = jnp.pad(g.reshape(r // c, c * gw), ((0, 0), (0, c)))
    return flat.reshape(r // c, c, gw + 1)[:, :, :w].reshape(r, w)


def band_window_from_slabs(own: jax.Array, carry: jax.Array, bw: int) -> jax.Array:
    """Assemble the dense ``(C+bw, C+bw)`` working window of one block step
    from its two skewed-layout slabs: ``own`` ``(C, C+2bw)`` (the step's own
    rows) and ``carry`` (the next block's first ``bw`` rows — ``(bw, 2bw)``
    when ``C ≥ bw``, ``(bw, C+bw)`` sliced at column ``bw-C`` otherwise)."""
    c = own.shape[0]
    top = own[:, bw:]  # window columns 0..C+bw-1 of the step's own rows
    if c >= bw:
        bot = jnp.concatenate([jnp.zeros((bw, c - bw), own.dtype), carry], axis=1)
    else:
        bot = carry
    return jnp.concatenate([top, bot], axis=0)


def factor_band_window(window: jax.Array, npiv: int, bw: int) -> jax.Array:
    """No-pivot LU of the dense band window ``(npiv+bw, npiv+bw)``, retiring
    pivots ``0..npiv-1``.  Each bi-vector elimination is *confined to the
    ``(bw+1, bw+1)`` sub-block the band can reach* — the paper's naturally
    equalized unit: every step is one identical fixed-shape fused update
    (scale the L column by the pivot, subtract the outer product), with no
    masking waste on the ``(npiv+bw)²`` window.  Collectively the ``npiv``
    steps apply the block step's rank-``npiv`` Schur update to the
    ``(bw, bw)`` carry corner.  Shared verbatim by the Pallas kernels and
    the pure-jnp mirror (bitwise contract)."""

    def piv(p, wnd):
        blk = jax.lax.dynamic_slice(wnd, (p, p), (bw + 1, bw + 1))
        pivot = blk[:1, :1]
        l_col = blk[:, :1] / pivot
        u_row = blk[:1, :]
        upd = blk - l_col * u_row  # rank-1 Schur update on the reachable block
        blk = jnp.concatenate(
            [u_row, jnp.concatenate([l_col[1:], upd[1:, 1:]], axis=1)], axis=0
        )
        return jax.lax.dynamic_update_slice(wnd, blk, (p, p))

    return jax.lax.fori_loop(0, npiv, piv, window)


def unit_lower_window_solve(lwin: jax.Array, y: jax.Array, bw: int) -> jax.Array:
    """Blocked forward substitution against the packed in-block window
    (unit-lower L read strictly below the diagonal): per ``C2`` strip a
    short masked-axpy recurrence (:func:`repro.core.blocked.strip_trsm`),
    then one rank-``C2`` GEMM retiring the ``bw`` rows the band couples."""
    c = lwin.shape[0]
    c2 = sub_block_width(c)
    for j in range(0, c, c2):
        strip = strip_trsm(lwin[j : j + c2, j : j + c2], y[j : j + c2, :])
        y = jax.lax.dynamic_update_slice(y, strip, (j, 0))
        hr = min(bw, c - j - c2)
        if hr:
            lpart = lwin[j + c2 : j + c2 + hr, j : j + c2]
            tail = y[j + c2 : j + c2 + hr, :] - jnp.dot(
                lpart, strip, preferred_element_type=jnp.float32
            ).astype(y.dtype)
            y = jax.lax.dynamic_update_slice(y, tail, (j + c2, 0))
    return y


def upper_window_solve(uwin: jax.Array, x: jax.Array, bw: int) -> jax.Array:
    """Blocked backward substitution against the packed in-block window
    (U on and above the diagonal), mirroring :func:`unit_lower_window_solve`
    bottom-up with :func:`repro.core.blocked.strip_utrsm` strips."""
    c = uwin.shape[0]
    c2 = sub_block_width(c)
    for j in range(c - c2, -1, -c2):
        strip = strip_utrsm(uwin[j : j + c2, j : j + c2], x[j : j + c2, :])
        x = jax.lax.dynamic_update_slice(x, strip, (j, 0))
        hr = min(bw, j)
        if hr:
            upart = uwin[j - hr : j, j : j + c2]
            head = x[j - hr : j, :] - jnp.dot(
                upart, strip, preferred_element_type=jnp.float32
            ).astype(x.dtype)
            x = jax.lax.dynamic_update_slice(x, head, (j - hr, 0))
    return x


# ---------------------------------------------------------------------------
# blocked band drivers — pure-jnp mirrors of the Pallas kernels
# ---------------------------------------------------------------------------
def skew_rows(n: int, bw: int, block: int) -> int:
    """Padded row count of the skewed band: a whole number of blocks plus
    enough carry blocks for the last step's ``bw`` overhang.  ONE formula
    shared by :func:`skew_pad` and the kernels' VMEM-budget estimate."""
    s = -(-n // block)
    return (s + max(1, -(-bw // block))) * block


def skew_pad(arow: jax.Array, bw: int, block: int) -> tuple[jax.Array, int]:
    """Identity-pad the band to :func:`skew_rows` rows and re-lay it into
    the skewed form the blocked drivers consume.  Returns ``(G, num_steps)``.
    Shared by the Pallas kernels and the pure-jnp mirrors — the bitwise
    kernel/mirror contract depends on both sides padding identically."""
    n = arow.shape[0]
    ap = pad_band_identity(arow, bw, skew_rows(n, bw, block))
    return band_to_skewed(ap, bw, block), -(-n // block)


def band_step_slabs(g: jax.Array, k, *, block: int, bw: int):
    """Slice one block step's (own, carry) slabs out of the skewed band at
    row offset ``k`` (traced or static).  Shared kernel/mirror code."""
    c = block
    gw = c + 2 * bw
    own = jax.lax.dynamic_slice(g, (k, 0), (c, gw))
    if c >= bw:
        carry = jax.lax.dynamic_slice(g, (k + c, 0), (bw, 2 * bw))
    else:
        carry = jax.lax.dynamic_slice(g, (k + c, bw - c), (bw, c + bw))
    return own, carry


def band_step_writeback(g: jax.Array, window: jax.Array, k, *, block: int, bw: int):
    """Write a factored window back into the skewed band: the step's own
    ``C`` rows are final; its ``bw`` carry rows flow into the next block's
    leading columns.  Shared kernel/mirror code."""
    c = block
    g = jax.lax.dynamic_update_slice(g, window[:c, :], (k, bw))
    if c >= bw:
        return jax.lax.dynamic_update_slice(g, window[c:, c - bw :], (k + c, 0))
    return jax.lax.dynamic_update_slice(g, window[c:, :], (k + c, bw - c))


def band_block_step(g: jax.Array, k, *, block: int, bw: int) -> jax.Array:
    """One blocked band LU step on the skewed band: assemble the dense
    window from two static slices, retire ``C`` pivots, write back.  Shared
    verbatim by the Pallas kernels and the pure-jnp mirror."""
    own, carry = band_step_slabs(g, k, block=block, bw=bw)
    window = factor_band_window(band_window_from_slabs(own, carry, bw), block, bw)
    return band_step_writeback(g, window, k, block=block, bw=bw)


@functools.partial(jax.jit, static_argnames=("bw", "block"))
def banded_lu_blocked(arow: jax.Array, *, bw: int, block: int | None = None) -> jax.Array:
    """Blocked no-pivot band LU: ``C`` rows retired per step through the
    dense band window on the skewed layout.  Op-identical mirror of
    :func:`repro.kernels.banded.banded_lu_blocked` /
    :func:`repro.kernels.banded.banded_lu_tiled` — bitwise-equal packed band
    factors by construction."""
    n = arow.shape[0]
    c = band_block_size(n, bw, block)
    g, s = skew_pad(arow, bw, c)
    for i in range(s):
        g = band_block_step(g, i * c, block=c, bw=bw)
    return skewed_to_band(g, bw, c)[:n]


@functools.partial(jax.jit, static_argnames=("bw", "block"))
def banded_solve_blocked(
    lu_band: jax.Array, b: jax.Array, *, bw: int, block: int | None = None
) -> jax.Array:
    """Blocked forward+backward substitution on the packed band factors —
    op-identical mirror of
    :func:`repro.kernels.banded.banded_solve_kernelized`."""
    lu_band = getattr(lu_band, "packed", lu_band)
    n = lu_band.shape[0]
    squeeze = b.ndim == 1
    bm = b[:, None] if squeeze else b
    m = bm.shape[1]
    c = band_block_size(n, bw, block)
    s = -(-n // c)
    np_rows = s * c
    # in the skewed layout each block's dense coupling strip F (C, C+2bw) —
    # columns k-bw .. k+C+bw-1 — is one contiguous row slice, no gather:
    # F[:, :bw] couples to rows above the block, F[:, bw:bw+C] is the
    # in-block packed L/U window, F[:, bw+C:] couples to rows below.
    g = band_to_skewed(pad_band_identity(lu_band, bw, np_rows), bw, c)
    # x carries `bw` zero margin rows on both ends so every block reads its
    # above/below coupling windows without branching (rows [bw, bw+n) real).
    xp = jnp.zeros((bw + np_rows + bw, m), bm.dtype).at[bw : bw + n].set(bm)
    for i in range(s):
        k = i * c
        f = g[k : k + c]
        yblk = xp[bw + k : bw + k + c] - jnp.dot(
            f[:, :bw], xp[k : k + bw], preferred_element_type=jnp.float32
        ).astype(xp.dtype)
        yblk = unit_lower_window_solve(f[:, bw : bw + c], yblk, bw)
        xp = jax.lax.dynamic_update_slice(xp, yblk, (bw + k, 0))
    for i in range(s - 1, -1, -1):
        k = i * c
        f = g[k : k + c]
        xblk = xp[bw + k : bw + k + c] - jnp.dot(
            f[:, bw + c :], xp[bw + k + c : bw + k + c + bw], preferred_element_type=jnp.float32
        ).astype(xp.dtype)
        xblk = upper_window_solve(f[:, bw : bw + c], xblk, bw)
        xp = jax.lax.dynamic_update_slice(xp, xblk, (bw + k, 0))
    x = xp[bw : bw + n]
    return x[:, 0] if squeeze else x


def banded_linear_solve_blocked(
    arow: jax.Array, b: jax.Array, *, bw: int, block: int | None = None
) -> jax.Array:
    """Factor + solve through the blocked mirrors."""
    return banded_solve_blocked(banded_lu_blocked(arow, bw=bw, block=block), b, bw=bw, block=block)
