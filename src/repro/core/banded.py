"""Banded ("sparse") EbV LU.

The paper's sparse matrices come from CFD stencils — banded systems.  For a
bandwidth-``bw`` matrix every elimination bi-vector has length exactly
``bw``: the vectors are *naturally equalized*, which is the EbV ideal case
(DESIGN.md §4).

Storage is row-aligned band form: ``arow[i, t] = A[i, i - bw + t]`` for
``t ∈ [0, 2bw]`` (zero outside the matrix).  Factorization costs
O(n·bw²) instead of O(n³).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "to_banded",
    "from_banded",
    "banded_lu",
    "banded_solve",
    "banded_lu_solve",
]


def to_banded(a: jax.Array, bw: int) -> jax.Array:
    """Dense (n, n) → row-aligned band (n, 2bw+1)."""
    n = a.shape[-1]
    i = jnp.arange(n)[:, None]
    t = jnp.arange(2 * bw + 1)[None, :]
    j = i - bw + t
    valid = (j >= 0) & (j < n)
    return jnp.where(valid, a[i, jnp.clip(j, 0, n - 1)], 0.0)


def from_banded(arow: jax.Array) -> jax.Array:
    """Row-aligned band (n, 2bw+1) → dense (n, n)."""
    n, w = arow.shape
    bw = (w - 1) // 2
    i = jnp.arange(n)[:, None]
    t = jnp.arange(w)[None, :]
    j = i - bw + t
    dense = jnp.zeros((n, n), arow.dtype)
    return dense.at[i, jnp.clip(j, 0, n - 1)].add(jnp.where((j >= 0) & (j < n), arow, 0.0))


def _update_indices(bw: int) -> tuple[np.ndarray, np.ndarray]:
    """Static gather map for the shifted-window rank-1 band update.

    For row offset ``s`` (1..bw) the touched columns of the row-band are
    ``t = bw+1-s .. 2bw-s`` and they consume ``u_tail[c - (bw+1-s)]``.
    """
    s = np.arange(1, bw + 1)[:, None]  # (bw, 1)
    c = np.arange(2 * bw + 1)[None, :]  # (1, 2bw+1)
    src = c - (bw + 1 - s)
    valid = (src >= 0) & (src < bw)
    return np.clip(src, 0, bw - 1), valid


@functools.partial(jax.jit, static_argnames=("bw",))
def banded_lu(arow: jax.Array, *, bw: int) -> jax.Array:
    """No-pivot LU on the row-aligned band; factors packed in place
    (``L`` strictly left of the centre diagonal, unit diagonal implicit)."""
    n = arow.shape[0]
    pad = jnp.zeros((bw, 2 * bw + 1), arow.dtype)
    ap = jnp.concatenate([arow, pad], axis=0)  # (n+bw, 2bw+1)
    src_idx, src_valid = _update_indices(bw)
    src_idx = jnp.asarray(src_idx)
    src_valid = jnp.asarray(src_valid)
    anti = (jnp.arange(bw), bw - 1 - jnp.arange(bw))  # L positions in the window

    def body(k, ap):
        pivot = ap[k, bw]
        window = jax.lax.dynamic_slice(ap, (k + 1, 0), (bw, 2 * bw + 1))
        # bi-vector: the L-column lives on the window's anti-diagonal …
        l = window[anti] / pivot
        # … and the U-row is the pivot row's upper tail.
        u_tail = jax.lax.dynamic_slice(ap, (k, bw + 1), (1, bw))[0]
        upd = l[:, None] * jnp.where(src_valid, u_tail[src_idx], 0.0)
        window = window - upd
        window = window.at[anti].set(l)
        return jax.lax.dynamic_update_slice(ap, window, (k + 1, 0))

    ap = jax.lax.fori_loop(0, n - 1, body, ap)
    return ap[:n]


@functools.partial(jax.jit, static_argnames=("bw",))
def banded_solve(lu_band: jax.Array, b: jax.Array, *, bw: int) -> jax.Array:
    """Forward+backward substitution on the packed band factors."""
    n = lu_band.shape[0]

    # forward: y_i = b_i − Σ_t L[i, i-bw+t] · y_{i-bw+t}
    ypad = jnp.concatenate([jnp.zeros((bw,), b.dtype), b])

    def fwd(i, ypad):
        window = jax.lax.dynamic_slice(ypad, (i,), (bw,))  # y_{i-bw} … y_{i-1}
        yi = ypad[i + bw] - jnp.dot(lu_band[i, :bw], window)
        return ypad.at[i + bw].set(yi)

    ypad = jax.lax.fori_loop(0, n, fwd, ypad)

    # backward: x_i = (y_i − Σ_t U[i, i+t] · x_{i+t}) / U[i, i]
    xpad = jnp.concatenate([ypad[bw:], jnp.zeros((bw,), b.dtype)])

    def bwd(j, xpad):
        i = n - 1 - j
        window = jax.lax.dynamic_slice(xpad, (i + 1,), (bw,))  # x_{i+1} … x_{i+bw}
        xi = (xpad[i] - jnp.dot(lu_band[i, bw + 1 :], window)) / lu_band[i, bw]
        return xpad.at[i].set(xi)

    xpad = jax.lax.fori_loop(0, n, bwd, xpad)
    return xpad[:n]


def banded_lu_solve(arow: jax.Array, b: jax.Array, *, bw: int) -> jax.Array:
    return banded_solve(banded_lu(arow, bw=bw), b, bw=bw)
