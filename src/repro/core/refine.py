"""f32 iterative refinement over a lower-precision factorization.

The mixed-precision tier (Chen, Liu & Yang's GEMM-heavy solve restructuring,
arXiv 1606.00541, applied at the precision axis): factor once in bf16 — MXU
native throughput, half the factor bytes — then recover f32 accuracy by
refining the solution against the *full-precision* operand:

    r_i = b - A x_i            (f32 residual against the exact A)
    d_i = solve(LU_bf16, r_i)  (cheap correction through the bf16 factors)
    x_{i+1} = x_i + d_i

For the diagonally-dominant operands of the paper contract the iteration
contracts by roughly the bf16 unit roundoff (~2^-8) per pass, so a handful
of sweeps reach f32-level residuals.  The loop is a ``lax.while_loop``
capped at ``max_iters`` — the cap bounds serving-tier latency, and the
iteration/residual actually reached are surfaced through
:func:`last_refinement` (recorded via ``jax.debug.callback`` so the numbers
escape jit) for stats plumbing (``SolveServiceStats``, the accuracy bench).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["RefineInfo", "iterative_refinement", "last_refinement", "DEFAULT_MAX_ITERS"]

DEFAULT_MAX_ITERS = 12


class RefineInfo(NamedTuple):
    iterations: jax.Array  # int32: refinement sweeps taken (0 = x0 sufficed)
    residual: jax.Array    # float32: final relative residual |Ax-b|/|b|


# Last refinement executed in this process (updated from inside jit via
# debug callback — execution-ordered, so eager consumers reading after
# block_until_ready() see the run they just dispatched).
_LAST: dict = {"iterations": None, "residual": None}


def last_refinement() -> dict:
    """``{"iterations": int | None, "residual": float | None}`` of the most
    recently *executed* refinement (None before any ran)."""
    return dict(_LAST)


def _note(iterations, residual) -> None:
    import numpy as np

    # vmapped refinements may deliver per-batch arrays; report the worst
    # member (the binding number for a latency/accuracy budget)
    _LAST["iterations"] = int(np.max(np.asarray(iterations)))
    _LAST["residual"] = float(np.max(np.asarray(residual)))


def iterative_refinement(
    a: jax.Array,
    b: jax.Array,
    x0: jax.Array,
    solve_fn: Callable[[jax.Array], jax.Array],
    *,
    tolerance: float,
    max_iters: int = DEFAULT_MAX_ITERS,
) -> tuple[jax.Array, RefineInfo]:
    """Refine ``x0`` toward ``solve(a, b)`` until the relative residual
    drops to ``tolerance`` or ``max_iters`` sweeps elapse.

    ``solve_fn`` maps a residual to a correction through the approximate
    (e.g. bf16) factors; ``a``/``b`` are consumed in f32 so the residual is
    measured against the exact operand.  Works for vector and matrix RHS
    (the residual norm is Frobenius over all columns).
    """
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    bnorm = jnp.maximum(jnp.linalg.norm(b32), jnp.float32(1e-30))

    def resid_norm(x):
        return jnp.linalg.norm(b32 - a32 @ x)

    def cond(carry):
        x, rn, it = carry
        return jnp.logical_and(rn > tolerance * bnorm, it < max_iters)

    def body(carry):
        x, _, it = carry
        r = b32 - a32 @ x
        x = x + solve_fn(r).astype(jnp.float32)
        return (x, resid_norm(x), it + 1)

    x0 = x0.astype(jnp.float32)
    x, rn, iters = jax.lax.while_loop(
        cond, body, (x0, resid_norm(x0), jnp.int32(0))
    )
    rel = rn / bnorm
    jax.debug.callback(_note, iters, rel)
    return x, RefineInfo(iterations=iters, residual=rel)
