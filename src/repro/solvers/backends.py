"""Backend registrations: every solver generation in one table.

Importing this module (done by ``repro.solvers``) populates the registry
with all existing implementations — the fused megakernel, the legacy
multi-launch blocked driver, VMEM/tiled substitution, the banded
blocked/tiled/scalar family, the batched VMEM grid kernels, the
multi-device shard_map LU, and the pure-jnp mirrors.  The static
``priority`` functions reproduce the pre-registry hardcoded dispatch
(fused-for-fp32, the 2048-order solve VMEM threshold, the 6 MB banded byte
cap) so a cache-less process is behaviour-identical to the historical
``kernels/ops.py`` tables.

Adding a backend is one :func:`repro.solvers.registry.register` call — see
``src/repro/solvers/README.md`` for the recipe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import banded as _core_banded
from repro.core import blocked as _core_blocked
from repro.core import solve as _core_solve
from repro.kernels import banded as _kbanded
from repro.kernels import batched_lu as _kbatched
from repro.kernels import ebv_lu as _k
from repro.kernels import trsm as _trsm

from .problem import Problem
from .registry import Backend, register

__all__ = ["SOLVE_VMEM_MAX_N", "BANDED_VMEM_MAX_BYTES", "BATCHED_VMEM_MAX_N", "banded_static_impl"]

# Above this order the packed (n, n) LU no longer comfortably shares VMEM
# with an RHS tile, so the static solve choice switches to the tiled driver.
SOLVE_VMEM_MAX_N = 2048

# Above this many skewed-band bytes the static banded choice switches from
# the VMEM-resident blocked kernel to the HBM-streaming tiled kernel (the
# VMEM kernel holds the skewed band twice — in and out — on real TPUs).
BANDED_VMEM_MAX_BYTES = 6 * 2**20

# Largest per-system order the batched grid kernels keep VMEM-resident
# ((n, n) matrix + (n, m) RHS per grid program).
BATCHED_VMEM_MAX_N = 1024


def _itemsize(p: Problem) -> int:
    return jnp.dtype(p.dtype).itemsize


def _is_f32(p: Problem) -> bool:
    return p.dtype == "float32"


def _local(p: Problem) -> bool:
    return p.devices == 1


def _banded_skew_bytes(p: Problem, block: int | None = None) -> int:
    c = _core_banded.band_block_size(p.n, p.bw, block)
    return _core_banded.skew_rows(p.n, p.bw, c) * (c + 2 * p.bw) * _itemsize(p)


def banded_static_impl(n: int, bw: int, block: int | None, itemsize: int) -> str:
    """The historical banded auto rule (kept callable for the shim/tests)."""
    c = _core_banded.band_block_size(n, bw, block)
    skew_bytes = _core_banded.skew_rows(n, bw, c) * (c + 2 * bw) * itemsize
    return "pallas_blocked" if skew_bytes <= BANDED_VMEM_MAX_BYTES else "pallas_tiled"


# ---------------------------------------------------------------------------
# jitted wrappers for the pure-jnp mirrors (the Pallas entry points are
# already jitted at their definitions; the mirrors were relying on the old
# monolithic jit around ops.* and would otherwise run eagerly)
# ---------------------------------------------------------------------------
_fused_blocked_lu_j = jax.jit(_core_blocked.fused_blocked_lu, static_argnames=("block",))
_lu_solve_j = jax.jit(_core_solve.lu_solve)


@functools.partial(jax.jit, static_argnames=("block", "col_tile", "interpret"))
def _pallas_blocked_lu(a, *, block: int, col_tile: int, interpret: bool | None):
    """Legacy multi-launch blocked driver: one panel kernel + one fused
    bi-vector step kernel per block column (kept as the forced-impl
    baseline; see kernels/README.md for the launch/traffic math)."""
    n = a.shape[-1]
    block = min(block, n)
    for k0 in range(0, n, block):
        b = min(block, n - k0)
        pan = _k.panel(a[k0:, k0 : k0 + b], interpret=interpret)
        a = a.at[k0:, k0 : k0 + b].set(pan)
        w = n - k0 - b
        if w > 0:
            ct = min(col_tile, w)
            if w % ct:
                # Pad the trailing width to the next tile multiple (tiles
                # capped at 128 lanes) instead of halving the tile — odd
                # widths used to degrade to 1-column tiles.  Zero columns are
                # inert through trsm and the rank-b update.
                ct = min(col_tile, 128)
                wp = -(-w // ct) * ct
                top = jnp.pad(a[k0 : k0 + b, k0 + b :], ((0, 0), (0, wp - w)))
                trail = jnp.pad(a[k0 + b :, k0 + b :], ((0, 0), (0, wp - w)))
                u12, new_trail = _k.fused_step(pan, top, trail, col_tile=ct, interpret=interpret)
                u12, new_trail = u12[:, :w], new_trail[:, :w]
            else:
                u12, new_trail = _k.fused_step(
                    pan, a[k0 : k0 + b, k0 + b :], a[k0 + b :, k0 + b :],
                    col_tile=ct, interpret=interpret,
                )
            a = a.at[k0 : k0 + b, k0 + b :].set(u12)
            a = a.at[k0 + b :, k0 + b :].set(new_trail)
    return a


@functools.partial(jax.jit, static_argnames=("block",))
def _batched_xla_lu(a, *, block: int = 256):
    return jax.vmap(lambda m: _core_blocked.fused_blocked_lu(m, block=block))(a)


_batched_xla_solve_j = jax.jit(jax.vmap(_core_solve.lu_solve))


@functools.partial(jax.jit, static_argnames=("bw", "block"))
def _batched_xla_banded_lu(arow, *, bw: int, block: int | None = None):
    return jax.vmap(lambda m: _core_banded.banded_lu_blocked(m, bw=bw, block=block))(arow)


@functools.partial(jax.jit, static_argnames=("bw", "block"))
def _batched_xla_banded_solve(lu_band, b, *, bw: int, block: int | None = None):
    return jax.vmap(lambda l, r: _core_banded.banded_solve_blocked(l, r, bw=bw, block=block))(lu_band, b)


def _distributed_lu(problem, a, *, mesh, axis="model", block=64, placement="ebv_folded", **_):
    from repro.core.distributed import distributed_blocked_lu

    return distributed_blocked_lu(a, mesh, axis=axis, block=block, placement=placement)


def _distributed_linear_solve(problem, a, b, *, mesh, axis="model", block=64, placement="ebv_folded", **_):
    from repro.core.distributed import distributed_lu_solve

    return distributed_lu_solve(a, b, mesh, axis=axis, block=block, placement=placement)


# ---------------------------------------------------------------------------
# dense factor
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_fused", op="factor", structure="dense",
    call=lambda p, a, *, block=256, interpret=None, **_: _k.lu_fused(a, block=block, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p),
    priority=lambda p: 3.0,
    vmem_bytes=lambda p: 3 * p.n * 256 * _itemsize(p),  # three (N, B) scratch slabs
))
register(Backend(
    name="xla", op="factor", structure="dense",
    call=lambda p, a, *, block=256, interpret=None, **_: _fused_blocked_lu_j(a, block=block),
    supports=_local,
    priority=lambda p: 2.0,  # static winner for non-fp32 (fused is fp32-only)
))
register(Backend(
    name="pallas_vmem", op="factor", structure="dense",
    call=lambda p, a, *, interpret=None, **_: _k.lu_vmem(a, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and p.n <= 4096,
    priority=lambda p: 1.0,
    autotune=False,  # not value-identical to the fused/xla twins
    vmem_bytes=lambda p: 2 * p.n * p.n * _itemsize(p),
))
register(Backend(
    name="pallas_blocked", op="factor", structure="dense",
    call=lambda p, a, *, block=256, col_tile=256, interpret=None, **_:
        _pallas_blocked_lu(a, block=block, col_tile=col_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 0.0,
    autotune=False,  # dominated multi-launch legacy driver (forced-impl only)
))
register(Backend(
    name="distributed", op="factor", structure="dense",
    call=_distributed_lu,
    supports=lambda p: p.devices > 1,
    priority=lambda p: 10.0,
    autotune=False,  # needs a mesh; not shootable by the single-host harness
))

# ---------------------------------------------------------------------------
# dense solve
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_vmem", op="solve", structure="dense",
    call=lambda p, lu, b, *, rhs_tile=256, interpret=None, **_:
        _trsm.solve_vmem(lu, b, rhs_tile=rhs_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 3.0 if p.n <= SOLVE_VMEM_MAX_N else 0.0,
    vmem_bytes=lambda p: (p.n * p.n + p.n * max(p.rhs, 1)) * _itemsize(p),
))
register(Backend(
    name="pallas_tiled", op="solve", structure="dense",
    call=lambda p, lu, b, *, block=256, rhs_tile=256, interpret=None, **_:
        _trsm.solve_tiled(lu, b, block=block, rhs_tile=rhs_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="xla", op="solve", structure="dense",
    call=lambda p, lu, b, **_: _lu_solve_j(lu, b),
    supports=_local,
    priority=lambda p: 0.5,
))

# ---------------------------------------------------------------------------
# banded factor
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_blocked", op="factor", structure="banded",
    call=lambda p, arow, *, bw, block=None, interpret=None, **_:
        _kbanded.banded_lu_blocked(arow, bw=bw, block=block, interpret=interpret),
    supports=_local,
    priority=lambda p: 3.0 if _banded_skew_bytes(p) <= BANDED_VMEM_MAX_BYTES else 0.0,
    vmem_bytes=lambda p: 2 * _banded_skew_bytes(p),
))
register(Backend(
    name="pallas_tiled", op="factor", structure="banded",
    call=lambda p, arow, *, bw, block=None, interpret=None, **_:
        _kbanded.banded_lu_tiled(arow, bw=bw, block=block, interpret=interpret),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="xla", op="factor", structure="banded",
    call=lambda p, arow, *, bw, block=None, **_: _core_banded.banded_lu_blocked(arow, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 0.5,
))
register(Backend(
    name="pallas_scalar", op="factor", structure="banded",
    call=lambda p, arow, *, bw, interpret=None, **_:
        _kbanded.banded_lu_kernelized(arow, bw=bw, interpret=interpret),
    supports=_local,
    priority=lambda p: 0.2,
    autotune=False,  # legacy scalar-sequential kernel (forced-impl only)
))
register(Backend(
    name="xla_scalar", op="factor", structure="banded",
    call=lambda p, arow, *, bw, **_: _core_banded.banded_lu(arow, bw=bw),
    supports=_local,
    priority=lambda p: 0.1,
    autotune=False,  # not value-identical to the blocked twins
))

# ---------------------------------------------------------------------------
# banded solve
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas", op="solve", structure="banded",
    call=lambda p, lub, b, *, bw, block=None, rhs_tile=256, interpret=None, **_:
        _kbanded.banded_solve_kernelized(lub, b, bw=bw, block=block, rhs_tile=rhs_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 2.0,
))
register(Backend(
    name="xla", op="solve", structure="banded",
    call=lambda p, lub, b, *, bw, block=None, **_:
        _core_banded.banded_solve_blocked(lub, b, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="xla_scalar", op="solve", structure="banded",
    # multi-RHS capability slot: the scalar sweep is vector-only (its padded
    # carry is 1-D), so a coalesced stacked-RHS dispatch (serve.solve_service)
    # must never be steered here even when the measured cache (keyed without
    # rhs) says it wins for vector solves.
    call=lambda p, lub, b, *, bw, **_: _core_banded.banded_solve(lub, b, bw=bw),
    supports=lambda p: _local(p) and p.rhs <= 1,
    priority=lambda p: 0.5,  # statically dominated; wins via measurement on
                             # this container (BENCH_kernels.json, banded_solve_*)
))

# ---------------------------------------------------------------------------
# batched dense (optimizer path: many small independent systems)
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_vmem", op="factor", structure="batched_dense",
    call=lambda p, a, *, interpret=None, **_: _kbatched.batched_lu_vmem(a, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and p.n <= BATCHED_VMEM_MAX_N,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: 2 * p.n * p.n * _itemsize(p),  # per grid program
))
register(Backend(
    name="xla", op="factor", structure="batched_dense",
    call=lambda p, a, *, block=256, **_: _batched_xla_lu(a, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="pallas_vmem", op="solve", structure="batched_dense",
    # rhs-aware capability: each grid program holds its whole (n, rhs) RHS
    # in VMEM next to the (n, n) factors, so a wide coalesced stack must
    # overflow to the vmapped mirror rather than the kernel.
    call=lambda p, lu, b, *, interpret=None, **_: _kbatched.batched_lu_solve_vmem(lu, b, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and p.n <= BATCHED_VMEM_MAX_N
        and max(p.rhs, 1) <= 4 * p.n,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: (2 * p.n * p.n + 2 * p.n * max(p.rhs, 1)) * _itemsize(p),
))
register(Backend(
    name="xla", op="solve", structure="batched_dense",
    call=lambda p, lu, b, **_: _batched_xla_solve_j(lu, b),
    supports=_local,
    priority=lambda p: 1.0,
))

# ---------------------------------------------------------------------------
# batched banded (optimizer / CFD ensemble path)
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_vmem", op="factor", structure="batched_banded",
    call=lambda p, arow, *, bw, block=None, interpret=None, **_:
        _kbanded.batched_banded_lu_vmem(arow, bw=bw, block=block, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and _banded_skew_bytes(p) <= BANDED_VMEM_MAX_BYTES,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: 2 * _banded_skew_bytes(p),
))
register(Backend(
    name="xla", op="factor", structure="batched_banded",
    call=lambda p, arow, *, bw, block=None, **_: _batched_xla_banded_lu(arow, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="pallas_vmem", op="solve", structure="batched_banded",
    # rhs-aware: the per-program RHS ((n, rhs)) shares VMEM with the skewed
    # band, so both must fit under the banded byte cap.
    call=lambda p, lub, b, *, bw, block=None, interpret=None, **_:
        _kbanded.batched_banded_solve_vmem(lub, b, bw=bw, block=block, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p)
        and _banded_skew_bytes(p) + 2 * p.n * max(p.rhs, 1) * _itemsize(p)
            <= BANDED_VMEM_MAX_BYTES,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: 2 * _banded_skew_bytes(p) + 2 * p.n * max(p.rhs, 1) * _itemsize(p),
))
register(Backend(
    name="xla", op="solve", structure="batched_banded",
    call=lambda p, lub, b, *, bw, block=None, **_: _batched_xla_banded_solve(lub, b, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))

# ---------------------------------------------------------------------------
# fused linear_solve (factor + substitution in one backend) — multi-device
# only; single-device linear_solve composes a factor and a solve selection
# in repro.kernels.ops.
# ---------------------------------------------------------------------------
register(Backend(
    name="distributed", op="linear_solve", structure="dense",
    call=_distributed_linear_solve,
    supports=lambda p: p.devices > 1,
    priority=lambda p: 10.0,
    autotune=False,
))
