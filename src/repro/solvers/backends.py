"""Backend registrations: every solver generation in one table.

Importing this module (done by ``repro.solvers``) populates the registry
with all existing implementations — the fused megakernel, the legacy
multi-launch blocked driver, VMEM/tiled substitution, the banded
blocked/tiled/scalar family, the batched VMEM grid kernels, the
multi-device shard_map LU, and the pure-jnp mirrors.  The static
``priority`` functions reproduce the pre-registry hardcoded dispatch
(fused-for-fp32, the 2048-order solve VMEM threshold, the 6 MB banded byte
cap) so a cache-less process is behaviour-identical to the historical
``kernels/ops.py`` tables.

Adding a backend is one :func:`repro.solvers.registry.register` call — see
``src/repro/solvers/README.md`` for the recipe.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import banded as _core_banded
from repro.core import blocked as _core_blocked
from repro.core import factorization as _fz
from repro.core import pivoted as _core_pivoted
from repro.core import randomized as _core_rand
from repro.core import refine as _core_refine
from repro.core import solve as _core_solve
from repro.core.factorization import packed_of as _packed
from repro.kernels import banded as _kbanded
from repro.kernels import batched_lu as _kbatched
from repro.kernels import ebv_lu as _k
from repro.kernels import trsm as _trsm

from .problem import Problem
from .registry import Backend, register

__all__ = [
    "SOLVE_VMEM_MAX_N",
    "BANDED_VMEM_MAX_BYTES",
    "BATCHED_VMEM_MAX_N",
    "BF16_IR_RESIDUAL_FLOOR",
    "RAND_LU_RESIDUAL_BOUND",
    "IR_MAX_ITERS",
    "banded_static_impl",
]

# Above this order the packed (n, n) LU no longer comfortably shares VMEM
# with an RHS tile, so the static solve choice switches to the tiled driver.
SOLVE_VMEM_MAX_N = 2048

# Above this many skewed-band bytes the static banded choice switches from
# the VMEM-resident blocked kernel to the HBM-streaming tiled kernel (the
# VMEM kernel holds the skewed band twice — in and out — on real TPUs).
BANDED_VMEM_MAX_BYTES = 6 * 2**20

# Largest per-system order the batched grid kernels keep VMEM-resident
# ((n, n) matrix + (n, m) RHS per grid program).
BATCHED_VMEM_MAX_N = 1024

# ---------------------------------------------------------------------------
# accuracy tiers (the tolerance gate's residual guarantees)
# ---------------------------------------------------------------------------
# Tightest relative residual the bf16-factor + f32-refinement path commits
# to for diagonally-dominant f32 operands: refinement contracts by the bf16
# unit roundoff (~2^-8) per sweep and floors at f32 residual round-off;
# 1e-6 is reached in 2-3 sweeps at n ≤ 2048 (test_accuracy_tiers pins it).
BF16_IR_RESIDUAL_FLOOR = 1e-6

# Residual the randomized rank-k tier guarantees for its documented operand
# class (numerical rank ≤ k, range-consistent RHS) — see
# repro.core.randomized; measured each run by the ``rand_lu_n2048_k256``
# bench row and gated in scripts/check.sh (observed ~5e-7, bound 1e-3).
RAND_LU_RESIDUAL_BOUND = 1e-3

# Refinement-sweep cap: bounds serving-tier latency; the count actually
# taken surfaces through repro.core.refine.last_refinement().
IR_MAX_ITERS = _core_refine.DEFAULT_MAX_ITERS


def _itemsize(p: Problem) -> int:
    return jnp.dtype(p.dtype).itemsize


def _is_f32(p: Problem) -> bool:
    return p.dtype == "float32"


def _local(p: Problem) -> bool:
    return p.devices == 1


def _banded_skew_bytes(p: Problem, block: int | None = None) -> int:
    c = _core_banded.band_block_size(p.n, p.bw, block)
    return _core_banded.skew_rows(p.n, p.bw, c) * (c + 2 * p.bw) * _itemsize(p)


def banded_static_impl(n: int, bw: int, block: int | None, itemsize: int) -> str:
    """The historical banded auto rule (kept callable for the shim/tests)."""
    c = _core_banded.band_block_size(n, bw, block)
    skew_bytes = _core_banded.skew_rows(n, bw, c) * (c + 2 * bw) * itemsize
    return "pallas_blocked" if skew_bytes <= BANDED_VMEM_MAX_BYTES else "pallas_tiled"


# ---------------------------------------------------------------------------
# jitted wrappers for the pure-jnp mirrors (the Pallas entry points are
# already jitted at their definitions; the mirrors were relying on the old
# monolithic jit around ops.* and would otherwise run eagerly)
# ---------------------------------------------------------------------------
_fused_blocked_lu_j = jax.jit(_core_blocked.fused_blocked_lu, static_argnames=("block",))
_lu_solve_j = jax.jit(_core_solve.lu_solve)


# ---------------------------------------------------------------------------
# Factorization-artifact adapters (the inverted-diagonal solve fast path).
# Raw legacy operands are accepted through the one-release enrich-on-the-fly
# shim (dense_artifact / banded_artifact); enriched artifacts go straight to
# the kernels with zero layout work.
# ---------------------------------------------------------------------------
def _dense_inverted_call(lu, b, *, block, rhs_tile, interpret):
    art = _fz.dense_artifact(lu, block=block or 256)
    return _trsm.solve_inverted(
        art.packed, art.linv, art.uinv, b, rhs_tile=rhs_tile, interpret=interpret
    )


def _dense_inverted_mirror_call(lu, b, *, block):
    art = _fz.dense_artifact(lu, block=block or 256)
    return _fz.dense_inverted_solve(art.packed, art.linv, art.uinv, b, block=art.block)


def _banded_inverted_call(lub, b, *, bw, block, rhs_tile, interpret):
    art = _fz.banded_artifact(lub, bw=bw, block=block)
    return _kbanded.banded_solve_inverted(
        art.linv, art.uinv, art.tlo, art.tup, b,
        n=art.n, bw=art.bw, rhs_tile=rhs_tile, interpret=interpret,
    )


def _banded_inverted_mirror_call(lub, b, *, bw, block):
    art = _fz.banded_artifact(lub, bw=bw, block=block)
    return _fz.banded_inverted_solve(
        art.linv, art.uinv, art.tlo, art.tup, b, n=art.n, bw=art.bw
    )


@functools.partial(jax.jit, static_argnames=("block",))
def _batched_dense_inverted_solve(lu, linv, uinv, b, *, block):
    return jax.vmap(
        lambda l, li, ui, r: _fz.dense_inverted_solve(l, li, ui, r, block=block)
    )(lu, linv, uinv, b)


@functools.partial(jax.jit, static_argnames=("n", "bw"))
def _batched_banded_inverted_solve(linv, uinv, tlo, tup, b, *, n, bw):
    return jax.vmap(
        lambda li, ui, lo, up, r: _fz.banded_inverted_solve(li, ui, lo, up, r, n=n, bw=bw)
    )(linv, uinv, tlo, tup, b)


def _batched_dense_inverted_call(lu, b, *, block):
    art = _fz.dense_artifact(lu, block=block or 256)
    return _batched_dense_inverted_solve(art.packed, art.linv, art.uinv, b, block=art.block)


def _batched_banded_inverted_call(lub, b, *, bw, block):
    art = _fz.banded_artifact(lub, bw=bw, block=block)
    return _batched_banded_inverted_solve(
        art.linv, art.uinv, art.tlo, art.tup, b, n=art.n, bw=art.bw
    )


def _banded_inverted_vmem_bytes(p: Problem) -> int:
    # the (S, C, C) inverse stacks are VMEM-resident for the whole program,
    # plus the two (S, C, bw) transfer stacks and one equalized RHS tile
    c = _core_banded.band_block_size(p.n, p.bw, None)
    s = -(-p.n // c)
    rt = _fz.equalized_rhs_tile(max(p.rhs, 1), 512)
    return (2 * s * c * c + 2 * s * c * p.bw + 2 * s * c * rt) * _itemsize(p)


@functools.partial(jax.jit, static_argnames=("block", "col_tile", "interpret"))
def _pallas_blocked_lu(a, *, block: int, col_tile: int, interpret: bool | None):
    """Legacy multi-launch blocked driver: one panel kernel + one fused
    bi-vector step kernel per block column (kept as the forced-impl
    baseline; see kernels/README.md for the launch/traffic math)."""
    n = a.shape[-1]
    block = min(block, n)
    for k0 in range(0, n, block):
        b = min(block, n - k0)
        pan = _k.panel(a[k0:, k0 : k0 + b], interpret=interpret)
        a = a.at[k0:, k0 : k0 + b].set(pan)
        w = n - k0 - b
        if w > 0:
            ct = min(col_tile, w)
            if w % ct:
                # Pad the trailing width to the next tile multiple (tiles
                # capped at 128 lanes) instead of halving the tile — odd
                # widths used to degrade to 1-column tiles.  Zero columns are
                # inert through trsm and the rank-b update.
                ct = min(col_tile, 128)
                wp = -(-w // ct) * ct
                top = jnp.pad(a[k0 : k0 + b, k0 + b :], ((0, 0), (0, wp - w)))
                trail = jnp.pad(a[k0 + b :, k0 + b :], ((0, 0), (0, wp - w)))
                u12, new_trail = _k.fused_step(pan, top, trail, col_tile=ct, interpret=interpret)
                u12, new_trail = u12[:, :w], new_trail[:, :w]
            else:
                u12, new_trail = _k.fused_step(
                    pan, a[k0 : k0 + b, k0 + b :], a[k0 + b :, k0 + b :],
                    col_tile=ct, interpret=interpret,
                )
            a = a.at[k0 : k0 + b, k0 + b :].set(u12)
            a = a.at[k0 + b :, k0 + b :].set(new_trail)
    return a


@functools.partial(jax.jit, static_argnames=("block",))
def _batched_xla_lu(a, *, block: int = 256):
    return jax.vmap(lambda m: _core_blocked.fused_blocked_lu(m, block=block))(a)


_batched_xla_solve_j = jax.jit(jax.vmap(_core_solve.lu_solve))


@functools.partial(jax.jit, static_argnames=("bw", "block"))
def _batched_xla_banded_lu(arow, *, bw: int, block: int | None = None):
    return jax.vmap(lambda m: _core_banded.banded_lu_blocked(m, bw=bw, block=block))(arow)


@functools.partial(jax.jit, static_argnames=("bw", "block"))
def _batched_xla_banded_solve(lu_band, b, *, bw: int, block: int | None = None):
    return jax.vmap(lambda l, r: _core_banded.banded_solve_blocked(l, r, bw=bw, block=block))(lu_band, b)


def _distributed_lu(problem, a, *, mesh, axis="model", block=64, placement="ebv_folded", **_):
    from repro.core.distributed import distributed_blocked_lu

    return distributed_blocked_lu(a, mesh, axis=axis, block=block, placement=placement)


def _distributed_linear_solve(problem, a, b, *, mesh, axis="model", block=64, placement="ebv_folded", **_):
    from repro.core.distributed import distributed_lu_solve

    return distributed_lu_solve(a, b, mesh, axis=axis, block=block, placement=placement)


# ---------------------------------------------------------------------------
# dense factor
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_fused", op="factor", structure="dense",
    call=lambda p, a, *, block=256, interpret=None, **_: _k.lu_fused(a, block=block, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p),
    priority=lambda p: 3.0,
    vmem_bytes=lambda p: 3 * p.n * 256 * _itemsize(p),  # three (N, B) scratch slabs
))
register(Backend(
    name="xla", op="factor", structure="dense",
    call=lambda p, a, *, block=256, interpret=None, **_: _fused_blocked_lu_j(a, block=block),
    supports=_local,
    priority=lambda p: 2.0,  # static winner for non-fp32 (fused is fp32-only)
))
register(Backend(
    name="pallas_vmem", op="factor", structure="dense",
    call=lambda p, a, *, interpret=None, **_: _k.lu_vmem(a, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and p.n <= 4096,
    priority=lambda p: 1.0,
    autotune=False,  # not value-identical to the fused/xla twins
    vmem_bytes=lambda p: 2 * p.n * p.n * _itemsize(p),
))
register(Backend(
    name="pallas_blocked", op="factor", structure="dense",
    call=lambda p, a, *, block=256, col_tile=256, interpret=None, **_:
        _pallas_blocked_lu(a, block=block, col_tile=col_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 0.0,
    autotune=False,  # dominated multi-launch legacy driver (forced-impl only)
))
register(Backend(
    name="distributed", op="factor", structure="dense",
    call=_distributed_lu,
    supports=lambda p: p.devices > 1,
    priority=lambda p: 10.0,
    autotune=False,  # needs a mesh; not shootable by the single-host harness
))
register(Backend(
    name="pivoted", op="factor", structure="dense",
    # last-resort fallback for operands outside the no-pivot class: the
    # escalation funnel reaches it after every no-pivot twin fails its
    # health screen.  Lowest priority so it can never win a default
    # selection; O(n) sequential rank-1 steps, so it must not.
    call=lambda p, a, **_: _core_pivoted.pivoted_lu(a),
    supports=_local,
    priority=lambda p: 0.05,
    autotune=False,  # different factor layout (PivotedFactors, not packed)
))

# ---------------------------------------------------------------------------
# dense solve
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_vmem", op="solve", structure="dense",
    call=lambda p, lu, b, *, rhs_tile=256, interpret=None, **_:
        _trsm.solve_vmem(_packed(lu), b, rhs_tile=rhs_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 3.0 if p.n <= SOLVE_VMEM_MAX_N else 0.0,
    vmem_bytes=lambda p: (p.n * p.n + p.n * max(p.rhs, 1)) * _itemsize(p),
))
register(Backend(
    name="pallas_tiled", op="solve", structure="dense",
    call=lambda p, lu, b, *, block=256, rhs_tile=256, interpret=None, **_:
        _trsm.solve_tiled(_packed(lu), b, block=block, rhs_tile=rhs_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="pallas_inverted", op="solve", structure="dense",
    # Factorization-artifact fast path: substitution against the factor-time
    # pre-inverted diagonal blocks (raw operands are enriched on the fly by
    # the one-release shim — the `enriched` capability keeps auto-selection
    # from ever steering a raw operand here).
    call=lambda p, lu, b, *, block=None, rhs_tile=512, interpret=None, **_:
        _dense_inverted_call(lu, b, block=block, rhs_tile=rhs_tile, interpret=interpret),
    supports=lambda p: _local(p) and p.enriched,
    priority=lambda p: 0.75,  # below the defaults: reach it measured or forced
    autotune=False,  # not value-identical to the strip-recurrence twins
    vmem_bytes=lambda p: (2 * p.n * 256 + p.n * max(p.rhs, 1)) * _itemsize(p),
))
register(Backend(
    name="xla_inverted", op="solve", structure="dense",
    # pure-jnp bitwise mirror of pallas_inverted (twin contract)
    call=lambda p, lu, b, *, block=None, interpret=None, **_:
        _dense_inverted_mirror_call(lu, b, block=block),
    supports=lambda p: _local(p) and p.enriched,
    priority=lambda p: 0.1,
    autotune=False,
))
register(Backend(
    name="xla", op="solve", structure="dense",
    call=lambda p, lu, b, **_: _lu_solve_j(_packed(lu), b),
    supports=_local,
    priority=lambda p: 0.5,
))
register(Backend(
    name="pivoted", op="solve", structure="dense",
    # consumes PivotedFactors (row permutation applied to the RHS before
    # substitution) — never auto-selected; repro.kernels.ops.lu_solve
    # forces it when handed pivoted factors, like the rank-k pattern.
    call=lambda p, factors, b, **_: _core_pivoted.pivoted_solve(factors, b),
    supports=lambda p: False,
    priority=lambda p: 0.0,
    autotune=False,
))

# ---------------------------------------------------------------------------
# banded factor
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_blocked", op="factor", structure="banded",
    call=lambda p, arow, *, bw, block=None, interpret=None, **_:
        _kbanded.banded_lu_blocked(arow, bw=bw, block=block, interpret=interpret),
    supports=_local,
    priority=lambda p: 3.0 if _banded_skew_bytes(p) <= BANDED_VMEM_MAX_BYTES else 0.0,
    vmem_bytes=lambda p: 2 * _banded_skew_bytes(p),
))
register(Backend(
    name="pallas_tiled", op="factor", structure="banded",
    call=lambda p, arow, *, bw, block=None, interpret=None, **_:
        _kbanded.banded_lu_tiled(arow, bw=bw, block=block, interpret=interpret),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="xla", op="factor", structure="banded",
    call=lambda p, arow, *, bw, block=None, **_: _core_banded.banded_lu_blocked(arow, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 0.5,
))
register(Backend(
    name="pallas_scalar", op="factor", structure="banded",
    call=lambda p, arow, *, bw, interpret=None, **_:
        _kbanded.banded_lu_kernelized(arow, bw=bw, interpret=interpret),
    supports=_local,
    priority=lambda p: 0.2,
    autotune=False,  # legacy scalar-sequential kernel (forced-impl only)
))
register(Backend(
    name="xla_scalar", op="factor", structure="banded",
    call=lambda p, arow, *, bw, **_: _core_banded.banded_lu(arow, bw=bw),
    supports=_local,
    priority=lambda p: 0.1,
    autotune=False,  # not value-identical to the blocked twins
))

# ---------------------------------------------------------------------------
# banded solve
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas", op="solve", structure="banded",
    call=lambda p, lub, b, *, bw, block=None, rhs_tile=256, interpret=None, **_:
        _kbanded.banded_solve_kernelized(_packed(lub), b, bw=bw, block=block, rhs_tile=rhs_tile, interpret=interpret),
    supports=_local,
    priority=lambda p: 2.0,
))
register(Backend(
    name="pallas_inverted", op="solve", structure="banded",
    # Factorization-artifact fast path: two-phase batched-GEMM substitution
    # against the factor-time inverted windows + pre-coupled transfer
    # blocks.  Statically below the blocked kernel (cache-less selection is
    # unchanged); the measured shootout rows (banded_solve_n16384_*) steer
    # enriched dispatches here where it wins.  The `enriched` capability
    # keeps raw-operand dispatches from paying the on-the-fly enrichment.
    call=lambda p, lub, b, *, bw, block=None, rhs_tile=512, interpret=None, **_:
        _banded_inverted_call(lub, b, bw=bw, block=block, rhs_tile=rhs_tile, interpret=interpret),
    supports=lambda p: _local(p) and p.enriched,
    priority=lambda p: 1.5,
    vmem_bytes=_banded_inverted_vmem_bytes,
))
register(Backend(
    name="xla_inverted", op="solve", structure="banded",
    # pure-jnp bitwise mirror of pallas_inverted (twin contract)
    call=lambda p, lub, b, *, bw, block=None, **_:
        _banded_inverted_mirror_call(lub, b, bw=bw, block=block),
    supports=lambda p: _local(p) and p.enriched,
    priority=lambda p: 0.1,
    autotune=False,
))
register(Backend(
    name="xla", op="solve", structure="banded",
    call=lambda p, lub, b, *, bw, block=None, **_:
        _core_banded.banded_solve_blocked(_packed(lub), b, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="xla_scalar", op="solve", structure="banded",
    # multi-RHS capability slot: the scalar sweep is vector-only (its padded
    # carry is 1-D), so a coalesced stacked-RHS dispatch (serve.solve_service)
    # must never be steered here even when the measured cache (keyed without
    # rhs) says it wins for vector solves.
    # rhs <= 1 admits both a vector and a single-column coalesced stack
    # (serve dispatches (n, 1)); the sweep itself is strictly 1-D, so
    # squeeze/re-expand around it.
    call=lambda p, lub, b, *, bw, **_: (
        _core_banded.banded_solve(_packed(lub), b[:, 0], bw=bw)[:, None]
        if getattr(b, "ndim", 1) == 2
        else _core_banded.banded_solve(_packed(lub), b, bw=bw)),
    supports=lambda p: _local(p) and p.rhs <= 1,
    priority=lambda p: 0.5,  # statically dominated; wins via measurement on
                             # this container (BENCH_kernels.json, banded_solve_*)
))

# ---------------------------------------------------------------------------
# batched dense (optimizer path: many small independent systems)
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_vmem", op="factor", structure="batched_dense",
    call=lambda p, a, *, interpret=None, **_: _kbatched.batched_lu_vmem(a, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and p.n <= BATCHED_VMEM_MAX_N,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: 2 * p.n * p.n * _itemsize(p),  # per grid program
))
register(Backend(
    name="xla", op="factor", structure="batched_dense",
    call=lambda p, a, *, block=256, **_: _batched_xla_lu(a, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="pallas_vmem", op="solve", structure="batched_dense",
    # rhs-aware capability: each grid program holds its whole (n, rhs) RHS
    # in VMEM next to the (n, n) factors, so a wide coalesced stack must
    # overflow to the vmapped mirror rather than the kernel.
    call=lambda p, lu, b, *, interpret=None, **_: _kbatched.batched_lu_solve_vmem(_packed(lu), b, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and p.n <= BATCHED_VMEM_MAX_N
        and max(p.rhs, 1) <= 4 * p.n,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: (2 * p.n * p.n + 2 * p.n * max(p.rhs, 1)) * _itemsize(p),
))
register(Backend(
    name="xla", op="solve", structure="batched_dense",
    call=lambda p, lu, b, **_: _batched_xla_solve_j(_packed(lu), b),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="pallas_inverted", op="solve", structure="batched_dense",
    # batched analog of the dense inverted-diagonal path (the grouped
    # optimizer stacks): routes through the vmapped mirror — value-identical
    # to the unbatched twins, reached by name via ops._batched_impl.
    call=lambda p, lu, b, *, block=None, interpret=None, **_:
        _batched_dense_inverted_call(lu, b, block=block),
    supports=lambda p: _local(p) and p.enriched,
    priority=lambda p: 0.75,
    autotune=False,
))

# ---------------------------------------------------------------------------
# batched banded (optimizer / CFD ensemble path)
# ---------------------------------------------------------------------------
register(Backend(
    name="pallas_vmem", op="factor", structure="batched_banded",
    call=lambda p, arow, *, bw, block=None, interpret=None, **_:
        _kbanded.batched_banded_lu_vmem(arow, bw=bw, block=block, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p) and _banded_skew_bytes(p) <= BANDED_VMEM_MAX_BYTES,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: 2 * _banded_skew_bytes(p),
))
register(Backend(
    name="xla", op="factor", structure="batched_banded",
    call=lambda p, arow, *, bw, block=None, **_: _batched_xla_banded_lu(arow, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="pallas_vmem", op="solve", structure="batched_banded",
    # rhs-aware: the per-program RHS ((n, rhs)) shares VMEM with the skewed
    # band, so both must fit under the banded byte cap.
    call=lambda p, lub, b, *, bw, block=None, interpret=None, **_:
        _kbanded.batched_banded_solve_vmem(_packed(lub), b, bw=bw, block=block, interpret=interpret),
    supports=lambda p: _is_f32(p) and _local(p)
        and _banded_skew_bytes(p) + 2 * p.n * max(p.rhs, 1) * _itemsize(p)
            <= BANDED_VMEM_MAX_BYTES,
    priority=lambda p: 2.0,
    vmem_bytes=lambda p: 2 * _banded_skew_bytes(p) + 2 * p.n * max(p.rhs, 1) * _itemsize(p),
))
register(Backend(
    name="xla", op="solve", structure="batched_banded",
    call=lambda p, lub, b, *, bw, block=None, **_: _batched_xla_banded_solve(_packed(lub), b, bw=bw, block=block),
    supports=_local,
    priority=lambda p: 1.0,
))
register(Backend(
    name="pallas_inverted", op="solve", structure="batched_banded",
    # batched analog of the two-phase inverted band solve (vmapped mirror)
    call=lambda p, lub, b, *, bw, block=None, interpret=None, **_:
        _batched_banded_inverted_call(lub, b, bw=bw, block=block),
    supports=lambda p: _local(p) and p.enriched,
    priority=lambda p: 1.5,
    autotune=False,
))

# ---------------------------------------------------------------------------
# fused linear_solve (factor + substitution in one backend) — multi-device
# only; single-device linear_solve composes a factor and a solve selection
# in repro.kernels.ops.
# ---------------------------------------------------------------------------
register(Backend(
    name="distributed", op="linear_solve", structure="dense",
    call=_distributed_linear_solve,
    supports=lambda p: p.devices > 1,
    priority=lambda p: 10.0,
    autotune=False,
))

# ---------------------------------------------------------------------------
# approximate tiers: admitted by the tolerance gate only (residual_bound
# set), so default-tolerance problems never see them.  Single-device
# linear_solve normally composes factor+solve in repro.kernels.ops; a
# tolerance-carrying call consults this slot first, which is where the
# mixed-precision path lives (it needs the full operand for refinement).
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("block", "tolerance", "max_iters", "interpret", "use_kernel"))
def _bf16_ir_solve(a, b, *, block, tolerance, max_iters, interpret, use_kernel):
    """Factor in bf16 (half the factor bytes, MXU-native), refine the
    solution in f32 against the full-precision operand."""
    # bf16 rounds the operand — that is the tier's accuracy class (half the
    # factor input precision) — while the factorization itself accumulates
    # in f32: the MXU contract for bf16 matmuls (bf16 operands, f32
    # accumulator), and ~6x faster than end-to-end bf16 emulation when the
    # kernel runs in interpret mode.
    a16 = a.astype(jnp.bfloat16).astype(jnp.float32)
    lu16 = (
        _k.lu_fused(a16, block=block, interpret=interpret)
        if use_kernel
        else _core_blocked.fused_blocked_lu(a16, block=block)
    )

    # The correction operator runs once per refinement sweep, so its cost
    # multiplies: pre-invert the diagonal blocks once and substitute via
    # the blocked inverted-diagonal sweeps (batched GEMMs) instead of the
    # 2n-step scalar recurrence of core.solve.lu_solve — same bf16-factor
    # accuracy class, the refinement loop still contracts to tolerance.
    linv, uinv = _fz.dense_block_inverses(lu16, block=block)

    def correct(r):
        return _fz.dense_inverted_solve(lu16, linv, uinv, r, block=block)

    x, _info = _core_refine.iterative_refinement(
        a, b, correct(b.astype(jnp.float32)), correct,
        tolerance=tolerance, max_iters=max_iters,
    )
    return x.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tolerance", "max_iters"))
def _bf16_ir_solve_batched(a, b, *, block, tolerance, max_iters):
    # same bf16-rounded-operand / f32-accumulation semantics as the
    # unbatched tier above
    lu16 = jax.vmap(lambda m: _core_blocked.fused_blocked_lu(m, block=block))(
        a.astype(jnp.bfloat16).astype(jnp.float32)
    )

    def one(ai, lui, bi):
        correct = lambda r: _core_solve.lu_solve(lui, r)
        x, _info = _core_refine.iterative_refinement(
            ai, bi, correct(bi.astype(jnp.float32)), correct,
            tolerance=tolerance, max_iters=max_iters,
        )
        return x

    return jax.vmap(one)(a, lu16, b).astype(a.dtype)


def _ir_tolerance(p: Problem) -> float:
    # refine to the caller's tolerance, never past the tier's floor (extra
    # sweeps below the floor only burn the iteration cap)
    return max(p.tolerance, BF16_IR_RESIDUAL_FLOOR)


register(Backend(
    name="bf16_ir", op="linear_solve", structure="dense",
    call=lambda p, a, b, *, block=256, interpret=None, **_: _bf16_ir_solve(
        a, b, block=block, tolerance=_ir_tolerance(p), max_iters=IR_MAX_ITERS,
        interpret=interpret, use_kernel=True),
    supports=lambda p: _is_f32(p) and _local(p),
    priority=lambda p: 5.0,  # the preferred approximate tier once admitted
    autotune=False,  # not value-identical to the exact tier
    residual_bound=lambda p: BF16_IR_RESIDUAL_FLOOR,
    vmem_bytes=lambda p: 3 * p.n * 256 * 2,  # bf16 megakernel scratch slabs
))
register(Backend(
    name="bf16_ir_xla", op="linear_solve", structure="dense",
    call=lambda p, a, b, *, block=256, interpret=None, **_: _bf16_ir_solve(
        a, b, block=block, tolerance=_ir_tolerance(p), max_iters=IR_MAX_ITERS,
        interpret=interpret, use_kernel=False),
    supports=lambda p: _is_f32(p) and _local(p),
    priority=lambda p: 4.0,
    autotune=False,
    residual_bound=lambda p: BF16_IR_RESIDUAL_FLOOR,
))
register(Backend(
    name="bf16_ir", op="linear_solve", structure="batched_dense",
    # the optimizer's grouped (B, n, n) preconditioner systems land here
    # when the run carries a solve tolerance
    call=lambda p, a, b, *, block=256, interpret=None, **_: _bf16_ir_solve_batched(
        a, b, block=block, tolerance=_ir_tolerance(p), max_iters=IR_MAX_ITERS),
    supports=lambda p: _is_f32(p) and _local(p),
    priority=lambda p: 5.0,
    autotune=False,
    residual_bound=lambda p: BF16_IR_RESIDUAL_FLOOR,
))


def _rand_rank(p: Problem, rank) -> int:
    # rank= comes through the public ops; an admitted auto-selection without
    # one sketches at n/8 (the class contract is the caller's to honour)
    return int(rank) if rank else max(1, p.n // 8)


register(Backend(
    name="rand_lu", op="factor", structure="dense",
    call=lambda p, a, *, rank=None, oversample=8, rng_key=None, interpret=None, **_:
        _core_rand.randomized_lu(
            a, rank=_rand_rank(p, rank), oversample=oversample, key=rng_key,
            lu_impl=lambda m: _k.lu_fused(m, interpret=interpret)),
    supports=lambda p: _is_f32(p) and _local(p),
    priority=lambda p: 0.1,  # statically dominated: reach it via rank=/impl=
    autotune=False,
    residual_bound=lambda p: RAND_LU_RESIDUAL_BOUND,
))
register(Backend(
    name="rand_lu", op="solve", structure="dense",
    # consumes RankKFactors, not a packed square factor — never
    # auto-selected; repro.kernels.ops.lu_solve forces it when handed
    # rank-k factors (the serve cache's low-rank tier)
    call=lambda p, factors, b, **_: _core_rand.randomized_solve(factors, b),
    supports=lambda p: False,
    priority=lambda p: 0.0,
    autotune=False,
    residual_bound=lambda p: RAND_LU_RESIDUAL_BOUND,
))
register(Backend(
    name="rand_lu", op="linear_solve", structure="dense",
    call=lambda p, a, b, *, rank=None, oversample=8, rng_key=None, interpret=None, **_:
        _core_rand.randomized_linear_solve(
            a, b, rank=_rand_rank(p, rank), oversample=oversample, key=rng_key,
            lu_impl=lambda m: _k.lu_fused(m, interpret=interpret),
            tolerance=(min(p.tolerance, RAND_LU_RESIDUAL_BOUND) if p.tolerance > 0
                       else RAND_LU_RESIDUAL_BOUND)),
    supports=lambda p: _is_f32(p) and _local(p),
    priority=lambda p: 0.5,  # below bf16_ir: admitted ≠ preferred
    autotune=False,
    residual_bound=lambda p: RAND_LU_RESIDUAL_BOUND,
))


# ---------------------------------------------------------------------------
# multi-device banded: SPIKE split solve vs replicated fallback.
#
# ``spike`` partitions the band into per-device diagonal blocks (see
# repro.core.spike / repro.kernels.spike), admitted only where the spike
# couplings cannot overlap (2·bw ≤ ceil(n/devices)).  ``replicated`` is the
# always-capable fallback: it re-dispatches the same operand as a devices=1
# problem through the ordinary local selection — correctness on one device,
# no scaling.  Both are ``autotune=True`` so the measured cache (keyed on
# ``devices``) weighs SPIKE against replication per (n, bw, devices); with
# no measurement the static priorities prefer SPIKE wherever it is admitted.
# A health-screened/residual-screened SPIKE dispatch demotes to replicated
# through the ordinary escalation funnel.
# ---------------------------------------------------------------------------
def _spike_ok(p: Problem) -> bool:
    from repro.core.spike import spike_supported

    return p.devices > 1 and spike_supported(p.n, p.bw, p.devices)


def _spike_lu(problem, arow, *, bw, mesh=None, axis="model", block=None,
              interpret=None, **_):
    if mesh is not None:
        from repro.kernels.spike import spike_lu_sharded

        return spike_lu_sharded(
            arow, bw=bw, mesh=mesh, axis=axis, block=block, interpret=interpret
        )
    from repro.core.spike import spike_lu

    return spike_lu(arow, bw=bw, devices=problem.devices, block=block)


def _spike_solve(problem, factors, b, *, bw=0, mesh=None, axis="model",
                 block=None, interpret=None, **_):
    if mesh is not None:
        from repro.kernels.spike import spike_solve_sharded

        return spike_solve_sharded(
            factors, b, mesh=mesh, axis=axis, block=block, interpret=interpret
        )
    from repro.core.spike import spike_solve

    return spike_solve(factors, b, block=block)


def _spike_linear_solve(problem, arow, b, *, bw, mesh=None, axis="model",
                        block=None, interpret=None, **_):
    if mesh is not None:
        from repro.kernels.spike import spike_linear_solve_sharded

        return spike_linear_solve_sharded(
            arow, b, bw=bw, mesh=mesh, axis=axis, block=block, interpret=interpret
        )
    from repro.core.spike import spike_linear_solve

    return spike_linear_solve(
        arow, b, bw=bw, devices=problem.devices, block=block
    )


def _replicated_banded_lu(problem, arow, *, bw, mesh=None, axis=None,
                          block=None, interpret=None, **_):
    from .registry import dispatch

    return dispatch(
        dataclasses.replace(problem, devices=1),
        arow, bw=bw, block=block, interpret=interpret,
    )


def _replicated_banded_linear_solve(problem, arow, b, *, bw, mesh=None,
                                    axis=None, block=None, interpret=None, **_):
    # single-device banded linear_solve has no fused backend (it composes in
    # repro.kernels.ops), so replication composes the local factor and solve
    # selections directly
    from .registry import dispatch

    local = dataclasses.replace(problem, devices=1)
    factors = dispatch(
        dataclasses.replace(local, op="factor"),
        arow, bw=bw, block=block, interpret=interpret,
    )
    return dispatch(
        dataclasses.replace(local, op="solve"),
        factors, b, bw=bw, block=block, interpret=interpret,
    )


register(Backend(
    name="spike", op="factor", structure="banded",
    call=_spike_lu,
    supports=_spike_ok,
    priority=lambda p: 10.0,
))
register(Backend(
    name="replicated", op="factor", structure="banded",
    call=_replicated_banded_lu,
    supports=lambda p: p.devices > 1,
    priority=lambda p: 1.0,
))
register(Backend(
    name="spike", op="solve", structure="banded",
    # consumes SpikeFactors, never auto-selected: repro.kernels.ops
    # .banded_solve forces it when handed a SPIKE artifact (the pivoted /
    # rank-k pattern)
    call=_spike_solve,
    supports=lambda p: False,
    priority=lambda p: 0.0,
    autotune=False,
))
register(Backend(
    name="spike", op="linear_solve", structure="banded",
    call=_spike_linear_solve,
    supports=_spike_ok,
    priority=lambda p: 10.0,
))
register(Backend(
    name="replicated", op="linear_solve", structure="banded",
    call=_replicated_banded_linear_solve,
    supports=lambda p: p.devices > 1,
    priority=lambda p: 1.0,
))
