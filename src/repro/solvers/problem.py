"""The :class:`Problem` descriptor — one hashable record per solver call.

Every dispatch decision in the repo flows through a ``Problem``: the public
ops in :mod:`repro.kernels.ops` build one from their array arguments, the
registry filters backends by capability against it, and the autotune cache
keys its measurements on it.  The descriptor is deliberately *shape-level*
(no array values): selection happens at trace time and must be a pure
function of shapes, dtype and device count.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["Problem", "OPS", "STRUCTURES"]

OPS = ("factor", "solve", "linear_solve", "decode")
STRUCTURES = ("dense", "banded", "batched_dense", "batched_banded", "paged_kv")


@dataclasses.dataclass(frozen=True)
class Problem:
    """Shape-level description of one solver invocation.

    ``n``        system order (for banded structures: number of band rows).
    ``bw``       band half-width; 0 for dense structures.
    ``batch``    leading batch size; 1 for unbatched structures.
    ``rhs``      RHS width for solve ops (1 for a vector RHS); 0 for factor.
    ``devices``  mesh extent the call spans; 1 means single-device.
    ``tolerance`` largest acceptable relative residual ``|Ax-b|/|b|``;
                 0.0 (the default) demands the exact tier, so approximate
                 backends (which declare a ``residual_bound``) are only
                 admitted when the caller states a tolerance they meet.
    ``verify_residual`` ask the registry to *measure* the relative residual
                 of eager ``linear_solve`` dispatches and treat a result
                 past the bound (``tolerance`` when set, else the exact-tier
                 default in ``registry.VERIFY_RESIDUAL_DEFAULT_BOUND``) as a
                 dispatch failure — feeding the escalation funnel instead of
                 returning a silently-wrong answer.
    ``enriched``  for solve ops: whether the factor operand is a
                 :class:`repro.core.factorization.Factorization` carrying
                 its factor-time enrichments (pre-inverted diagonal blocks).
                 The inverted-diagonal solve backends gate on it, so a raw
                 legacy operand is never steered into an
                 enrich-on-the-fly dispatch by a measured cache row.
                 Defaults True (the steady-state serving operand is an
                 enriched artifact); ``from_arrays`` downgrades it for raw
                 arrays.  Deliberately NOT part of the autotune cache key.
    """

    op: str
    structure: str
    n: int
    dtype: str = "float32"
    bw: int = 0
    batch: int = 1
    rhs: int = 0
    devices: int = 1
    tolerance: float = 0.0
    verify_residual: bool = False
    enriched: bool = True

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown op {self.op!r} (expected one of {OPS})")
        if self.structure not in STRUCTURES:
            raise ValueError(
                f"unknown structure {self.structure!r} (expected one of {STRUCTURES})"
            )
        if self.tolerance < 0:
            raise ValueError(f"tolerance must be >= 0, got {self.tolerance}")

    @property
    def banded(self) -> bool:
        return self.structure.endswith("banded")

    @property
    def batched(self) -> bool:
        return self.structure.startswith("batched_")

    @classmethod
    def from_arrays(
        cls, op: str, a, b=None, *, bw: int = 0, devices: int = 1,
        tolerance: float = 0.0, verify_residual: bool = False,
    ) -> "Problem":
        """Build a descriptor from the operand arrays.

        ``a`` is the matrix operand: ``(n, n)`` dense, ``(n, 2bw+1)``
        row-aligned band (``bw > 0``), or either with one leading batch
        axis.  ``b`` (optional) is the RHS whose trailing width becomes
        ``rhs`` (1 for a vector).
        """
        banded = bw > 0
        base = "banded" if banded else "dense"
        matrix_ndim = 2
        if a.ndim == matrix_ndim:
            structure, batch = base, 1
        elif a.ndim == matrix_ndim + 1:
            structure, batch = f"batched_{base}", int(a.shape[0])
        else:
            raise ValueError(
                f"{base} {op} expects a {matrix_ndim}-D matrix or one leading "
                f"batch axis; got shape {tuple(a.shape)}"
            )
        n = int(a.shape[-2]) if banded else int(a.shape[-1])
        rhs = 0
        if b is not None:
            # RHS ranks: (n,) / (n, m) unbatched, (B, n) / (B, n, m) batched
            rhs_ndim_vec = 1 + (1 if structure.startswith("batched_") else 0)
            rhs = 1 if b.ndim == rhs_ndim_vec else int(b.shape[-1])
        enriched = bool(getattr(a, "enriched", False)) if op == "solve" else True
        return cls(
            op=op,
            structure=structure,
            n=n,
            dtype=jnp.dtype(a.dtype).name,
            bw=int(bw),
            batch=batch,
            rhs=rhs,
            devices=int(devices),
            tolerance=float(tolerance),
            verify_residual=bool(verify_residual),
            enriched=enriched,
        )
