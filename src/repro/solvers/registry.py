"""Backend registry + selection engine.

One table for the whole solver stack: every kernel generation (fused
megakernel, blocked drivers, banded blocked/tiled/scalar, batched VMEM grid
kernels, multi-device shard_map LU, pure-jnp mirrors) registers a
:class:`Backend` under its ``(op, structure)`` slot.  Selection is a
three-stage funnel:

1. **capability filter** — ``Backend.supports(problem)`` prunes backends
   that cannot run the problem at all (dtype, VMEM footprint, device count),
   and the **tolerance gate** prunes approximate backends (those declaring a
   ``residual_bound``) unless the problem carries a tolerance that bound
   meets — a default (``tolerance == 0``) problem only ever sees the exact
   tier, preserving pre-tolerance selection bitwise;
2. **measured selection** — the autotune cache
   (:mod:`repro.solvers.cache`) picks the fastest *measured* capable
   backend among those flagged ``autotune=True``;
3. **static fallback** — with no transferable measurement, the highest
   ``priority(problem)`` wins.  The registered priorities reproduce the
   pre-registry hardcoded heuristics exactly (``pallas_fused`` for fp32
   dense, the 2048-order VMEM solve threshold, the 6 MB banded byte cap),
   so a cache-less process behaves like the historical ``ops.py`` tables.

``impl=`` on the public ops is a *forced override*: it bypasses stages 2-3
(and the capability filter — forcing an unsupported backend is an explicit
request and fails with that backend's own error).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from . import cache as _cache
from .problem import Problem

__all__ = [
    "Backend",
    "register",
    "backends_for",
    "get_backend",
    "candidates",
    "select",
    "dispatch",
    "add_dispatch_hook",
    "remove_dispatch_hook",
    "record_dispatches",
]


@dataclasses.dataclass(frozen=True)
class Backend:
    """One dispatchable implementation.

    ``call``      ``(problem, *arrays, **kw) -> result``; adapters accept
                  and ignore kwargs meant for other backends (``**_``) so
                  the public ops can pass their full kwarg set through.
    ``supports``  capability predicate; auto-selection only considers
                  backends whose predicate holds.
    ``priority``  static heuristic rank (higher wins) used when no
                  measurement transfers.
    ``autotune``  whether the backend competes in measured selection and is
                  swept by ``scripts/autotune.py``.  Kept False for
                  dominated legacy drivers and for backends whose output is
                  not value-identical to the default of their slot (a cache
                  flip must never change bitwise behaviour of twin-backed
                  slots).
    ``vmem_bytes`` optional footprint estimate (documentation + capability
                  predicates build on it).
    ``residual_bound`` relative residual ``|Ax-b|/|b|`` the backend
                  guarantees for its documented operand class, or None for
                  exact backends.  Approximate backends (non-None) only
                  enter auto-selection when ``problem.tolerance`` is set
                  and at least as loose as this bound.
    """

    name: str
    op: str
    structure: str
    call: Callable
    supports: Callable[[Problem], bool] = lambda p: True
    priority: Callable[[Problem], float] = lambda p: 0.0
    autotune: bool = True
    vmem_bytes: Callable[[Problem], int] | None = None
    residual_bound: Callable[[Problem], float] | None = None


_REGISTRY: dict[tuple[str, str], dict[str, Backend]] = {}


def register(backend: Backend, *, overwrite: bool = False) -> Backend:
    slot = _REGISTRY.setdefault((backend.op, backend.structure), {})
    if backend.name in slot and not overwrite:
        raise ValueError(
            f"backend {backend.name!r} already registered for "
            f"({backend.op}, {backend.structure})"
        )
    slot[backend.name] = backend
    return backend


def backends_for(op: str, structure: str) -> list[Backend]:
    return list(_REGISTRY.get((op, structure), {}).values())


def get_backend(op: str, structure: str, name: str) -> Backend:
    slot = _REGISTRY.get((op, structure), {})
    if name not in slot:
        raise ValueError(
            f"unknown impl {name!r} for ({op}, {structure}); "
            f"registered: {sorted(slot)}"
        )
    return slot[name]


def _tolerance_admits(backend: Backend, problem: Problem) -> bool:
    """The accuracy gate of the funnel: exact backends always pass;
    approximate backends pass only when the caller declared a tolerance at
    least as loose as the backend's guaranteed residual bound."""
    if backend.residual_bound is None:
        return True
    return problem.tolerance > 0 and backend.residual_bound(problem) <= problem.tolerance


def candidates(problem: Problem, *, allow: Callable[[Backend], bool] | None = None) -> list[Backend]:
    """Capability- and tolerance-filtered backends for ``problem``
    (optionally restricted by ``allow``, e.g. the legacy ``impl="pallas"``
    pallas-only auto)."""
    out = [
        b for b in backends_for(problem.op, problem.structure)
        if b.supports(problem) and _tolerance_admits(b, problem)
    ]
    if allow is not None:
        out = [b for b in out if allow(b)]
    return out


def select(
    problem: Problem,
    *,
    impl: str | None = None,
    cache: _cache.AutotuneCache | None = None,
    allow: Callable[[Backend], bool] | None = None,
) -> Backend:
    """Pick the backend for ``problem``: forced ``impl`` > measured winner >
    static priority."""
    if impl is not None:
        return get_backend(problem.op, problem.structure, impl)
    cands = candidates(problem, allow=allow)
    if not cands:
        raise ValueError(
            f"no capable backend for {problem} among "
            f"{[b.name for b in backends_for(problem.op, problem.structure)]}"
        )
    cache = _cache.get_cache() if cache is None else cache
    measured = cache.best(problem, [b.name for b in cands if b.autotune])
    if measured is not None:
        return get_backend(problem.op, problem.structure, measured)
    return max(cands, key=lambda b: b.priority(problem))


# ---------------------------------------------------------------------------
# dispatch observability — the hook layer higher-level caches build on.
# The serving layer's factorization cache (repro.serve.solve_service) counts
# factor vs solve dispatches through here to prove factor-once/solve-many;
# tests and benches use record_dispatches() for the same accounting.
# ---------------------------------------------------------------------------
_DISPATCH_HOOKS: list[Callable[[Problem, Backend], None]] = []


def add_dispatch_hook(fn: Callable[[Problem, Backend], None]) -> Callable:
    """Register ``fn(problem, backend)`` to observe every registry dispatch
    (called after selection, before the backend runs).  Returns ``fn`` so it
    can be handed straight to :func:`remove_dispatch_hook`."""
    _DISPATCH_HOOKS.append(fn)
    return fn


def remove_dispatch_hook(fn: Callable) -> None:
    try:
        _DISPATCH_HOOKS.remove(fn)
    except ValueError:
        pass


class record_dispatches:
    """Context manager collecting ``(problem, backend_name)`` for every
    dispatch inside the block::

        with record_dispatches() as log:
            ops.linear_solve(a, b)
        assert sum(p.op == "factor" for p, _ in log) == 1
    """

    def __enter__(self) -> list[tuple[Problem, str]]:
        self.log: list[tuple[Problem, str]] = []
        self._fn = add_dispatch_hook(lambda p, b: self.log.append((p, b.name)))
        return self.log

    def __exit__(self, *exc):
        remove_dispatch_hook(self._fn)
        return False


def dispatch(
    problem: Problem,
    *arrays,
    impl: str | None = None,
    cache: _cache.AutotuneCache | None = None,
    allow: Callable[[Backend], bool] | None = None,
    **kw,
):
    """Select and run in one step (the public ops' workhorse)."""
    backend = select(problem, impl=impl, cache=cache, allow=allow)
    for hook in _DISPATCH_HOOKS:
        hook(problem, backend)
    return backend.call(problem, *arrays, **kw)
