"""Backend registry + selection engine.

One table for the whole solver stack: every kernel generation (fused
megakernel, blocked drivers, banded blocked/tiled/scalar, batched VMEM grid
kernels, multi-device shard_map LU, pure-jnp mirrors) registers a
:class:`Backend` under its ``(op, structure)`` slot.  Selection is a
three-stage funnel:

1. **capability filter** — ``Backend.supports(problem)`` prunes backends
   that cannot run the problem at all (dtype, VMEM footprint, device count),
   and the **tolerance gate** prunes approximate backends (those declaring a
   ``residual_bound``) unless the problem carries a tolerance that bound
   meets — a default (``tolerance == 0``) problem only ever sees the exact
   tier, preserving pre-tolerance selection bitwise;
2. **measured selection** — the autotune cache
   (:mod:`repro.solvers.cache`) picks the fastest *measured* capable
   backend among those flagged ``autotune=True``;
3. **static fallback** — with no transferable measurement, the highest
   ``priority(problem)`` wins.  The registered priorities reproduce the
   pre-registry hardcoded heuristics exactly (``pallas_fused`` for fp32
   dense, the 2048-order VMEM solve threshold, the 6 MB banded byte cap),
   so a cache-less process behaves like the historical ``ops.py`` tables.

``impl=`` on the public ops is a *forced override*: it bypasses stages 2-3
(and the capability filter — forcing an unsupported backend is an explicit
request and fails with that backend's own error).

**Escalation funnel** (layer 2 of the failure-isolating pipeline): when a
dispatch carries a *validator* — a factor health screen from
``ops.lu(..., health=)``, the built-in relative-residual check armed by
``Problem.verify_residual``, or an injected fault plan
(:mod:`repro.solvers.faults`) — an auto-selected dispatch becomes a retry
loop over the capable candidates, best-first: a backend whose call raises
or whose result fails validation is *demoted* for that problem shape
(skipped for the next ``DEMOTION_TTL`` same-shape dispatches), an
escalation event fires (``add_escalation_hook`` / ``record_escalations``),
and the next candidate runs.  The last resort for dense factors is the
partial-pivoting ``pivoted`` backend (:mod:`repro.core.pivoted`) registered
at the lowest priority.  When every candidate fails, the dispatch raises a
structured :class:`SolveFailure` carrying the problem, the per-backend
escalation chain, and the final health record — never NaN factors.  A
default dispatch (no validator, no active faults, no demotions) takes the
exact pre-funnel fast path, so default selection and results stay
bitwise-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from . import cache as _cache
from .problem import Problem

__all__ = [
    "Backend",
    "SolveFailure",
    "register",
    "backends_for",
    "get_backend",
    "candidates",
    "select",
    "dispatch",
    "add_dispatch_hook",
    "remove_dispatch_hook",
    "record_dispatches",
    "add_escalation_hook",
    "remove_escalation_hook",
    "record_escalations",
    "demotions",
    "clear_demotions",
    "DEMOTION_TTL",
    "VERIFY_RESIDUAL_DEFAULT_BOUND",
]


@dataclasses.dataclass(frozen=True)
class Backend:
    """One dispatchable implementation.

    ``call``      ``(problem, *arrays, **kw) -> result``; adapters accept
                  and ignore kwargs meant for other backends (``**_``) so
                  the public ops can pass their full kwarg set through.
    ``supports``  capability predicate; auto-selection only considers
                  backends whose predicate holds.
    ``priority``  static heuristic rank (higher wins) used when no
                  measurement transfers.
    ``autotune``  whether the backend competes in measured selection and is
                  swept by ``scripts/autotune.py``.  Kept False for
                  dominated legacy drivers and for backends whose output is
                  not value-identical to the default of their slot (a cache
                  flip must never change bitwise behaviour of twin-backed
                  slots).
    ``vmem_bytes`` optional footprint estimate (documentation + capability
                  predicates build on it).
    ``residual_bound`` relative residual ``|Ax-b|/|b|`` the backend
                  guarantees for its documented operand class, or None for
                  exact backends.  Approximate backends (non-None) only
                  enter auto-selection when ``problem.tolerance`` is set
                  and at least as loose as this bound.
    """

    name: str
    op: str
    structure: str
    call: Callable
    supports: Callable[[Problem], bool] = lambda p: True
    priority: Callable[[Problem], float] = lambda p: 0.0
    autotune: bool = True
    vmem_bytes: Callable[[Problem], int] | None = None
    residual_bound: Callable[[Problem], float] | None = None


_REGISTRY: dict[tuple[str, str], dict[str, Backend]] = {}


def register(backend: Backend, *, overwrite: bool = False) -> Backend:
    slot = _REGISTRY.setdefault((backend.op, backend.structure), {})
    if backend.name in slot and not overwrite:
        raise ValueError(
            f"backend {backend.name!r} already registered for "
            f"({backend.op}, {backend.structure})"
        )
    slot[backend.name] = backend
    return backend


def backends_for(op: str, structure: str) -> list[Backend]:
    return list(_REGISTRY.get((op, structure), {}).values())


def get_backend(op: str, structure: str, name: str) -> Backend:
    slot = _REGISTRY.get((op, structure), {})
    if name not in slot:
        raise ValueError(
            f"unknown impl {name!r} for ({op}, {structure}); "
            f"registered: {sorted(slot)}"
        )
    return slot[name]


def _tolerance_admits(backend: Backend, problem: Problem) -> bool:
    """The accuracy gate of the funnel: exact backends always pass;
    approximate backends pass only when the caller declared a tolerance at
    least as loose as the backend's guaranteed residual bound."""
    if backend.residual_bound is None:
        return True
    return problem.tolerance > 0 and backend.residual_bound(problem) <= problem.tolerance


def candidates(problem: Problem, *, allow: Callable[[Backend], bool] | None = None) -> list[Backend]:
    """Capability- and tolerance-filtered backends for ``problem``
    (optionally restricted by ``allow``, e.g. the legacy ``impl="pallas"``
    pallas-only auto)."""
    out = [
        b for b in backends_for(problem.op, problem.structure)
        if b.supports(problem) and _tolerance_admits(b, problem)
    ]
    if allow is not None:
        out = [b for b in out if allow(b)]
    return out


def select(
    problem: Problem,
    *,
    impl: str | None = None,
    cache: _cache.AutotuneCache | None = None,
    allow: Callable[[Backend], bool] | None = None,
) -> Backend:
    """Pick the backend for ``problem``: forced ``impl`` > measured winner >
    static priority."""
    if impl is not None:
        return get_backend(problem.op, problem.structure, impl)
    cands = candidates(problem, allow=allow)
    if not cands:
        raise ValueError(
            f"no capable backend for {problem} among "
            f"{[b.name for b in backends_for(problem.op, problem.structure)]}"
        )
    cache = _cache.get_cache() if cache is None else cache
    measured = cache.best(problem, [b.name for b in cands if b.autotune])
    if measured is not None:
        return get_backend(problem.op, problem.structure, measured)
    return max(cands, key=lambda b: b.priority(problem))


# ---------------------------------------------------------------------------
# dispatch observability — the hook layer higher-level caches build on.
# The serving layer's factorization cache (repro.serve.solve_service) counts
# factor vs solve dispatches through here to prove factor-once/solve-many;
# tests and benches use record_dispatches() for the same accounting.
# ---------------------------------------------------------------------------
_DISPATCH_HOOKS: list[Callable[[Problem, Backend], None]] = []


def add_dispatch_hook(fn: Callable[[Problem, Backend], None]) -> Callable:
    """Register ``fn(problem, backend)`` to observe every registry dispatch
    (called after selection, before the backend runs).  Returns ``fn`` so it
    can be handed straight to :func:`remove_dispatch_hook`."""
    _DISPATCH_HOOKS.append(fn)
    return fn


def remove_dispatch_hook(fn: Callable) -> None:
    try:
        _DISPATCH_HOOKS.remove(fn)
    except ValueError:
        pass


class record_dispatches:
    """Context manager collecting ``(problem, backend_name)`` for every
    dispatch inside the block::

        with record_dispatches() as log:
            ops.linear_solve(a, b)
        assert sum(p.op == "factor" for p, _ in log) == 1
    """

    def __enter__(self) -> list[tuple[Problem, str]]:
        self.log: list[tuple[Problem, str]] = []
        self._fn = add_dispatch_hook(lambda p, b: self.log.append((p, b.name)))
        return self.log

    def __exit__(self, *exc):
        remove_dispatch_hook(self._fn)
        return False


# ---------------------------------------------------------------------------
# failure structure + escalation state
# ---------------------------------------------------------------------------
class SolveFailure(RuntimeError):
    """Terminal dispatch failure: every capable backend raised or failed
    validation.  Structured — callers (the solve service) turn it into a
    per-ticket result value instead of NaN answers:

    ``problem``  the dispatched :class:`Problem`;
    ``chain``    the escalation chain, one ``{"backend", "reason"}`` dict
                 per failed attempt in the order tried;
    ``health``   the last :class:`repro.core.health.FactorHealth` record a
                 validator produced, or None (e.g. pure exception chains).
    """

    def __init__(self, message: str, *, problem: Problem | None = None,
                 chain: list | None = None, health=None):
        super().__init__(message)
        self.problem = problem
        self.chain = chain or []
        self.health = health


# Demotion: after a backend fails for a problem shape, skip it for the next
# DEMOTION_TTL *screened* dispatches of that shape (repeated hostile traffic
# goes straight to the survivor instead of re-failing every candidate; plain
# unscreened dispatches never consult the table).
# TTL-bounded so a transient fault can't permanently re-steer healthy
# traffic; faults.inject clears the table on exit for the same reason.
DEMOTION_TTL = 8

# Bound the built-in verify_residual check applies to exact-tier
# (tolerance == 0) linear solves; f32 no-pivot solves of in-class operands
# measure ~1e-7, so 1e-4 trips only on genuinely wrong answers.
VERIFY_RESIDUAL_DEFAULT_BOUND = 1e-4

_DEMOTIONS: dict[tuple, int] = {}  # (shape key, backend name) -> remaining TTL


def _shape_key(p: Problem) -> tuple:
    # ``devices`` is part of the shape: a SPIKE demotion on the 8-device
    # mesh must not suppress the (disjoint) single-device candidate set,
    # nor leak across mesh sizes.
    return (p.op, p.structure, p.dtype, p.n, p.bw, p.batch, p.devices)


def _demote(problem: Problem, name: str) -> None:
    _DEMOTIONS[(_shape_key(problem), name)] = DEMOTION_TTL


def _tick_demotions(key: tuple) -> None:
    """Age every demotion of this shape by one dispatch; drop the expired."""
    for k in [k for k in _DEMOTIONS if k[0] == key]:
        _DEMOTIONS[k] -= 1
        if _DEMOTIONS[k] <= 0:
            del _DEMOTIONS[k]


def demotions() -> dict[tuple, int]:
    """Snapshot of the active demotion table (tests/diagnostics)."""
    return dict(_DEMOTIONS)


def clear_demotions() -> None:
    _DEMOTIONS.clear()


_ESCALATION_HOOKS: list[Callable] = []


def add_escalation_hook(fn: Callable) -> Callable:
    """Register ``fn(problem, failed_backend_name, next_backend_name | None,
    reason)`` to observe every escalation event (``next`` is None on the
    terminal failure).  Returns ``fn`` for :func:`remove_escalation_hook`."""
    _ESCALATION_HOOKS.append(fn)
    return fn


def remove_escalation_hook(fn: Callable) -> None:
    try:
        _ESCALATION_HOOKS.remove(fn)
    except ValueError:
        pass


def _notify_escalation(problem, failed: str, nxt: str | None, reason: str) -> None:
    """Fire the escalation hooks.  Internal — dispatch calls it per funnel
    step, and the composed exact path in ``ops.linear_solve`` calls it when
    its post-hoc residual check (which spans two dispatches, so it cannot
    live inside either) fails over to the pivoted last resort."""
    for hook in _ESCALATION_HOOKS:
        hook(problem, failed, nxt, reason)


class record_escalations:
    """Context manager collecting ``(problem, failed, next, reason)`` for
    every escalation inside the block — the isolation tests' proof that a
    healthy rerun escalates zero times."""

    def __enter__(self) -> list[tuple]:
        self.log: list[tuple] = []
        self._fn = add_escalation_hook(
            lambda p, failed, nxt, reason: self.log.append((p, failed, nxt, reason))
        )
        return self.log

    def __exit__(self, *exc):
        remove_escalation_hook(self._fn)
        return False


def _eager(arrays) -> bool:
    import jax

    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _residual_validator(arrays, kw):
    """Built-in validator for ``Problem.verify_residual`` linear_solve
    dispatches: measure ``|Ax-b|/|b|`` of the eager result against the
    declared bound (``tolerance`` when set, else the exact-tier default)."""
    from repro.core import health as _health

    a, b = arrays[0], arrays[1]

    def validate(problem, backend, result):
        bound = problem.tolerance if problem.tolerance > 0 else VERIFY_RESIDUAL_DEFAULT_BOUND
        rel = float(_health.relative_residual(a, b, result, bw=problem.bw))
        if not rel <= bound:  # NaN-safe
            return (f"residual {rel:.3e} > bound {bound:.1e} from {backend.name}", None)
        return None

    return validate


def _run_attempt(plans, problem, backend, arrays, kw):
    """One dispatch attempt with fault plans applied around the call."""
    matched = [p for p in plans if p.matches(problem, backend.name)]
    for p in matched:
        p.before_call(problem, backend.name)
    result = backend.call(problem, *arrays, **kw)
    for p in matched:
        result = p.after_call(problem, backend.name, result)
    return result


def dispatch(
    problem: Problem,
    *arrays,
    impl: str | None = None,
    cache: _cache.AutotuneCache | None = None,
    allow: Callable[[Backend], bool] | None = None,
    validate: Callable | None = None,
    **kw,
):
    """Select and run in one step (the public ops' workhorse).

    ``validate(problem, backend, result)`` returns None to accept or a
    ``(reason, health_record | None)`` pair to reject — rejection feeds the
    escalation funnel on auto dispatches and raises :class:`SolveFailure`
    on forced ones.  Validation and the built-in residual check only run
    eagerly; under tracing (jit/vmap rules call dispatch at trace time)
    results pass through unscreened.
    """
    from . import faults as _faults

    plans = _faults.active_plans()
    eager = (validate is not None or plans or problem.verify_residual) and _eager(arrays)
    if validate is None and eager and problem.verify_residual and problem.op == "linear_solve":
        validate = _residual_validator(arrays, kw)

    if impl is not None:
        # forced override: no escalation target exists, but faults still
        # apply and a failed validation still raises the structured failure
        # instead of returning a known-bad result.
        backend = get_backend(problem.op, problem.structure, impl)
        for hook in _DISPATCH_HOOKS:
            hook(problem, backend)
        result = _run_attempt(plans, problem, backend, arrays, kw)
        if validate is not None and eager:
            err = validate(problem, backend, result)
            if err is not None:
                reason, health = err
                raise SolveFailure(
                    f"forced impl {impl!r} failed validation for {problem}: {reason}",
                    problem=problem,
                    chain=[{"backend": backend.name, "reason": reason}],
                    health=health,
                )
        return result

    if not plans and validate is None:
        # The pre-funnel fast path: selection, hook order and the single
        # call are exactly the historical dispatch — bitwise-default.
        # Demotions are deliberately NOT consulted here: they only steer
        # *screened* dispatches (validator or fault plan present), so an
        # earlier hostile operand can never re-route plain default traffic.
        backend = select(problem, cache=cache, allow=allow)
        for hook in _DISPATCH_HOOKS:
            hook(problem, backend)
        return backend.call(problem, *arrays, **kw)

    # --- escalation funnel -------------------------------------------------
    winner = select(problem, cache=cache, allow=allow)
    rest = sorted(
        (b for b in candidates(problem, allow=allow) if b.name != winner.name),
        key=lambda b: b.priority(problem), reverse=True,
    )
    ordered = [winner] + rest
    key = _shape_key(problem)
    _tick_demotions(key)
    live = [b for b in ordered if (key, b.name) not in _DEMOTIONS] or ordered
    chain: list[dict] = []
    last_health = None
    for i, backend in enumerate(live):
        for hook in _DISPATCH_HOOKS:
            hook(problem, backend)
        health = None
        try:
            result = _run_attempt(plans, problem, backend, arrays, kw)
            err = validate(problem, backend, result) if (validate and eager) else None
            if err is None:
                return result
            reason, health = err
        except Exception as e:  # noqa: BLE001 — every backend error escalates
            reason = f"{type(e).__name__}: {e}"
        last_health = health if health is not None else last_health
        chain.append({"backend": backend.name, "reason": reason})
        _demote(problem, backend.name)
        nxt = live[i + 1].name if i + 1 < len(live) else None
        _notify_escalation(problem, backend.name, nxt, reason)
    raise SolveFailure(
        f"all {len(live)} capable backends failed for {problem}: "
        + " -> ".join(f"{c['backend']} ({c['reason']})" for c in chain),
        problem=problem, chain=chain, health=last_health,
    )
