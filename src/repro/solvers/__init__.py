"""`repro.solvers` — the unified solver dispatch registry.

One subsystem decides *which* implementation runs every factor/solve in the
repo:

* :class:`Problem` (``problem.py``) — shape-level descriptor of a call;
* :class:`Backend` (``registry.py``) — callable + capability predicate +
  static priority, registered per ``(op, structure)`` slot;
* ``cache.py`` — the measured autotune cache (persisted JSON, populated by
  ``scripts/autotune.py`` and seeded by the smoke bench) that makes
  selection measurement-driven;
* ``backends.py`` — registrations for every kernel generation (imported
  here for its side effects).

Public ops in :mod:`repro.kernels.ops` are a thin compatibility shim over
:func:`select`/:func:`dispatch`; see ``README.md`` in this directory.
"""
from .problem import Problem, OPS, STRUCTURES
from .registry import (
    Backend,
    DEMOTION_TTL,
    VERIFY_RESIDUAL_DEFAULT_BOUND,
    SolveFailure,
    add_dispatch_hook,
    add_escalation_hook,
    backends_for,
    candidates,
    clear_demotions,
    demotions,
    dispatch,
    get_backend,
    record_dispatches,
    record_escalations,
    register,
    remove_dispatch_hook,
    remove_escalation_hook,
    select,
)
from .cache import AutotuneCache, get_cache, cache_path, invalidate
from .faults import FaultPlan, InjectedFault, inject
from . import backends as _backends  # noqa: F401  (side effect: registration)

__all__ = [
    "Problem",
    "Backend",
    "OPS",
    "STRUCTURES",
    "SolveFailure",
    "register",
    "backends_for",
    "candidates",
    "get_backend",
    "select",
    "dispatch",
    "add_dispatch_hook",
    "remove_dispatch_hook",
    "record_dispatches",
    "add_escalation_hook",
    "remove_escalation_hook",
    "record_escalations",
    "demotions",
    "clear_demotions",
    "DEMOTION_TTL",
    "VERIFY_RESIDUAL_DEFAULT_BOUND",
    "FaultPlan",
    "InjectedFault",
    "inject",
    "AutotuneCache",
    "get_cache",
    "cache_path",
    "invalidate",
]
