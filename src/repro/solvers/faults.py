"""Deterministic fault injection at the registry dispatch boundary.

Proving that the failure-isolating pipeline actually isolates — a poisoned
coalesced group resolving to structured failures while its flush-mates
stay bitwise-correct — requires *deterministic* faults: hand-crafting a
matrix that breaks exactly one backend at exactly one dispatch is fragile
and couples tests to kernel numerics.  Instead, tests and
``benchmarks/serve_bench.py --chaos`` push a :class:`FaultPlan` onto a
stack the registry consults at every dispatch attempt:

    with faults.inject(nan_pivot_at=0, match=lambda p: p.n == 96):
        ops.lu(a, health=True)     # factors come back pivot-poisoned

Three fault kinds, composable in one plan:

* ``backend_raises`` — the matched backend raises :class:`InjectedFault`
  *instead of running* (models a kernel crash / compile failure); the
  funnel escalates past it.
* ``nan_pivot_at=i`` — the matched backend runs, then pivot ``i`` of its
  packed factor result is overwritten with NaN (models silent no-pivot
  blow-up); only health screening can catch it.
* ``slow_dispatch_us`` — a host-side sleep before the backend runs
  (models a straggler; lets deadline shedding be tested without real load).

Plans are matched by ``op``/``backend``/``match(problem)`` and optionally
budgeted (``times=``); every application is appended to ``plan.applied``
so tests assert exactly what fired.  Leaving the ``inject`` context
clears the registry's demotion table — faults must not leak selection
state into subsequent healthy traffic (the bitwise-default contract).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax.numpy as jnp

__all__ = ["InjectedFault", "FaultPlan", "inject", "active_plans"]


class InjectedFault(RuntimeError):
    """Raised by a ``backend_raises`` plan in place of running the backend."""


@dataclasses.dataclass
class FaultPlan:
    """One active fault description (see module docstring).

    ``match``/``backend``/``op`` restrict which dispatch attempts the plan
    applies to (all ``None`` = every attempt); ``times`` caps total
    applications across the plan's lifetime (``None`` = unlimited).
    """

    nan_pivot_at: int | None = None
    backend_raises: bool = False
    slow_dispatch_us: float = 0.0
    match: Callable | None = None  # problem predicate
    backend: str | None = None     # backend-name restriction
    op: str | None = None          # op restriction ("factor", "solve", ...)
    times: int | None = None
    applied: list = dataclasses.field(default_factory=list)

    def matches(self, problem, backend_name: str) -> bool:
        if self.times is not None and len(self.applied) >= self.times:
            return False
        if self.op is not None and problem.op != self.op:
            return False
        if self.backend is not None and backend_name != self.backend:
            return False
        if self.match is not None and not self.match(problem):
            return False
        return True

    def _note(self, problem, backend_name: str, kind: str) -> None:
        self.applied.append((problem, backend_name, kind))

    # -- the two registry touch points --------------------------------------
    def before_call(self, problem, backend_name: str) -> None:
        """Pre-call faults: straggler sleep, then injected crash."""
        if self.slow_dispatch_us:
            self._note(problem, backend_name, "slow_dispatch")
            time.sleep(self.slow_dispatch_us / 1e6)
        if self.backend_raises:
            self._note(problem, backend_name, "backend_raises")
            raise InjectedFault(
                f"injected fault: backend {backend_name!r} raised for {problem}"
            )

    def after_call(self, problem, backend_name: str, result):
        """Post-call faults: poison pivot ``nan_pivot_at`` of a packed
        factor result (dense diagonal or band pivot column)."""
        if self.nan_pivot_at is None or problem.op != "factor":
            return result
        i = int(self.nan_pivot_at)
        if not hasattr(result, "at"):  # factor records (rank-k, pivoted):
            return result              # poisoning targets packed arrays only
        self._note(problem, backend_name, "nan_pivot")
        nan = jnp.asarray(float("nan"), result.dtype)
        if problem.banded:
            return result.at[..., i, problem.bw].set(nan)
        return result.at[..., i, i].set(nan)


_ACTIVE: list[FaultPlan] = []


def active_plans() -> list[FaultPlan]:
    """The currently-injected plans (outermost first).  Consulted by
    :func:`repro.solvers.registry.dispatch` on every attempt."""
    return list(_ACTIVE)


class inject:
    """Context manager arming one :class:`FaultPlan` (kwargs are the plan
    fields).  Yields the plan so tests can assert ``plan.applied``.  On
    exit the plan is disarmed and the registry's demotion table is cleared
    (injected failures must not steer later healthy selections)."""

    def __init__(self, **kwargs):
        self.plan = FaultPlan(**kwargs)

    def __enter__(self) -> FaultPlan:
        _ACTIVE.append(self.plan)
        return self.plan

    def __exit__(self, *exc):
        try:
            _ACTIVE.remove(self.plan)
        except ValueError:
            pass
        from . import registry

        registry.clear_demotions()
        return False
