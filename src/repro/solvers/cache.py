"""Measured autotune cache: persisted backend timings keyed by problem shape.

The selection engine (:mod:`repro.solvers.registry`) prefers *measurement*
over heuristics: when the cache holds timings for a problem close enough in
size to the one being dispatched, the fastest measured capable backend wins;
otherwise selection falls back to the static priorities (which reproduce the
pre-registry hardcoded thresholds).

The cache is one JSON file:

* ``$REPRO_SOLVERS_CACHE`` when set (tests and ``scripts/check.sh`` pin a
  repo-local file for determinism),
* ``~/.cache/repro_solvers.json`` otherwise.

It is populated by ``scripts/autotune.py`` (the ``time_shootout`` harness
from :mod:`benchmarks.common`) and *seeded* by the smoke bench
(``benchmarks/run.py --smoke`` records the shootout rows it already times,
so the committed ``BENCH_kernels.json`` and the dispatch decisions can never
silently disagree).

Nearest-size matching: a measurement only transfers to problems within
``NEAREST_MAX_RATIO`` (4x) in both ``n`` and effective band width.  Beyond
that the regimes differ too much (a 16384-order measurement says nothing
about an 96-order dispatch) and the static heuristics take over.
"""
from __future__ import annotations

import json
import math
import os
import tempfile
import warnings

from .problem import Problem

__all__ = [
    "AutotuneCache",
    "ENV_VAR",
    "NEAREST_MAX_RATIO",
    "cache_path",
    "get_cache",
    "invalidate",
]

ENV_VAR = "REPRO_SOLVERS_CACHE"
DEFAULT_USER_PATH = os.path.join("~", ".cache", "repro_solvers.json")
NEAREST_MAX_RATIO = 4.0
_VERSION = 1

# fields that identify a measurement row (rhs/batch excluded: timings are
# dominated by n/bw, and keying on every shape dimension would fragment the
# cache into single-use entries).  ``tolerance`` IS a key field: approximate
# tiers are not value-identical to the exact tier, so a measurement taken at
# a loose tolerance must never steer a tighter problem's selection (entries
# persisted before the field existed load as tolerance-0 == exact rows).
# ``devices`` is likewise a key field: the single-device and mesh-sharded
# candidate sets are disjoint (SPIKE vs replication), so a single-device
# measured win must never steer a multi-device dispatch or vice versa
# (pre-devices caches load as devices-1 == local rows).
_KEY_FIELDS = ("op", "structure", "dtype", "bw", "n", "tolerance", "devices")


def cache_path() -> str:
    return os.path.expanduser(os.environ.get(ENV_VAR) or DEFAULT_USER_PATH)


_KEY_DEFAULTS = {"tolerance": 0.0, "devices": 1}


def _entry_key(e: dict) -> tuple:
    # entries built by hand (tests, old tools) may omit tolerance == exact
    # and devices == 1 (single-device)
    return tuple(
        e.get(f, _KEY_DEFAULTS[f]) if f in _KEY_DEFAULTS else e[f]
        for f in _KEY_FIELDS
    )


def _problem_key(p: Problem) -> tuple:
    return (p.op, p.structure, p.dtype, p.bw, p.n, float(p.tolerance), int(p.devices))


class AutotuneCache:
    """In-memory view of the persisted measurement file."""

    def __init__(self, path: str | None = None, entries: list[dict] | None = None):
        self.path = path
        self.entries: list[dict] = entries or []

    # -- persistence --------------------------------------------------------
    @classmethod
    def load(cls, path: str) -> "AutotuneCache":
        entries: list[dict] = []
        try:
            with open(path) as f:
                raw = json.load(f)
            for e in raw.get("entries", []):
                e.setdefault("tolerance", 0.0)  # pre-tolerance caches = exact rows
                e.setdefault("devices", 1)  # pre-devices caches = local rows
                if all(f in e for f in _KEY_FIELDS) and isinstance(e.get("times_us"), dict):
                    entries.append(e)
        except FileNotFoundError:
            pass  # no cache yet == empty cache
        except (OSError, ValueError, AttributeError, TypeError, KeyError) as err:
            # truncated write, hand-edited file, or a JSON document of the
            # wrong shape: warn (a silently-vanished cache looks like a perf
            # regression) and start empty — static priorities take over until
            # fresh measurements land.
            warnings.warn(
                f"autotune cache {path!r} is unreadable "
                f"({type(err).__name__}: {err}); starting with an empty cache",
                RuntimeWarning,
                stacklevel=2,
            )
            entries = []
        return cls(path=path, entries=entries)

    def save(self, path: str | None = None) -> str:
        path = path or self.path or cache_path()
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        payload = {"version": _VERSION, "entries": self.entries}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.path = path
        return path

    # -- recording ----------------------------------------------------------
    def record(self, problem: Problem, times_us: dict[str, float]) -> dict:
        """Merge backend timings for ``problem``'s shape key; returns the
        entry.  Existing timings for the same backend are overwritten (latest
        measurement wins)."""
        key = _problem_key(problem)
        for e in self.entries:
            if _entry_key(e) == key:
                e["times_us"].update({k: round(float(v), 2) for k, v in times_us.items()})
                return e
        entry = dict(zip(_KEY_FIELDS, key))
        entry["times_us"] = {k: round(float(v), 2) for k, v in times_us.items()}
        self.entries.append(entry)
        return entry

    def record_widths(self, problem: Problem, width_us: dict[int, float]) -> dict:
        """Merge stacked-RHS coalescing-width timings (width → measured µs
        per dispatch at that width) into ``problem``'s entry.  Consumed by
        :meth:`best_width` — the serve layer's coalescing-width cap."""
        key = _problem_key(problem)
        for e in self.entries:
            if _entry_key(e) == key:
                entry = e
                break
        else:
            entry = dict(zip(_KEY_FIELDS, key))
            entry["times_us"] = {}
            self.entries.append(entry)
        entry.setdefault("width_us", {}).update(
            {str(int(w)): round(float(v), 2) for w, v in width_us.items()}
        )
        return entry

    def record_page_sizes(self, problem: Problem, page_us: dict[int, float]) -> dict:
        """Merge KV-cache page-size timings (page size → measured paged-serve
        µs at that size) into ``problem``'s entry (op="decode",
        structure="paged_kv", n=max_len).  Consumed by
        :meth:`best_page_size` — the serving engine's default page size."""
        key = _problem_key(problem)
        for e in self.entries:
            if _entry_key(e) == key:
                entry = e
                break
        else:
            entry = dict(zip(_KEY_FIELDS, key))
            entry["times_us"] = {}
            self.entries.append(entry)
        entry.setdefault("page_us", {}).update(
            {str(int(p)): round(float(v), 2) for p, v in page_us.items()}
        )
        return entry

    # -- lookup -------------------------------------------------------------
    def lookup(self, problem: Problem) -> dict | None:
        key = _problem_key(problem)
        for e in self.entries:
            if _entry_key(e) == key:
                return e
        return None

    def _matches(self, problem: Problem) -> list[tuple[float, dict]]:
        out = []
        for e in self.entries:
            # exact match on every non-size key — in particular tolerance
            # and devices: nearest-size transfer interpolates over *speed*,
            # never over *accuracy tier* (a loose-tolerance win must not
            # leak into a tight dispatch) nor over *device count* (the
            # single-device and mesh-sharded candidate sets are disjoint).
            if (
                e["op"], e["structure"], e["dtype"],
                e.get("tolerance", 0.0), e.get("devices", 1),
            ) != (
                problem.op, problem.structure, problem.dtype,
                float(problem.tolerance), int(problem.devices),
            ):
                continue
            n_ratio = max(e["n"], problem.n) / max(min(e["n"], problem.n), 1)
            bwa, bwb = e["bw"] + 1, problem.bw + 1
            bw_ratio = max(bwa, bwb) / min(bwa, bwb)
            if n_ratio > NEAREST_MAX_RATIO or bw_ratio > NEAREST_MAX_RATIO:
                continue
            out.append((math.log(n_ratio) + math.log(bw_ratio), e))
        out.sort(key=lambda t: t[0])
        return out

    def best(self, problem: Problem, candidates: list[str]) -> str | None:
        """Fastest measured backend among ``candidates`` for the nearest
        matching measurement, or None when nothing transferable exists."""
        for _, e in self._matches(problem):
            times = {k: v for k, v in e["times_us"].items() if k in candidates}
            if times:
                return min(times, key=times.get)
        return None

    def best_width(self, problem: Problem) -> int | None:
        """Measured-best coalescing width (most µs-per-column efficient) for
        the nearest matching stacked-RHS sweep, or None when nothing
        transferable was measured — callers fall back to full coalescing."""
        for _, e in self._matches(problem):
            wu = e.get("width_us")
            if wu:
                return int(min(wu, key=lambda w: wu[w] / int(w)))
        return None

    def best_page_size(self, problem: Problem) -> int | None:
        """Measured-fastest KV page size for the nearest matching paged-serve
        sweep, or None when nothing transferable was measured — the engine
        falls back to its built-in default."""
        for _, e in self._matches(problem):
            pu = e.get("page_us")
            if pu:
                return int(min(pu, key=pu.get))
        return None


# ---------------------------------------------------------------------------
# module-level cache with mtime-based reload (the autotune script and the
# smoke bench write the file mid-process; dispatch must see fresh data)
# ---------------------------------------------------------------------------
_loaded: tuple[str, float, AutotuneCache] | None = None


def _mtime(path: str) -> float:
    try:
        return os.stat(path).st_mtime
    except OSError:
        return -1.0


def get_cache() -> AutotuneCache:
    global _loaded
    path = cache_path()
    mt = _mtime(path)
    if _loaded is not None and _loaded[0] == path and _loaded[1] == mt:
        return _loaded[2]
    cache = AutotuneCache.load(path)
    _loaded = (path, mt, cache)
    return cache


def invalidate() -> None:
    """Drop the module-level cache (tests that swap ``$REPRO_SOLVERS_CACHE``)."""
    global _loaded
    _loaded = None
