"""Distribution layer: logical-axis sharding policy (:mod:`.sharding`) and
pipeline parallelism (:mod:`.pipeline_par`).  See ``README.md`` in this
directory for the design."""
from . import sharding  # noqa: F401
