"""GPipe pipeline parallelism over one mesh axis.

The stage dimension of the weights is sharded over ``axis``; microbatches
flow through the ranks with a single-hop ``ppermute`` per tick.  Tick ``t``
has rank ``r`` working on microbatch ``t − r`` (inactive ranks compute on
zeros — SPMD uniformity, same trick as the EbV equal-block schedule), so a
full forward takes ``M + P − 1`` ticks and the idle ("bubble") fraction is
``(P − 1) / (M + P − 1)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import shard_map


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """Idle fraction of the GPipe schedule: (P−1)/(M+P−1)."""
    p, m = num_stages, num_microbatches
    return (p - 1) / (m + p - 1)


def gpipe_forward(stage_fn, stage_params, microbatches, *, mesh, axis: str = "pipe"):
    """Run ``microbatches`` through ``num_stages`` pipeline stages.

    stage_fn: ``(w, x) -> y`` for one stage on one microbatch.
    stage_params: pytree whose leaves lead with the stage dimension
    (``(P, ...)``), sharded over ``axis``.
    microbatches: ``(M, ...)`` array, replicated.

    Returns the ``(M, ...)`` outputs of the final stage, replicated (the
    last rank's results are broadcast with one masked ``psum``).
    """
    num_stages = dict(mesh.shape)[axis]
    num_mb = microbatches.shape[0]
    ticks = num_mb + num_stages - 1

    def local_fn(w, xs):
        w = jax.tree.map(lambda a: a[0], w)  # drop the sharded stage dim
        r = jax.lax.axis_index(axis)

        def tick(carry, t):
            out_buf, x_in = carry
            mb = t - r
            active = (mb >= 0) & (mb < num_mb)
            mb_c = jnp.clip(mb, 0, num_mb - 1)
            inp = jnp.where(r == 0, xs[mb_c], x_in)
            y = stage_fn(w, inp)
            y = jnp.where(active, y, jnp.zeros_like(y))
            nxt = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(num_stages - 1)]
            )
            upd = jax.lax.dynamic_update_slice_in_dim(out_buf, y[None], mb_c, axis=0)
            out_buf = jnp.where(active & (r == num_stages - 1), upd, out_buf)
            return (out_buf, nxt), None

        init = (jnp.zeros_like(xs), jnp.zeros_like(xs[0]))
        (out_buf, _), _ = jax.lax.scan(
            tick, init, jnp.arange(ticks, dtype=jnp.int32)
        )
        # only the last rank holds real outputs; masked-psum broadcast
        return jax.lax.psum(out_buf, axis)

    stage_specs = jax.tree.map(
        lambda a: P(axis, *(None,) * (a.ndim - 1)), stage_params
    )
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(stage_specs, P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(stage_params, microbatches)
