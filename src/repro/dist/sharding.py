"""Single sharding-policy layer for the whole system.

Every parameter / activation / cache leaf in the model code carries a tuple
of **logical axis names** (``("embed", "heads_x_dim")``, ``("act_batch",
"act_seq", "act_embed")``, ...).  This module owns the only mapping from
those names to physical mesh axes:

  * ``RULE_PRESETS`` — named logical→mesh rule tables (``default`` is
    TP-over-``model`` + DP-over-``pod``/``data``; ``zero3`` additionally
    shards the ``embed`` axis over ``data``, ZeRO-3 style).
  * ``rules_for(cfg, mesh)`` — config-aware specialization: any rule whose
    shard granularity would split *below a whole head* (attention q/kv
    heads, SSD state heads) falls back to replication.  This is the EbV
    philosophy applied to placement: a shard that cannot be cut into equal
    whole units is not cut at all (see README.md).
  * ``use_mesh_rules(mesh, rules)`` / ``active_mesh()`` — a thread-local
    mesh+rules context; model code calls ``constrain(x, axes)`` which is a
    no-op outside any context, so the same code runs on 1 CPU device and on
    a production mesh.
  * ``resolve_spec(shape, axes)`` — logical axes → ``PartitionSpec`` with
    per-dimension divisibility fallback (an indivisible dim is replicated,
    never padded), recording every fallback in ``_CTX.log`` for the dry-run
    analysis artifacts.
  * ``split_axes`` / ``prepend_axis`` — pytree helpers for the
    ``(array, axes)`` leaf convention used by every ``init_*``.
  * ``shard_map`` — thin version-compat wrapper over JAX's shard_map (the
    ``check_vma``/``check_rep`` rename and module move).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec


# ---------------------------------------------------------------------------
# rule presets: logical axis name -> mesh axis (str), tuple of mesh axes, or
# None (replicated).  Mesh axes absent from the active mesh are ignored.
# ---------------------------------------------------------------------------
_DEFAULT_RULES = {
    # parameters
    "embed": None,
    "vocab": "model",
    "heads_x_dim": "model",
    "kv_x_dim": "model",
    "ff": "model",
    "expert": None,  # experts replicated; TP slices d_ff (DESIGN.md §5)
    "layers": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "state_heads": "model",
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": "model",
    "act_embed": None,
    # decode caches
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_kv": "model",
}

RULE_PRESETS = {
    "default": dict(_DEFAULT_RULES),
    # ZeRO-3 style: additionally shard the embed (fan-in) dim of every
    # weight over the data axis; activations keep the default layout.
    "zero3": {**_DEFAULT_RULES, "embed": "data"},
}


# ---------------------------------------------------------------------------
# mesh + rules context
# ---------------------------------------------------------------------------
class _Ctx(threading.local):
    def __init__(self):
        self.mesh = None
        self.rules = None
        # fallback log: tuples of (logical_axis, mesh_axis, reason).  Kept
        # after the context exits so the dry-run can harvest it.
        self.log = []


_CTX = _Ctx()


def active_mesh():
    """The mesh installed by :func:`use_mesh_rules`, or None."""
    return _CTX.mesh


def active_rules():
    """The rule table installed by :func:`use_mesh_rules` (default preset
    when none was given)."""
    return _CTX.rules if _CTX.rules is not None else RULE_PRESETS["default"]


@contextlib.contextmanager
def use_mesh_rules(mesh, rules=None):
    """Install (mesh, rules) as the active sharding policy.

    ``rules=None`` means the ``default`` preset with resolve-time
    divisibility fallback only; pass :func:`rules_for` output for the
    config-aware head-granularity policy.  The fallback log is reset on
    entry and *kept* on exit (the dry-run reads it after compiling).
    """
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(rules) if rules is not None else None
    _CTX.log = []
    try:
        yield mesh
    finally:
        _CTX.mesh, _CTX.rules = prev


# ---------------------------------------------------------------------------
# small mesh utilities (work on jax.sharding.Mesh and any duck-typed object
# with .axis_names / .shape — tests use a FakeMesh)
# ---------------------------------------------------------------------------
def axis_names(mesh) -> tuple:
    return tuple(mesh.axis_names)


def shape(mesh) -> dict:
    return dict(mesh.shape)


def devices(mesh):
    return getattr(mesh, "devices", None)


def split(mesh, axis: str, sizes, names):
    """Split one mesh axis into several (e.g. ``data=32`` → ``pod=2 ×
    data=16``); returns a new Mesh over the same devices."""
    sizes, names = tuple(sizes), tuple(names)
    old_names = axis_names(mesh)
    if axis not in old_names:
        raise ValueError(f"mesh has no axis {axis!r} (has {old_names})")
    msh = shape(mesh)
    prod = 1
    for s in sizes:
        prod *= s
    if prod != msh[axis]:
        raise ValueError(f"cannot split {axis}={msh[axis]} into {sizes}")
    new_shape, new_names = [], []
    for n in old_names:
        if n == axis:
            new_shape.extend(sizes)
            new_names.extend(names)
        else:
            new_shape.append(msh[n])
            new_names.append(n)
    return jax.sharding.Mesh(
        mesh.devices.reshape(tuple(new_shape)), tuple(new_names)
    )


def _mesh_axis_size(mesh, value) -> int:
    """Product of the sizes of the mesh axes a rule value refers to (axes
    missing from the mesh contribute 1)."""
    if value is None:
        return 1
    msh = shape(mesh)
    parts = value if isinstance(value, tuple) else (value,)
    size = 1
    for a in parts:
        size *= msh.get(a, 1)
    return size


# ---------------------------------------------------------------------------
# config-aware rules
# ---------------------------------------------------------------------------
def rules_for(cfg, mesh, base=None) -> dict:
    """Specialize a rule table to (config, mesh).

    Head-granularity policy: a logical axis that would be split below one
    whole unit (attention head, kv head, SSD state head) is replicated
    instead — sub-head shards break the GQA/SSD math and (EbV invariant)
    cannot be equal whole work units.  Per-dimension *size* divisibility is
    additionally enforced later by :func:`resolve_spec`.
    """
    rules = dict(base if base is not None else active_rules())
    rules.update(dict(getattr(cfg, "logical_rules_overrides", ()) or ()))

    def gate(name: str, units: int, what: str):
        value = rules.get(name)
        if value is None:
            return
        size = _mesh_axis_size(mesh, value)
        if size > 1 and units % size != 0:
            rules[name] = None
            _CTX.log.append(
                (name, str(value), f"{what}={units} % {size} != 0 -> replicated")
            )

    gate("heads_x_dim", cfg.num_heads, "num_heads")
    gate("kv_x_dim", cfg.num_kv_heads, "num_kv_heads")
    gate("cache_kv", cfg.num_kv_heads, "num_kv_heads")
    if getattr(cfg, "ssm_state", 0):
        gate("ssm_inner", cfg.ssm_heads, "ssm_heads")
        gate("ssm_heads", cfg.ssm_heads, "ssm_heads")
        gate("state_heads", cfg.ssm_heads, "ssm_heads")
    if getattr(cfg, "num_experts", 0):
        gate("expert", cfg.num_experts, "num_experts")
    return rules


# ---------------------------------------------------------------------------
# spec resolution
# ---------------------------------------------------------------------------
def resolve_spec(shape_, axes, *, mesh=None, rules=None) -> PartitionSpec:
    """Logical axes tuple → PartitionSpec for an array of ``shape_``.

    Per dimension: look its logical name up in the rules, drop mesh axes
    that are absent from the mesh or already used by another dimension, then
    keep the longest prefix of the remaining axes whose size product divides
    the dimension (indivisible → replicate, logged to ``_CTX.log``).
    """
    mesh = mesh if mesh is not None else active_mesh()
    if mesh is None:
        return PartitionSpec()
    rules = rules if rules is not None else active_rules()
    dims = tuple(shape_)
    ax = tuple(axes)
    if len(ax) < len(dims):
        ax = ax + (None,) * (len(dims) - len(ax))
    elif len(ax) > len(dims):
        raise ValueError(f"axes {ax} longer than shape {dims}")
    msh = shape(mesh)
    used: set = set()
    entries = []
    for dim, name in zip(dims, ax):
        value = rules.get(name) if name is not None else None
        parts = value if isinstance(value, tuple) else ((value,) if value else ())
        keep, prod = [], 1
        for a in parts:
            if a not in msh or a in used:
                continue
            if msh[a] == 1:
                continue  # size-1 axes add nothing; keep specs minimal
            if dim % (prod * msh[a]) == 0:
                keep.append(a)
                prod *= msh[a]
            else:
                _CTX.log.append(
                    (str(name), a, f"dim {dim} % {prod * msh[a]} != 0 -> replicated")
                )
                break  # prefix semantics: drop this axis and everything after
        used.update(keep)
        entries.append(None if not keep else (keep[0] if len(keep) == 1 else tuple(keep)))
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def constrain(x, axes):
    """``with_sharding_constraint`` by logical axes; identity when no mesh
    context is active (single-device smoke paths)."""
    mesh = active_mesh()
    if mesh is None or not isinstance(mesh, jax.sharding.Mesh):
        return x
    spec = resolve_spec(x.shape, axes, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# (array, axes)-pair pytree helpers
# ---------------------------------------------------------------------------
def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )


def _is_pair(x) -> bool:
    return (
        isinstance(x, tuple)
        and len(x) == 2
        and _is_axes(x[1])
        and not isinstance(x[0], (tuple, str))
    )


def split_axes(tree):
    """Split an init-style pytree whose leaves are ``(array, logical_axes)``
    pairs into (arrays_tree, axes_tree).  Bare array leaves get all-None
    axes of matching rank."""
    flat, treedef = jax.tree.flatten(tree, is_leaf=_is_pair)
    arrays, axes = [], []
    for leaf in flat:
        if _is_pair(leaf):
            arrays.append(leaf[0])
            axes.append(leaf[1])
        else:
            arrays.append(leaf)
            axes.append((None,) * getattr(leaf, "ndim", 0))
    return treedef.unflatten(arrays), treedef.unflatten(axes)


def prepend_axis(axes_tree, name: str):
    """Prepend a logical axis name to every axes tuple in a tree (layer
    stacking: per-layer axes → scanned-stack axes)."""
    return jax.tree.map(
        lambda ax: (name,) + tuple(ax), axes_tree, is_leaf=_is_axes
    )


# ---------------------------------------------------------------------------
# shard_map version compat
# ---------------------------------------------------------------------------
def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """JAX-version-portable ``shard_map``.

    Newer JAX exposes ``jax.shard_map(..., check_vma=)``; older releases
    have ``jax.experimental.shard_map.shard_map(..., check_rep=)``.  All
    repo call sites go through here so the skew lives in one place.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma,
            )
        except TypeError:  # transitional releases: jax.shard_map w/ check_rep
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma,
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
