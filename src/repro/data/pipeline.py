"""Deterministic, shard-aware, resumable token pipeline.

Two sources:
  * synthetic — counter-based Philox streams keyed by (seed, step, shard):
    O(1) random access, so restore-from-checkpoint is exact and free, and
    every data shard generates only its own slice (no host broadcast).
  * file — a flat uint16/uint32 token memmap, strided deterministically by
    (step, shard) so restarts and elastic re-sharding replay identically.

A background prefetch thread keeps ``depth`` batches ready.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class TokenPipeline:
    def __init__(
        self,
        *,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        shard_index: int = 0,
        num_shards: int = 1,
        seed: int = 0,
        token_file: str | None = None,
        start_step: int = 0,
        prefetch_depth: int = 2,
    ):
        assert global_batch % num_shards == 0, (global_batch, num_shards)
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_shards
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.seed = seed
        self.step = start_step
        self._tokens = None
        if token_file is not None:
            self._tokens = np.memmap(token_file, dtype=np.uint16, mode="r")
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch_depth)
        self._thread = None
        self._stop = threading.Event()

    # ---- deterministic batch synthesis -----------------------------------
    def _batch_at(self, step: int) -> np.ndarray:
        if self._tokens is not None:
            n = len(self._tokens)
            per_step = self.global_batch * self.seq_len
            base = (step * per_step) % max(n - per_step, 1)
            local = base + self.shard_index * self.local_batch * self.seq_len
            flat = np.asarray(self._tokens[local : local + self.local_batch * self.seq_len])
            if flat.size < self.local_batch * self.seq_len:  # wrap
                flat = np.concatenate([flat, self._tokens[: self.local_batch * self.seq_len - flat.size]])
            return (flat.astype(np.int32) % self.vocab_size).reshape(self.local_batch, self.seq_len)
        rng = np.random.Generator(
            np.random.Philox(key=self.seed, counter=step * self.num_shards + self.shard_index)
        )
        return rng.integers(
            0, self.vocab_size, size=(self.local_batch, self.seq_len), dtype=np.int32
        )

    # ---- iteration & prefetch ---------------------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = {"tokens": self._batch_at(step), "step": step}
            while not self._stop.is_set():
                try:
                    self._queue.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
        while not self._queue.empty():
            self._queue.get_nowait()

    def __next__(self):
        if self._thread is not None:
            batch = self._queue.get()
        else:
            batch = {"tokens": self._batch_at(self.step), "step": self.step}
        self.step = batch["step"] + 1
        return batch

    def __iter__(self):
        return self

    # ---- checkpointable state ---------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.stop()
        self.step = int(state["step"])
        self.seed = int(state["seed"])
        return self
