"""Deterministic shard-aware data pipeline."""
from .pipeline import TokenPipeline  # noqa: F401
