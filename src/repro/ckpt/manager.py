"""Fault-tolerant, elastic checkpointing.

Layout (one directory per step):

    <dir>/step_000000123/
        manifest.json       # tree structure, shapes, dtypes, data state
        arrays.npz          # logical (unsharded) arrays, keyed by flat path

Properties:
  * **atomic** — written to ``step_X.tmp`` then ``os.replace``d, so a crash
    mid-save never corrupts the latest checkpoint;
  * **elastic** — arrays are stored *logically* (mesh-independent); restore
    re-shards onto whatever mesh/sharding the restarted job uses, so a
    512-chip run restores onto 256 chips and vice versa;
  * **async** — ``save(..., blocking=False)`` hands the host copy to a
    writer thread so the step loop isn't stalled;
  * **self-pruning** — keeps the newest ``keep`` checkpoints.

(At real 1000+-node scale the npz body would be replaced by per-host
sharded writes into a blob store; the manifest/atomic-rename/elastic logic
is shared.  Documented in DESIGN.md §5.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, {p[len(k) + 1 :]: a for p, a in flat.items() if p.split("/")[0] == k}) for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        t = [
            _unflatten_into(v, {p[len(str(i)) + 1 :]: a for p, a in flat.items() if p.split("/")[0] == str(i)})
            for i, v in enumerate(template)
        ]
        return type(template)(t)
    if template is None:
        return None
    return flat[""]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._writer: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, extra: dict | None = None, blocking: bool = True):
        """``tree`` is any pytree of jax/np arrays (params/opt_state/...);
        ``extra`` is JSON-serializable metadata (data-pipeline cursor, RNG)."""
        self.wait()  # serialize with any in-flight async writer
        flat = _flatten(tree)
        # gather to host as logical arrays (elastic format)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "extra": extra or {},
                "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._prune()

        if blocking:
            _write()
        else:
            self._writer = threading.Thread(target=_write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, *, step: int | None = None, shardings=None):
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings — arrays are ``device_put`` onto them (elastic)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings,
                is_leaf=lambda x: isinstance(x, np.ndarray),
            )
        return tree, manifest["extra"], step
