"""Fault-tolerant elastic checkpointing."""
from .manager import CheckpointManager  # noqa: F401
