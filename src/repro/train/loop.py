"""Training loop: jit'd train step (grad accumulation, optimizer update),
fault-tolerant checkpoint/resume, straggler detection, throughput logging.

Works identically on 1 CPU device (smoke/example scale) and on the
production mesh (launch/train.py attaches shardings).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.data.pipeline import TokenPipeline
from repro.ckpt.manager import CheckpointManager
from . import optimizer as opt_lib


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    microbatches: int = 1
    learning_rate: float = 3e-4
    warmup_steps: int = 20
    optimizer: str = "adamw"  # adamw | ebv
    max_grad_norm: float = 1.0
    seed: int = 0
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0  # step slower than EMA×this → flagged


def make_batch_fn(model_cfg: ModelConfig, train_cfg: TrainConfig):
    """Turn pipeline token batches into the model's input dict (stub
    frontends included — frames/patch embeddings are deterministic)."""
    def fn(tokens):
        tokens = jnp.asarray(tokens)
        if model_cfg.family == "vlm":
            p = model_cfg.num_prefix_embeds
            rng = jax.random.PRNGKey(train_cfg.seed)
            prefix = jax.random.normal(
                rng, (tokens.shape[0], p, model_cfg.d_model), jnp.float32
            ).astype(jnp.dtype(model_cfg.dtype))
            return {"tokens": tokens[:, : tokens.shape[1] - p], "prefix_embeds": prefix}
        if model_cfg.family == "encdec":
            rng = jax.random.PRNGKey(train_cfg.seed)
            frames = jax.random.normal(
                rng, (tokens.shape[0], max(tokens.shape[1] // 4, 1), model_cfg.d_model), jnp.float32
            ).astype(jnp.dtype(model_cfg.dtype))
            return {"tokens": tokens, "frames": frames}
        return {"tokens": tokens}

    return fn


def make_train_step(model_cfg: ModelConfig, optimizer: opt_lib.Optimizer, *, microbatches: int = 1):
    """Returns step(params, opt_state, batch) → (params, opt_state, metrics).
    With ``microbatches > 1`` gradients are accumulated via lax.scan
    (sequential microbatches, constant memory).

    The f32 accumulator carry is sharding-constrained to the parameter
    layout (EXPERIMENTS.md §Perf iteration 1): an unconstrained scan carry
    is replicated by GSPMD, which turns every per-microbatch gradient into
    an f32 all-gather and the reductions into full all-reduces."""
    from repro.dist.sharding import active_mesh, constrain

    param_axes_tree = lm.param_axes(model_cfg)

    def _constrain_like_params(tree):
        if active_mesh() is None:
            return tree
        flat, td = jax.tree.flatten(tree)
        flat_ax = td.flatten_up_to(param_axes_tree)
        return td.unflatten([constrain(g, ax) for g, ax in zip(flat, flat_ax)])

    def loss_fn(params, batch):
        return lm.train_loss(params, batch, model_cfg)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _constrain_like_params(grads)
        else:
            def split_mb(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split_mb, batch)

            def acc_body(acc, mbatch):
                (l, m), g = grad_fn(params, mbatch)
                # constrain g itself: sharding then propagates INTO the
                # backward (partial-sum psums lower as reduce-scatters
                # instead of replicating all-reduces)
                g = _constrain_like_params(g)
                acc_g, acc_l = acc
                new_g = _constrain_like_params(jax.tree.map(jnp.add, acc_g, g))
                return (new_g, acc_l + l), m

            zero_g = _constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (grads, loss_sum), metrics = jax.lax.scan(acc_body, (zero_g, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: (g / microbatches).astype(g.dtype), grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        params, opt_state = optimizer.update(grads, opt_state, params)
        out = {"loss": loss, "gnorm": opt_state.pop("gnorm", jnp.zeros(()))}
        if isinstance(metrics, dict):
            out.update(metrics)
        return params, opt_state, out

    return step


def train(
    model_cfg: ModelConfig,
    train_cfg: TrainConfig,
    *,
    params=None,
    jit_kwargs: dict | None = None,
    on_metrics=None,
):
    """End-to-end driver.  Returns (params, history)."""
    key = jax.random.PRNGKey(train_cfg.seed)
    schedule = opt_lib.warmup_cosine(
        train_cfg.learning_rate, train_cfg.warmup_steps, train_cfg.steps
    )
    optimizer = opt_lib.get_optimizer(
        train_cfg.optimizer, schedule, max_grad_norm=train_cfg.max_grad_norm
    )
    if params is None:
        params = lm.init_params(key, model_cfg)
    opt_state = optimizer.init(params)

    pipe = TokenPipeline(
        vocab_size=model_cfg.vocab_size,
        seq_len=train_cfg.seq_len,
        global_batch=train_cfg.global_batch,
        seed=train_cfg.seed,
    )
    mgr = CheckpointManager(train_cfg.ckpt_dir) if train_cfg.ckpt_dir else None
    start_step = 0
    if mgr and mgr.latest_step() is not None:
        (params, opt_state), extra, start_step = mgr.restore((params, opt_state))
        pipe.restore(extra["data"])
        print(f"[train] resumed from step {start_step}")
    pipe.step = max(pipe.step, start_step)

    batch_fn = make_batch_fn(model_cfg, train_cfg)
    step_fn = jax.jit(
        make_train_step(model_cfg, optimizer, microbatches=train_cfg.microbatches),
        donate_argnums=(0, 1),
        **(jit_kwargs or {}),
    )

    history = []
    ema = None
    for step in range(start_step, train_cfg.steps):
        raw = next(pipe)
        batch = batch_fn(raw["tokens"])
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        # straggler mitigation hook: synchronous SPMD means a slow host shows
        # up as a slow step; flag it for the launcher's restart policy.
        if ema is not None and dt > train_cfg.straggler_factor * ema and step > start_step + 2:
            print(f"[train][straggler] step {step} took {dt:.3f}s (ema {ema:.3f}s)")
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        history.append({"step": step, "time_s": dt, **metrics})
        if on_metrics:
            on_metrics(history[-1])
        if step % train_cfg.log_every == 0:
            tok_s = train_cfg.global_batch * train_cfg.seq_len / dt
            print(f"[train] step {step:5d} loss {metrics['loss']:.4f} {dt*1e3:7.1f} ms/step {tok_s:,.0f} tok/s")
        if mgr and (step + 1) % train_cfg.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state), extra={"data": pipe.state()}, blocking=False)
    if mgr:
        mgr.save(train_cfg.steps, (params, opt_state), extra={"data": pipe.state()})
        mgr.wait()
    return params, history
