"""Optimizers, built from scratch (no optax): AdamW, gradient clipping,
LR schedules, and the paper-technique integration — an EbV-preconditioned
second-order optimizer whose inverse application is a batched EbV LU solve
(DESIGN.md §3): for every 2-D parameter factor we maintain a Kronecker-factor
covariance ``C = β₂C + (1−β₂) G Gᵀ`` and precondition with the solution of
``(C/τ + λI) P = G`` — the linear system the paper's solver was built for,
instead of the usual inverse-p-th-root eigendecomposition.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)

    return schedule


def constant_lr(lr: float):
    return lambda step: jnp.float32(lr)


# ---------------------------------------------------------------------------
# global-norm clipping
# ---------------------------------------------------------------------------
def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(
    schedule,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float | None = 1.0,
    state_dtype=None,
) -> Optimizer:
    """AdamW with decoupled weight decay and optional global-norm clipping.
    ``state_dtype`` lets the biggest configs keep m/v in bf16 (memory table
    in EXPERIMENTS.md §Dry-run)."""

    def init(params):
        def zeros_like(p):
            dt = state_dtype or (p.dtype if jnp.issubdtype(p.dtype, jnp.floating) else jnp.float32)
            return jnp.zeros(p.shape, dt)

        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros_like, params),
            "nu": jax.tree.map(zeros_like, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        else:
            gnorm = global_norm(grads)
        lr = schedule(step)

        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g32 = g.astype(jnp.float32)
            mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
            nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
            step_dir = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + eps)
            if weight_decay and p.ndim >= 2:  # no decay on norms/scalars
                step_dir = step_dir + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * step_dir
            return newp.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"step": step, "mu": mu, "nu": nu, "gnorm": gnorm}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# EbV-preconditioned optimizer (the paper's solver inside the optimizer)
# ---------------------------------------------------------------------------
def ebv_preconditioned(
    schedule,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float | None = 1.0,
    damping: float = 1e-3,
    max_precond_dim: int = 1024,
    solver_block: int = 128,
    graft_scale: float = 0.3,
    solver_impl: str | None = None,
    solve_tolerance: float | str | None = None,
) -> Optimizer:
    """Second-order preconditioning via EbV LU solves.

    Eligible leaves: 2-D with min(shape) ≤ ``max_precond_dim`` — the
    covariance is built on the smaller dim.  Ineligible leaves fall back to
    AdamW.  Per step: the covariance EMA sees the *raw* gradient (clipping
    rescales each step differently, and an EMA over inconsistently-scaled
    G·Gᵀ terms stops estimating curvature), the solve's right-hand side is
    the bias-corrected Adam momentum (built from clipped gradients); the
    solved direction is then norm-grafted onto ``graft_scale ×`` the Adam
    step's magnitude — Shampoo-style grafting, which inherits Adam's
    step-size decay near convergence instead of re-normalizing the whitened
    direction to a constant-size step (that oscillates on stiff problems).

    The per-parameter ``(C/τ + λI) P = G`` systems are *grouped by order and
    solved as one batched call per group* through the ``repro.solvers``
    registry (``ops.linear_solve`` on stacked ``(B, n, n)`` operands) — on
    the registry's static/measured choice that is the batched Pallas grid
    kernel (:mod:`repro.kernels.batched_lu`), one grid program per
    parameter-factor system, instead of the per-leaf pure-jnp reference
    this optimizer used to unroll.  Eager calls factor each group with
    ``enrich=True``, so the dispatch carries a batched
    :class:`~repro.core.factorization.Factorization` artifact and the
    substitution runs the inverted-diagonal backend (one batched GEMM per
    block row instead of per-system triangular recurrences).
    ``solver_impl`` forces a backend (e.g. ``"xla"`` for the vmapped
    mirror).

    ``solve_tolerance`` opens the registry's approximate solver tiers for
    the preconditioner solves: a float is passed through as the largest
    acceptable relative residual; ``"auto"`` derives it from the EMA noise
    floor — the covariance estimate ``C`` carries relative sampling noise
    of order ``1 − β₂`` per update (each EMA step replaces that fraction of
    ``C`` with a single-sample ``G Gᵀ``), so solving the preconditioner
    system much past a tenth of that noise is numerical theatre.  ``None``
    (the default) keeps the exact tier — bitwise-identical to the
    pre-tolerance optimizer."""
    from repro.kernels import ops as kops

    if solve_tolerance == "auto":
        # EMA noise floor: (1 − β₂) relative covariance noise, solved one
        # decade past it; floored at bf16_ir's guaranteed residual so the
        # derived tolerance always admits at least one approximate tier.
        solve_tol = max(1e-6, (1.0 - b2) * 0.1)
    else:
        solve_tol = float(solve_tolerance) if solve_tolerance else 0.0

    adam = adamw(
        schedule, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
        max_grad_norm=None, state_dtype=jnp.float32,
    )

    def eligible(p):
        return p.ndim == 2 and min(p.shape) <= max_precond_dim

    def init(params):
        st = adam.init(params)
        st["cov"] = jax.tree.map(
            lambda p: jnp.zeros((min(p.shape), min(p.shape)), jnp.float32)
            if eligible(p)
            else jnp.zeros((0, 0), jnp.float32),
            params,
        )
        return st

    def update(grads, state, params):
        gnorm = global_norm(grads)
        if max_grad_norm is not None:
            clip_scale = jnp.minimum(1.0, max_grad_norm / jnp.maximum(gnorm, 1e-9))
        else:
            clip_scale = jnp.float32(1.0)

        step = state["step"] + 1
        lr = schedule(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        flat_c = treedef.flatten_up_to(state["cov"])

        # ---- pass 1: Adam stats + covariance EMAs; collect the eligible
        # (C/τ + λI) P = G systems, grouped by order n --------------------
        stats = []
        groups: dict[int, list[tuple[int, jax.Array, jax.Array]]] = {}
        for i, (p, g, mu, nu, cov) in enumerate(
            zip(flat_p, flat_g, flat_mu, flat_nu, flat_c)
        ):
            gc32 = g.astype(jnp.float32) * clip_scale
            mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * gc32
            nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * gc32 * gc32
            adam_dir = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + eps)
            left = None
            if eligible(p):
                # covariance on the RAW gradient: clipping rescales every
                # step by a different factor, and an EMA over
                # inconsistently-scaled G·Gᵀ terms stops estimating
                # curvature.
                g32 = g.astype(jnp.float32)
                left = p.shape[0] <= p.shape[1]
                gg = g32 @ g32.T if left else g32.T @ g32
                cov = b2 * cov + (1 - b2) * gg
                n = cov.shape[0]
                tr = jnp.trace(cov) / n
                a = cov / jnp.maximum(tr, 1e-12) + damping * jnp.eye(n, dtype=jnp.float32)
                rhs = mu32 / bc1
                groups.setdefault(n, []).append((i, a, rhs if left else rhs.T))
            stats.append((mu32, nu32, adam_dir, cov, left))

        # ---- batched solves: one registry dispatch per order group (the
        # batched Pallas grid kernels — one program per parameter-factor
        # system); narrower RHSs inside a group zero-pad to the widest ----
        solved: dict[int, jax.Array] = {}
        for n, items in sorted(groups.items()):
            mmax = max(r.shape[1] for _, _, r in items)
            a3 = jnp.stack([a for _, a, _ in items])
            r3 = jnp.stack(
                [jnp.pad(r, ((0, 0), (0, mmax - r.shape[1]))) for _, _, r in items]
            )
            x3 = kops.linear_solve(
                a3, r3, impl=solver_impl, block=min(solver_block, n),
                tolerance=solve_tol, enrich=True,
            )
            for j, (i, _, r) in enumerate(items):
                solved[i] = x3[j, :, : r.shape[1]]

        # ---- pass 2: grafting, weight decay, parameter update -----------
        def finish(i, p, mu, nu):
            mu32, nu32, adam_dir, cov, left = stats[i]
            if i in solved:
                pre = solved[i] if left else solved[i].T
                # graft onto (a fraction of) the Adam step's magnitude so
                # the step size decays with Adam's near convergence
                target = graft_scale * jnp.linalg.norm(adam_dir)
                step_dir = pre * (target / jnp.maximum(jnp.linalg.norm(pre), 1e-12))
            else:
                step_dir = adam_dir
            if weight_decay and p.ndim >= 2:  # no decay on norms/scalars
                step_dir = step_dir + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
            return newp, mu32.astype(mu.dtype), nu32.astype(nu.dtype), cov

        out = [
            finish(i, p, mu, nu)
            for i, (p, mu, nu) in enumerate(zip(flat_p, flat_mu, flat_nu))
        ]
        return treedef.unflatten([o[0] for o in out]), {
            "step": step,
            "mu": treedef.unflatten([o[1] for o in out]),
            "nu": treedef.unflatten([o[2] for o in out]),
            "cov": treedef.unflatten([o[3] for o in out]),
            "gnorm": gnorm,
        }

    return Optimizer(init, update)


def get_optimizer(name: str, schedule, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(schedule, **kw)
    if name == "ebv":
        return ebv_preconditioned(schedule, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
