"""Gradient compression for cross-pod data parallelism: int8 quantization
with error feedback, applied to the pod-axis all-reduce via ``shard_map``.

Inside a pod the DP reduction stays full-precision (GSPMD reduce-scatter,
ICI is fast); *between* pods (DCI — the slow link at 1000+-node scale) the
summand is quantized to int8 with a per-leaf fp32 scale, psum'd, and
dequantized; the quantization residual is carried to the next step
(error feedback), which keeps SGD-style convergence guarantees.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import shard_map


def quantize(x, *, bits: int = 8):
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    maxv = jnp.max(jnp.abs(x32))
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(maxv, 1e-12) / qmax
    q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error):
    """(grads + error) → (quantized tree, scales, new error)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        deq = dequantize(q, s)
        return q, s, target - deq

    flat, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat, flat_e)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    new_e = treedef.unflatten([o[2] for o in out])
    return qs, scales, new_e


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, error, *, mesh, axis: str = "pod"):
    """Cross-``axis`` mean of ``grads`` with int8 + error-feedback transport.

    grads leaves must be replicated across ``axis`` *within* each shard
    group already (i.e. call this after the intra-pod reduction).  Returns
    (reduced grads fp32, new error tree).
    """
    n = mesh.shape[axis]

    def local_fn(g, e):
        q, s, new_e = compress_with_feedback(g, e)
        # wire payload per pod: int8 q + one fp32 scale.  Each pod's scale
        # differs, so the exact reduction is the per-pod-scale weighted sum
        # of the gathered int8 payloads.
        gathered_scales = jax.tree.map(lambda ss: jax.lax.all_gather(ss, axis), s)
        gathered_q = jax.tree.map(lambda qq: jax.lax.all_gather(qq, axis), q)
        red = jax.tree.map(
            lambda qs, ss: jnp.tensordot(ss, qs.astype(jnp.float32), axes=([0], [0])) / n,
            gathered_q, gathered_scales,
        )
        return red, new_e

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False,
    )
    return fn(grads, error)


def compression_ratio(params) -> float:
    """Wire-bytes ratio of int8+scale vs fp32 transport."""
    total = sum(p.size for p in jax.tree.leaves(params))
    return (total * 1 + 4 * len(jax.tree.leaves(params))) / (total * 4)
