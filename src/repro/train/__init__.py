"""Training substrate: optimizers (incl. EbV-preconditioned), loop, grad compression."""
from . import optimizer, loop, grad_compress  # noqa: F401
