"""Batched serving engine (prefill + KV-cache decode)."""
from .engine import Engine  # noqa: F401
