"""Serving subsystem: continuous-batching generation + solve front end.

* :mod:`repro.serve.scheduler` — shape-bucketed queue, EBV-equalized slot
  filling, deadline/FIFO ordering, padding stats;
* :mod:`repro.serve.engine` — slot-based prefill/decode generation engine;
* :mod:`repro.serve.paged` — paged KV-cache page pool, prompt-prefix
  fingerprint chains, and the refcounted shared-prefix cache;
* :mod:`repro.serve.solve_service` — factor-once/solve-many linear-system
  service with an LRU factorization cache and coalesced multi-RHS solves.
"""
from .engine import Engine, EngineStats, GenRequest  # noqa: F401
from .paged import PagePool, PrefixCache, prefix_chain  # noqa: F401
from .scheduler import Scheduler, bucket_length  # noqa: F401
from .solve_service import (  # noqa: F401
    DeadlineMiss,
    NotFlushed,
    SolveService,
    UnknownTicket,
)
