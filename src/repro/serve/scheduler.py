"""Shape-bucketed request queue with EBV-style equalized slot filling.

The scheduler is the admission layer shared by the generation engine
(:mod:`repro.serve.engine`) and the linear-system front end
(:mod:`repro.serve.solve_service`).  It is payload-agnostic: callers submit
opaque payloads tagged with a *bucket* (the dispatch shape the payload pads
to — prompt-length bucket for LM requests, ``(structure, n, bw, dtype)``
for solve requests), a *cost* estimate, and an optional *deadline*.

Ordering is earliest-deadline-first, then FIFO.  Requests that carry a
deadline are never reordered past one another and always admit ahead of
deadline-free traffic.

**Equalized slot filling** (the paper's eq.-7 pairing, applied to the
request queue): when ``k`` slots free simultaneously, picking the first
``k`` FIFO requests can hand every slot a heavy request — they all finish
late together and the next dispatches run underfull.  Instead the scheduler
looks at a bounded window (``2k``) of deadline-free eligible requests,
sorts it by cost, and picks ``k`` via the fold order
(:func:`repro.core.ebv.fold_index`: heaviest, lightest, 2nd-heaviest,
2nd-lightest, …) so each admitted batch mixes long- and short-lived
occupants and the slots turn over at staggered, balanced times — every
decode dispatch stays a full batch.  The window bound keeps the reordering
fair: a request can be overtaken at most once before it is in the front
``k`` of the window and must be picked.

Padding accounting: the caller reports real vs padded sizes at submission
(``real=``, ``padded=``); ``stats.padding_frac`` is the fraction of
dispatched prompt tokens that were bucket padding.

**Page-granular equalized filling** (paged serving engine): with a paged KV
cache the unit of slot occupancy is the fixed-size *page*, not the dense
``max_len`` row — the same equalization the paper applies to elimination
vectors, applied to storage: every allocation is page-shaped, so the fold
pick mixes page-heavy and page-light requests exactly as it mixes
long/short-lived occupants, and the pool fills uniformly with no
per-slot reservation.  Requests carry their prompt's page-block
fingerprint chain in ``ScheduledRequest.prefix`` (computed once at
submission — ``repro.serve.paged.prefix_chain``), so the engine's
admission step can map shared leading pages to refcounted pool pages and
skip the shared part of the prefill.  Two fragmentation axes are
reported: ``padding_frac`` (bucket padding inside the prefill dispatch)
and ``page_frac`` (internal fragmentation of partially-filled last pages,
from the engine's ``live_tokens`` / ``page_tokens`` accounting).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Hashable

from repro.core.ebv import fold_index

__all__ = ["ScheduledRequest", "SchedulerStats", "Scheduler", "bucket_length"]


def bucket_length(n: int, bucket: int) -> int:
    """Round ``n`` up to the enclosing shape bucket (multiple of ``bucket``)."""
    if bucket <= 1:
        return n
    return -(-n // bucket) * bucket


@dataclasses.dataclass
class ScheduledRequest:
    """One queue entry.  ``cost`` is the slot-occupancy estimate the
    equalizer balances (for LM requests: padded prompt + new tokens)."""

    payload: Any
    bucket: Hashable
    cost: float = 1.0
    deadline: float | None = None
    seq: int = 0
    real: int = 0
    padded: int = 0
    # prompt page-block fingerprint chain (list of digests) for paged
    # shared-prefix admission; None for non-paged traffic
    prefix: Any = None

    @property
    def priority(self) -> tuple:
        return (self.deadline if self.deadline is not None else math.inf, self.seq)


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    admitted: int = 0
    real_tokens: int = 0
    padding_tokens: int = 0
    equalized_picks: int = 0
    # admissions whose return order was permuted toward even per-shard load
    # (mesh-sharded engine only; 0 for single-shard serving)
    shard_balanced: int = 0
    # paged-engine fragmentation accounting (filled at slot retirement):
    # live_tokens = tokens a request actually occupied, page_tokens = the
    # page-rounded allocation that backed them
    live_tokens: int = 0
    page_tokens: int = 0

    @property
    def padding_frac(self) -> float:
        tot = self.real_tokens + self.padding_tokens
        return self.padding_tokens / tot if tot else 0.0

    @property
    def page_frac(self) -> float:
        """Internal fragmentation: fraction of allocated page slots left
        empty by partially-filled last pages (0.0 for dense serving)."""
        if not self.page_tokens:
            return 0.0
        return (self.page_tokens - self.live_tokens) / self.page_tokens


class Scheduler:
    def __init__(self):
        self._queue: list[ScheduledRequest] = []
        self._seq = itertools.count()
        self.stats = SchedulerStats()

    def __len__(self) -> int:
        return len(self._queue)

    def submit(
        self,
        payload: Any,
        *,
        bucket: Hashable = None,
        cost: float = 1.0,
        deadline: float | None = None,
        real: int = 0,
        padded: int = 0,
        prefix: Any = None,
    ) -> ScheduledRequest:
        req = ScheduledRequest(
            payload=payload, bucket=bucket, cost=cost, deadline=deadline,
            seq=next(self._seq), real=real, padded=padded, prefix=prefix,
        )
        self._queue.append(req)
        self.stats.submitted += 1
        return req

    def buckets(self) -> dict[Hashable, int]:
        """Pending request count per shape bucket."""
        out: dict[Hashable, int] = {}
        for r in self._queue:
            out[r.bucket] = out.get(r.bucket, 0) + 1
        return out

    def take(
        self,
        k: int,
        *,
        equalize: bool = True,
        shards: list[int] | None = None,
        shard_load: list[float] | None = None,
    ) -> list[ScheduledRequest]:
        """Admit up to ``k`` requests.

        Deadline-bearing requests go first, in strict EDF order.  Remaining
        slots fill from the FIFO front window of deadline-free requests with
        the equalized fold pick (see module docstring); ``equalize=False``
        degrades to plain FIFO.

        **Shard-occupancy-aware ordering** (mesh-sharded engine):
        ``shards[i]`` names the shard of the i-th slot the caller will fill
        with the i-th returned request, and ``shard_load`` carries the live
        cost per shard.  The *choice* of requests is unchanged — only their
        return order is permuted, heaviest-cost request to
        lightest-loaded target shard (the eq.-7 pairing applied across the
        mesh), so equalized slot filling balances live tokens per shard
        instead of stacking the heavy picks on whichever shard's slots
        freed first."""
        if k <= 0 or not self._queue:
            return []
        with_dl = sorted(
            (r for r in self._queue if r.deadline is not None), key=lambda r: r.priority
        )
        picked: list[ScheduledRequest] = with_dl[:k]
        rest = k - len(picked)
        if rest > 0:
            fifo = sorted(
                (r for r in self._queue if r.deadline is None), key=lambda r: r.seq
            )
            window = fifo[: 2 * rest]
            if equalize and len(window) > rest:
                by_cost = sorted(window, key=lambda r: (-r.cost, r.seq))
                picked += [by_cost[fold_index(i, len(by_cost))] for i in range(rest)]
                self.stats.equalized_picks += rest
            else:
                picked += window[:rest]
        for r in picked:
            self._queue.remove(r)
            self.stats.admitted += 1
            self.stats.real_tokens += r.real
            self.stats.padding_tokens += r.padded
        if shards is not None and len(set(shards[: len(picked)])) > 1:
            picked = self._balance_shards(
                picked, shards[: len(picked)], shard_load
            )
        return picked

    def _balance_shards(
        self,
        picked: list[ScheduledRequest],
        shards: list[int],
        shard_load: list[float] | None,
    ) -> list[ScheduledRequest]:
        """Permute ``picked`` so position i (→ a slot on ``shards[i]``)
        receives the request that keeps per-shard live cost most even:
        greedily hand the heaviest remaining request to the target slot
        whose shard currently carries the least cost (deadline holders keep
        EDF order among themselves — only their slot assignment moves)."""
        nsh = max(shards) + 1
        load = list(shard_load) + [0.0] * (nsh - len(shard_load or [])) \
            if shard_load else [0.0] * nsh
        by_cost = sorted(
            range(len(picked)), key=lambda i: (-picked[i].cost, picked[i].seq)
        )
        slots_left = list(range(len(picked)))
        out: list[ScheduledRequest | None] = [None] * len(picked)
        for i in by_cost:
            pos = min(slots_left, key=lambda s: (load[shards[s]], s))
            slots_left.remove(pos)
            out[pos] = picked[i]
            load[shards[pos]] += picked[i].cost
        self.stats.shard_balanced += len(picked)
        return [r for r in out if r is not None]

    def drain(self) -> list[ScheduledRequest]:
        """All pending requests in priority order (used by batch front ends
        that coalesce the whole queue, e.g. the solve service)."""
        out = sorted(self._queue, key=lambda r: r.priority)
        for r in out:
            self.stats.admitted += 1
            self.stats.real_tokens += r.real
            self.stats.padding_tokens += r.padded
        self._queue.clear()
        return out

    def restore(self, entries: list[ScheduledRequest]) -> None:
        """Return un-processed ``drain``/``take`` entries to the queue.

        Transactional callers (a flush that fails mid-way) must not lose the
        remainder of the batch.  Entries keep their original ``seq`` and
        ``deadline``, so re-draining preserves the original order, and the
        admission accounting is reversed so stats reflect only work actually
        handed off."""
        for r in entries:
            self._queue.append(r)
            self.stats.admitted -= 1
            self.stats.real_tokens -= r.real
            self.stats.padding_tokens -= r.padded
