"""Linear-system serving front end: factor once, solve many.

The dominant real traffic shape for a solver service is GLU3.0's
circuit-simulation pattern — the SAME matrix arrives over and over with
fresh right-hand sides (transient timesteps, Monte-Carlo sweeps, parameter
scans).  The service exploits it twice:

* **factorization cache** — an LRU keyed by matrix *fingerprint*
  (content hash of bytes + shape + dtype + bandwidth).  A hit skips the
  factorization dispatch entirely and jumps straight to substitution;
* **RHS coalescing** — pending requests against one fingerprint hstack
  their RHS columns into a single wide solve dispatch
  (:func:`repro.core.solve.stack_rhs`).  Substitution columns are
  independent, so the coalesced results are bitwise-identical to
  per-request solves while paying one kernel launch.

Everything routes through :class:`repro.solvers.Problem` descriptors and
the registry, so the autotuned backend selection (and its multi-RHS
capability filter — e.g. the vector-only scalar banded solve is pruned when
``rhs > 1``) decides *how* each coalesced dispatch runs.  Dispatch counts in
``stats`` come from the registry's dispatch hook, not from self-reporting.

Admission/ordering rides the shared :class:`repro.serve.scheduler.Scheduler`
(buckets = ``(structure, n, bw, dtype)``; deadline/FIFO order decides which
matrix group flushes first).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import jax.numpy as jnp
import numpy as np

from repro import solvers
from repro.kernels import ops as kops
from repro.core.solve import split_rhs, stack_rhs
from .scheduler import Scheduler

__all__ = ["SolveRequest", "SolveServiceStats", "SolveService", "fingerprint"]


def fingerprint(a, *, bw: int = 0) -> str:
    """Content hash identifying a matrix operand (dense or row-aligned
    band): sha1 over the raw bytes + shape + dtype + bandwidth."""
    arr = np.asarray(a)
    h = hashlib.sha1()
    h.update(str((arr.shape, arr.dtype.str, int(bw))).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class SolveRequest:
    ticket: int
    fp: str
    a: object  # matrix operand (kept until its group's factor lands in cache)
    b: object  # RHS (n,) or (n, m)
    bw: int
    deadline: float | None = None


@dataclasses.dataclass
class SolveServiceStats:
    requests: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    factor_dispatches: int = 0
    solve_dispatches: int = 0
    coalesced_requests: int = 0  # requests that shared a solve dispatch
    solved_columns: int = 0

    @property
    def hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0


class SolveService:
    """Batch front end over the solver registry.

    ``submit`` enqueues; ``flush`` drains the queue grouped by matrix
    fingerprint — one factorization dispatch per *cold* matrix, one
    coalesced stacked-RHS solve dispatch per (matrix, RHS-width-compatible)
    group — and returns ``{ticket: solution}``.  ``solve`` is the
    submit+flush convenience for a single request.
    """

    def __init__(self, *, cache_entries: int = 16):
        self.cache_entries = cache_entries
        self._lru: OrderedDict[str, object] = OrderedDict()  # fp -> packed factors
        self._sched = Scheduler()
        self._tickets = 0
        self._done: dict[int, object] = {}  # flushed, not yet redeemed
        self.stats = SolveServiceStats()

    # -- admission ----------------------------------------------------------
    def submit(self, a, b, *, bw: int = 0, deadline: float | None = None) -> int:
        """Enqueue ``a x = b`` (``bw > 0`` = row-aligned band operand);
        returns a ticket redeemable at the next :meth:`flush`."""
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        ticket = self._tickets
        self._tickets += 1
        req = SolveRequest(
            ticket=ticket, fp=fingerprint(a, bw=bw), a=a, b=b, bw=bw, deadline=deadline
        )
        n = int(a.shape[-2]) if bw else int(a.shape[-1])
        structure = "banded" if bw else "dense"
        cols = 1 if b.ndim == 1 else int(b.shape[-1])
        self._sched.submit(
            req, bucket=(structure, n, bw, str(a.dtype)), cost=float(cols),
            deadline=deadline, real=cols,
        )
        self.stats.requests += 1
        return ticket

    def pending(self) -> int:
        return len(self._sched)

    # -- factorization cache ------------------------------------------------
    def _factors_for(self, req: SolveRequest):
        if req.fp in self._lru:
            self.stats.cache_hits += 1
            self._lru.move_to_end(req.fp)
            return self._lru[req.fp]
        self.stats.cache_misses += 1
        if req.bw:
            factors = kops.banded_lu(req.a, bw=req.bw)
        else:
            factors = kops.lu(req.a)
        self._lru[req.fp] = factors
        while len(self._lru) > self.cache_entries:
            self._lru.popitem(last=False)
            self.stats.cache_evictions += 1
        return factors

    # -- the flush ----------------------------------------------------------
    def flush(self) -> dict[int, object]:
        """Serve every pending request; returns ``{ticket: x}`` for the
        whole drained queue.  Results are also retained until redeemed via
        :meth:`result`, so a convenience :meth:`solve` draining the queue
        cannot lose earlier submissions' answers."""
        counting = solvers.add_dispatch_hook(self._count_dispatch)
        try:
            results: dict[int, object] = {}
            groups: OrderedDict[str, list[SolveRequest]] = OrderedDict()
            for entry in self._sched.drain():
                groups.setdefault(entry.payload.fp, []).append(entry.payload)
            for fp, reqs in groups.items():
                factors = self._factors_for(reqs[0])
                # hit/miss accounting is per REQUEST: coalesced group members
                # past the leader are served without a factorization too
                self.stats.cache_hits += len(reqs) - 1
                stacked, widths, squeezes = stack_rhs([r.b for r in reqs])
                self.stats.solved_columns += int(stacked.shape[-1])
                if len(reqs) > 1:
                    self.stats.coalesced_requests += len(reqs)
                if reqs[0].bw:
                    x = kops.banded_solve(factors, stacked, bw=reqs[0].bw)
                else:
                    x = kops.lu_solve(factors, stacked)
                for r, xr in zip(reqs, split_rhs(x, widths, squeezes)):
                    results[r.ticket] = xr
            self._done.update(results)
            return results
        finally:
            solvers.remove_dispatch_hook(counting)

    def result(self, ticket: int):
        """Redeem (pop) a flushed ticket; raises KeyError if the ticket was
        never flushed or was already redeemed."""
        return self._done.pop(ticket)

    def solve(self, a, b, *, bw: int = 0):
        """submit + flush for one request (still hits/extends the cache).
        Other pending requests flushed alongside stay redeemable via
        :meth:`result`."""
        ticket = self.submit(a, b, bw=bw)
        self.flush()
        return self.result(ticket)

    def _count_dispatch(self, problem, backend) -> None:
        if problem.op == "factor":
            self.stats.factor_dispatches += 1
        elif problem.op in ("solve", "linear_solve"):
            self.stats.solve_dispatches += 1
