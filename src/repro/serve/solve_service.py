"""Linear-system serving front end: factor once, solve many.

The dominant real traffic shape for a solver service is GLU3.0's
circuit-simulation pattern — the SAME matrix arrives over and over with
fresh right-hand sides (transient timesteps, Monte-Carlo sweeps, parameter
scans).  The service exploits it twice:

* **factorization cache** — an LRU keyed by matrix *fingerprint*
  (content hash of bytes + shape + dtype + bandwidth).  A hit skips the
  factorization dispatch entirely and jumps straight to substitution;
* **RHS coalescing** — pending requests against one fingerprint hstack
  their RHS columns into a single wide solve dispatch
  (:func:`repro.core.solve.stack_rhs`).  Substitution columns are
  independent, so the coalesced results are bitwise-identical to
  per-request solves while paying one kernel launch.

Everything routes through :class:`repro.solvers.Problem` descriptors and
the registry, so the autotuned backend selection (and its multi-RHS
capability filter — e.g. the vector-only scalar banded solve is pruned when
``rhs > 1``) decides *how* each coalesced dispatch runs.  Dispatch counts in
``stats`` come from the registry's dispatch hook, not from self-reporting.

**Accuracy tiers.**  Requests carry a ``tolerance`` (largest acceptable
relative residual; 0.0 = exact).  The factorization cache holds factors
*per accuracy tier* under each fingerprint — tier 0.0 for packed exact
factors, tier ``RAND_LU_RESIDUAL_BOUND`` for rank-k factors produced by a
``rank=`` request.  A request is served by any cached tier **at or below**
its tolerance (a tighter factor always satisfies a looser request); the
reverse — an approximate factor serving a tighter request — is structurally
impossible, because eligibility is ``tier <= tolerance``.  The tolerance
also threads into every factor/solve :class:`~repro.solvers.Problem`, so
the registry's tolerance gate and the autotune cache key see it.

**Coalescing-width cap.**  Stacked-RHS solves normally coalesce every
pending column into one dispatch.  When ``scripts/autotune.py`` has swept
dispatch widths for a transferable shape (``AutotuneCache.best_width``),
the stack is chunked at the measured most-µs-per-column-efficient width
instead — unmeasured shapes keep full coalescing.

**Mesh routing.**  A service built with ``mesh=`` routes banded groups
whose band fits the mesh partition (:func:`repro.core.spike.spike_supported`)
through the multi-device registry path: factorization dispatches as a
``devices > 1`` problem — SPIKE split factors vs replication, weighed per
``(n, bw, devices)`` by the measured autotune cache — and a SPIKE-factored
group's coalesced stacked-RHS substitution runs shard-local over the mesh
with one reduced spike solve for the whole stack.  Bands too wide for the
partition (and dense traffic) stay on the single-device path unchanged.

Admission/ordering rides the shared :class:`repro.serve.scheduler.Scheduler`
(buckets = ``(structure, n, bw, dtype, tolerance)``; deadline/FIFO order
decides which matrix group flushes first).

**Failure isolation.**  Factorizations are health-screened by default
(``ops.lu(..., health=)`` → the registry escalation funnel), so a hostile
operand escalates through the capable backends and — only when every one
fails — surfaces as a structured :class:`repro.solvers.SolveFailure`.  The
service degrades instead of dying: the failing coalesced group's tickets
resolve to the failure *value* (other groups in the same flush are
untouched), the unhealthy factors are never admitted to the LRU, and the
fingerprint enters a **negative cache** (quarantine) for the next
``quarantine_ttl`` flushes — repeat offenders short-circuit without
re-dispatching.  A ``clock=`` makes deadlines real: requests already past
deadline at drain are shed as :class:`DeadlineMiss` values rather than
burning a dispatch.  ``flush`` is transactional — an unexpected exception
mid-flush requeues every unprocessed entry with seq/deadline intact.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro import solvers
from repro.core import health as _health
from repro.core import refine as _refine
from repro.core.factorization import Factorization
from repro.core.pivoted import PivotedFactors
from repro.core.randomized import RankKFactors
from repro.core.solve import split_rhs, stack_rhs
from repro.core.spike import SpikeFactors, spike_supported
from repro.kernels import ops as kops
from repro.solvers.backends import RAND_LU_RESIDUAL_BOUND
from .scheduler import Scheduler

__all__ = [
    "SolveRequest",
    "SolveServiceStats",
    "SolveService",
    "fingerprint",
    "DeadlineMiss",
    "UnknownTicket",
    "NotFlushed",
]


class UnknownTicket(KeyError):
    """The ticket was never issued, or its result was already redeemed."""


class NotFlushed(KeyError):
    """The ticket is still queued — call :meth:`SolveService.flush` first."""


@dataclasses.dataclass(frozen=True)
class DeadlineMiss:
    """Result value for a request already past its deadline at drain time:
    the service sheds it instead of burning a dispatch on a stale answer."""

    ticket: int
    deadline: float
    now: float


def fingerprint(a, *, bw: int = 0) -> str:
    """Content hash identifying a matrix operand (dense or row-aligned
    band): sha1 over the raw bytes + shape + dtype + bandwidth."""
    arr = np.asarray(a)
    h = hashlib.sha1()
    h.update(str((arr.shape, arr.dtype.str, int(bw))).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class SolveRequest:
    ticket: int
    fp: str
    a: object  # matrix operand (kept until its group's factor lands in cache)
    b: object  # RHS (n,) or (n, m)
    bw: int
    deadline: float | None = None
    tolerance: float = 0.0  # largest acceptable relative residual (0 = exact)
    rank: int | None = None  # request the randomized rank-k factor tier


@dataclasses.dataclass
class SolveServiceStats:
    requests: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    factor_dispatches: int = 0
    solve_dispatches: int = 0
    coalesced_requests: int = 0  # requests that shared a solve dispatch
    solved_columns: int = 0
    approx_solves: int = 0  # dispatches served by a residual-bound (approximate) tier
    width_capped_dispatches: int = 0  # extra dispatches forced by the coalescing cap
    failed_requests: int = 0  # tickets resolved to a structured SolveFailure
    escalations: int = 0  # registry escalation events observed during flushes
    quarantined: int = 0  # tickets short-circuited by the negative cache
    shed_deadline: int = 0  # tickets shed as DeadlineMiss at drain
    last_refine_iterations: int | None = None  # refinement sweeps of the last
                                               # approximate solve (None = none ran)

    @property
    def hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0


class SolveService:
    """Batch front end over the solver registry.

    ``submit`` enqueues; ``flush`` drains the queue grouped by matrix
    fingerprint — one factorization dispatch per *cold* matrix, one
    coalesced stacked-RHS solve dispatch per (matrix, RHS-width-compatible)
    group — and returns ``{ticket: solution}``.  ``solve`` is the
    submit+flush convenience for a single request.
    """

    def __init__(
        self,
        *,
        cache_entries: int = 16,
        health=True,
        quarantine_ttl: int = 8,
        clock=None,
        verify_residual: bool = False,
        mesh=None,
        mesh_axis: str = "model",
    ):
        """``health=`` screens every factorization (``True`` = default
        thresholds, a :class:`repro.core.health.HealthThresholds` to tune,
        ``None``/``False`` to disable — restoring the unscreened ops).
        ``quarantine_ttl`` is how many subsequent flushes a
        terminally-failed fingerprint short-circuits for.  ``clock``
        (e.g. ``time.monotonic``) arms deadline shedding; without one,
        deadlines only order the flush (the historical behaviour).
        ``verify_residual=True`` additionally gates every coalesced solve
        on its measured relative residual.  ``mesh=`` (a ``jax.sharding``
        mesh spanning > 1 device along ``mesh_axis``) routes banded groups
        whose band fits the mesh partition (``spike_supported``) through
        the multi-device registry path — SPIKE split factors vs replication
        decided per ``(n, bw, devices)`` by the measured autotune cache,
        and the coalesced stacked-RHS substitution runs sharded."""
        self.cache_entries = cache_entries
        self.health = health
        self.quarantine_ttl = quarantine_ttl
        self.verify_residual = verify_residual
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self._clock = clock
        # fp -> {accuracy tier -> factors}; tier 0.0 = exact packed factors,
        # tier t > 0 = approximate factors guaranteeing relative residual t.
        # LRU order (and the entry budget) is per fingerprint.
        self._lru: OrderedDict[str, dict[float, object]] = OrderedDict()
        # negative cache: fp -> (expiry flush count, the SolveFailure)
        self._quarantine: dict[str, tuple[int, object]] = {}
        self._flush_count = 0
        self._sched = Scheduler()
        self._tickets = 0
        self._pending_tickets: set[int] = set()
        self._done: dict[int, object] = {}  # flushed, not yet redeemed
        self.stats = SolveServiceStats()

    # -- admission ----------------------------------------------------------
    def submit(
        self,
        a,
        b,
        *,
        bw: int = 0,
        deadline: float | None = None,
        tolerance: float = 0.0,
        rank: int | None = None,
    ) -> int:
        """Enqueue ``a x = b`` (``bw > 0`` = row-aligned band operand);
        returns a ticket redeemable at the next :meth:`flush`.

        ``tolerance`` is the largest acceptable relative residual — it keys
        the scheduler bucket and selects which cached factor tiers may serve
        the request (any tier ≤ tolerance).  ``rank=`` asks for the
        randomized rank-k tier (dense only; requires ``tolerance`` at least
        the tier's guaranteed bound)."""
        if tolerance < 0:
            raise ValueError(f"tolerance must be >= 0, got {tolerance}")
        if rank is not None:
            if bw:
                raise ValueError("rank= (randomized tier) is dense-only")
            if tolerance < RAND_LU_RESIDUAL_BOUND:
                raise ValueError(
                    f"rank= produces factors guaranteed to {RAND_LU_RESIDUAL_BOUND:g} "
                    f"relative residual; request tolerance {tolerance:g} is tighter"
                )
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        ticket = self._tickets
        self._tickets += 1
        req = SolveRequest(
            ticket=ticket, fp=fingerprint(a, bw=bw), a=a, b=b, bw=bw,
            deadline=deadline, tolerance=float(tolerance), rank=rank,
        )
        n = int(a.shape[-2]) if bw else int(a.shape[-1])
        structure = "banded" if bw else "dense"
        cols = 1 if b.ndim == 1 else int(b.shape[-1])
        self._sched.submit(
            req, bucket=(structure, n, bw, str(a.dtype), float(tolerance)),
            cost=float(cols), deadline=deadline, real=cols,
        )
        self.stats.requests += 1
        self._pending_tickets.add(ticket)
        return ticket

    def pending(self) -> int:
        return len(self._sched)

    def quarantined_fingerprints(self) -> set[str]:
        """Fingerprints currently in the negative cache (diagnostics)."""
        return set(self._quarantine)

    # -- factorization cache ------------------------------------------------
    @staticmethod
    def _factor_tier(factors) -> float:
        """The accuracy tier a factor object belongs to: the residual its
        producing backend guarantees (rank-k factors), 0.0 for exact.
        Factorization artifacts carry their tier as metadata."""
        if isinstance(factors, Factorization):
            return factors.tier
        return RAND_LU_RESIDUAL_BOUND if isinstance(factors, RankKFactors) else 0.0

    def _band_spans_mesh(self, req: SolveRequest) -> bool:
        """True when this banded operand should take the multi-device
        path: a mesh is configured, it spans > 1 device, and the band is
        narrow enough for the SPIKE partition (``2·bw ≤ ceil(n/d)``)."""
        if self.mesh is None or not req.bw:
            return False
        devices = int(self.mesh.shape[self.mesh_axis])
        n = int(req.a.shape[-2])
        return devices > 1 and spike_supported(n, req.bw, devices)

    def _factors_for(self, req: SolveRequest, tolerance: float):
        tiers = self._lru.get(req.fp)
        if tiers is not None:
            # a cached tier serves the request iff it is at least as tight
            # as the request's tolerance — never the reverse.  Among the
            # eligible tiers the tightest wins (best answer, same price).
            eligible = [t for t in tiers if t <= tolerance]
            if eligible:
                self.stats.cache_hits += 1
                self._lru.move_to_end(req.fp)
                return tiers[min(eligible)]
        self.stats.cache_misses += 1
        # With health screening on, a SolveFailure propagates out of these
        # ops before anything reaches the LRU — unhealthy factors are never
        # admitted (success past the screen *is* the admission check).
        if req.bw:
            # enrich at factor time: the banded serve steady state is
            # many solves per factor, so the pre-inverted blocks pay for
            # themselves and every cache hit solves via the two-phase
            # inverted path with zero layout work.  When the band spans a
            # mesh, route through the multi-device registry path (SPIKE
            # split factors vs replication, measured per (n, bw, devices));
            # bands too wide for the partition stay on the local path.
            mesh = self.mesh if self._band_spans_mesh(req) else None
            factors = kops.banded_lu(
                req.a, bw=req.bw, tolerance=tolerance, health=self.health,
                enrich=True, mesh=mesh, mesh_axis=self.mesh_axis,
            )
        elif req.rank is not None:
            factors = kops.lu(
                req.a, rank=req.rank, tolerance=tolerance, health=self.health
            )
        else:
            factors = kops.lu(req.a, tolerance=tolerance, health=self.health)
        if self.health:
            factors, _record = factors  # screened ops return (factors, health)
        if isinstance(factors, Factorization):
            # stamp the cache identity on the artifact — a future consumer
            # (or a re-submitted artifact) carries its own fingerprint and
            # never needs the matrix bytes re-hashed or re-screened.
            factors = factors.with_meta(fingerprint=req.fp)
        self._lru.setdefault(req.fp, {})[self._factor_tier(factors)] = factors
        self._lru.move_to_end(req.fp)
        while len(self._lru) > self.cache_entries:
            self._lru.popitem(last=False)
            self.stats.cache_evictions += 1
        return factors

    # -- the flush ----------------------------------------------------------
    def flush(self) -> dict[int, object]:
        """Serve every pending request; returns ``{ticket: result}`` for the
        whole drained queue.  Results are also retained until redeemed via
        :meth:`result`, so a convenience :meth:`solve` draining the queue
        cannot lose earlier submissions' answers.

        A result is a solution array, a :class:`repro.solvers.SolveFailure`
        (the request's coalesced group exhausted the escalation funnel, or
        its fingerprint is quarantined), or a :class:`DeadlineMiss` (shed at
        drain — only when the service was built with a ``clock``).  One
        group failing never disturbs the other groups in the flush."""
        counting = solvers.add_dispatch_hook(self._count_dispatch)
        escalating = solvers.add_escalation_hook(self._count_escalation)
        self._flush_count += 1
        for fp in [f for f, (exp, _) in self._quarantine.items()
                   if exp < self._flush_count]:
            del self._quarantine[fp]
        drained = self._sched.drain()
        processed: set[int] = set()  # seq of every entry whose group completed
        results: dict[int, object] = {}
        try:
            now = self._clock() if self._clock is not None else None
            live = []
            for entry in drained:
                r = entry.payload
                if now is not None and r.deadline is not None and r.deadline < now:
                    results[r.ticket] = DeadlineMiss(
                        ticket=r.ticket, deadline=r.deadline, now=now
                    )
                    self.stats.shed_deadline += 1
                    processed.add(entry.seq)
                else:
                    live.append(entry)
            groups: OrderedDict[tuple, list] = OrderedDict()
            for entry in live:
                p = entry.payload
                # rank-tier requests coalesce separately from exact requests
                # against the same matrix — they want different factors.
                groups.setdefault((p.fp, p.rank), []).append(entry)
            for (fp, rank), entries in groups.items():
                reqs = [e.payload for e in entries]
                quarantined = self._quarantine.get(fp)
                if quarantined is not None:
                    # negative cache: this operand already exhausted the
                    # funnel recently — short-circuit without dispatching.
                    for r in reqs:
                        results[r.ticket] = quarantined[1]
                    self.stats.quarantined += len(reqs)
                    processed.update(e.seq for e in entries)
                    continue
                # tightest member tolerance governs the whole coalesced
                # dispatch: every member accepts its residual.
                group_tol = min(r.tolerance for r in reqs)
                try:
                    factors = self._factors_for(reqs[0], group_tol)
                    # hit/miss accounting is per REQUEST: coalesced group
                    # members past the leader skip the factorization too
                    self.stats.cache_hits += len(reqs) - 1
                    stacked, widths, squeezes = stack_rhs([r.b for r in reqs])
                    self.stats.solved_columns += int(stacked.shape[-1])
                    if len(reqs) > 1:
                        self.stats.coalesced_requests += len(reqs)
                    x = self._dispatch_solve(reqs[0], factors, stacked, group_tol)
                    if self.verify_residual:
                        self._check_residual(reqs[0], stacked, x, group_tol)
                except solvers.SolveFailure as failure:
                    # graceful degradation: the whole group resolves to the
                    # structured failure VALUE (never NaN answers, never an
                    # exception that would abort the other groups), and the
                    # fingerprint enters the negative cache.
                    for r in reqs:
                        results[r.ticket] = failure
                    self.stats.failed_requests += len(reqs)
                    self._quarantine[fp] = (
                        self._flush_count + self.quarantine_ttl, failure
                    )
                    processed.update(e.seq for e in entries)
                    continue
                for r, xr in zip(reqs, split_rhs(x, widths, squeezes)):
                    results[r.ticket] = xr
                processed.update(e.seq for e in entries)
            return results
        finally:
            solvers.remove_dispatch_hook(counting)
            solvers.remove_escalation_hook(escalating)
            # commit every completed group's answers even when a later group
            # raised: callers redeem them via result().
            self._done.update(results)
            self._pending_tickets.difference_update(results)
            # transactional drain: an exception mid-flush must not lose the
            # rest of the batch — unprocessed entries go back to the queue
            # with their original seq/deadline intact.
            remaining = [e for e in drained if e.seq not in processed]
            if remaining:
                self._sched.restore(remaining)

    def _check_residual(self, req: SolveRequest, stacked, x, tolerance: float) -> None:
        """``verify_residual`` gate on the coalesced answer; a miss raises
        :class:`SolveFailure` into the group's failure handling."""
        bound = tolerance if tolerance > 0 else solvers.VERIFY_RESIDUAL_DEFAULT_BOUND
        rel = float(_health.relative_residual(req.a, stacked, x, bw=req.bw))
        if not rel <= bound:  # NaN-safe
            problem = solvers.Problem.from_arrays(
                "linear_solve", req.a, stacked, bw=req.bw,
                tolerance=tolerance, verify_residual=True,
            )
            raise solvers.SolveFailure(
                f"coalesced solve residual {rel:.3e} > bound {bound:.1e} "
                f"for {problem}",
                problem=problem,
                chain=[{"backend": "serve", "reason": f"residual {rel:.3e}"}],
            )

    def _dispatch_solve(self, req: SolveRequest, factors, stacked, tolerance: float):
        """One coalesced substitution — chunked at the autotuned coalescing
        width when the registry has measured one for this shape."""
        def run(cols):
            if isinstance(factors, SpikeFactors):
                # split factors substitute shard-locally over the mesh; the
                # coalesced stack is one wide multi-RHS spike solve.
                return kops.banded_solve(
                    factors, cols, bw=req.bw, tolerance=tolerance,
                    mesh=self.mesh, mesh_axis=self.mesh_axis,
                )
            if req.bw:
                return kops.banded_solve(factors, cols, bw=req.bw, tolerance=tolerance)
            return kops.lu_solve(factors, cols, tolerance=tolerance)

        width = int(stacked.shape[-1])
        cap = None
        if not isinstance(factors, (RankKFactors, PivotedFactors, SpikeFactors)):
            # width measurements only exist for packed-factor substitution;
            # rank-k solves are GEMM-shaped and always coalesce fully,
            # pivoted factors (the escalation last resort) are too rare to
            # have measured widths, and SPIKE split factors coalesce fully
            # so the reduced spike system is solved exactly once.
            problem = solvers.Problem.from_arrays(
                "solve", factors, stacked, bw=req.bw, tolerance=tolerance
            )
            cap = solvers.get_cache().best_width(problem)
        if cap and width > cap:
            pieces = [
                run(stacked[..., i : i + cap]) for i in range(0, width, cap)
            ]
            self.stats.width_capped_dispatches += len(pieces) - 1
            x = jnp.concatenate(pieces, axis=-1)
        else:
            x = run(stacked)
        if isinstance(factors, RankKFactors) and tolerance > 0.0:
            # polish the approximate-tier answer to the group tolerance
            # against the full operand; the sweep count lands in stats.
            x, info = _refine.iterative_refinement(
                req.a, stacked, x, run, tolerance=tolerance
            )
            jax.block_until_ready(x)
            self.stats.last_refine_iterations = int(info.iterations)
        return x

    def result(self, ticket: int):
        """Redeem (pop) a flushed ticket.  Raises :class:`NotFlushed` when
        the ticket is still queued and :class:`UnknownTicket` when it was
        never issued or was already redeemed (both subclass ``KeyError``)."""
        try:
            return self._done.pop(ticket)
        except KeyError:
            pass
        if ticket in self._pending_tickets:
            raise NotFlushed(
                f"ticket {ticket} has not been flushed yet (call flush())"
            )
        raise UnknownTicket(f"ticket {ticket} was never issued or already redeemed")

    def solve(self, a, b, *, bw: int = 0, tolerance: float = 0.0, rank: int | None = None):
        """submit + flush for one request (still hits/extends the cache).
        Other pending requests flushed alongside stay redeemable via
        :meth:`result`.  A request that terminally failed raises its
        :class:`SolveFailure` (batch callers using submit/flush/result get
        it as a value instead)."""
        ticket = self.submit(a, b, bw=bw, tolerance=tolerance, rank=rank)
        self.flush()
        out = self.result(ticket)
        if isinstance(out, solvers.SolveFailure):
            raise out
        return out

    def _count_dispatch(self, problem, backend) -> None:
        if problem.op == "factor":
            self.stats.factor_dispatches += 1
        elif problem.op in ("solve", "linear_solve"):
            self.stats.solve_dispatches += 1
            if getattr(backend, "residual_bound", None) is not None:
                self.stats.approx_solves += 1

    def _count_escalation(self, problem, failed, nxt, reason) -> None:
        self.stats.escalations += 1
