"""Linear-system serving front end: factor once, solve many.

The dominant real traffic shape for a solver service is GLU3.0's
circuit-simulation pattern — the SAME matrix arrives over and over with
fresh right-hand sides (transient timesteps, Monte-Carlo sweeps, parameter
scans).  The service exploits it twice:

* **factorization cache** — an LRU keyed by matrix *fingerprint*
  (content hash of bytes + shape + dtype + bandwidth).  A hit skips the
  factorization dispatch entirely and jumps straight to substitution;
* **RHS coalescing** — pending requests against one fingerprint hstack
  their RHS columns into a single wide solve dispatch
  (:func:`repro.core.solve.stack_rhs`).  Substitution columns are
  independent, so the coalesced results are bitwise-identical to
  per-request solves while paying one kernel launch.

Everything routes through :class:`repro.solvers.Problem` descriptors and
the registry, so the autotuned backend selection (and its multi-RHS
capability filter — e.g. the vector-only scalar banded solve is pruned when
``rhs > 1``) decides *how* each coalesced dispatch runs.  Dispatch counts in
``stats`` come from the registry's dispatch hook, not from self-reporting.

**Accuracy tiers.**  Requests carry a ``tolerance`` (largest acceptable
relative residual; 0.0 = exact).  The factorization cache holds factors
*per accuracy tier* under each fingerprint — tier 0.0 for packed exact
factors, tier ``RAND_LU_RESIDUAL_BOUND`` for rank-k factors produced by a
``rank=`` request.  A request is served by any cached tier **at or below**
its tolerance (a tighter factor always satisfies a looser request); the
reverse — an approximate factor serving a tighter request — is structurally
impossible, because eligibility is ``tier <= tolerance``.  The tolerance
also threads into every factor/solve :class:`~repro.solvers.Problem`, so
the registry's tolerance gate and the autotune cache key see it.

**Coalescing-width cap.**  Stacked-RHS solves normally coalesce every
pending column into one dispatch.  When ``scripts/autotune.py`` has swept
dispatch widths for a transferable shape (``AutotuneCache.best_width``),
the stack is chunked at the measured most-µs-per-column-efficient width
instead — unmeasured shapes keep full coalescing.

Admission/ordering rides the shared :class:`repro.serve.scheduler.Scheduler`
(buckets = ``(structure, n, bw, dtype, tolerance)``; deadline/FIFO order
decides which matrix group flushes first).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro import solvers
from repro.core import refine as _refine
from repro.core.randomized import RankKFactors
from repro.core.solve import split_rhs, stack_rhs
from repro.kernels import ops as kops
from repro.solvers.backends import RAND_LU_RESIDUAL_BOUND
from .scheduler import Scheduler

__all__ = ["SolveRequest", "SolveServiceStats", "SolveService", "fingerprint"]


def fingerprint(a, *, bw: int = 0) -> str:
    """Content hash identifying a matrix operand (dense or row-aligned
    band): sha1 over the raw bytes + shape + dtype + bandwidth."""
    arr = np.asarray(a)
    h = hashlib.sha1()
    h.update(str((arr.shape, arr.dtype.str, int(bw))).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class SolveRequest:
    ticket: int
    fp: str
    a: object  # matrix operand (kept until its group's factor lands in cache)
    b: object  # RHS (n,) or (n, m)
    bw: int
    deadline: float | None = None
    tolerance: float = 0.0  # largest acceptable relative residual (0 = exact)
    rank: int | None = None  # request the randomized rank-k factor tier


@dataclasses.dataclass
class SolveServiceStats:
    requests: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    cache_evictions: int = 0
    factor_dispatches: int = 0
    solve_dispatches: int = 0
    coalesced_requests: int = 0  # requests that shared a solve dispatch
    solved_columns: int = 0
    approx_solves: int = 0  # dispatches served by a residual-bound (approximate) tier
    width_capped_dispatches: int = 0  # extra dispatches forced by the coalescing cap
    last_refine_iterations: int | None = None  # refinement sweeps of the last
                                               # approximate solve (None = none ran)

    @property
    def hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0


class SolveService:
    """Batch front end over the solver registry.

    ``submit`` enqueues; ``flush`` drains the queue grouped by matrix
    fingerprint — one factorization dispatch per *cold* matrix, one
    coalesced stacked-RHS solve dispatch per (matrix, RHS-width-compatible)
    group — and returns ``{ticket: solution}``.  ``solve`` is the
    submit+flush convenience for a single request.
    """

    def __init__(self, *, cache_entries: int = 16):
        self.cache_entries = cache_entries
        # fp -> {accuracy tier -> factors}; tier 0.0 = exact packed factors,
        # tier t > 0 = approximate factors guaranteeing relative residual t.
        # LRU order (and the entry budget) is per fingerprint.
        self._lru: OrderedDict[str, dict[float, object]] = OrderedDict()
        self._sched = Scheduler()
        self._tickets = 0
        self._done: dict[int, object] = {}  # flushed, not yet redeemed
        self.stats = SolveServiceStats()

    # -- admission ----------------------------------------------------------
    def submit(
        self,
        a,
        b,
        *,
        bw: int = 0,
        deadline: float | None = None,
        tolerance: float = 0.0,
        rank: int | None = None,
    ) -> int:
        """Enqueue ``a x = b`` (``bw > 0`` = row-aligned band operand);
        returns a ticket redeemable at the next :meth:`flush`.

        ``tolerance`` is the largest acceptable relative residual — it keys
        the scheduler bucket and selects which cached factor tiers may serve
        the request (any tier ≤ tolerance).  ``rank=`` asks for the
        randomized rank-k tier (dense only; requires ``tolerance`` at least
        the tier's guaranteed bound)."""
        if tolerance < 0:
            raise ValueError(f"tolerance must be >= 0, got {tolerance}")
        if rank is not None:
            if bw:
                raise ValueError("rank= (randomized tier) is dense-only")
            if tolerance < RAND_LU_RESIDUAL_BOUND:
                raise ValueError(
                    f"rank= produces factors guaranteed to {RAND_LU_RESIDUAL_BOUND:g} "
                    f"relative residual; request tolerance {tolerance:g} is tighter"
                )
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        ticket = self._tickets
        self._tickets += 1
        req = SolveRequest(
            ticket=ticket, fp=fingerprint(a, bw=bw), a=a, b=b, bw=bw,
            deadline=deadline, tolerance=float(tolerance), rank=rank,
        )
        n = int(a.shape[-2]) if bw else int(a.shape[-1])
        structure = "banded" if bw else "dense"
        cols = 1 if b.ndim == 1 else int(b.shape[-1])
        self._sched.submit(
            req, bucket=(structure, n, bw, str(a.dtype), float(tolerance)),
            cost=float(cols), deadline=deadline, real=cols,
        )
        self.stats.requests += 1
        return ticket

    def pending(self) -> int:
        return len(self._sched)

    # -- factorization cache ------------------------------------------------
    @staticmethod
    def _factor_tier(factors) -> float:
        """The accuracy tier a factor object belongs to: the residual its
        producing backend guarantees (rank-k factors), 0.0 for exact."""
        return RAND_LU_RESIDUAL_BOUND if isinstance(factors, RankKFactors) else 0.0

    def _factors_for(self, req: SolveRequest, tolerance: float):
        tiers = self._lru.get(req.fp)
        if tiers is not None:
            # a cached tier serves the request iff it is at least as tight
            # as the request's tolerance — never the reverse.  Among the
            # eligible tiers the tightest wins (best answer, same price).
            eligible = [t for t in tiers if t <= tolerance]
            if eligible:
                self.stats.cache_hits += 1
                self._lru.move_to_end(req.fp)
                return tiers[min(eligible)]
        self.stats.cache_misses += 1
        if req.bw:
            factors = kops.banded_lu(req.a, bw=req.bw, tolerance=tolerance)
        elif req.rank is not None:
            factors = kops.lu(req.a, rank=req.rank, tolerance=tolerance)
        else:
            factors = kops.lu(req.a, tolerance=tolerance)
        self._lru.setdefault(req.fp, {})[self._factor_tier(factors)] = factors
        self._lru.move_to_end(req.fp)
        while len(self._lru) > self.cache_entries:
            self._lru.popitem(last=False)
            self.stats.cache_evictions += 1
        return factors

    # -- the flush ----------------------------------------------------------
    def flush(self) -> dict[int, object]:
        """Serve every pending request; returns ``{ticket: x}`` for the
        whole drained queue.  Results are also retained until redeemed via
        :meth:`result`, so a convenience :meth:`solve` draining the queue
        cannot lose earlier submissions' answers."""
        counting = solvers.add_dispatch_hook(self._count_dispatch)
        drained = self._sched.drain()
        processed: set[int] = set()  # seq of every entry whose group completed
        try:
            results: dict[int, object] = {}
            groups: OrderedDict[tuple, list] = OrderedDict()
            for entry in drained:
                p = entry.payload
                # rank-tier requests coalesce separately from exact requests
                # against the same matrix — they want different factors.
                groups.setdefault((p.fp, p.rank), []).append(entry)
            for (fp, rank), entries in groups.items():
                reqs = [e.payload for e in entries]
                # tightest member tolerance governs the whole coalesced
                # dispatch: every member accepts its residual.
                group_tol = min(r.tolerance for r in reqs)
                factors = self._factors_for(reqs[0], group_tol)
                # hit/miss accounting is per REQUEST: coalesced group members
                # past the leader are served without a factorization too
                self.stats.cache_hits += len(reqs) - 1
                stacked, widths, squeezes = stack_rhs([r.b for r in reqs])
                self.stats.solved_columns += int(stacked.shape[-1])
                if len(reqs) > 1:
                    self.stats.coalesced_requests += len(reqs)
                x = self._dispatch_solve(reqs[0], factors, stacked, group_tol)
                for r, xr in zip(reqs, split_rhs(x, widths, squeezes)):
                    results[r.ticket] = xr
                processed.update(e.seq for e in entries)
            return results
        finally:
            solvers.remove_dispatch_hook(counting)
            # commit every completed group's answers even when a later group
            # raised: callers redeem them via result().
            self._done.update(results)
            # transactional drain: an exception mid-flush must not lose the
            # rest of the batch — unprocessed entries go back to the queue
            # with their original seq/deadline intact.
            remaining = [e for e in drained if e.seq not in processed]
            if remaining:
                self._sched.restore(remaining)

    def _dispatch_solve(self, req: SolveRequest, factors, stacked, tolerance: float):
        """One coalesced substitution — chunked at the autotuned coalescing
        width when the registry has measured one for this shape."""
        def run(cols):
            if req.bw:
                return kops.banded_solve(factors, cols, bw=req.bw, tolerance=tolerance)
            return kops.lu_solve(factors, cols, tolerance=tolerance)

        width = int(stacked.shape[-1])
        cap = None
        if not isinstance(factors, RankKFactors):
            # width measurements only exist for packed-factor substitution;
            # rank-k solves are GEMM-shaped and always coalesce fully.
            problem = solvers.Problem.from_arrays(
                "solve", factors, stacked, bw=req.bw, tolerance=tolerance
            )
            cap = solvers.get_cache().best_width(problem)
        if cap and width > cap:
            pieces = [
                run(stacked[..., i : i + cap]) for i in range(0, width, cap)
            ]
            self.stats.width_capped_dispatches += len(pieces) - 1
            x = jnp.concatenate(pieces, axis=-1)
        else:
            x = run(stacked)
        if isinstance(factors, RankKFactors) and tolerance > 0.0:
            # polish the approximate-tier answer to the group tolerance
            # against the full operand; the sweep count lands in stats.
            x, info = _refine.iterative_refinement(
                req.a, stacked, x, run, tolerance=tolerance
            )
            jax.block_until_ready(x)
            self.stats.last_refine_iterations = int(info.iterations)
        return x

    def result(self, ticket: int):
        """Redeem (pop) a flushed ticket; raises KeyError if the ticket was
        never flushed or was already redeemed."""
        return self._done.pop(ticket)

    def solve(self, a, b, *, bw: int = 0, tolerance: float = 0.0, rank: int | None = None):
        """submit + flush for one request (still hits/extends the cache).
        Other pending requests flushed alongside stay redeemable via
        :meth:`result`."""
        ticket = self.submit(a, b, bw=bw, tolerance=tolerance, rank=rank)
        self.flush()
        return self.result(ticket)

    def _count_dispatch(self, problem, backend) -> None:
        if problem.op == "factor":
            self.stats.factor_dispatches += 1
        elif problem.op in ("solve", "linear_solve"):
            self.stats.solve_dispatches += 1
            if getattr(backend, "residual_bound", None) is not None:
                self.stats.approx_solves += 1
