"""Paged KV-cache bookkeeping: page pool, prefix fingerprints, prefix cache.

The serving engine's paged mode replaces one dense ``(max_len)`` KV row per
slot with fixed-size **pages** drawn from a shared pool — the serving-side
analogue of the EBV paper's equalized work unit: every allocation is the
same size, so heterogeneous sequence lengths fill the pool uniformly
instead of fragmenting it, and capacity scales with *live tokens* rather
than ``slots × max_len``.

Three pieces, all host-side (device arrays never live here):

* :class:`PagePool` — free-list allocator over ``num_pages`` page ids with
  per-page refcounts.  Page 0 is reserved as the **scrap page**: idle
  page-table rows point at it so stale decode writes from retired slots
  land harmlessly; it is never allocated and never read by a live row.
  A mesh-sharded engine uses one pool per shard over disjoint global id
  ranges (:class:`ShardedPagePool`), each with its own shard-local scrap.
* :func:`prefix_chain` — sha1 chain over page-size token blocks (the same
  bytes+shape+dtype fingerprint shape as the ``SolveService`` matrix
  fingerprint), one digest per *full* page of prompt.  Digest ``j`` commits
  to blocks ``0..j``, so equal chain prefixes imply equal token prefixes.
* :class:`PrefixCache` — maps chain digests to pool pages holding the
  already-computed K/V for that prompt prefix.  A lookup retains the hit
  pages for the caller (refcounted, read-only sharing); insertion retains
  one index reference per page.  Eviction is LRU over entries whose pages
  no live slot references.

Copy-on-write is structural: shared pages are never written — the engine
only shares *full* prompt pages strictly before the first decode-write
position, and a divergent prompt stops matching the chain at its first
divergent block, so its tail K/V is recomputed into freshly-owned pages.
"""
from __future__ import annotations

import hashlib
from collections import deque

import numpy as np

__all__ = ["PagePool", "ShardedPagePool", "PrefixCache", "prefix_chain"]

#: Reserved scrap page id — sink for writes from idle page-table rows.
SCRAP_PAGE = 0


class PagePool:
    """Free-list allocator of fixed-size KV pages with refcounts.

    ``num_pages`` counts device pages including the reserved scrap page 0,
    so ``capacity == num_pages - 1`` pages are allocatable.  ``alloc`` is
    all-or-nothing: a request that cannot get every page it needs gets
    none, so a partially-admitted slot can never corrupt live pages.

    ``base`` offsets every page id by a constant: a mesh-sharded engine
    gives each shard its own pool over the global id range
    ``[base, base + num_pages)`` (shard k of a pool axis laid out over the
    mesh owns exactly that contiguous page block), with id ``base`` as the
    shard-local scrap page so idle rows of that shard's slots sink writes
    without crossing shards.  ``base == 0`` (the default) is the historical
    single-pool layout, scrap page 0 included.
    """

    def __init__(self, num_pages: int, page_size: int, *, base: int = 0):
        if num_pages < 2:
            raise ValueError(f"pool needs >= 2 pages (1 is reserved scrap), got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if base < 0:
            raise ValueError(f"base must be >= 0, got {base}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.base = int(base)
        self._free: deque[int] = deque(range(base + 1, base + num_pages))
        self._ref = [0] * num_pages  # indexed by (page - base)
        self.peak_used = 0
        self.failed_allocs = 0

    @property
    def capacity(self) -> int:
        return self.num_pages - 1

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return self.capacity - len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Take ``n`` pages (refcount 1 each), or ``None`` if short."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            self.failed_allocs += 1
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._ref[p - self.base] = 1
        self.peak_used = max(self.peak_used, self.used)
        return pages

    def retain(self, pages: list[int]) -> None:
        for p in pages:
            if p == self.base or self._ref[p - self.base] <= 0:
                raise ValueError(f"retain of unallocated page {p}")
            self._ref[p - self.base] += 1

    def release(self, pages: list[int]) -> None:
        for p in pages:
            if p == self.base or self._ref[p - self.base] <= 0:
                raise ValueError(f"release of unallocated page {p}")
            self._ref[p - self.base] -= 1
            if self._ref[p - self.base] == 0:
                self._free.append(p)

    def refcount(self, page: int) -> int:
        return self._ref[page - self.base]

    def writable(self, page: int) -> bool:
        """A page is safe to write only while exactly one holder owns it."""
        return self._ref[page - self.base] == 1


class ShardedPagePool:
    """Per-shard :class:`PagePool` s over disjoint global page-id ranges.

    The mesh-sharded engine lays the KV page pool over a mesh axis: shard
    ``k`` of ``shards`` owns the contiguous global ids
    ``[k·P, (k+1)·P)`` (``P = pages_per_shard``), i.e. exactly the page
    block a ``PartitionSpec`` over the pool's page axis would place on
    device ``k`` — so every page a slot touches (scrap included) is local
    to the slot's shard, and allocation pressure is tracked per shard
    (occupancy feeds the scheduler's shard-balanced admission).

    The facade mirrors the single-pool API where the engine consumes it;
    ``alloc`` additionally takes the target shard (all-or-nothing within
    that shard — pages are never borrowed across shards, locality is the
    point), and ``release``/``retain`` route by id range.
    """

    def __init__(self, shards: int, pages_per_shard: int, page_size: int):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.shards = int(shards)
        self.pages_per_shard = int(pages_per_shard)
        self.page_size = int(page_size)
        self.pools = [
            PagePool(pages_per_shard, page_size, base=k * pages_per_shard)
            for k in range(shards)
        ]
        self.num_pages = shards * self.pages_per_shard

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    def scrap(self, shard: int) -> int:
        """The shard-local scrap page id (idle page-table rows of that
        shard's slots point here)."""
        return self.pools[shard].base

    @property
    def capacity(self) -> int:
        return sum(p.capacity for p in self.pools)

    @property
    def shard_capacity(self) -> int:
        """Allocatable pages per shard — the binding per-request bound (a
        request's pages never span shards)."""
        return self.pages_per_shard - 1

    @property
    def free(self) -> int:
        return sum(p.free for p in self.pools)

    @property
    def used(self) -> int:
        return sum(p.used for p in self.pools)

    @property
    def peak_used(self) -> int:
        return sum(p.peak_used for p in self.pools)

    @property
    def failed_allocs(self) -> int:
        return sum(p.failed_allocs for p in self.pools)

    def shard_used(self) -> list[int]:
        """Live page count per shard (the scheduler's occupancy signal)."""
        return [p.used for p in self.pools]

    def alloc(self, n: int, shard: int = 0) -> list[int] | None:
        return self.pools[shard].alloc(n)

    def _by_shard(self, pages: list[int]) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {}
        for p in pages:
            out.setdefault(self.shard_of(p), []).append(p)
        return out

    def retain(self, pages: list[int]) -> None:
        for k, ps in self._by_shard(pages).items():
            self.pools[k].retain(ps)

    def release(self, pages: list[int]) -> None:
        for k, ps in self._by_shard(pages).items():
            self.pools[k].release(ps)

    def refcount(self, page: int) -> int:
        return self.pools[self.shard_of(page)].refcount(page)

    def writable(self, page: int) -> bool:
        return self.pools[self.shard_of(page)].writable(page)


def prefix_chain(tokens, page_size: int, *, salt: str = "") -> list[str]:
    """sha1 chain over full page-size blocks of a prompt.

    Digest ``j`` hashes (digest ``j-1``, block ``j`` bytes, shape, dtype,
    page size) — the SolveService fingerprint shape — so two prompts share
    a chain prefix of length ``h`` iff their first ``h`` pages of tokens
    are identical.  Partial trailing blocks are never fingerprinted: a
    page must be *full* to be shareable.

    ``salt`` seeds the chain: the engine passes the request's bucket
    length, because prefix K/V is bitwise-reproducible only between
    prompts prefilled at the SAME padded length (the attention reduction
    axis is the bucket length; different buckets round differently in the
    last ulp).  Salting keeps every cache hit exact rather than
    approximately-equal.
    """
    toks = np.ascontiguousarray(np.asarray(tokens, dtype=np.int32))
    chain: list[str] = []
    digest = hashlib.sha1(f"chain|{salt}".encode()).digest() if salt else b"\x00" * 20
    for j in range(toks.size // page_size):
        blk = toks[j * page_size : (j + 1) * page_size]
        h = hashlib.sha1(digest)
        h.update(blk.tobytes())
        h.update(f"|{blk.shape}|{blk.dtype}|{page_size}".encode())
        digest = h.digest()
        chain.append(h.hexdigest())
    return chain


class PrefixCache:
    """LRU index from prefix-chain digests to read-only pool pages.

    Each entry holds one pool reference; a lookup hit retains one more per
    page *for the caller* (the engine releases them at slot retirement).
    Entries are evicted LRU-first, but only when no live slot still
    references the page (``refcount == 1``).  Evicting a mid-chain entry
    orphans its suffix digests — they can no longer be hit, are never
    LRU-bumped, and age out on later sweeps.
    """

    def __init__(self, pool: PagePool):
        self._pool = pool
        self._pages: dict[str, int] = {}  # insertion order == LRU order
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0

    def __len__(self) -> int:
        return len(self._pages)

    @property
    def pages(self) -> dict[str, int]:
        return dict(self._pages)

    def lookup(self, chain: list[str]) -> list[int]:
        """Longest cached prefix of ``chain``; retains the hit pages."""
        got: list[int] = []
        for digest in chain:
            page = self._pages.get(digest)
            if page is None:
                break
            got.append(page)
            self._pages[digest] = self._pages.pop(digest)  # bump to MRU
        if got:
            self._pool.retain(got)
            self.hits += 1
            self.hit_tokens += len(got) * self._pool.page_size
        else:
            self.misses += 1
        return got

    def insert(self, chain: list[str], pages: list[int]) -> None:
        """Index ``pages[j]`` as the K/V for chain block ``j`` (dedup)."""
        for digest, page in zip(chain, pages):
            if digest in self._pages:
                continue
            self._pool.retain([page])
            self._pages[digest] = page

    def evict(self, need_free: int) -> int:
        """Drop LRU entries (only index-held pages) until the pool has
        ``need_free`` free pages; returns the number of pages freed."""
        freed = 0
        for digest, page in list(self._pages.items()):
            if self._pool.free >= need_free:
                break
            if self._pool.refcount(page) == 1:
                del self._pages[digest]
                self._pool.release([page])
                freed += 1
        return freed

    def clear(self) -> int:
        """Drop every index reference (pages pinned by live slots survive
        until those slots retire)."""
        n = len(self._pages)
        for digest, page in list(self._pages.items()):
            del self._pages[digest]
            self._pool.release([page])
        return n
