"""Batched serving engine: prefill + decode with KV caches.

Static batching with uniform positions (continuous batching raggedness is
handled upstream by padding into the fixed request grid — the per-slot mask
lives in the cache ``pos`` arrays).  Greedy or temperature sampling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 512, jit_kwargs: dict | None = None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        kw = jit_kwargs or {}

        def _prefill(params, batch):
            return lm.prefill(params, batch, cfg, cache_len=max_len)

        def _decode(params, caches, tokens, pos):
            return lm.decode_step(params, caches, tokens, pos, cfg)

        self._prefill = jax.jit(_prefill, **kw)
        self._decode = jax.jit(_decode, donate_argnums=(1,), **kw)

    def _model_batch(self, tokens):
        cfg = self.cfg
        b, s = tokens.shape
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(0)
            prefix = jax.random.normal(key, (b, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
            return {"tokens": jnp.asarray(tokens), "prefix_embeds": prefix.astype(jnp.dtype(cfg.dtype))}
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(0)
            frames = jax.random.normal(key, (b, max(s // 4, 1), cfg.d_model), jnp.float32)
            return {"tokens": jnp.asarray(tokens), "frames": frames.astype(jnp.dtype(cfg.dtype))}
        return {"tokens": jnp.asarray(tokens)}

    def generate(
        self, prompts: np.ndarray, *, max_new_tokens: int = 32,
        temperature: float = 0.0, seed: int = 0,
    ) -> np.ndarray:
        """prompts: (B, S0) int32 → (B, S0 + max_new_tokens) int32."""
        prompts = np.asarray(prompts, np.int32)
        b, s0 = prompts.shape
        prompt_offset = self.cfg.num_prefix_embeds if self.cfg.family == "vlm" else 0
        assert s0 + prompt_offset + max_new_tokens <= self.max_len, "max_len too small"
        caches, logits = self._prefill(self.params, self._model_batch(prompts))
        # Split before the first use: sampling with the root key and then
        # re-splitting it would correlate the first sampled token with every
        # later step's subkey stream.
        key, sub = jax.random.split(jax.random.PRNGKey(seed))
        out = [prompts]
        tok = self._sample(logits[:, -1], temperature, sub)
        pos = s0 + prompt_offset
        for i in range(max_new_tokens - 1):
            out.append(np.asarray(tok))
            caches, logits = self._decode(self.params, caches, tok, jnp.asarray(pos + i, jnp.int32))
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, -1], temperature, sub)
        out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key):
        logits = logits[:, : self.cfg.vocab_size]  # drop padded vocab tail
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)[:, None]
