"""Slot-based continuous-batching serving engine.

The engine owns a fixed grid of ``slots`` batch rows over one KV cache and
two jitted entry points:

* ``_prefill`` — full-sequence forward of ONE shape-bucketed prompt
  ((1, bucket_len); compiled once per bucket), returning the request's
  cache rows and the logits at its true last token (``last=`` gather —
  right-pad tokens are causally inert);
* ``_decode`` — one token for EVERY slot ((slots, 1)) with per-row
  positions; compiled exactly once.

Slot lifecycle: a request admitted from the scheduler is prefilled and its
cache rows are scattered into a free slot (``pos`` entries past the true
prompt length forced to −1 so pad K/V never match); the slot then rides
every decode dispatch until its token budget is spent, at which point its
device-side output row is transferred (once — no per-token host sync) and
the slot is refilled mid-stream from the queue.  Because every per-row
computation in the model is independent of the other rows, a request's
tokens are bitwise-identical no matter which slot it lands in or what else
is in flight (MoE is the one exception: expert capacity couples rows, so
under-filled tail batches can drop tokens differently than full ones).

Sampling is per-slot: each request owns a PRNG stream derived from its
``seed`` only (split once at admission, then once per decode step), so
temperature>0 outputs are also independent of batch composition.

``generate`` is kept as the lockstep-compatible wrapper: one slot per
prompt row, exact-length buckets, per-row seeds ``seed + i``.

**Paged mode** (``paged=True``): slots no longer own dense ``(max_len)``
KV rows — the attention cache is a shared pool of fixed-size pages
(:mod:`repro.serve.paged`), each slot holds a page table, and the decode
page walk happens inside one Pallas gather kernel per layer
(:mod:`repro.kernels.paged_attn`).  Capacity becomes O(live tokens)
instead of O(slots × max_len).  The two jitted entry points are
unchanged in kind: ``_prefill`` gains an optional prior-prefix K/V input
(warm shared-prefix admission skips recomputing cached pages) and
``_decode`` takes the page table as a plain device array, so admissions
and retirements never recompile anything.  Prefix sharing is refcounted
and read-only: only *full* prompt pages strictly before the first decode
-write position are shared, so a shared page is never written and
copy-on-write is structural (a divergent prompt stops matching the
fingerprint chain at its first divergent block and recomputes its tail
into pages it owns).  When the pool runs dry, admission *queues* —
``Scheduler.restore`` puts the batch back — rather than corrupting live
pages.

**Mesh-sharded serving** (``shards=`` / ``mesh=``): the slot grid and the
paged page pool split into per-shard partitions — slot ``s`` of ``nslots``
lives on shard ``s·shards // nslots``, draws pages only from that shard's
disjoint pool id range (its own scrap page included), and hits only that
shard's prefix index, so every page a slot touches is local to its shard.
Admission stays equalized *and* balanced across shards: the scheduler's
shard-aware ``take`` hands the heaviest picks to the lightest-loaded
shards.  Capacity scales with the mesh (``paged_capacity_slots`` sums the
per-shard pools) while per-row independence keeps each request's tokens
bitwise-identical to a single-shard serve.  With ``mesh=`` the persistent
pool K/V parks laid out over the mesh axis between ``serve()`` calls.

**EOS early exit**: requests carrying ``eos_token`` keep a device-side
done flag + truncation index next to the ``(slots, max_new)`` output
buffer; flags are polled every ``eos_poll`` decode steps (one tiny
transfer, no per-token host sync) and finished slots retire early,
freeing their pages mid-stream.  The final readback stays ONE transfer
per request (output row ++ truncation index, fetched together).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from .paged import PagePool, PrefixCache, ShardedPagePool, prefix_chain
from .scheduler import Scheduler, bucket_length

__all__ = ["GenRequest", "EngineStats", "Engine"]


@dataclasses.dataclass
class GenRequest:
    """One generation request.  ``seed`` alone determines the sampling
    stream (slot- and batch-independent); give concurrent requests distinct
    seeds for independent draws."""

    tokens: np.ndarray  # (S0,) int32 prompt
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0
    deadline: float | None = None
    # stop early when this token is sampled (output truncates at and
    # includes it); None keeps the fixed max_new_tokens budget
    eos_token: int | None = None


@dataclasses.dataclass
class EngineStats:
    prefill_dispatches: int = 0
    decode_dispatches: int = 0
    generated_tokens: int = 0
    padding_frac: float = 0.0
    # ("prefill", request_index) / ("decode", active_slot_count) in issue
    # order — tests assert prefill insertion happens mid-decode from this
    events: list = dataclasses.field(default_factory=list)
    sched: object | None = None  # SchedulerStats of the last serve() call
    # paged mode
    prefix_hits: int = 0        # admissions that reused >= 1 cached page
    prefix_hit_tokens: int = 0  # prompt tokens whose prefill was skipped
    page_frac: float = 0.0      # partial-last-page fragmentation (sched)
    peak_active: int = 0        # max concurrently-occupied slots
    pool_peak_pages: int = 0    # engine-lifetime peak pool occupancy
    # mesh-sharded serving: peak concurrent live cost per shard (the
    # balance the shard-aware scheduler maintains); [] for single-shard
    shard_peak_cost: list = dataclasses.field(default_factory=list)
    # EOS early exit
    early_exits: int = 0        # slots retired before their token budget

    @property
    def tokens_per_dispatch(self) -> float:
        return self.generated_tokens / max(self.decode_dispatches + self.prefill_dispatches, 1)


class Engine:
    def __init__(
        self, params, cfg: ModelConfig, *, max_len: int = 512, slots: int = 4,
        bucket: int = 1, jit_kwargs: dict | None = None,
        paged: bool = False, page_size: int | None = None,
        pool_pages: int | None = None, prefix_reuse: bool = True,
        eos_poll: int = 4, shards: int = 1, mesh=None, mesh_axis: str = "model",
    ):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.bucket = bucket
        self.paged = paged
        self.eos_poll = max(int(eos_poll), 1)
        # mesh-sharded serving: the slot grid and (paged) KV pool split into
        # `shards` disjoint partitions — slot s of nslots lives on shard
        # s·shards // nslots, every page it touches comes from that shard's
        # pool range, and admission balances live cost per shard (the
        # scheduler's shard-aware take).  Per-row model computation is
        # independent of batch composition, so each request's tokens stay
        # bitwise-identical to a single-shard serve.  Passing ``mesh=`` sets
        # shards from the mesh axis and parks the persistent page pool
        # arrays over it between serve() calls.
        if mesh is not None:
            shards = mesh.shape[mesh_axis]
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if shards > slots:
            raise ValueError(
                f"shards ({shards}) cannot exceed slots ({slots}): a shard "
                "with no slot would idle its whole pool partition"
            )
        self.shards = int(shards)
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        self.stats = EngineStats()
        kw = jit_kwargs or {}

        if paged:
            if cfg.sliding_window is not None:
                raise ValueError(
                    "paged KV cache does not support sliding-window archs "
                    "(the ring layout is position-modular, pages are not)"
                )
            if cfg.family == "ssm":
                raise ValueError(
                    "pure-SSM archs have no attention KV cache to page"
                )
            page_size = int(page_size or self._default_page_size(max_len))
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            if prefix_reuse and cfg.family == "dense" and bucket > 1 and page_size % bucket != 0:
                raise ValueError(
                    f"prefix sharing needs page_size ({page_size}) to be a "
                    f"multiple of bucket ({bucket}) so a shared prefix plus "
                    "a bucketed tail reproduces the cold bucket length; pass "
                    "prefix_reuse=False to page without sharing"
                )
            self.page_size = page_size
            self.max_len = -(-max_len // page_size) * page_size
            self.pages_per_slot = self.max_len // page_size
            if self.shards == 1:
                self.pool = PagePool(
                    pool_pages or slots * self.pages_per_slot + 1, page_size
                )
                pools = [self.pool]
            else:
                # per-shard pools over disjoint global id ranges; pool_pages
                # (when given) is the TOTAL budget, split evenly
                per = (
                    -(-pool_pages // self.shards) if pool_pages
                    else -(-slots // self.shards) * self.pages_per_slot + 1
                )
                self.pool = ShardedPagePool(self.shards, per, page_size)
                pools = self.pool.pools
            # prefix K/V is only bitwise-reproducible for plain sequence
            # positions with no prompt offset — dense family exactly.
            # Sharded: one index per shard (hit pages must be local to the
            # admitted slot's shard — pages are never borrowed across).
            reuse = prefix_reuse and cfg.family == "dense"
            self.prefix_caches = [PrefixCache(p) if reuse else None for p in pools]
            self.prefix_cache = self.prefix_caches[0]  # single-shard alias
            self._pages = None  # persistent {"k_pages","v_pages"} device arrays

            def _prefill(params, batch, last, prior):
                return lm.prefill(params, batch, cfg, last=last, prior=prior, raw_kv=True)

            def _decode(params, caches, tokens, pos, page_table):
                return lm.decode_step(
                    params, caches, tokens, pos, cfg, page_table=page_table
                )
        else:
            self.max_len = max_len
            self.pool = None
            self.prefix_cache = None
            self.prefix_caches = [None] * self.shards

            def _prefill(params, batch, last):
                return lm.prefill(params, batch, cfg, cache_len=self.max_len, last=last)

            def _decode(params, caches, tokens, pos):
                return lm.decode_step(params, caches, tokens, pos, cfg)

        self._prefill = jax.jit(_prefill, **kw)
        self._decode = jax.jit(_decode, donate_argnums=(1,), **kw)

    def _default_page_size(self, max_len: int) -> int:
        """Autotuned page size when `scripts/autotune.py` has measured a
        transferable sweep (op="decode", structure="paged_kv"); 16 outside
        measured territory."""
        try:
            from repro.solvers.cache import get_cache
            from repro.solvers.problem import Problem

            best = get_cache().best_page_size(
                Problem(
                    op="decode", structure="paged_kv", n=max_len,
                    dtype=jnp.dtype(self.cfg.dtype).name,
                )
            )
            if best:
                return int(best)
        except Exception:
            pass
        return 16

    def paged_capacity_slots(self, pages_per_request: int | None = None) -> int:
        """How many concurrent slots the pool can back if every request
        needs ``pages_per_request`` pages (worst case: a full slot).
        Sharded pools sum per-shard capacity, so capacity scales with the
        mesh: each added shard brings its own page partition."""
        per = max(pages_per_request or self.pages_per_slot, 1)
        if self.shards > 1:
            # pages never cross shards: count whole requests per shard
            return sum(p.capacity // per for p in self.pool.pools)
        return max(self.pool.capacity // per, 0)

    # ------------------------------------------------------------------
    # shard layout helpers
    # ------------------------------------------------------------------
    def _slot_shard(self, slot: int, nslots: int) -> int:
        """Contiguous slot→shard partition: slot s of nslots lives on shard
        ``s·shards // nslots`` (block layout — what a PartitionSpec over the
        slot axis would place per device)."""
        return min(slot * self.shards // max(nslots, 1), self.shards - 1)

    def _scrap_id(self, slot: int, nslots: int) -> int:
        """The scrap page id for ``slot``'s shard (0 when single-shard)."""
        if self.shards == 1:
            return 0
        return self.pool.scrap(self._slot_shard(slot, nslots))

    def _alloc_pages(self, n: int, shard: int) -> list[int] | None:
        if self.shards == 1:
            return self.pool.alloc(n)
        return self.pool.alloc(n, shard)

    # ------------------------------------------------------------------
    # paged-cache helpers
    # ------------------------------------------------------------------
    def _request_pages(self, s0: int, lb: int, max_new: int) -> int:
        """Pages a request occupies end-to-end: the padded prefill width or
        the final sequence length, whichever rounds to more pages."""
        off = self._prompt_offset
        return -(-max(lb + off, s0 + off + max_new) // self.page_size)

    def _paged_caches(self, nslots: int, enc_len: int):
        """Fresh per-serve cache pytree over the persistent page pool: the
        K/V pool arrays survive across serve() calls (prefix-cache hits read
        pages written by earlier calls); per-slot parts (SSM state, cross
        K/V) are rebuilt for the current slot count."""
        caches = lm.init_paged_caches(
            self.cfg, nslots, self.pool.num_pages, self.page_size, enc_len=enc_len
        )
        if self._pages is not None:
            pages = dict(self._pages)
            if self.mesh is not None:
                # The pool parks laid out over the mesh between serve()
                # calls; canonicalize placement for the jitted dispatches
                # (the same stance as repro.kernels.spike) so the
                # bitwise-per-request contract holds against a
                # single-device serve.
                pages = jax.device_put(pages, jax.devices()[0])
            caches["attn"] = pages
        return caches

    def _gather_prior(self, caches, pages: list[int]):
        """Assemble the prior-prefix K/V (L, 1, Sp, KV, Dh) for a warm
        prefill from the hit pool pages (read-only gather)."""
        idx = jnp.asarray(pages, jnp.int32)
        kp = caches["attn"]["k_pages"]  # (L, NP, pg, KV, Dh)
        nl, _, pg, kv, dh = kp.shape

        def sel(pool):
            return pool[:, idx].reshape(nl, 1, len(pages) * pg, kv, dh)

        return {"k": sel(kp), "v": sel(caches["attn"]["v_pages"])}

    def _scatter_pages(self, caches, raw, pages: list[int]):
        """Write fresh prefill K/V ({"k","v"}: (L, 1, S, KV, Dh)) into pool
        ``pages`` (page j of the suffix → pages[j]).  Pad-position K/V past
        the true prompt is scattered too but never read: decode overwrites
        position ``cur`` before attending with length ``cur + 1``."""
        if not pages:
            return caches
        idx = jnp.asarray(pages, jnp.int32)
        pg = self.page_size

        def put(pool, fresh):
            nl, _, s, kv, dh = fresh.shape
            pad = len(pages) * pg - s
            if pad:
                fresh = jnp.pad(fresh, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            blocks = fresh.reshape(nl, len(pages), pg, kv, dh).astype(pool.dtype)
            return pool.at[:, idx].set(blocks)

        caches["attn"] = {
            "k_pages": put(caches["attn"]["k_pages"], raw["k"]),
            "v_pages": put(caches["attn"]["v_pages"], raw["v"]),
        }
        return caches

    # ------------------------------------------------------------------
    # request-shaping helpers
    # ------------------------------------------------------------------
    def _model_batch(self, tokens):
        cfg = self.cfg
        b, s = tokens.shape
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(0)
            prefix = jax.random.normal(key, (b, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
            return {"tokens": jnp.asarray(tokens), "prefix_embeds": prefix.astype(jnp.dtype(cfg.dtype))}
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(0)
            frames = jax.random.normal(key, (b, max(s // 4, 1), cfg.d_model), jnp.float32)
            return {"tokens": jnp.asarray(tokens), "frames": frames.astype(jnp.dtype(cfg.dtype))}
        return {"tokens": jnp.asarray(tokens)}

    @property
    def _prompt_offset(self) -> int:
        return self.cfg.num_prefix_embeds if self.cfg.family == "vlm" else 0

    def _bucket_len(self, s0: int, fixed: int | None) -> int:
        lb = fixed if fixed is not None else bucket_length(s0, self.bucket)
        w = self.cfg.sliding_window
        if w is not None and lb > w:
            # The prefill ring keeps only the last `w` *sequence* positions,
            # so pad tokens past the window would evict real prompt K/V
            # before _insert_slot can mask them — pad only while the whole
            # padded prompt still fits in the ring, else prefill exact.
            return s0
        return lb

    # ------------------------------------------------------------------
    # continuous-batching serve loop
    # ------------------------------------------------------------------
    def serve(
        self, requests, *, slots: int | None = None, equalize: bool = True,
    ) -> list[np.ndarray]:
        """Serve ``requests`` (GenRequests) to completion; returns, per
        request (input order), the (S0_i + max_new_i,) int32 token array."""
        reqs = list(requests)
        if not reqs:
            return []
        nslots = min(slots or self.slots, len(reqs))
        offset = self._prompt_offset
        # encdec cross-attention caches are sized by the encoder length,
        # which tracks the padded prompt length — pin ONE bucket for the
        # whole call so every slot's cross cache rows agree.
        fixed_bucket = None
        if self.cfg.family == "encdec":
            fixed_bucket = max(bucket_length(len(r.tokens), self.bucket) for r in reqs)
        for r in reqs:
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"max_new_tokens must be >= 1, got {r.max_new_tokens} "
                    "(the first token comes from prefill; a slot holding a "
                    "zero-budget request would never retire)"
                )
            lb = self._bucket_len(len(r.tokens), fixed_bucket)
            assert lb + offset + r.max_new_tokens <= self.max_len, "max_len too small"
            if self.paged:
                need = self._request_pages(len(r.tokens), lb, r.max_new_tokens)
                cap = getattr(self.pool, "shard_capacity", self.pool.capacity)
                if need > cap:
                    raise ValueError(
                        f"request needs {need} pages of {self.page_size} but "
                        f"{'each shard' if self.shards > 1 else 'the pool'} "
                        f"only holds {cap}; raise pool_pages to at least "
                        f"{(need + 1) * self.shards} (one page per "
                        f"{'shard' if self.shards > 1 else 'pool'} is reserved scrap)"
                    )

        sched = Scheduler()
        prefix_reuse = self.paged and any(c is not None for c in self.prefix_caches)
        for i, r in enumerate(reqs):
            s0 = len(r.tokens)
            lb = self._bucket_len(s0, fixed_bucket)
            chain = None
            if prefix_reuse:
                # salt = the bucket length: prefix K/V is bitwise-exact only
                # between prompts prefilled at the same padded length, so
                # hits must never cross buckets (see paged.prefix_chain)
                chain = prefix_chain(r.tokens, self.page_size, salt=f"lb={lb}")
            sched.submit(
                (i, r), bucket=lb, cost=lb + r.max_new_tokens,
                deadline=r.deadline, real=s0, padded=lb - s0, prefix=chain,
            )

        self.stats = stats = EngineStats()
        enc_len = max((fixed_bucket or 0) // 4, 1) if self.cfg.family == "encdec" else 0
        if self.paged:
            caches = self._paged_caches(nslots, enc_len)
            # idle rows sink writes into their own shard's scrap page
            # (all-zeros — the historical layout — when single-shard)
            page_table = jnp.asarray(
                np.array(
                    [[self._scrap_id(s, nslots)] * self.pages_per_slot
                     for s in range(nslots)],
                    np.int32,
                )
            )
        else:
            caches = lm.init_caches(self.cfg, nslots, self.max_len, enc_len=enc_len)
            page_table = None
        out_cap = max(r.max_new_tokens for r in reqs)
        tok = jnp.zeros((nslots, 1), jnp.int32)
        pos = jnp.zeros((nslots,), jnp.int32)
        keys = jnp.zeros((nslots, 2), jnp.uint32)
        temps = jnp.zeros((nslots,), jnp.float32)
        out_buf = jnp.zeros((nslots, out_cap), jnp.int32)
        out_idx = jnp.zeros((nslots,), jnp.int32)
        # device-side EOS state: compared/updated inside the decode loop,
        # polled (one tiny transfer) every eos_poll steps
        any_eos = any(r.eos_token is not None for r in reqs)
        eos_vec = jnp.full((nslots,), -1, jnp.int32)
        done = jnp.zeros((nslots,), bool)
        done_idx = jnp.full((nslots,), out_cap, jnp.int32)
        eos_countdown = self.eos_poll
        active: list[dict | None] = [None] * nslots
        results: list[np.ndarray | None] = [None] * len(reqs)
        # live admitted cost per shard — the scheduler's occupancy signal
        shard_cost = [0.0] * self.shards

        def finish(slot):
            nonlocal page_table
            st = active[slot]
            r = reqs[st["rid"]]
            shard_cost[st["shard"]] -= st["cost"]
            if r.eos_token is not None:
                # output row ++ truncation index, fetched together — still
                # ONE transfer per request
                packed = np.asarray(
                    jnp.concatenate([out_buf[slot], done_idx[slot][None]])
                )
                n = min(int(packed[-1]), r.max_new_tokens)
                new = packed[:n]
            else:
                n = r.max_new_tokens
                new = np.asarray(out_buf[slot, :n])  # ONE transfer
            results[st["rid"]] = np.concatenate([np.asarray(r.tokens, np.int32), new])
            stats.generated_tokens += n
            if self.paged:
                self.pool.release(st["pages"])
                page_table = page_table.at[slot].set(  # → shard-local scrap
                    jnp.full(
                        (self.pages_per_slot,), self._scrap_id(slot, nslots),
                        jnp.int32,
                    )
                )
                sched.stats.live_tokens += st["valid"] + n
                sched.stats.page_tokens += len(st["pages"]) * self.page_size
            active[slot] = None

        while len(sched) or any(active):
            free = [s for s in range(nslots) if active[s] is None]
            if free and len(sched):
                taken = sched.take(
                    len(free), equalize=equalize,
                    shards=(
                        [self._slot_shard(s, nslots) for s in free]
                        if self.shards > 1 else None
                    ),
                    shard_load=shard_cost if self.shards > 1 else None,
                )
                while taken:
                    sr = taken.pop(0)
                    slot = free.pop(0)
                    shard = self._slot_shard(slot, nslots)
                    pcache = self.prefix_caches[shard] if self.paged else None
                    rid, r = sr.payload
                    s0 = len(r.tokens)
                    lb = self._bucket_len(s0, fixed_bucket)
                    hit_pages: list[int] = []
                    new_pages: list[int] = []
                    prior = None
                    if self.paged:
                        if pcache is not None and sr.prefix:
                            # strictly-before-the-last-token limit keeps at
                            # least one suffix token to prefill (the logits
                            # source) — and, with the s0 // page insert limit
                            # below, guarantees shared pages are never
                            # decode-written (structural copy-on-write).
                            # Sharded: only this shard's index is consulted,
                            # so hit pages are always slot-local.
                            hit_pages = pcache.lookup(
                                sr.prefix[: (s0 - 1) // self.page_size]
                            )
                        need = self._request_pages(s0, lb, r.max_new_tokens)
                        need_new = need - len(hit_pages)
                        new_pages = self._alloc_pages(need_new, shard)
                        if new_pages is None and pcache is not None:
                            pcache.evict(need_new)
                            new_pages = self._alloc_pages(need_new, shard)
                        if new_pages is None:
                            # pool exhausted: queue the rest of the batch
                            # rather than corrupting live pages
                            if hit_pages:
                                self.pool.release(hit_pages)
                            if not any(a is not None for a in active):
                                raise RuntimeError(
                                    "page pool exhausted with no slot in "
                                    "flight — per-request capacity was "
                                    "checked upfront, so only the prefix "
                                    "index can be pinning pages and evict() "
                                    "should have freed it"
                                )
                            sched.restore([sr] + taken)
                            free.insert(0, slot)
                            break
                    shared = len(hit_pages) * (self.page_size if self.paged else 0)
                    if hit_pages:
                        prior = self._gather_prior(caches, hit_pages)
                        stats.prefix_hits += 1
                        stats.prefix_hit_tokens += shared
                    tail, tail_lb = s0 - shared, lb - shared
                    prompt = np.zeros((1, tail_lb), np.int32)
                    prompt[0, :tail] = np.asarray(r.tokens[shared:], np.int32)
                    last = jnp.asarray([tail + offset - 1], jnp.int32)
                    if self.paged:
                        new_caches, logits = self._prefill(
                            self.params, self._model_batch(prompt), last, prior
                        )
                    else:
                        new_caches, logits = self._prefill(
                            self.params, self._model_batch(prompt), last
                        )
                    stats.prefill_dispatches += 1
                    stats.events.append(("prefill", rid))
                    valid = s0 + offset
                    if self.paged:
                        rest = dict(new_caches)
                        attn_raw = rest.pop("attn")
                        if rest:  # per-slot parts: SSM state, cross K/V
                            live = {k2: caches[k2] for k2 in rest}
                            caches.update(_insert_slot(live, rest, slot, valid))
                        npg = -(-attn_raw["k"].shape[2] // self.page_size)
                        caches = self._scatter_pages(caches, attn_raw, new_pages[:npg])
                        row = hit_pages + new_pages
                        row_np = np.zeros((self.pages_per_slot,), np.int32)
                        row_np[: len(row)] = row
                        page_table = page_table.at[slot].set(jnp.asarray(row_np))
                        if pcache is not None and sr.prefix:
                            # full prompt pages only: decode writes start at
                            # position s0, i.e. page >= s0 // page_size
                            ins = s0 // self.page_size
                            pcache.insert(sr.prefix[:ins], row[:ins])
                    else:
                        caches = _insert_slot(caches, new_caches, slot, valid)
                    # split before first use (same key discipline the
                    # lockstep engine regression-tested): the root key is
                    # never consumed directly
                    key, sub = jax.random.split(jax.random.PRNGKey(r.seed))
                    t0 = self._sample(
                        logits[:, -1], jnp.asarray([r.temperature], jnp.float32), sub[None]
                    )
                    tok = tok.at[slot].set(t0[0])
                    pos = pos.at[slot].set(valid)
                    keys = keys.at[slot].set(key)
                    temps = temps.at[slot].set(r.temperature)
                    out_buf = out_buf.at[slot].set(
                        jnp.zeros((out_cap,), jnp.int32).at[0].set(t0[0, 0])
                    )
                    out_idx = out_idx.at[slot].set(1)
                    if any_eos:
                        e = r.eos_token if r.eos_token is not None else -1
                        eos_vec = eos_vec.at[slot].set(e)
                        d0 = (t0[0, 0] == e) if e >= 0 else jnp.asarray(False)
                        done = done.at[slot].set(d0)
                        done_idx = done_idx.at[slot].set(jnp.where(d0, 1, out_cap))
                    active[slot] = {
                        "rid": rid, "left": r.max_new_tokens - 1,
                        "shard": shard, "cost": sr.cost,
                    }
                    shard_cost[shard] += sr.cost
                    stats.shard_peak_cost = [
                        max(a, b) for a, b in zip(
                            stats.shard_peak_cost or [0.0] * self.shards,
                            shard_cost,
                        )
                    ]
                    if self.paged:
                        active[slot]["pages"] = row
                        active[slot]["valid"] = valid
                    if active[slot]["left"] == 0:
                        finish(slot)
                        free.insert(0, slot)
            stats.peak_active = max(
                stats.peak_active, sum(a is not None for a in active)
            )
            if not any(active):
                continue
            split2 = jax.vmap(lambda k: jax.random.split(k))(keys)  # (S, 2, 2)
            keys, subs = split2[:, 0], split2[:, 1]
            if self.paged:
                caches, logits = self._decode(self.params, caches, tok, pos, page_table)
            else:
                caches, logits = self._decode(self.params, caches, tok, pos)
            stats.decode_dispatches += 1
            stats.events.append(("decode", sum(a is not None for a in active)))
            tok = self._sample(logits[:, -1], temps, subs)
            out_buf = jax.vmap(
                lambda row, t, i: jax.lax.dynamic_update_slice(row, t, (i,))
            )(out_buf, tok[:, 0:1], out_idx)
            out_idx = out_idx + 1
            pos = pos + 1
            if any_eos:
                hit = (tok[:, 0] == eos_vec) & (eos_vec >= 0) & (~done)
                done_idx = jnp.where(hit, out_idx, done_idx)
                done = done | hit
            for slot in range(nslots):
                if active[slot] is not None:
                    active[slot]["left"] -= 1
                    if active[slot]["left"] == 0:
                        finish(slot)
            eos_countdown -= 1
            if any_eos and eos_countdown <= 0:
                eos_countdown = self.eos_poll
                flags = np.asarray(done)  # one (slots,) bool transfer
                for slot in range(nslots):
                    if (
                        active[slot] is not None
                        and reqs[active[slot]["rid"]].eos_token is not None
                        and flags[slot]
                    ):
                        stats.early_exits += 1
                        finish(slot)
        stats.padding_frac = sched.stats.padding_frac
        stats.sched = sched.stats
        if self.paged:
            stats.page_frac = sched.stats.page_frac
            stats.pool_peak_pages = self.pool.peak_used
            # pool K/V persists across serve() calls: pages pinned by the
            # prefix index stay readable for the next call's warm prefills
            self._pages = {
                "k_pages": caches["attn"]["k_pages"],
                "v_pages": caches["attn"]["v_pages"],
            }
            if self.mesh is not None:
                # park the persistent pool over the mesh: shard k's page
                # range [k·P, (k+1)·P) lands on device k of the axis —
                # exactly the blocks its slots allocate from, so the
                # resident KV footprint per device is 1/shards of the pool.
                # _paged_caches canonicalizes back before the next jitted
                # dispatch (bitwise-per-request contract).
                from jax.sharding import NamedSharding, PartitionSpec

                self._pages = jax.device_put(
                    self._pages,
                    NamedSharding(
                        self.mesh,
                        PartitionSpec(None, self.mesh_axis, None, None, None),
                    ),
                )
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # lockstep-compatible wrapper
    # ------------------------------------------------------------------
    def generate(
        self, prompts: np.ndarray, *, max_new_tokens: int = 32,
        temperature: float = 0.0, seed: int = 0,
    ) -> np.ndarray:
        """prompts: (B, S0) int32 → (B, S0 + max_new_tokens) int32.

        Runs the serve loop with one slot per row and exact-length buckets
        (no padding).  Row ``i`` samples from seed ``seed + i`` so rows
        draw independently; tokens accumulate in the device-side buffer and
        transfer once per row (the old loop synced the host every token)."""
        prompts = np.asarray(prompts, np.int32)
        b, s0 = prompts.shape
        reqs = [
            GenRequest(
                tokens=prompts[i], max_new_tokens=max_new_tokens,
                temperature=temperature, seed=seed + i,
            )
            for i in range(b)
        ]
        out = self.serve(reqs, slots=b)
        return np.stack(out)

    def _sample(self, logits, temperature, key):
        logits = logits[:, : self.cfg.vocab_size]  # drop padded vocab tail
        t = jnp.asarray(temperature, jnp.float32)
        key = jnp.asarray(key)
        if t.ndim == 0 and key.ndim == 1:
            # legacy lockstep signature: one stream for the whole batch
            if float(t) <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return jax.random.categorical(key, logits / t, axis=-1).astype(jnp.int32)[:, None]
        t = jnp.broadcast_to(t, (logits.shape[0],))
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        safe = jnp.where(t > 0.0, t, 1.0)
        sampled = jax.vmap(jax.random.categorical)(key, logits / safe[:, None]).astype(jnp.int32)
        return jnp.where(t > 0.0, sampled, greedy)[:, None]


def _insert_slot(live, new, slot: int, valid_len: int):
    """Scatter a prefilled (batch-1) cache pytree into row ``slot`` of the
    live caches.  ``pos`` leaves are masked by *position value* (>=
    ``valid_len`` → −1) so bucket-pad K/V slots can never be attended.
    For sliding-window caches this relies on ``Engine._bucket_len`` keeping
    the padded prompt inside the ring (pads past the window would evict
    real K/V before this mask could catch them)."""

    def fix(path, lv, nw):
        row = nw[:, 0]
        if path and getattr(path[-1], "key", None) == "pos":
            row = jnp.where((row >= 0) & (row < valid_len), row, -1)
        return lv.at[:, slot].set(row)

    return jax.tree_util.tree_map_with_path(fix, live, new)
