"""Slot-based continuous-batching serving engine.

The engine owns a fixed grid of ``slots`` batch rows over one KV cache and
two jitted entry points:

* ``_prefill`` — full-sequence forward of ONE shape-bucketed prompt
  ((1, bucket_len); compiled once per bucket), returning the request's
  cache rows and the logits at its true last token (``last=`` gather —
  right-pad tokens are causally inert);
* ``_decode`` — one token for EVERY slot ((slots, 1)) with per-row
  positions; compiled exactly once.

Slot lifecycle: a request admitted from the scheduler is prefilled and its
cache rows are scattered into a free slot (``pos`` entries past the true
prompt length forced to −1 so pad K/V never match); the slot then rides
every decode dispatch until its token budget is spent, at which point its
device-side output row is transferred (once — no per-token host sync) and
the slot is refilled mid-stream from the queue.  Because every per-row
computation in the model is independent of the other rows, a request's
tokens are bitwise-identical no matter which slot it lands in or what else
is in flight (MoE is the one exception: expert capacity couples rows, so
under-filled tail batches can drop tokens differently than full ones).

Sampling is per-slot: each request owns a PRNG stream derived from its
``seed`` only (split once at admission, then once per decode step), so
temperature>0 outputs are also independent of batch composition.

``generate`` is kept as the lockstep-compatible wrapper: one slot per
prompt row, exact-length buckets, per-row seeds ``seed + i``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from .scheduler import Scheduler, bucket_length

__all__ = ["GenRequest", "EngineStats", "Engine"]


@dataclasses.dataclass
class GenRequest:
    """One generation request.  ``seed`` alone determines the sampling
    stream (slot- and batch-independent); give concurrent requests distinct
    seeds for independent draws."""

    tokens: np.ndarray  # (S0,) int32 prompt
    max_new_tokens: int = 32
    temperature: float = 0.0
    seed: int = 0
    deadline: float | None = None


@dataclasses.dataclass
class EngineStats:
    prefill_dispatches: int = 0
    decode_dispatches: int = 0
    generated_tokens: int = 0
    padding_frac: float = 0.0
    # ("prefill", request_index) / ("decode", active_slot_count) in issue
    # order — tests assert prefill insertion happens mid-decode from this
    events: list = dataclasses.field(default_factory=list)
    sched: object | None = None  # SchedulerStats of the last serve() call

    @property
    def tokens_per_dispatch(self) -> float:
        return self.generated_tokens / max(self.decode_dispatches + self.prefill_dispatches, 1)


class Engine:
    def __init__(
        self, params, cfg: ModelConfig, *, max_len: int = 512, slots: int = 4,
        bucket: int = 1, jit_kwargs: dict | None = None,
    ):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.slots = slots
        self.bucket = bucket
        self.stats = EngineStats()
        kw = jit_kwargs or {}

        def _prefill(params, batch, last):
            return lm.prefill(params, batch, cfg, cache_len=max_len, last=last)

        def _decode(params, caches, tokens, pos):
            return lm.decode_step(params, caches, tokens, pos, cfg)

        self._prefill = jax.jit(_prefill, **kw)
        self._decode = jax.jit(_decode, donate_argnums=(1,), **kw)

    # ------------------------------------------------------------------
    # request-shaping helpers
    # ------------------------------------------------------------------
    def _model_batch(self, tokens):
        cfg = self.cfg
        b, s = tokens.shape
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(0)
            prefix = jax.random.normal(key, (b, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
            return {"tokens": jnp.asarray(tokens), "prefix_embeds": prefix.astype(jnp.dtype(cfg.dtype))}
        if cfg.family == "encdec":
            key = jax.random.PRNGKey(0)
            frames = jax.random.normal(key, (b, max(s // 4, 1), cfg.d_model), jnp.float32)
            return {"tokens": jnp.asarray(tokens), "frames": frames.astype(jnp.dtype(cfg.dtype))}
        return {"tokens": jnp.asarray(tokens)}

    @property
    def _prompt_offset(self) -> int:
        return self.cfg.num_prefix_embeds if self.cfg.family == "vlm" else 0

    def _bucket_len(self, s0: int, fixed: int | None) -> int:
        lb = fixed if fixed is not None else bucket_length(s0, self.bucket)
        w = self.cfg.sliding_window
        if w is not None and lb > w:
            # The prefill ring keeps only the last `w` *sequence* positions,
            # so pad tokens past the window would evict real prompt K/V
            # before _insert_slot can mask them — pad only while the whole
            # padded prompt still fits in the ring, else prefill exact.
            return s0
        return lb

    # ------------------------------------------------------------------
    # continuous-batching serve loop
    # ------------------------------------------------------------------
    def serve(
        self, requests, *, slots: int | None = None, equalize: bool = True,
    ) -> list[np.ndarray]:
        """Serve ``requests`` (GenRequests) to completion; returns, per
        request (input order), the (S0_i + max_new_i,) int32 token array."""
        reqs = list(requests)
        if not reqs:
            return []
        nslots = min(slots or self.slots, len(reqs))
        offset = self._prompt_offset
        # encdec cross-attention caches are sized by the encoder length,
        # which tracks the padded prompt length — pin ONE bucket for the
        # whole call so every slot's cross cache rows agree.
        fixed_bucket = None
        if self.cfg.family == "encdec":
            fixed_bucket = max(bucket_length(len(r.tokens), self.bucket) for r in reqs)
        for r in reqs:
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"max_new_tokens must be >= 1, got {r.max_new_tokens} "
                    "(the first token comes from prefill; a slot holding a "
                    "zero-budget request would never retire)"
                )
            lb = self._bucket_len(len(r.tokens), fixed_bucket)
            assert lb + offset + r.max_new_tokens <= self.max_len, "max_len too small"

        sched = Scheduler()
        for i, r in enumerate(reqs):
            s0 = len(r.tokens)
            lb = self._bucket_len(s0, fixed_bucket)
            sched.submit(
                (i, r), bucket=lb, cost=lb + r.max_new_tokens,
                deadline=r.deadline, real=s0, padded=lb - s0,
            )

        self.stats = stats = EngineStats()
        enc_len = max((fixed_bucket or 0) // 4, 1) if self.cfg.family == "encdec" else 0
        caches = lm.init_caches(self.cfg, nslots, self.max_len, enc_len=enc_len)
        out_cap = max(r.max_new_tokens for r in reqs)
        tok = jnp.zeros((nslots, 1), jnp.int32)
        pos = jnp.zeros((nslots,), jnp.int32)
        keys = jnp.zeros((nslots, 2), jnp.uint32)
        temps = jnp.zeros((nslots,), jnp.float32)
        out_buf = jnp.zeros((nslots, out_cap), jnp.int32)
        out_idx = jnp.zeros((nslots,), jnp.int32)
        active: list[dict | None] = [None] * nslots
        results: list[np.ndarray | None] = [None] * len(reqs)

        def finish(slot):
            st = active[slot]
            r = reqs[st["rid"]]
            new = np.asarray(out_buf[slot, : r.max_new_tokens])  # ONE transfer
            results[st["rid"]] = np.concatenate([np.asarray(r.tokens, np.int32), new])
            stats.generated_tokens += r.max_new_tokens
            active[slot] = None

        while len(sched) or any(active):
            free = [s for s in range(nslots) if active[s] is None]
            if free and len(sched):
                for sr in sched.take(len(free), equalize=equalize):
                    slot = free.pop(0)
                    rid, r = sr.payload
                    s0 = len(r.tokens)
                    lb = self._bucket_len(s0, fixed_bucket)
                    prompt = np.zeros((1, lb), np.int32)
                    prompt[0, :s0] = np.asarray(r.tokens, np.int32)
                    last = jnp.asarray([s0 + offset - 1], jnp.int32)
                    new_caches, logits = self._prefill(
                        self.params, self._model_batch(prompt), last
                    )
                    stats.prefill_dispatches += 1
                    stats.events.append(("prefill", rid))
                    valid = s0 + offset
                    caches = _insert_slot(caches, new_caches, slot, valid)
                    # split before first use (same key discipline the
                    # lockstep engine regression-tested): the root key is
                    # never consumed directly
                    key, sub = jax.random.split(jax.random.PRNGKey(r.seed))
                    t0 = self._sample(
                        logits[:, -1], jnp.asarray([r.temperature], jnp.float32), sub[None]
                    )
                    tok = tok.at[slot].set(t0[0])
                    pos = pos.at[slot].set(valid)
                    keys = keys.at[slot].set(key)
                    temps = temps.at[slot].set(r.temperature)
                    out_buf = out_buf.at[slot].set(
                        jnp.zeros((out_cap,), jnp.int32).at[0].set(t0[0, 0])
                    )
                    out_idx = out_idx.at[slot].set(1)
                    active[slot] = {"rid": rid, "left": r.max_new_tokens - 1}
                    if active[slot]["left"] == 0:
                        finish(slot)
                        free.insert(0, slot)
            if not any(active):
                continue
            split2 = jax.vmap(lambda k: jax.random.split(k))(keys)  # (S, 2, 2)
            keys, subs = split2[:, 0], split2[:, 1]
            caches, logits = self._decode(self.params, caches, tok, pos)
            stats.decode_dispatches += 1
            stats.events.append(("decode", sum(a is not None for a in active)))
            tok = self._sample(logits[:, -1], temps, subs)
            out_buf = jax.vmap(
                lambda row, t, i: jax.lax.dynamic_update_slice(row, t, (i,))
            )(out_buf, tok[:, 0:1], out_idx)
            out_idx = out_idx + 1
            pos = pos + 1
            for slot in range(nslots):
                if active[slot] is not None:
                    active[slot]["left"] -= 1
                    if active[slot]["left"] == 0:
                        finish(slot)
        stats.padding_frac = sched.stats.padding_frac
        stats.sched = sched.stats
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    # lockstep-compatible wrapper
    # ------------------------------------------------------------------
    def generate(
        self, prompts: np.ndarray, *, max_new_tokens: int = 32,
        temperature: float = 0.0, seed: int = 0,
    ) -> np.ndarray:
        """prompts: (B, S0) int32 → (B, S0 + max_new_tokens) int32.

        Runs the serve loop with one slot per row and exact-length buckets
        (no padding).  Row ``i`` samples from seed ``seed + i`` so rows
        draw independently; tokens accumulate in the device-side buffer and
        transfer once per row (the old loop synced the host every token)."""
        prompts = np.asarray(prompts, np.int32)
        b, s0 = prompts.shape
        reqs = [
            GenRequest(
                tokens=prompts[i], max_new_tokens=max_new_tokens,
                temperature=temperature, seed=seed + i,
            )
            for i in range(b)
        ]
        out = self.serve(reqs, slots=b)
        return np.stack(out)

    def _sample(self, logits, temperature, key):
        logits = logits[:, : self.cfg.vocab_size]  # drop padded vocab tail
        t = jnp.asarray(temperature, jnp.float32)
        key = jnp.asarray(key)
        if t.ndim == 0 and key.ndim == 1:
            # legacy lockstep signature: one stream for the whole batch
            if float(t) <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return jax.random.categorical(key, logits / t, axis=-1).astype(jnp.int32)[:, None]
        t = jnp.broadcast_to(t, (logits.shape[0],))
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        safe = jnp.where(t > 0.0, t, 1.0)
        sampled = jax.vmap(jax.random.categorical)(key, logits / safe[:, None]).astype(jnp.int32)
        return jnp.where(t > 0.0, sampled, greedy)[:, None]


def _insert_slot(live, new, slot: int, valid_len: int):
    """Scatter a prefilled (batch-1) cache pytree into row ``slot`` of the
    live caches.  ``pos`` leaves are masked by *position value* (>=
    ``valid_len`` → −1) so bucket-pad K/V slots can never be attended.
    For sliding-window caches this relies on ``Engine._bucket_len`` keeping
    the padded prompt inside the ring (pads past the window would evict
    real K/V before this mask could catch them)."""

    def fix(path, lv, nw):
        row = nw[:, 0]
        if path and getattr(path[-1], "key", None) == "pos":
            row = jnp.where((row >= 0) & (row < valid_len), row, -1)
        return lv.at[:, slot].set(row)

    return jax.tree_util.tree_map_with_path(fix, live, new)
