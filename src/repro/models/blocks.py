"""Per-layer block definitions for every architecture family.

A block is (init_fn, apply_fn) where apply is
``(params, x, positions, mode, cache, cfg, enc_out) -> (x, new_cache, aux)``.
All blocks are pre-norm residual.  The same block is stacked ``num_layers``
times via ``lax.scan`` over stacked params (see ``lm.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import common as C
from . import mamba2 as M
from . import moe as MOE


def init_block(key, cfg: ModelConfig, *, encoder: bool = False):
    ks = C.split(key, 8)
    p = {}
    fam = "dense" if encoder else cfg.family
    if fam in ("dense", "vlm", "moe", "hybrid", "encdec"):
        p["ln_attn"] = C.init_norm(cfg)
        p["attn"] = C.init_attention(ks[0], cfg)
    if fam == "encdec":
        p["ln_cross"] = C.init_norm(cfg)
        p["cross"] = C.init_attention(ks[4], cfg)
    if fam in ("dense", "vlm", "encdec"):
        p["ln_mlp"] = C.init_norm(cfg)
        p["mlp"] = C.init_mlp(ks[1], cfg)
    if fam == "moe":
        p["ln_mlp"] = C.init_norm(cfg)
        p["moe"] = MOE.init_moe(ks[2], cfg)
    if fam == "ssm":
        p["ln_ssm"] = C.init_norm(cfg)
        p["ssm"] = M.init_ssm(ks[3], cfg)
    if fam == "hybrid":
        # Hymba: attention and mamba heads in parallel on the same input,
        # combined with learned per-channel gates.
        p["ssm"] = M.init_ssm(ks[3], cfg)
        p["beta_attn"] = (jnp.ones((cfg.d_model,), jnp.float32), ("embed",))
        p["beta_ssm"] = (jnp.ones((cfg.d_model,), jnp.float32), ("embed",))
        p["ln_mlp"] = C.init_norm(cfg)
        p["mlp"] = C.init_mlp(ks[1], cfg)
    return p


def apply_block(
    p, x, cfg: ModelConfig, *, positions, mode="train", cache=None,
    enc_out=None, kv_chunk=1024, cache_len=None, seq_positions=None,
    lengths=None, page_table=None, prior=None, raw_kv=False,
):
    """One decoder layer.  Returns (x, new_cache, aux).

    ``page_table`` / ``prior`` / ``raw_kv`` feed the paged-serving variants
    of the attention sublayer (see ``common.apply_attention_layer``); SSM
    and cross-attention caches stay per-slot dense.
    """
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family
    new_cache = {}

    if fam in ("dense", "vlm", "moe", "encdec"):
        h = C.apply_norm(p["ln_attn"], x, cfg.norm)
        attn_out, ac = C.apply_attention_layer(
            p["attn"], h, cfg, positions=positions, mode=mode,
            cache=None if cache is None else cache["attn"], kv_chunk=kv_chunk,
            cache_len=cache_len, seq_positions=seq_positions,
            page_table=page_table, prior=prior, raw_kv=raw_kv,
        )
        if ac is not None:
            new_cache["attn"] = ac
        x = x + attn_out
        if fam == "encdec":
            h = C.apply_norm(p["ln_cross"], x, cfg.norm)
            cross_out, ckv = C.apply_cross_attention_layer(
                p["cross"], h, cfg,
                enc_out=enc_out,
                cross_kv=None if cache is None else (cache["cross_k"], cache["cross_v"]),
            )
            x = x + cross_out
            if mode == "prefill":
                new_cache["cross_k"], new_cache["cross_v"] = ckv
            elif mode == "decode":
                new_cache["cross_k"], new_cache["cross_v"] = cache["cross_k"], cache["cross_v"]
        h = C.apply_norm(p["ln_mlp"], x, cfg.norm)
        if fam == "moe":
            mo, aux = MOE.apply_moe(p["moe"], h, cfg)
            x = x + mo
        else:
            x = x + C.apply_mlp(p["mlp"], h, cfg)

    elif fam == "ssm":
        h = C.apply_norm(p["ln_ssm"], x, cfg.norm)
        so, sc = M.apply_ssm_layer(
            p["ssm"], h, cfg, mode=mode,
            cache=None if cache is None else cache["ssm"], lengths=lengths,
        )
        if sc is not None:
            new_cache["ssm"] = sc
        x = x + so

    elif fam == "hybrid":
        h = C.apply_norm(p["ln_attn"], x, cfg.norm)
        attn_out, ac = C.apply_attention_layer(
            p["attn"], h, cfg, positions=positions, mode=mode,
            cache=None if cache is None else cache["attn"], kv_chunk=kv_chunk,
            cache_len=cache_len, seq_positions=seq_positions,
            page_table=page_table, prior=prior, raw_kv=raw_kv,
        )
        ssm_out, sc = M.apply_ssm_layer(
            p["ssm"], h, cfg, mode=mode,
            cache=None if cache is None else cache["ssm"], lengths=lengths,
        )
        if ac is not None:
            new_cache["attn"] = ac
        if sc is not None:
            new_cache["ssm"] = sc
        mix = 0.5 * (
            attn_out * p["beta_attn"].astype(x.dtype)
            + ssm_out * p["beta_ssm"].astype(x.dtype)
        )
        x = x + mix
        h = C.apply_norm(p["ln_mlp"], x, cfg.norm)
        x = x + C.apply_mlp(p["mlp"], h, cfg)

    else:
        raise ValueError(fam)

    return x, (new_cache or None), aux


def apply_encoder_block(p, x, cfg: ModelConfig, *, kv_chunk=1024):
    """Bidirectional encoder layer (whisper): full self-attn + MLP."""
    b, s, _ = x.shape
    h = C.apply_norm(p["ln_attn"], x, cfg.norm)
    hh, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (h @ p["attn"]["wq"]).reshape(b, s, hh, dh)
    k = (h @ p["attn"]["wk"]).reshape(b, s, kv, dh)
    v = (h @ p["attn"]["wv"]).reshape(b, s, kv, dh)
    pos = jnp.arange(s, dtype=jnp.int32)
    out = C.attention(
        q, k, v, q_positions=pos, kv_positions=pos,
        causal=False, window=None, kv_chunk=kv_chunk,
    )
    x = x + out @ p["attn"]["wo"]
    h = C.apply_norm(p["ln_mlp"], x, cfg.norm)
    return x + C.apply_mlp(p["mlp"], h, cfg)


def init_block_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype, *, enc_len: int = 0):
    """Cache pytree for ONE layer (stacked over layers by the caller)."""
    fam = cfg.family
    c = {}
    if fam in ("dense", "vlm", "moe", "encdec", "hybrid"):
        c["attn"] = C.init_attention_cache(cfg, batch, seq_len, dtype)
    if fam == "encdec":
        kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        c["cross_k"] = jnp.zeros((batch, enc_len, kv, dh), dtype)
        c["cross_v"] = jnp.zeros((batch, enc_len, kv, dh), dtype)
    if fam in ("ssm", "hybrid"):
        c["ssm"] = M.init_ssm_cache(cfg, batch, dtype)
    return c
