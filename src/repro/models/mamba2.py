"""Mamba-2 (SSD, state-space duality) block — chunked matmul formulation.

Training/prefill uses the chunkwise-parallel SSD algorithm (intra-chunk
attention-like matmuls + inter-chunk state scan): MXU-friendly, O(S·Q)
memory.  Decode is the O(1) recurrent update on the (B, H, hd, N) state.
Single B/C group (G=1), as in the 1.3B config.

Projections are SPLIT per output segment (z, x, B, C, dt) instead of one
fused ``in_proj`` (§Perf iteration 0): a fused (D, 2di+2N+H) output dim
cannot shard cleanly — slicing z/x/B/C out of a model-sharded flat dim
forces GSPMD reshards every layer.  Split projections give each segment its
natural sharding (x, z → TP over heads; B, C, dt → replicated, they are
small).  The math is identical (the conv is depthwise, so per-segment convs
equal the fused conv).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import dense_init, split, apply_norm
from repro.utils import flags


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    return di, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    di, n, h, hd, cw = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = split(key, 9)
    # dt bias: softplus(dt_bias) log-uniform in [1e-3, 1e-1]
    u = jax.random.uniform(ks[7], (h,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    conv = lambda k, ch, ax: (jax.random.normal(k, (cw, ch), jnp.float32).astype(dt) * 0.1, (None, ax))
    return {
        "z_proj": dense_init(ks[0], (d, di), ("embed", "ssm_inner"), dt),
        "x_proj": dense_init(ks[1], (d, di), ("embed", "ssm_inner"), dt),
        "b_proj": dense_init(ks[2], (d, n), ("embed", None), dt),
        "c_proj": dense_init(ks[3], (d, n), ("embed", None), dt),
        "dt_proj": dense_init(ks[4], (d, h), ("embed", None), dt),
        "conv_x": conv(ks[5], di, "ssm_inner"),
        "conv_b": conv(ks[6], n, None),
        "conv_c": conv(ks[8], n, None),
        "a_log": (jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)), ("ssm_heads",)),
        "d_skip": (jnp.ones((h,), jnp.float32), ("ssm_heads",)),
        "dt_bias": (dt_bias, ("ssm_heads",)),
        "norm_scale": (jnp.ones((di,), jnp.float32), ("ssm_inner",)),
        "out_proj": dense_init(ks[7], (di, d), ("ssm_inner", "embed"), dt, scale=di**-0.5),
    }


def _gated_norm(p, y, z):
    return apply_norm({"scale": p["norm_scale"]}, y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), "rmsnorm")


def _causal_conv(xbc, w):
    """Depthwise causal conv along S: xbc (B, S, C), w (cw, C)."""
    cw = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(cw))
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype)


def ssd_chunked(x, dt, a, bmat, cmat, *, chunk: int):
    """Chunkwise-parallel SSD.

    x: (B, S, H, P); dt, a: (B, S, H) (a = dt·A, negative); bmat/cmat: (B, S, N).
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    s_pad = -(-s // q) * q
    if s_pad != s:
        # zero padding is exact: a=0 ⇒ decay exp(0)=1 (state preserved), B·x=0
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, s_pad - s), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, s_pad - s), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, s_pad - s), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, s_pad - s), (0, 0)))
    c = s_pad // q
    xc = (x * dt[..., None]).reshape(b, c, q, h, p)
    ac = a.reshape(b, c, q, h)
    bc = bmat.reshape(b, c, q, n)
    cc = cmat.reshape(b, c, q, n)

    acum = jnp.cumsum(ac, axis=2)  # (B,C,Q,H) within-chunk cumulative log-decay
    asum = acum[:, :, -1]  # (B,C,H)

    # ---- intra-chunk (masked decay-weighted "attention") ----
    cb = jnp.einsum("bcqn,bckn->bcqk", cc, bc, preferred_element_type=jnp.float32)
    ldecay = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # (B,C,Q,K,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(ldecay), 0.0)
    att = cb[..., None] * lmat  # (B,C,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att.astype(x.dtype), xc)

    # ---- chunk states and inter-chunk scan ----
    decay_to_end = jnp.exp(asum[:, :, None, :] - acum)  # (B,C,Q,H)
    states = jnp.einsum(
        "bckn,bckh,bckhp->bchpn", bc.astype(jnp.float32), decay_to_end, xc.astype(jnp.float32)
    )  # (B,C,H,P,N)

    def scan_body(hprev, xs):
        st, asum_c = xs  # (B,H,P,N), (B,H)
        hnew = hprev * jnp.exp(asum_c)[:, :, None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hfinal, hprevs = jax.lax.scan(
        scan_body, h0, (states.transpose(1, 0, 2, 3, 4), asum.transpose(1, 0, 2)),
        unroll=flags.scan_unroll(),
    )
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)  # (B,C,H,P,N) state entering each chunk

    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cc.astype(jnp.float32), hprevs)
    y_inter = y_inter * jnp.exp(acum)[..., None]
    y = y_intra + y_inter.astype(x.dtype)
    return y.reshape(b, s_pad, h, p)[:, :s], hfinal


def apply_ssm_layer(p, xin, cfg: ModelConfig, *, mode="train", cache=None,
                    lengths=None):
    """Mamba-2 mixer sublayer.  cache: {"conv_x","conv_b","conv_c": raw
    pre-conv tails, "state": (B, H, P, N)} for decode; ``prefill`` returns a
    freshly built cache, ``train`` returns cache=None.

    ``lengths`` ((B,) int32, prefill only): per-row true prompt lengths of a
    right-padded shape-bucketed batch.  Pad rows get ``dt`` forced to
    exactly 0 after the softplus — decay ``exp(dt·A) = exp(0) = 1`` leaves
    the state untouched and the state input ``B·(x·dt) = 0`` adds nothing
    (the same identity the chunk padding inside :func:`ssd_chunked` relies
    on) — and the cached conv tails are gathered at each row's true end, so
    the final state, conv windows and every real row's output are exactly
    those of the unpadded prompt (pad-invariant prefill)."""
    b, s, _ = xin.shape
    di, n, h, hd, cw = _dims(cfg)
    z = xin @ p["z_proj"]
    xr = xin @ p["x_proj"]
    br = xin @ p["b_proj"]
    cr = xin @ p["c_proj"]
    dtr = xin @ p["dt_proj"]
    a_neg = -jnp.exp(p["a_log"])  # (H,)

    if mode in ("train", "prefill"):
        x = _causal_conv(xr, p["conv_x"]).reshape(b, s, h, hd)
        bmat = _causal_conv(br, p["conv_b"])
        cmat = _causal_conv(cr, p["conv_c"])
        dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
        if lengths is not None:
            valid = jnp.arange(s)[None, :] < jnp.asarray(lengths, jnp.int32)[:, None]
            dt = jnp.where(valid[:, :, None], dt, 0.0)
        a = dt * a_neg
        y, hfinal = ssd_chunked(x, dt.astype(xin.dtype), a, bmat, cmat, chunk=cfg.ssm_chunk)
        y = y + x * p["d_skip"][:, None].astype(x.dtype)
        y = y.reshape(b, s, di)
        new_cache = None
        if mode == "prefill":
            if lengths is None:
                def tail(r):
                    if s >= cw - 1:
                        return r[:, s - (cw - 1) :, :]
                    return jnp.pad(r, ((0, 0), (cw - 1 - s, 0), (0, 0)))
            else:
                # per-row true conv tails: the cw−1 pre-conv inputs ending
                # at each row's last real token; rows shorter than the
                # window left-fill with zeros (matching init_ssm_cache)
                idx = (jnp.asarray(lengths, jnp.int32)[:, None]
                       - (cw - 1) + jnp.arange(cw - 1)[None, :])  # (B, cw-1)

                def tail(r):
                    take = jnp.take_along_axis(
                        r, jnp.maximum(idx, 0)[:, :, None], axis=1
                    )
                    return jnp.where((idx >= 0)[:, :, None], take,
                                     jnp.zeros((), r.dtype))

            new_cache = {"conv_x": tail(xr), "conv_b": tail(br), "conv_c": tail(cr),
                         "state": hfinal}
    else:
        # decode: conv via cached window, then O(1) recurrent state update
        def conv_step(r_new, cache_seg, w):
            window = jnp.concatenate([cache_seg, r_new], axis=1)  # (B, cw, C)
            out = jnp.einsum("bwc,wc->bc", window, w)
            return jax.nn.silu(out.astype(jnp.float32)).astype(r_new.dtype), window[:, 1:]

        xo, ncx = conv_step(xr, cache["conv_x"], p["conv_x"])
        bo, ncb = conv_step(br, cache["conv_b"], p["conv_b"])
        co, ncc = conv_step(cr, cache["conv_c"], p["conv_c"])
        state = cache["state"]
        x = xo.reshape(b, 1, h, hd)
        dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])  # (B,1,H)
        da = jnp.exp(dt * a_neg)  # (B,1,H)
        xdt = (x * dt[..., None].astype(x.dtype))[:, 0]  # (B,H,P)
        state = state * da[:, 0, :, None, None] + jnp.einsum(
            "bn,bhp->bhpn", bo.astype(jnp.float32), xdt.astype(jnp.float32)
        )
        y = jnp.einsum("bn,bhpn->bhp", co.astype(jnp.float32), state)
        y = y.astype(xin.dtype) + x[:, 0] * p["d_skip"][:, None].astype(x.dtype)
        y = y.reshape(b, 1, di)
        new_cache = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc, "state": state}

    y = _gated_norm(p, y, z)
    return y @ p["out_proj"], new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    di, n, h, hd, cw = _dims(cfg)
    return {
        "conv_x": jnp.zeros((batch, cw - 1, di), dtype),
        "conv_b": jnp.zeros((batch, cw - 1, n), dtype),
        "conv_c": jnp.zeros((batch, cw - 1, n), dtype),
        "state": jnp.zeros((batch, h, hd, n), jnp.float32),
    }
