"""Mixture-of-Experts layer: top-k routing with capacity-bounded dispatch.

Dispatch is the sort-free slot-assignment scheme (rank-within-expert via a
cumsum over the one-hot routing matrix, scatter-add into an (E·cap, D)
buffer, gather back) — O(T·E) intermediates, no (T, E, cap) one-hot tensor.

Distribution (DESIGN.md §5): the layer is an explicit ``shard_map`` island —
GSPMD cannot shard the (B,S,D)→(T,D) token merge across two mesh axes, so
we take manual control of the comms:

  * enter: activations all-gathered from SP (seq sharded over ``model``)
    into full-sequence local blocks (Megatron-SP entry);
  * dispatch: purely local, per-shard capacity ``cf·T_local·k/E``;
  * experts: TP — every chip holds a d_ff slice of all experts, so routing
    never crosses chips (the EP all-to-all alternative is a §Perf
    comparison point);
  * exit: psum_scatter over ``model`` returns to the SP layout (one
    reduce-scatter, completing the Megatron-SP pair).

Without a mesh (smoke tests) the same local function runs unwrapped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import sharding as sh
from .common import dense_init, split, _activation


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    ks = split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), ("embed", "expert"), jnp.float32),
        "wd": dense_init(ks[3], (e, f, d), ("expert", "ff", "embed"), dt, scale=f**-0.5),
    }
    if cfg.mlp_gated:
        p["wg"] = dense_init(ks[1], (e, d, f), ("expert", "embed", "ff"), dt, scale=d**-0.5)
        p["wu"] = dense_init(ks[2], (e, d, f), ("expert", "embed", "ff"), dt, scale=d**-0.5)
    else:
        p["wu"] = dense_init(ks[2], (e, d, f), ("expert", "embed", "ff"), dt, scale=d**-0.5)
    return p


def _moe_grouped(p, xt, cfg: ModelConfig, *, group_tokens: int = 16384):
    """Token-grouped dispatch: scan :func:`_moe_local` over token groups so
    the (E·cap, D) slot buffer stays O(group) instead of O(T) — top-8 × cf
    1.25 otherwise allocates 10× the token activation (granite train_4k
    peaked at 31 GiB before grouping; EXPERIMENTS.md §Perf).  Capacity is
    per group (finer-grained drops — standard 'token groups' semantics)."""
    t, d = xt.shape
    if t <= group_tokens:
        return _moe_local(p, xt, cfg)
    g = -(-t // group_tokens)
    pad = g * group_tokens - t
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(g, group_tokens, d)

    from repro.utils import flags

    if pad:
        # the tail group is underfull: carry each group's VALID token count
        # so phantom pad rows neither consume capacity slots nor skew the
        # aux loss, and the tail's effective capacity scales to its real
        # population — underfull tails route like full groups.
        counts = jnp.full((g,), group_tokens, jnp.int32).at[-1].set(group_tokens - pad)

        def body(_, args):
            xb, rb = args
            out, aux = _moe_local(p, xb, cfg, valid_count=rb)
            return None, (out, aux)

        _, (out, aux) = jax.lax.scan(body, None, (xg, counts), unroll=flags.scan_unroll())
    else:

        def body(_, xb):
            out, aux = _moe_local(p, xb, cfg)
            return None, (out, aux)

        _, (out, aux) = jax.lax.scan(body, None, xg, unroll=flags.scan_unroll())
    out = out.reshape(g * group_tokens, d)[:t]
    return out, aux.mean()


def _moe_local(p, xt, cfg: ModelConfig, valid_count=None):
    """Local-token MoE: xt (T, D) → (out (T, D) [partial over the ff shard],
    aux).  Dispatch/combine never leave the chip.

    ``valid_count`` (traced int32 scalar, or None = all ``T`` rows real)
    marks the leading real-token population of a zero-padded block: pad
    rows are masked out of routing, capacity ranking, and the aux loss,
    and the capacity bound scales to the real population —
    ``⌊cf·R·k/E⌋`` — so an underfull tail group drops tokens at the same
    per-token rate as a full one.  Buffer shapes stay static (sized by the
    full-group capacity) so the scan over groups keeps one trace."""
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    row_valid = None if valid_count is None else jnp.arange(t) < valid_count

    # bf16 inputs, fp32 accumulation — never materializes an f32 token copy
    gate_logits = jnp.einsum(
        "td,de->te", xt, p["router"].astype(xt.dtype),
        preferred_element_type=jnp.float32,
    )  # (T, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * Σ_e fraction_e · mean-prob_e
    cap = max(int(cfg.moe_capacity_factor * t * k / e), 1)
    if row_valid is None:
        frac = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (t * k)
        aux = e * jnp.sum(frac * probs.mean(0))
    else:
        r = jnp.maximum(valid_count, 1)
        hits = jnp.repeat(row_valid.astype(jnp.float32), k)
        frac = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(hits) / (r * k)
        mean_p = jnp.sum(probs * row_valid[:, None], axis=0) / r
        aux = e * jnp.sum(frac * mean_p)
        cap_eff = jnp.where(
            valid_count == t,
            cap,
            jnp.maximum(
                (cfg.moe_capacity_factor * valid_count * k // e).astype(jnp.int32), 1
            ),
        )

    buf = jnp.zeros((e * cap, d), xt.dtype)
    slots = []
    prev_counts = jnp.zeros((e,), jnp.int32)
    for j in range(k):
        ej = top_e[:, j]  # (T,)
        onehot = jax.nn.one_hot(ej, e, dtype=jnp.int32)  # (T, E)
        if row_valid is not None:
            onehot = onehot * row_valid[:, None].astype(jnp.int32)
        rank = jnp.cumsum(onehot, axis=0) - onehot + prev_counts[None, :]
        rank_j = jnp.take_along_axis(rank, ej[:, None], axis=1)[:, 0]  # (T,)
        prev_counts = prev_counts + onehot.sum(0)
        if row_valid is None:
            valid = rank_j < cap
        else:
            valid = (rank_j < cap_eff) & row_valid
        slot = jnp.where(valid, ej * cap + rank_j, e * cap - 1)  # overflow dropped
        slots.append((slot, valid))
        buf = buf.at[slot].add(jnp.where(valid[:, None], xt, 0.0))

    eb = buf.reshape(e, cap, d)
    if cfg.mlp_gated:
        h = _activation(
            jnp.einsum("ecd,edf->ecf", eb, p["wg"]), cfg.mlp_activation
        ) * jnp.einsum("ecd,edf->ecf", eb, p["wu"])
    else:
        h = _activation(jnp.einsum("ecd,edf->ecf", eb, p["wu"]), cfg.mlp_activation)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"]).reshape(e * cap, d)

    out = jnp.zeros_like(xt)
    for j, (slot, valid) in enumerate(slots):
        gathered = out_buf[slot]
        w = (top_p[:, j] * valid).astype(xt.dtype)
        out = out + gathered * w[:, None]
    return out, aux


def apply_moe(p, x, cfg: ModelConfig):
    """x: (B, S, D) → (B, S, D) + aux loss.  shard_map island on a mesh."""
    b, s, d = x.shape
    mesh = sh.active_mesh()
    if mesh is None:
        out, aux = _moe_grouped(p, x.reshape(b * s, d), cfg)
        return out.reshape(b, s, d), aux

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # shard batch only over the axis prefix that divides it (decode B=1 etc.)
    while batch_axes:
        size = 1
        for a in batch_axes:
            size *= mesh.shape[a]
        if b % size == 0:
            break
        batch_axes = batch_axes[:-1]
    tp = "model" in mesh.axis_names and s % mesh.shape["model"] == 0
    x_spec = P(batch_axes or None, "model" if tp else None, None)
    w_ff = P(None, None, "model") if tp else P(None, None, None)
    w_fd = P(None, "model", None) if tp else P(None, None, None)

    def local_fn(x, router, wu, wd, wg):
        if tp:
            x = jax.lax.all_gather(x, "model", axis=1, tiled=True)  # SP → full seq
        bl, sl, _ = x.shape
        pl = {"router": router, "wu": wu, "wd": wd}
        if cfg.mlp_gated:
            pl["wg"] = wg
        out, aux = _moe_grouped(pl, x.reshape(bl * sl, d), cfg)
        out = out.reshape(bl, sl, d)
        if tp:
            # partial over the ff shard + return to SP layout: one fused
            # reduce-scatter over `model` along the sequence dim.
            out = jax.lax.psum_scatter(out, "model", scatter_dimension=1, tiled=True)
            aux = jax.lax.pmean(aux, "model")
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out, aux

    fn = sh.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_ff, w_fd, w_ff),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    # ungated configs pass wu as a (DCE'd) stand-in for wg
    out, aux = fn(x, p["router"], p["wu"], p["wd"], p.get("wg", p["wu"]))
    return out, aux
