"""Top-level language model: embeddings → stacked blocks (lax.scan) →
final norm → (chunked) loss / logits.  Covers all 10 assigned architectures
via the family dispatch in :mod:`repro.models.blocks`.

Entry points (all pure):
  * ``init_params(key, cfg)``      — arrays-only param pytree (eval_shape-able).
  * ``param_axes(cfg)``            — matching logical-axes pytree.
  * ``train_loss(params, batch)``  — scalar CE (+ MoE aux), chunked over
                                      sequence to avoid a (B,S,V) fp32 tensor.
  * ``prefill(params, batch)``     — (cache, last-token logits).
  * ``decode_step(params, cache, tokens, pos)`` — (cache, logits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain, split_axes, prepend_axis
from repro.utils import flags
from . import blocks as B
from . import common as C

ACT_AXES = ("act_batch", "act_seq", "act_embed")


def padded_vocab_size(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // 128) * 128


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    vp = padded_vocab_size(cfg)
    ks = C.split(key, 6)
    params = {
        "embed": C.dense_init(ks[0], (vp, cfg.d_model), (), dt, scale=0.02)[0],
        "ln_f": {"scale": jnp.ones((cfg.d_model,), jnp.float32)},
        "unembed": C.dense_init(ks[2], (cfg.d_model, vp), (), dt)[0],
    }

    def one(k):
        return split_axes(B.init_block(k, cfg))[0]

    params["blocks"] = jax.vmap(one)(jax.random.split(ks[1], cfg.num_layers))

    if cfg.family == "encdec":
        def one_enc(k):
            return split_axes(B.init_block(k, cfg, encoder=True))[0]

        params["enc_blocks"] = jax.vmap(one_enc)(jax.random.split(ks[3], cfg.encoder_layers))
        params["enc_ln_f"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    return params


def param_axes(cfg: ModelConfig):
    """Logical axes tree matching :func:`init_params` (derived from the
    reduced config — same structure, tiny arrays)."""
    r = cfg.reduced()
    key = jax.random.PRNGKey(0)
    ax = {
        "embed": ("vocab", "embed"),
        "ln_f": {"scale": ("embed",)},
        "unembed": ("embed", "vocab"),
        "blocks": prepend_axis(split_axes(B.init_block(key, r))[1], "layers"),
    }
    if cfg.family == "encdec":
        ax["enc_blocks"] = prepend_axis(split_axes(B.init_block(key, r, encoder=True))[1], "layers")
        ax["enc_ln_f"] = {"scale": ("embed",)}
    return ax


# ---------------------------------------------------------------------------
# input embedding per family
# ---------------------------------------------------------------------------
def _sinusoid(positions, d):
    inv = 1.0 / (10000 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[:, None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def mrope_positions(cfg: ModelConfig, batch: int, seq: int, *, grid: int = 16):
    """Qwen2-VL M-RoPE position streams (3, B, S): vision prefix gets a 2-D
    (h, w) grid at t=0; text advances all three streams together."""
    p = cfg.num_prefix_embeds
    idx = np.arange(seq)
    t = np.where(idx < p, 0, idx - p + grid)
    h = np.where(idx < p, idx // grid, idx - p + grid)
    w = np.where(idx < p, idx % grid, idx - p + grid)
    pos = jnp.asarray(np.stack([t, h, w]), jnp.int32)  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


def _embed_inputs(params, batch, cfg: ModelConfig, *, mode):
    """→ (x, positions, loss_mask, enc_out).  ``batch`` dict per family:
    lm/ssm/hybrid/moe: {tokens}; vlm: {tokens, prefix_embeds}; encdec:
    {tokens, frames} (frames = precomputed frame embeddings — frontend stub).
    """
    emb = params["embed"]
    enc_out = None
    if cfg.family == "vlm":
        tok_x = jnp.take(emb, batch["tokens"], axis=0)
        x = jnp.concatenate([batch["prefix_embeds"].astype(tok_x.dtype), tok_x], axis=1)
        bsz, s = x.shape[0], x.shape[1]
        positions = mrope_positions(cfg, bsz, s)
        mask = jnp.concatenate(
            [jnp.zeros((bsz, cfg.num_prefix_embeds), bool), jnp.ones_like(batch["tokens"], bool)],
            axis=1,
        )
    elif cfg.family == "encdec":
        frames = batch["frames"]
        fpos = jnp.arange(frames.shape[1], dtype=jnp.int32)
        h = frames + _sinusoid(fpos, cfg.d_model)[None].astype(frames.dtype)
        h = constrain(h, ACT_AXES)

        def enc_body(h, bp):
            h = B.apply_encoder_block(bp, h, cfg)
            return constrain(h, ACT_AXES), None

        enc_body = jax.checkpoint(enc_body) if mode == "train" else enc_body
        h, _ = jax.lax.scan(enc_body, h, params["enc_blocks"], unroll=flags.scan_unroll())
        enc_out = C.apply_norm(params["enc_ln_f"], h, cfg.norm)
        x = jnp.take(emb, batch["tokens"], axis=0)
        tpos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = x + _sinusoid(tpos, cfg.d_model)[None].astype(x.dtype)
        positions = jnp.broadcast_to(tpos[None], x.shape[:2])
        mask = jnp.ones(x.shape[:2], bool)
    else:
        x = jnp.take(emb, batch["tokens"], axis=0)
        bsz, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
        mask = jnp.ones((bsz, s), bool)
    return x, positions, mask, enc_out


# ---------------------------------------------------------------------------
# block stack
# ---------------------------------------------------------------------------
def _scan_blocks(params, x, cfg: ModelConfig, *, positions, mode, caches=None,
                 enc_out=None, kv_chunk=1024, cache_len=None, seq_positions=None,
                 lengths=None, page_table=None, prior=None, raw_kv=False):
    # scan xs: block params, plus (when present) per-layer caches and the
    # per-layer prior prefix K/V ({"k","v"} stacked on a leading layer axis)
    def body(x, xs):
        cache = prior_l = None
        if caches is not None and prior is not None:
            bp, cache, prior_l = xs
        elif caches is not None:
            bp, cache = xs
        elif prior is not None:
            bp, prior_l = xs
        else:
            bp = xs
        x, new_cache, aux = B.apply_block(
            bp, x, cfg, positions=positions, mode=mode, cache=cache,
            enc_out=enc_out, kv_chunk=kv_chunk, cache_len=cache_len,
            seq_positions=seq_positions, lengths=lengths,
            page_table=page_table, prior=prior_l, raw_kv=raw_kv,
        )
        x = constrain(x, ACT_AXES)
        return x, (new_cache, aux)

    if mode == "train":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots" else None
        )
        body_fn = jax.checkpoint(body, policy=policy)
    else:
        body_fn = body
    xs_list = [params["blocks"]]
    if caches is not None:
        xs_list.append(caches)
    if prior is not None:
        xs_list.append(prior)
    xs = xs_list[0] if len(xs_list) == 1 else tuple(xs_list)
    x, (new_caches, auxs) = jax.lax.scan(body_fn, x, xs, unroll=flags.scan_unroll())
    return x, new_caches, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------
def _chunked_ce(x, w, labels, mask, *, seq_chunk=512):
    """Next-token CE without materializing (B, S, V) fp32 logits: scan over
    sequence chunks, fp32 log-softmax per chunk."""
    b, s, d = x.shape
    nc = -(-s // seq_chunk)
    pad = nc * seq_chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xc = x.reshape(b, nc, seq_chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, seq_chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, nc, seq_chunk).transpose(1, 0, 2)

    def body(acc, xs):
        xi, li, mi = xs
        logits = jnp.einsum("bsd,dv->bsv", xi, w, preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - ll) * mi)
        return (acc[0] + loss, acc[1] + jnp.sum(mi)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xc, lc, mc),
        unroll=flags.scan_unroll(),
    )
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(params, batch, cfg: ModelConfig, *, kv_chunk=1024, aux_weight=0.01):
    x, positions, mask, enc_out = _embed_inputs(params, batch, cfg, mode="train")
    seq_pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    x = constrain(x, ACT_AXES)
    x, _, aux = _scan_blocks(
        params, x, cfg, positions=positions, mode="train", enc_out=enc_out,
        kv_chunk=kv_chunk, seq_positions=seq_pos,
    )
    x = C.apply_norm(params["ln_f"], x, cfg.norm)
    tokens = batch["tokens"]
    if cfg.family == "vlm":  # loss only over text positions
        p = cfg.num_prefix_embeds
        x = x[:, p:]
        mask = mask[:, p:]
    labels = tokens[:, 1:]
    ce = _chunked_ce(x[:, :-1], params["unembed"], labels, mask[:, 1:].astype(jnp.float32))
    metrics = {"ce": ce, "aux": aux}
    return ce + aux_weight * aux, metrics


def prefill(params, batch, cfg: ModelConfig, *, cache_len=None, kv_chunk=1024, last=None,
            prior=None, raw_kv=False):
    """Full-sequence forward building the decode cache; returns
    (caches, last-token logits).

    ``last`` (optional, (B,) int32): per-row index of the token whose logits
    to return instead of the trailing position — the serving engine prefills
    right-padded shape-bucketed prompts and samples from each request's true
    last token (causality keeps those logits untouched by the pad tail).

    ``prior`` (optional): layer-stacked {"k","v": (L, B, Sp, KV, Dh)} —
    already-computed K/V for a shared prompt prefix of Sp tokens.  The rows
    in ``batch`` are then the prompt *suffix*: positions are offset by Sp
    and attention runs over (prior ++ fresh).  Dense-family only (position
    streams are plain sequence indices).  ``raw_kv=True`` returns each
    layer's fresh K/V verbatim (for the paged engine to scatter into pool
    pages) instead of dense cache rows; ``last`` indices stay in suffix
    coordinates."""
    x, positions, _, enc_out = _embed_inputs(params, batch, cfg, mode="prefill")
    seq_pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    if prior is not None:
        sp = prior["k"].shape[2]
        positions = positions + sp
        seq_pos = seq_pos + sp
    x = constrain(x, ACT_AXES)
    seq = x.shape[1]
    # per-row true lengths (from the serving engine's last= gather) make the
    # recurrent SSM/hybrid prefill pad-invariant; attention is already
    # causally inert to right padding.
    lengths = None if last is None else jnp.asarray(last, jnp.int32) + 1
    x, caches, _ = _scan_blocks(
        params, x, cfg, positions=positions, mode="prefill", enc_out=enc_out,
        kv_chunk=kv_chunk, cache_len=cache_len, seq_positions=seq_pos,
        lengths=lengths, prior=prior, raw_kv=raw_kv,
    )
    x = C.apply_norm(params["ln_f"], x, cfg.norm)
    if last is None:
        sel = x[:, -1:]
    else:
        idx = jnp.asarray(last, jnp.int32)[:, None, None]
        sel = jnp.take_along_axis(x, jnp.broadcast_to(idx, (x.shape[0], 1, x.shape[-1])), axis=1)
    logits = jnp.einsum("bsd,dv->bsv", sel, params["unembed"], preferred_element_type=jnp.float32)
    return caches, logits


def decode_step(params, caches, tokens, pos, cfg: ModelConfig, *, page_table=None):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 (lockstep —
    every row at the same depth) or (B,) int32 per-row positions (continuous
    batching: each slot advances independently); caches: per-layer-stacked
    pytree from :func:`prefill` / :func:`init_caches`.  Returns
    (new_caches, logits (B, 1, V)).

    With a paged cache (:func:`init_paged_caches`), ``page_table`` (B, NP)
    int32 maps each row's logical pages to pool pages; the attention
    sublayer resolves it inside one Pallas gather kernel per layer."""
    emb = params["embed"]
    x = jnp.take(emb, tokens, axis=0)
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    if cfg.family == "encdec":
        x = x + _sinusoid(pos, cfg.d_model)[:, None, :].astype(x.dtype)
    if cfg.mrope_sections is not None:
        # same stream law as mrope_positions for text: val = pos − P + grid.
        # The temporal mask stream (positions[0]) must stay the raw absolute
        # position, so we offset only for rope and let apply_rope consume it;
        # t/h/w coincide for text tokens.
        mpos = pos - cfg.num_prefix_embeds + 16
        positions = jnp.broadcast_to(mpos[None, :, None], (3, b, 1))
    else:
        positions = pos[:, None]
    seq_pos = pos
    x, new_caches, _ = _scan_blocks(
        params, x, cfg, positions=positions, mode="decode", caches=caches,
        seq_positions=seq_pos, page_table=page_table,
    )
    x = C.apply_norm(params["ln_f"], x, cfg.norm)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"], preferred_element_type=jnp.float32)
    return new_caches, logits


def init_caches(cfg: ModelConfig, batch: int, seq_len: int, *, enc_len: int = 0, dtype=None):
    """Per-layer-stacked empty cache pytree (for decode-only dry-runs)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    one = B.init_block_cache(cfg, batch, seq_len, dtype, enc_len=enc_len)
    return jax.tree.map(lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one)


def init_paged_caches(cfg: ModelConfig, batch: int, num_pages: int, page_size: int,
                      *, enc_len: int = 0, dtype=None):
    """Layer-stacked cache pytree with the attention K/V held as a shared
    page pool ``(L, num_pages, page_size, KV, Dh)`` instead of per-slot rows.
    Non-attention cache parts (SSM state, encdec cross K/V) stay per-slot
    dense — only the token-indexed KV grows with sequence length.  Sliding
    -window archs are not pageable (the ring layout is position-modular)."""
    if cfg.sliding_window is not None:
        raise ValueError("paged KV cache does not support sliding-window archs")
    dtype = dtype or jnp.dtype(cfg.dtype)
    one = B.init_block_cache(cfg, batch, page_size, dtype, enc_len=enc_len)
    if "attn" not in one:
        raise ValueError(f"family {cfg.family!r} has no attention KV cache to page")
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    one["attn"] = {
        "k_pages": jnp.zeros((num_pages, page_size, kv, dh), dtype),
        "v_pages": jnp.zeros((num_pages, page_size, kv, dh), dtype),
    }
    return jax.tree.map(lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one)


def cache_axes(cfg: ModelConfig):
    """Logical sharding axes for the cache pytree (layer-stacked)."""
    ax_attn = {
        "k": ("layers", "cache_batch", "cache_seq", "cache_kv", None),
        "v": ("layers", "cache_batch", "cache_seq", "cache_kv", None),
        "pos": ("layers", "cache_batch", None),
    }
    ax = {}
    if cfg.family in ("dense", "vlm", "moe", "encdec", "hybrid"):
        ax["attn"] = ax_attn
    if cfg.family == "encdec":
        ax["cross_k"] = ("layers", "cache_batch", "cache_seq", "cache_kv", None)
        ax["cross_v"] = ("layers", "cache_batch", "cache_seq", "cache_kv", None)
    if cfg.family in ("ssm", "hybrid"):
        ax["ssm"] = {
            "conv_x": ("layers", "cache_batch", None, "ssm_inner"),
            "conv_b": ("layers", "cache_batch", None, None),
            "conv_c": ("layers", "cache_batch", None, None),
            "state": ("layers", "cache_batch", "state_heads", None, None),
        }
    return ax
