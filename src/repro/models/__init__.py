"""The 10 assigned architectures: dense/MoE/SSM/hybrid/enc-dec/VLM families."""
from . import common, blocks, lm, mamba2, moe  # noqa: F401
