"""Shared model components: norms, RoPE / M-RoPE, GQA attention (chunked
flash-style with causal / sliding-window masking), MLPs, init helpers.

All modules are plain-function + dict-pytree style (no framework dependency);
compute is bf16 with fp32 softmax/norm/accumulation.  Logical sharding axes
are attached per-leaf by ``repro.dist.sharding`` via the ``AXES`` metadata
returned from each ``init_*`` (leaf name → tuple of logical axis names).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils import flags

MASK_VALUE = -1e30


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, shape, axes, dtype, scale=None):
    """Fan-in scaled normal init; returns (array, logical-axes)."""
    fan_in = shape[-2] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype), axes


def split(key, n):
    return jax.random.split(key, n)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dim=None):
    dim = dim or cfg.d_model
    return {"scale": (jnp.ones((dim,), jnp.float32), ("embed",))}


def apply_norm(p, x, kind: str):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        xf = xf - xf.mean(-1, keepdims=True)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float, mrope_sections=None):
    """x: (B, S, H, Dh); positions: (B, S) or (3, B, S) for M-RoPE."""
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)  # (Dh/2,)
    if mrope_sections is None:
        angles = positions[..., None].astype(jnp.float32) * inv  # (B,S,Dh/2)
    else:
        # Qwen2-VL M-RoPE: frequency bands split into (t, h, w) sections,
        # each driven by its own position stream.
        sec = jnp.concatenate(
            [jnp.full((s,), i, jnp.int32) for i, s in enumerate(mrope_sections)]
        )  # (Dh/2,) section selector
        pos_sel = jnp.take(positions, sec, axis=0)  # (Dh/2, B, S)
        angles = jnp.moveaxis(pos_sel, 0, -1).astype(jnp.float32) * inv
    cos, sin = jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, chunked flash-style)
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * dh), ("embed", "heads_x_dim"), dt),
        "wk": dense_init(ks[1], (d, kv * dh), ("embed", "kv_x_dim"), dt),
        "wv": dense_init(ks[2], (d, kv * dh), ("embed", "kv_x_dim"), dt),
        "wo": dense_init(ks[3], (h * dh, d), ("heads_x_dim", "embed"), dt, scale=(h * dh) ** -0.5),
    }


def _online_softmax_step(carry, s, v_chunk):
    """One kv-chunk of the online-softmax accumulation.

    s: (B, KV, rep, Sq, Ck) fp32 masked scores; v_chunk: (B, Ck, KV, Dh).
    """
    m, l, acc = carry
    m_new = jnp.maximum(m, s.max(-1))
    m_new = jnp.maximum(m_new, -1e25)  # guard fully-masked rows
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l = l * corr + p.sum(-1)
    pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(v_chunk.dtype), v_chunk)
    acc = acc * corr[..., None].astype(acc.dtype) + pv
    return m_new, l, acc


def attention(
    q, k, v, *, q_positions, kv_positions, causal: bool,
    window: int | None, kv_chunk: int = 1024, schedule: str = "rect",
):
    """Chunked GQA attention with O(Sq·chunk) working set.

    q: (B, Sq, H, Dh); k/v: (B, Sk, KV, Dh); positions are absolute token
    indices used for causal and sliding-window masking (position < 0 on the
    kv side marks an invalid / not-yet-filled cache slot).

    Schedules (EXPERIMENTS.md §Perf):
      * ``rect`` — baseline: every kv chunk visited for the full q range;
        masked chunks contribute zero but still cost FLOPs.
      * ``tri``  — causal full self-attention only: square (q, kv) chunk
        pairs enumerated lower-triangularly (band-limited under SWA),
        halving (or better) the attention FLOPs.
    """
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, sq, kvh, rep, dh).transpose(0, 2, 3, 1, 4)  # (B,KV,rep,Sq,Dh)
    scale = dh**-0.5

    kv_chunk = min(kv_chunk, sk)
    num_chunks = sk // kv_chunk if sk % kv_chunk == 0 else -(-sk // kv_chunk)

    if schedule == "tri" and causal and sq == sk and num_chunks > 1:
        out = _attention_tri(
            qg, k, v, q_positions=q_positions, kv_positions=kv_positions,
            window=window, chunk=kv_chunk, scale=scale,
        )
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h * dh).astype(q.dtype)

    def masked_scores(k_chunk, kpos_chunk):
        s = jnp.einsum("bgrqd,bkgd->bgrqk", qg, k_chunk, preferred_element_type=jnp.float32)
        s = s * scale
        mask = kpos_chunk[None, :] >= 0  # valid slot
        if causal:
            mask &= kpos_chunk[None, :] <= q_positions[:, None]
        if window is not None:
            mask &= q_positions[:, None] - kpos_chunk[None, :] < window
        return jnp.where(mask[None, None, None], s, MASK_VALUE)

    if num_chunks == 1:
        s = masked_scores(k, kv_positions)
        m = jnp.maximum(s.max(-1), -1e25)
        p = jnp.exp(s - m[..., None])
        out = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(v.dtype), v)
        out = out / jnp.maximum(p.sum(-1), 1e-30)[..., None].astype(out.dtype)
    else:
        pad = num_chunks * kv_chunk - sk
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)
        kc = k.reshape(b, num_chunks, kv_chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(b, num_chunks, kv_chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
        pc = kv_positions.reshape(num_chunks, kv_chunk)

        def body(carry, xs):
            k_chunk, v_chunk, kpos = xs
            s = masked_scores(k_chunk, kpos)
            return _online_softmax_step(carry, s, v_chunk), None

        init = (
            jnp.full((b, kvh, rep, sq), -jnp.inf, jnp.float32),
            jnp.zeros((b, kvh, rep, sq), jnp.float32),
            jnp.zeros((b, kvh, rep, sq, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(body, init, (kc, vc, pc), unroll=flags.scan_unroll())
        out = acc / jnp.maximum(l, 1e-30)[..., None]

    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h * dh).astype(q.dtype)


def ebv_attention_sharded(q, k, v, *, q_positions, window, scale=None):
    """**EbV-scheduled causal self-attention** — the paper's equalization
    trick applied to sequence-parallel attention (EXPERIMENTS.md §Perf).

    Plain SP causal attention is load-imbalanced: rank r's contiguous
    q-shard needs (r+1)/P of the kv prefix — rank P−1 does P× rank 0's
    work, and SPMD uniformity forces everyone to pay the rectangle.  The
    paper's pairing (work unit r ↔ n−1−r) fixes exactly this: rank r
    processes q-blocks {r, 2P−1−r}; their causal work sums to
    ``(r+1) + (2P−r) = 2P+1`` kv-blocks — **constant across ranks** — so
    the triangular schedule becomes a fixed-shape, perfectly balanced SPMD
    loop (FLOPs = the causal triangle, ½ the rectangular baseline, zero
    straggler ranks).

    The fold exchange happens *inside* the island with 8 single-block
    static ``ppermute``s (O(B·c·H·Dh) each) — no resharding of the
    seq-sharded operands (the v1 outside-permutation gather replicated q
    and blew peak memory 5×; §Perf log).

    q: (B, S, H, Dh) seq-shardable; k/v: (B, S, KV, Dh); ``q_positions``
    must be ``arange(S)`` (train/prefill).  Returns (B, S, H·Dh) in
    original order.  Requires a ``model`` mesh axis and S % 2P == 0.
    """
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as shlib

    mesh = shlib.active_mesh()
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    p_ = mesh.shape["model"]
    nb = 2 * p_
    c = s // nb
    scale = scale if scale is not None else dh**-0.5

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while batch_axes:
        size = 1
        for a in batch_axes:
            size *= mesh.shape[a]
        if b % size == 0:
            break
        batch_axes = batch_axes[:-1]

    ax = "model"

    def _exchange(slot0, slot1, pairs_by_slot):
        """Route local c-blocks by static (src→dst) tables; each table entry
        also says which slot the source sends.  Returns what this rank
        receives (zeros if it is not a destination in the table)."""
        out = None
        for pairs, slot_sel in pairs_by_slot:
            if not pairs:
                continue
            send = slot0 if slot_sel == 0 else slot1
            got = jax.lax.ppermute(send, ax, pairs)
            out = got if out is None else out + got
        return out

    def local(ql, kl, vl):
        r = jax.lax.axis_index(ax)
        bl = ql.shape[0]
        kf = jax.lax.all_gather(kl, ax, axis=1, tiled=True)  # (Bl, S, KV, Dh)
        vf = jax.lax.all_gather(vl, ax, axis=1, tiled=True)

        # ---- fold-in: local contiguous blocks (2r, 2r+1) → (r, nb−1−r) ----
        s0, s1 = ql[:, :c], ql[:, c:]
        # need block t (t = this rank): owner t//2, slot t%2
        pA = [(t // 2, t) for t in range(p_) if t % 2 == 0]
        pB = [(t // 2, t) for t in range(p_) if t % 2 == 1]
        q_lo = _exchange(s0, s1, [(pA, 0), (pB, 1)])
        # need block nb−1−t: owner (nb−1−t)//2, slot (nb−1−t)%2
        pC = [((nb - 1 - t) // 2, t) for t in range(p_) if (nb - 1 - t) % 2 == 0]
        pD = [((nb - 1 - t) // 2, t) for t in range(p_) if (nb - 1 - t) % 2 == 1]
        q_hi = _exchange(s0, s1, [(pC, 0), (pD, 1)])

        def to_heads(q_blk_seq):  # (Bl, c, H·Dh-ish) → (Bl, KV, rep, c, Dh)
            return q_blk_seq.reshape(bl, c, kvh, rep, dh).transpose(0, 2, 3, 1, 4)

        qg_lo, qg_hi = to_heads(q_lo), to_heads(q_hi)
        m = jnp.full((bl, kvh, rep, 2, c), -jnp.inf, jnp.float32)
        l = jnp.zeros((bl, kvh, rep, 2, c), jnp.float32)
        acc = jnp.zeros((bl, kvh, rep, 2, c, dh), jnp.float32)
        pos_lo = r * c + jnp.arange(c, dtype=jnp.int32)
        pos_hi = (nb - 1 - r) * c + jnp.arange(c, dtype=jnp.int32)

        def step(carry, j):  # 2P+1 equal steps — every one does real work
            m, l, acc = carry
            use_lo = j <= r
            kv_idx = jnp.where(use_lo, j, j - (r + 1))
            half = jnp.where(use_lo, 0, 1)
            q_blk = jnp.where(use_lo, qg_lo, qg_hi)
            qp = jnp.where(use_lo, pos_lo, pos_hi)
            k_blk = jax.lax.dynamic_slice_in_dim(kf, kv_idx * c, c, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(vf, kv_idx * c, c, axis=1)
            kp = kv_idx * c + jnp.arange(c, dtype=jnp.int32)
            sc = jnp.einsum("bgrqd,bkgd->bgrqk", q_blk, k_blk, preferred_element_type=jnp.float32)
            sc = sc * scale
            mask = kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            sc = jnp.where(mask[None, None, None], sc, MASK_VALUE)
            m_b = jax.lax.dynamic_index_in_dim(m, half, axis=3, keepdims=False)
            l_b = jax.lax.dynamic_index_in_dim(l, half, axis=3, keepdims=False)
            a_b = jax.lax.dynamic_index_in_dim(acc, half, axis=3, keepdims=False)
            nm, nl, na = _online_softmax_step((m_b, l_b, a_b), sc, v_blk)
            m = jax.lax.dynamic_update_slice_in_dim(m, nm[:, :, :, None], half, axis=3)
            l = jax.lax.dynamic_update_slice_in_dim(l, nl[:, :, :, None], half, axis=3)
            acc = jax.lax.dynamic_update_slice_in_dim(acc, na[:, :, :, None], half, axis=3)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            step, (m, l, acc), jnp.arange(nb + 1, dtype=jnp.int32),
            unroll=flags.scan_unroll(),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (Bl,KV,rep,2,c,Dh)
        out = out.transpose(0, 3, 4, 1, 2, 5).reshape(bl, 2, c, h * dh).astype(ql.dtype)
        o_lo, o_hi = out[:, 0], out[:, 1]

        # ---- fold-out: computed blocks (r, nb−1−r) → contiguous (2t, 2t+1)
        # block 2t: rank 2t slot-lo if 2t<P else rank nb−1−2t slot-hi
        q1 = [(2 * t, t) for t in range(p_) if 2 * t < p_]
        q2 = [(nb - 1 - 2 * t, t) for t in range(p_) if 2 * t >= p_]
        blk_even = _exchange(o_lo, o_hi, [(q1, 0), (q2, 1)])
        # block 2t+1: rank 2t+1 slot-lo if 2t+1<P else rank nb−2−2t slot-hi
        q3 = [(2 * t + 1, t) for t in range(p_) if 2 * t + 1 < p_]
        q4 = [(nb - 2 - 2 * t, t) for t in range(p_) if 2 * t + 1 >= p_]
        blk_odd = _exchange(o_lo, o_hi, [(q3, 0), (q4, 1)])
        return jnp.concatenate([blk_even, blk_odd], axis=1)  # (Bl, 2c, H·Dh)

    fn = shlib.shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(batch_axes or None, ax, None, None),
            P(batch_axes or None, ax, None, None),
            P(batch_axes or None, ax, None, None),
        ),
        out_specs=P(batch_axes or None, ax, None),
        check_vma=False,
    )
    return fn(q, k, v)


def _attention_tri(qg, k, v, *, q_positions, kv_positions, window, chunk, scale):
    """Triangular-schedule causal attention (§Perf optimization).

    Enumerates only the (q-chunk, kv-chunk) pairs below the causal diagonal
    (and inside the SWA band), scanning them in q-major order so the online
    softmax stays sequential per q chunk.  FLOPs ≈ ½ of the rectangular
    schedule (less under SWA); working set unchanged.
    """
    b, kvh, rep, sq, dh = qg.shape
    c = chunk
    n = -(-sq // c)
    pad = n * c - sq
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad), constant_values=-(10**9))
        kv_positions = jnp.pad(kv_positions, (0, pad), constant_values=-1)

    pairs = [
        (qi, ki)
        for qi in range(n)
        for ki in range(qi + 1)
        # band limit under sliding window: newest kv position in chunk ki is
        # ki*c + c - 1; oldest q position is qi*c — skip fully-expired pairs
        if window is None or (qi * c) - (ki * c + c - 1) < window
    ]
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

    def body(carry, pair):
        m, l, acc = carry
        qi, ki = pair
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * c, c, axis=3)
        k_blk = jax.lax.dynamic_slice_in_dim(k, ki * c, c, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, ki * c, c, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(q_positions, qi * c, c)
        kpos = jax.lax.dynamic_slice_in_dim(kv_positions, ki * c, c)
        s = jnp.einsum("bgrqd,bkgd->bgrqk", q_blk, k_blk, preferred_element_type=jnp.float32)
        s = s * scale
        mask = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(mask[None, None, None], s, MASK_VALUE)

        m_blk = jax.lax.dynamic_slice_in_dim(m, qi * c, c, axis=3)
        l_blk = jax.lax.dynamic_slice_in_dim(l, qi * c, c, axis=3)
        acc_blk = jax.lax.dynamic_slice_in_dim(acc, qi * c, c, axis=3)
        new = _online_softmax_step((m_blk, l_blk, acc_blk), s, v_blk)
        m = jax.lax.dynamic_update_slice_in_dim(m, new[0], qi * c, axis=3)
        l = jax.lax.dynamic_update_slice_in_dim(l, new[1], qi * c, axis=3)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, new[2], qi * c, axis=3)
        return (m, l, acc), None

    init = (
        jnp.full((b, kvh, rep, n * c), -jnp.inf, jnp.float32),
        jnp.zeros((b, kvh, rep, n * c), jnp.float32),
        jnp.zeros((b, kvh, rep, n * c, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init, (qi_arr, ki_arr), unroll=flags.scan_unroll()
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out[:, :, :, :sq]


def apply_attention_layer(
    p, x, cfg: ModelConfig, *, positions, mode="train", cache=None,
    cache_len=None, kv_chunk=1024, seq_positions=None,
    page_table=None, prior=None, raw_kv=False,
):
    """Full attention sublayer: qkv proj → rope → (cache update) → attention
    → out proj.  Returns (out, new_cache).

    modes: ``train`` (no cache), ``prefill`` (full-seq attention, returns a
    freshly built cache of ``cache_len`` slots), ``decode`` (single token
    against ``cache``).  ``cache``: {"k","v": (B, Sc, KV, Dh), "pos":
    (B, Sc) int32 absolute position per cache slot *per sequence*, −1 =
    empty}.  Decode positions are per-row (``seq_positions`` (B,)), so each
    batch slot may sit at a different depth — the substrate of the serving
    engine's continuous batching.  Sliding-window archs use a ring buffer of
    ``Sc == window`` slots.

    Paged serving variants:

    * decode against a **paged** cache ``{"k_pages","v_pages":
      (P, page, KV, Dh)}`` — ``page_table`` (B, NP) int32 maps each row's
      logical page index to a pool page (idle rows hold 0, the scrap
      page); the page walk and gather happen inside ONE Pallas kernel
      (``repro.kernels.paged_attn``), bitwise-identical to the dense row
      attention above.
    * warm shared-prefix prefill: ``prior`` = {"k","v": (B, Sp, KV, Dh)}
      already-computed prefix K/V — fresh rows (positions offset by Sp at
      the caller) attend over (prior ++ fresh).
    * ``raw_kv=True`` returns the fresh K/V verbatim ({"k","v"}) instead
      of a dense ``_build_cache`` row, so the engine can scatter it into
      pool pages.
    """
    b, s, _ = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)

    # masking / cache-slot positions are SEQUENCE indices; ``positions``
    # feeds rope only (M-RoPE streams differ from sequence order).
    tpos = seq_positions if seq_positions is not None else (
        positions if cfg.mrope_sections is None else positions[0]
    )
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    if mode in ("train", "prefill"):
        pos1d = tpos[0] if tpos.ndim > 1 else tpos
        from repro.dist import sharding as _sh

        mesh = _sh.active_mesh()
        sched = cfg.attention_schedule
        if prior is not None:
            # warm shared-prefix prefill: fresh rows (already offset to
            # positions Sp..Sp+s−1) attend over (cached prefix ++ fresh)
            pk, pv = prior["k"].astype(k.dtype), prior["v"].astype(v.dtype)
            sp = pk.shape[1]
            kvpos = jnp.concatenate(
                [jnp.arange(sp, dtype=jnp.int32), pos1d.astype(jnp.int32)]
            )
            out = attention(
                q, jnp.concatenate([pk, k], axis=1), jnp.concatenate([pv, v], axis=1),
                q_positions=pos1d, kv_positions=kvpos,
                causal=True, window=cfg.sliding_window, kv_chunk=kv_chunk,
            )
        elif (
            sched == "ebv" and mesh is not None and "model" in mesh.axis_names
            and s == k.shape[1] and s % (2 * mesh.shape["model"]) == 0
        ):
            out = ebv_attention_sharded(
                q, k, v, q_positions=pos1d, window=cfg.sliding_window
            )
        else:
            out = attention(
                q, k, v,
                q_positions=pos1d, kv_positions=pos1d,
                causal=True, window=cfg.sliding_window, kv_chunk=kv_chunk,
                schedule="rect" if sched == "ebv" else sched,
            )
        new_cache = None
        if mode == "prefill":
            if raw_kv:
                new_cache = {"k": k, "v": v}
            else:
                new_cache = _build_cache(cfg, k, v, pos1d, cache_len or s)
    elif mode == "decode" and "k_pages" in cache:
        kp, vp = cache["k_pages"], cache["v_pages"]
        page = kp.shape[1]
        np_ = page_table.shape[1]
        cur = (tpos[0] if tpos.ndim > 1 else tpos).astype(jnp.int32)
        cur = jnp.broadcast_to(cur, (b,))
        pidx = jnp.clip(cur // page, 0, np_ - 1)
        pi = jnp.take_along_axis(page_table, pidx[:, None], axis=1)[:, 0]
        # idle rows point at page 0 (scrap); clamp any −1 hole there too so
        # stale writes from retired slots never touch a live page
        pi = jnp.maximum(pi, 0)
        off = cur % page
        kp = kp.at[pi, off].set(k[:, 0].astype(kp.dtype))
        vp = vp.at[pi, off].set(v[:, 0].astype(vp.dtype))
        from repro.kernels.paged_attn import paged_decode_attention

        out = paged_decode_attention(q[:, 0], kp, vp, page_table, cur + 1)[:, None]
        new_cache = {"k_pages": kp, "v_pages": vp}
    elif mode == "decode":
        sc = cache["k"].shape[1]
        # per-row current positions: (B,) — rows advance independently
        cur = (tpos[0] if tpos.ndim > 1 else tpos).astype(jnp.int32)
        cur = jnp.broadcast_to(cur, (b,))
        slot = cur % sc if cfg.sliding_window is not None else cur

        def row_update(ck_r, cv_r, cp_r, k_r, v_r, sl_r, cu_r):
            ck_r = jax.lax.dynamic_update_slice(ck_r, k_r.astype(ck_r.dtype), (sl_r, 0, 0))
            cv_r = jax.lax.dynamic_update_slice(cv_r, v_r.astype(cv_r.dtype), (sl_r, 0, 0))
            cp_r = jax.lax.dynamic_update_slice(cp_r, cu_r[None], (sl_r,))
            return ck_r, cv_r, cp_r

        ck, cv, cpos = jax.vmap(row_update)(
            cache["k"], cache["v"], cache["pos"], k, v, slot, cur
        )

        def row_attn(q_r, k_r, v_r, cu_r, cp_r):
            return attention(
                q_r[None], k_r[None], v_r[None],
                q_positions=jnp.full((s,), cu_r, jnp.int32),
                kv_positions=cp_r,
                causal=True, window=cfg.sliding_window, kv_chunk=max(sc, 1),
            )[0]

        out = jax.vmap(row_attn)(q, ck, cv, cur, cpos)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        raise ValueError(mode)

    return out @ p["wo"], new_cache


def _build_cache(cfg: ModelConfig, k, v, pos1d, cache_len: int):
    """Prefill → decode cache layout (ring buffer for sliding window).

    ``pos`` is materialized per sequence ((B, Sc)) even though prefill
    positions are uniform across the batch: decode advances rows
    independently under continuous batching."""
    b, s = k.shape[0], k.shape[1]
    if cfg.sliding_window is not None:
        w = min(cfg.sliding_window, cache_len)
        if s >= w:
            ck, cv = k[:, s - w :], v[:, s - w :]
            cpos = pos1d[s - w :].astype(jnp.int32)
            # ring layout: slot = pos % w; with w | s the slice is already
            # ring-aligned, otherwise roll into place.
            shift = (s - w) % w
            ck = jnp.roll(ck, shift, axis=1)
            cv = jnp.roll(cv, shift, axis=1)
            cpos = jnp.roll(cpos, shift)
            return {"k": ck, "v": cv, "pos": jnp.broadcast_to(cpos[None], (b, w))}
        pad = w - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cpos = jnp.pad(pos1d.astype(jnp.int32), (0, pad), constant_values=-1)
        return {"k": ck, "v": cv, "pos": jnp.broadcast_to(cpos[None], (b, w))}
    pad = cache_len - s
    ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cpos = jnp.pad(pos1d.astype(jnp.int32), (0, pad), constant_values=-1)
    return {"k": ck, "v": cv, "pos": jnp.broadcast_to(cpos[None], (b, cache_len))}


def apply_cross_attention_layer(p, x, cfg: ModelConfig, *, enc_out=None, cross_kv=None):
    """Encoder-decoder cross attention (no rope, not causal).

    Either ``enc_out`` (B, Se, D) (train/prefill: project K/V here) or
    ``cross_kv`` = (k, v) precomputed (decode).  Returns (out, (k, v)).
    """
    b, s, _ = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    if cross_kv is None:
        se = enc_out.shape[1]
        k = (enc_out @ p["wk"]).reshape(b, se, kv, dh)
        v = (enc_out @ p["wv"]).reshape(b, se, kv, dh)
    else:
        k, v = cross_kv
    kvpos = jnp.zeros((k.shape[1],), jnp.int32)
    out = attention(
        q, k, v,
        q_positions=jnp.zeros((s,), jnp.int32), kv_positions=kvpos,
        causal=False, window=None, kv_chunk=k.shape[1],
    )
    return out @ p["wo"], (k, v)


def init_attention_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype):
    sc = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, sc, kv, dh), dtype),
        "v": jnp.zeros((batch, sc, kv, dh), dtype),
        "pos": jnp.full((batch, sc), -1, jnp.int32),
    }


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = split(key, 3)
    p = {"wd": dense_init(ks[2], (f, d), ("ff", "embed"), dt, scale=f**-0.5)}
    if cfg.mlp_gated:
        p["wg"] = dense_init(ks[0], (d, f), ("embed", "ff"), dt)
        p["wu"] = dense_init(ks[1], (d, f), ("embed", "ff"), dt)
    else:
        p["wu"] = dense_init(ks[1], (d, f), ("embed", "ff"), dt)
    return p


def _activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def apply_mlp(p, x, cfg: ModelConfig):
    if cfg.mlp_gated:
        h = _activation(x @ p["wg"], cfg.mlp_activation) * (x @ p["wu"])
    else:
        h = _activation(x @ p["wu"], cfg.mlp_activation)
    return h @ p["wd"]
