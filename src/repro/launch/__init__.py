"""Launchers: mesh, dry-run, roofline, train, serve.  (dryrun sets XLA
device-count flags at module import — import it only as __main__.)"""
