"""Serving launcher: batched generation with optional multi-device mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
        --devices 8 --mesh 2x4
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    import numpy as np
    from repro.configs.base import get_config
    from repro.dist import sharding as shlib
    from repro.launch.mesh import parse_mesh_arg
    from repro.models import lm
    from repro.serve.engine import Engine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.int32)
    max_len = args.prompt_len + args.new_tokens + cfg.num_prefix_embeds + 8

    if args.mesh:
        mesh = parse_mesh_arg(args.mesh)
        with shlib.use_mesh_rules(mesh):
            eng = Engine(params, cfg, max_len=max_len)
            out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    else:
        eng = Engine(params, cfg, max_len=max_len)
        out = eng.generate(prompts, max_new_tokens=args.new_tokens)

    print(f"generated {out.shape}; sample: {out[0, args.prompt_len:].tolist()}")


if __name__ == "__main__":
    main()
