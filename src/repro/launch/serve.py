"""Serving launcher: continuous-batching generation with optional mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
        --devices 8 --mesh 2x4 --slots 4 --ragged --temperature 0.8 --seed 3
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--batch", type=int, default=4, help="request count")
    ap.add_argument("--slots", type=int, default=4, help="concurrent batch slots")
    ap.add_argument("--bucket", type=int, default=8, help="prompt-length shape bucket")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt/new-token lengths across requests")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help=">0 enables per-slot sampled decoding")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed (request i uses seed+i)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache with shared-prefix reuse")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool size incl. the reserved scrap page "
                         "(0: slots * pages-per-slot + 1)")
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the paged KV pool into this many "
                         "per-shard pools with block slot pinning and "
                         "shard-balanced admission (paged mode)")
    args = ap.parse_args()
    if args.shards > 1 and not args.paged:
        ap.error("--shards requires --paged (per-shard pools shard the page pool)")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import numpy as np
    from repro.configs.base import get_config
    from repro.dist import sharding as shlib
    from repro.launch.mesh import parse_mesh_arg
    from repro.models import lm
    from repro.serve.engine import Engine, GenRequest

    import jax

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.batch):
        s0 = args.prompt_len
        nt = args.new_tokens
        if args.ragged:
            s0 = int(rng.integers(max(args.prompt_len // 4, 1), args.prompt_len + 1))
            nt = int(rng.integers(max(args.new_tokens // 4, 1), args.new_tokens + 1))
        reqs.append(GenRequest(
            tokens=rng.integers(0, cfg.vocab_size, (s0,)).astype(np.int32),
            max_new_tokens=nt, temperature=args.temperature, seed=args.seed + i,
        ))
    max_len = args.prompt_len + args.bucket + args.new_tokens + cfg.num_prefix_embeds + 8

    paged_kw = {}
    if args.paged:
        if args.page_size % args.bucket != 0 and args.bucket > 1:
            ap.error(
                f"--page-size {args.page_size} must be a multiple of "
                f"--bucket {args.bucket}: shared-prefix hits are only "
                "bitwise-exact within one padded length, so page and "
                "bucket boundaries must agree"
            )
        # worst-case pages one request can occupy, from the CLI's own
        # request-shaping knobs — the same arithmetic the engine enforces
        # per request at serve() time
        worst = max_len
        pages_per_req = -(-worst // args.page_size)
        if args.pool_pages:
            if args.shards > 1:
                # per-shard pools each reserve their own scrap page, and a
                # request draws only from its slot's shard
                per = -(-args.pool_pages // args.shards)
                cap = args.shards * ((per - 1) // pages_per_req)
            else:
                cap = (args.pool_pages - 1) // pages_per_req
            if cap < 1:
                ap.error(
                    f"--pool-pages {args.pool_pages} cannot hold even one "
                    f"request (worst case {pages_per_req} pages of "
                    f"{args.page_size}); need >= {pages_per_req + 1}"
                )
            if args.slots > cap:
                ap.error(
                    f"--slots {args.slots} exceeds the pool's worst-case "
                    f"concurrency {cap} ({args.pool_pages - 1} usable pages "
                    f"/ {pages_per_req} pages per request); lower --slots "
                    "or raise --pool-pages"
                )
        paged_kw = dict(paged=True, page_size=args.page_size,
                        pool_pages=args.pool_pages or None,
                        shards=args.shards)

    def serve():
        eng = Engine(params, cfg, max_len=max_len, slots=args.slots,
                     bucket=args.bucket, **paged_kw)
        t0 = time.perf_counter()
        outs = eng.serve(reqs)
        return eng, outs, time.perf_counter() - t0

    if args.mesh:
        mesh = parse_mesh_arg(args.mesh)
        with shlib.use_mesh_rules(mesh):
            eng, outs, dt = serve()
    else:
        eng, outs, dt = serve()

    st = eng.stats
    gen = st.generated_tokens
    print(f"served {len(reqs)} requests ({gen} new tokens) in {dt*1e3:.1f} ms "
          f"({len(reqs)/dt:.1f} req/s, {gen/dt:,.0f} tok/s)")
    print(f"dispatches: {st.prefill_dispatches} prefill + {st.decode_dispatches} decode "
          f"({st.tokens_per_dispatch:.2f} tok/dispatch)")
    print(f"padding waste: {100*st.padding_frac:.1f}% of prompt tokens "
          f"(bucket={args.bucket})")
    if args.paged:
        print(f"page pool: peak {st.pool_peak_pages}/{eng.pool.capacity} pages "
              f"of {eng.page_size} ({st.peak_active} slots at peak); "
              f"page waste {100*st.page_frac:.1f}%")
        if eng.shards > 1:
            peaks = st.shard_peak_cost or [0.0] * eng.shards
            print(f"shards: {eng.shards} per-shard pools, peak cost "
                  + " ".join(f"s{i}={c:.0f}" for i, c in enumerate(peaks)))
        print(f"prefix reuse: {st.prefix_hits} warm admissions, "
              f"{st.prefix_hit_tokens} prompt tokens skipped")
    print(f"sample: {outs[0][len(reqs[0].tokens):].tolist()}")


if __name__ == "__main__":
    main()
