"""Production training launcher: builds the mesh, attaches sharding rules,
and runs the fault-tolerant training loop with sharded params/opt-state.

On this container it runs reduced configs on small host-device meshes
(``--devices N`` sets --xla_force_host_platform_device_count); on a real
TPU cluster the same entrypoint runs under the runtime's process-per-host
launcher with the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --reduced \
        --devices 8 --mesh 2x4 --steps 20
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--reduced", action="store_true", help="CPU-scale config")
    ap.add_argument("--devices", type=int, default=0, help="force host device count")
    ap.add_argument("--mesh", default="", help="e.g. 2x4 → (data=2, model=4); 2x2x2 adds pod")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", choices=["adamw", "ebv"], default="adamw")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax
    from repro.configs.base import get_config
    from repro.dist import sharding as shlib
    from repro.launch.mesh import make_production_mesh, parse_mesh_arg
    from repro.launch import specs as S
    from repro.models import lm
    from repro.train.loop import TrainConfig, train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.replace(**{k: v for k, v in vars(cfg.reduced()).items() if k != "name"})

    mesh = None
    if args.mesh:
        mesh = parse_mesh_arg(args.mesh)
    elif jax.device_count() >= 256:
        mesh = make_production_mesh(multi_pod=jax.device_count() >= 512)

    tc = TrainConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        microbatches=args.microbatches, learning_rate=args.lr,
        warmup_steps=max(args.steps // 10, 2), optimizer=args.optimizer,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )

    if mesh is None:
        train(cfg, tc)
        return

    with shlib.use_mesh_rules(mesh):
        p_axes = lm.param_axes(cfg)
        p_struct = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(tc.seed))
        p_sh = S.shardings_for_args(p_struct, p_axes, mesh)
        params = jax.jit(
            lambda k: lm.init_params(k, cfg), out_shardings=p_sh
        )(jax.random.PRNGKey(tc.seed))
        print(f"[launch] mesh={dict(mesh.shape)} params sharded across {mesh.devices.size} devices")
        train(cfg, tc, params=params)


if __name__ == "__main__":
    main()
