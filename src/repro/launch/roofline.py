"""Roofline analysis (deliverable g): reads the dry-run artifacts and emits
the per-(arch × shape) three-term roofline table.

    compute    = HLO_FLOPs/device  / peak_FLOP/s          (197 TF bf16, v5e)
    memory     = HLO_bytes/device  / HBM_bw               (819 GB/s)
    collective = wire_bytes/device / link_bw              (50 GB/s/link, 1 link
                                                           conservatively)

HLO totals are the scan-unrolled two-point extrapolations recorded by
dryrun.py (exact static counts).  MODEL_FLOPS is the analytic useful work:
6·N·D (train), 2·N·D (prefill), 2·N·B (decode), with N → N_active for MoE.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh single] [--md out.md]
"""
import argparse
import json
import os

from repro.configs.base import ARCH_IDS, SHAPE_CELLS, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def param_counts(cfg):
    """(total, active) parameter counts — analytic, no tracing."""
    import jax
    from repro.models import lm

    struct = jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    total = expert_ffn = 0

    def walk(tree, path=""):
        nonlocal total, expert_ffn
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + "/" + k)
        else:
            total += tree.size
            # expert FFN weights scale by k/E; the router counts fully
            if "/moe/" in path and not path.endswith("/router"):
                expert_ffn += tree.size

    walk(struct)
    if cfg.num_experts:
        active = total - expert_ffn + expert_ffn * cfg.experts_per_token / cfg.num_experts
    else:
        active = total
    return total, active


def model_flops(cfg, cell, total, active):
    d_tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * total * d_tokens if not cfg.num_experts else 6.0 * active * d_tokens
    if cell.kind == "prefill":
        return 2.0 * active * d_tokens
    return 2.0 * active * cell.global_batch  # decode: one token per sequence


def suggest(dominant, rec):
    if dominant == "collective":
        return "cut per-layer SP/FSDP gathers (resharding rules; DP-heavier layout) and overlap with compute"
    if dominant == "memory":
        return "raise arithmetic intensity: larger fused blocks, fewer remat round-trips, bf16 end-to-end"
    return "cut wasted FLOPs: triangular attention schedule, less remat recompute"


def analyze(mesh_name: str, out_dir: str):
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        total, active = param_counts(cfg)
        for cell_name, cell in SHAPE_CELLS.items():
            path = os.path.join(out_dir, mesh_name, f"{arch}__{cell_name}.json")
            if not os.path.exists(path):
                continue
            rec = json.load(open(path))
            if rec["status"] != "ok":
                rows.append({"arch": arch, "cell": cell_name, "status": rec["status"],
                             "reason": rec.get("reason", rec.get("error", ""))[:90]})
                continue
            c = rec["cost"]
            devices = rec["devices"]
            t_comp = c["flops_per_device"] / PEAK_FLOPS_BF16
            t_mem = c["bytes_per_device"] / HBM_BW
            t_coll = c["wire_bytes_per_device"] / ICI_BW
            terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
            dominant = max(terms, key=terms.get)
            mf = model_flops(cfg, cell, total, active)
            hlo_total = c["flops_per_device"] * devices
            useful = mf / hlo_total if hlo_total else 0.0
            # roofline fraction: useful work at peak vs the bound set by the
            # dominant term
            step_time = max(terms.values())
            frac = (mf / devices / PEAK_FLOPS_BF16) / step_time if step_time else 0.0
            rows.append({
                "arch": arch, "cell": cell_name, "status": "ok",
                "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
                "dominant": dominant, "model_flops": mf,
                "useful_ratio": useful, "roofline_frac": frac,
                "peak_gib": rec["memory"]["peak_bytes_est"] / 2**30,
                "suggestion": suggest(dominant, rec),
            })
    return rows


def to_markdown(rows, mesh_name):
    out = [f"### Roofline — {mesh_name} pod mesh (per-device terms, seconds/step)\n"]
    out.append("| arch | cell | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful (model/HLO) | roofline frac | peak GiB/dev | next lever |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['cell']} | — | — | — | {r['status']} | — | — | — | — | {r.get('reason','')} |")
            continue
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | {r['peak_gib']:.1f} | {r['suggestion']} |"
        )
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    ap.add_argument("--json", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    rows = analyze(args.mesh, args.out)
    md = to_markdown(rows, args.mesh)
    print(md)
    if args.json:
        json.dump(rows, open(args.json, "w"), indent=1)
    if args.md:
        open(args.md, "w").write(md)


if __name__ == "__main__":
    main()
