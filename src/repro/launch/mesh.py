"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax initialization).

Topology (TPU v5e target):
  * single pod: (data=16, model=16) — 256 chips;
  * multi-pod:  (pod=2, data=16, model=16) — 512 chips, the ``pod`` axis is
    the cross-DCI data-parallel axis (gradient all-reduce only, optionally
    int8-compressed — ``repro.train.grad_compress``).
"""
from __future__ import annotations

import jax


def _make(shape, axes) -> jax.sharding.Mesh:
    # axis_types only exists on newer jax; Auto is the default there anyway.
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / examples / PP experiments)."""
    return _make(tuple(shape), tuple(axes))


def parse_mesh_arg(arg: str) -> jax.sharding.Mesh:
    """CLI ``--mesh`` spec → mesh: ``8`` → (model,), ``2x4`` →
    (data, model), ``2x2x2`` → (pod, data, model)."""
    dims = tuple(int(x) for x in arg.split("x"))
    names = {1: ("model",), 2: ("data", "model"), 3: ("pod", "data", "model")}.get(len(dims))
    if names is None:
        raise SystemExit(f"--mesh takes 1-3 'x'-separated dims, got {arg!r}")
    return make_mesh(dims, names)


# v5e hardware constants used by the roofline analysis (EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
