"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax initialization).

Topology (TPU v5e target):
  * single pod: (data=16, model=16) — 256 chips;
  * multi-pod:  (pod=2, data=16, model=16) — 512 chips, the ``pod`` axis is
    the cross-DCI data-parallel axis (gradient all-reduce only, optionally
    int8-compressed — ``repro.train.grad_compress``).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh (tests / examples / PP experiments)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


# v5e hardware constants used by the roofline analysis (EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
