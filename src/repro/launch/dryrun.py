import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import/initialization (device count locks on first
#   backend init).  512 host devices back both production meshes.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × shape-cell) and both production meshes, lower +
compile the right step function against ShapeDtypeStruct inputs with full
sharding annotations, then record:

  * ``memory_analysis()``  — per-device bytes (proves it fits a 16 GB v5e);
  * ``cost_analysis()``    — per-device HLO FLOPs / bytes (roofline terms);
  * collective operand bytes parsed from the compiled HLO;
  * the op histogram and compile wall time.

Artifacts: ``artifacts/dryrun/<mesh>/<arch>__<cell>.json`` (cached; --force
re-runs).  EXPERIMENTS.md §Dry-run and §Roofline are generated from these.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch llama3_8b --cell train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh both --all
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPE_CELLS, get_config, cell_applicable
from repro.dist import sharding as shlib
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.utils import flags
from repro.utils.hlo import collective_bytes, cost_analysis_dict, op_histogram

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def _reduced_depth(cfg, layers: int):
    kw = {"num_layers": layers}
    if cfg.family == "encdec":
        kw["encoder_layers"] = layers
    return cfg.replace(**kw)


def _compile_cost(cfg, cell, mesh, rules):
    """Lower+compile with all scans unrolled; exact static cost/collectives."""
    rules = shlib.rules_for(cfg, mesh, rules)
    with shlib.use_mesh_rules(mesh, rules), flags.analysis_unroll():
        fn, args, axes = S.make_cell_fn(cfg, cell)
        in_sh = S.shardings_for_args(args, axes, mesh, rules)
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, num_devices=mesh.devices.size, weighted=True)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "wire": coll["total_wire"],
        "operand": coll["total"],
    }


def extrapolated_cost(cfg, cell, mesh, rules) -> dict:
    """Exact per-device totals for the real depth via two-point linear
    extrapolation over unrolled reduced-depth compiles (scan bodies are
    depth-identical, so cost is affine in L — verified by the two points)."""
    l_real = cfg.num_layers
    l2, l4 = (2, 4) if l_real >= 4 else (1, 2)
    c2 = _compile_cost(_reduced_depth(cfg, l2), cell, mesh, rules)
    c4 = _compile_cost(_reduced_depth(cfg, l4), cell, mesh, rules)
    out = {}
    for k in ("flops", "bytes", "wire", "operand"):
        per_layer = (c4[k] - c2[k]) / (l4 - l2)
        out[k] = c2[k] + per_layer * (l_real - l2)
        out[f"{k}_per_layer"] = per_layer
    out["points"] = {f"L{l2}": c2, f"L{l4}": c4}
    return out


def run_cell(arch: str, cell_name: str, *, multi_pod: bool, out_dir: str, force: bool = False,
             rules: dict | None = None, tag: str = "", overrides: dict | None = None) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch}__{cell_name}{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cell = SHAPE_CELLS[cell_name]
    ok, reason = cell_applicable(cfg, cell)
    record = {
        "arch": arch, "cell": cell_name, "mesh": mesh_name,
        "kind": cell.kind, "seq_len": cell.seq_len, "global_batch": cell.global_batch,
    }
    if not ok:
        record["status"] = "skipped"
        record["reason"] = reason
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    arch_rules = shlib.rules_for(cfg, mesh, rules)
    t0 = time.time()
    try:
        with shlib.use_mesh_rules(mesh, arch_rules):
            fn, args, axes = S.make_cell_fn(cfg, cell)
            in_sh = S.shardings_for_args(args, axes, mesh, arch_rules)
            donate = (0, 1) if cell.kind == "train" else ((1,) if cell.kind == "decode" else ())
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        fallbacks = [list(x) for x in (shlib._CTX.log or [])]
        t1 = time.time()
        extrap = extrapolated_cost(cfg, cell, mesh, rules)
        record.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            analysis_s=round(time.time() - t1, 1),
            devices=int(mesh.devices.size),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_bytes_est": mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            cost={
                # exact per-device totals (scan-unrolled two-point extrapolation)
                "flops_per_device": extrap["flops"],
                "bytes_per_device": extrap["bytes"],
                "wire_bytes_per_device": extrap["wire"],
                "collective_operand_bytes": extrap["operand"],
                "extrapolation": extrap["points"],
                # raw static analysis of the rolled-loop production compile
                # (while bodies counted once — kept for cross-reference)
                "flops_static_raw": cost.get("flops", 0.0),
                "bytes_static_raw": cost.get("bytes accessed", 0.0),
            },
            collectives=collective_bytes(hlo, num_devices=int(mesh.devices.size), weighted=True),
            ops=op_histogram(hlo),
            sharding_fallbacks=fallbacks,
        )
    except Exception as e:  # a failure here is a bug in the system — record it
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--cell", choices=list(SHAPE_CELLS) + ["all"], default="all")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    ap.add_argument("--rules", choices=["default", "zero3"], default="default",
                    help="sharding-rule preset (§Perf comparisons)")
    ap.add_argument("--schedule", choices=["rect", "tri", "ebv"], default=None,
                    help="attention schedule override (§Perf)")
    ap.add_argument("--tag", default="", help="artifact suffix for §Perf variants")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    cells = list(SHAPE_CELLS) if args.cell == "all" else [args.cell]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "multi"]
    rules = shlib.RULE_PRESETS[args.rules]
    overrides = {"attention_schedule": args.schedule} if args.schedule else None

    failures = 0
    for multi in meshes:
        for arch in archs:
            for cell in cells:
                rec = run_cell(arch, cell, multi_pod=multi, out_dir=args.out, force=args.force,
                               rules=rules, tag=args.tag, overrides=overrides)
                name = f"[{rec['mesh']:6s}] {arch:22s} {cell:12s}"
                if rec["status"] == "ok":
                    gb = rec["memory"]["peak_bytes_est"] / 2**30
                    fl = rec["cost"]["flops_per_device"]
                    cb = rec["cost"]["wire_bytes_per_device"] / 2**20
                    print(f"{name} OK   peak={gb:7.2f} GiB/dev  flops/dev={fl:.3e}  wire={cb:9.1f} MiB  "
                          f"compile={rec.get('compile_s', 0):.0f}s", flush=True)
                elif rec["status"] == "skipped":
                    print(f"{name} SKIP ({rec['reason'][:60]})", flush=True)
                else:
                    failures += 1
                    print(f"{name} FAIL {rec['error'][:120]}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
