"""ShapeDtypeStruct input specs + sharding trees for every
(architecture × shape-cell) dry-run function — the shannon/kernels pattern:
weak-type-correct, shardable, zero device allocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist import sharding as sh
from repro.models import lm
from repro.train import optimizer as opt_lib
from repro.train.loop import make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------
def batch_struct(cfg: ModelConfig, cell: ShapeCell):
    b, s = cell.global_batch, cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "vlm":
        p = cfg.num_prefix_embeds
        return {
            "tokens": sds((b, s - p), jnp.int32),
            "prefix_embeds": sds((b, p, cfg.d_model), dt),
        }
    if cfg.family == "encdec":
        return {
            "tokens": sds((b, s), jnp.int32),
            "frames": sds((b, max(s // 4, 1), cfg.d_model), dt),
        }
    return {"tokens": sds((b, s), jnp.int32)}


def batch_axes(cfg: ModelConfig):
    ax = {"tokens": ("act_batch", None)}
    if cfg.family == "vlm":
        ax["prefix_embeds"] = ("act_batch", None, None)
    if cfg.family == "encdec":
        ax["frames"] = ("act_batch", None, None)
    return ax


# ---------------------------------------------------------------------------
# param / optimizer / cache specs
# ---------------------------------------------------------------------------
def params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))


def optimizer_for(cfg: ModelConfig):
    """AdamW; m/v in bf16 for the ≥100B configs so the step state fits v5e
    HBM (EXPERIMENTS.md §Dry-run memory table)."""
    big = cfg.d_model * cfg.d_ff * cfg.num_layers > 5e10  # ≈ >100B params
    return opt_lib.adamw(
        opt_lib.warmup_cosine(3e-4, 2000, 100_000),
        state_dtype=jnp.bfloat16 if big else None,
    )


def opt_axes(cfg: ModelConfig, params_axes):
    return {"step": (), "mu": params_axes, "nu": params_axes}


def caches_struct(cfg: ModelConfig, cell: ShapeCell):
    enc_len = max(cell.seq_len // 4, 1) if cfg.family == "encdec" else 0
    return jax.eval_shape(
        lambda: lm.init_caches(cfg, cell.global_batch, cell.seq_len, enc_len=enc_len)
    )


def caches_axes_tree(cfg: ModelConfig):
    return lm.cache_axes(cfg)


# ---------------------------------------------------------------------------
# step functions per cell kind
# ---------------------------------------------------------------------------
def make_cell_fn(cfg: ModelConfig, cell: ShapeCell, *, kv_chunk: int = 1024):
    """Returns (fn, args_struct, args_axes) for lowering."""
    if cell.kind == "train":
        optimizer = optimizer_for(cfg)
        p_struct = params_struct(cfg)
        n_params = sum(x.size for x in jax.tree.leaves(p_struct))
        # ≥50B-param configs train with gradient-accumulation microbatches
        # (production memory posture; see EXPERIMENTS.md §Dry-run)
        microbatches = 8 if n_params > 2e11 else (4 if n_params > 5e10 else 1)
        step = make_train_step(cfg, optimizer, microbatches=microbatches)
        p_axes = lm.param_axes(cfg)
        o_struct = jax.eval_shape(optimizer.init, p_struct)
        args = (p_struct, o_struct, batch_struct(cfg, cell))
        axes = (p_axes, opt_axes(cfg, p_axes), batch_axes(cfg))
        return step, args, axes

    if cell.kind == "prefill":
        def fn(params, batch):
            return lm.prefill(params, batch, cfg, kv_chunk=kv_chunk)

        args = (params_struct(cfg), batch_struct(cfg, cell))
        axes = (lm.param_axes(cfg), batch_axes(cfg))
        return fn, args, axes

    if cell.kind == "decode":
        def fn(params, caches, tokens, pos):
            return lm.decode_step(params, caches, tokens, pos, cfg)

        args = (
            params_struct(cfg),
            caches_struct(cfg, cell),
            sds((cell.global_batch, 1), jnp.int32),
            sds((), jnp.int32),
        )
        axes = (
            lm.param_axes(cfg),
            caches_axes_tree(cfg),
            ("act_batch", None),
            (),
        )
        return fn, args, axes

    raise ValueError(cell.kind)


def shardings_for_args(args, axes, mesh, rules=None):
    """NamedSharding pytree matching (args, axes)."""
    def is_ax(x):
        return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)

    return jax.tree.map(
        lambda ax, st: jax.sharding.NamedSharding(
            mesh, sh.resolve_spec(st.shape, ax, mesh=mesh, rules=rules)
        ),
        axes, args, is_leaf=is_ax,
    )
