"""Benchmark helpers: timing, CSV emission, baselines.

The paper's "CPU" baseline is a sequential scalar LU; ours is the numpy
rank-1-update loop (single core, no XLA fusion) — the honest host baseline.
The "GPU" analogue on this container is the jit-compiled vectorized EbV
path (XLA CPU): the comparison measures the *vectorization/parallelization*
win, which is the paper's claim; absolute GTX280 numbers are not
reproducible (EXPERIMENTS.md §Paper-validation).
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (blocks on jax arrays).

    Warmup blocks on the whole result pytree — tuple/list results used to
    slip through (``hasattr`` guard was False for containers), letting the
    first timed iter absorb the warmup call's compile+dispatch."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def time_shootout(fns: dict, *args, warmup: int = 1, iters: int = 5) -> dict[str, float]:
    """Median wall seconds per call for several contenders, sampled
    *round-robin* rather than back-to-back.

    Sequential per-impl timing biases whichever contender runs first: on
    this container the host visibly drifts (throttle recovery after a heavy
    preceding section) on the ~100 ms scale, which put a systematic ~5%
    penalty on the first-measured impl.  Interleaving spreads the drift
    evenly across contenders so close races (e.g. the fused LU vs its
    op-identical xla mirror) aren't decided by measurement order."""
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    samples: dict[str, list[float]] = {name: [] for name in fns}
    for _ in range(iters):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples[name].append(time.perf_counter() - t0)
    return {name: float(np.median(ts)) for name, ts in samples.items()}


# ---------------------------------------------------------------------------
# sequential scalar baselines (the paper's "CPU" column)
# ---------------------------------------------------------------------------
def numpy_lu_baseline(a: np.ndarray) -> np.ndarray:
    a = a.copy()
    n = a.shape[0]
    for k in range(n - 1):
        a[k + 1 :, k] /= a[k, k]
        a[k + 1 :, k + 1 :] -= np.outer(a[k + 1 :, k], a[k, k + 1 :])
    return a


def numpy_banded_baseline(arow: np.ndarray, bw: int) -> np.ndarray:
    ap = np.concatenate([arow.copy(), np.zeros((bw, arow.shape[1]), arow.dtype)], 0)
    n = arow.shape[0]
    w = 2 * bw + 1
    for k in range(n - 1):
        pivot = ap[k, bw]
        u_tail = ap[k, bw + 1 :]
        for s in range(1, bw + 1):
            l = ap[k + s, bw - s] / pivot
            ap[k + s, bw - s] = l
            lo = bw + 1 - s
            ap[k + s, lo : lo + bw] -= l * u_tail
    return ap[:n]
