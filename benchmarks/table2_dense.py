"""Paper Table 2: dense LU factorization+solve times and speedup.

Two EbV rows per size: the pure-jnp blocked path (``xla``) and the
single-dispatch fused Pallas megakernel (``pallas_fused``), both against the
sequential numpy rank-1 baseline (the paper's "CPU" column).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocked_lu, lu_solve, make_diagonally_dominant
from repro.kernels import ops as kops
from .common import emit, numpy_lu_baseline, time_call

SIZES = [256, 512, 1024, 2048]
FULL_SIZES = [500, 1000, 2000, 4000, 8000]


def run(full: bool = False, sizes: list[int] | None = None) -> dict[str, float]:
    sizes = sizes if sizes is not None else (FULL_SIZES if full else SIZES)
    rows: dict[str, float] = {}
    for n in sizes:
        a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))

        block = min(256, max(32, n // 8))
        # extra warmup + a wider median than time_call's defaults: these
        # rows feed scripts/check.sh's cross-PR 1.5x gate, and the ~3 ms
        # n=256 calls otherwise swing >2x run-to-run on this host (throttle
        # recovery right after compile)
        ebv = jax.jit(lambda a, b: lu_solve(blocked_lu(a, block=block), b))
        t_ebv = time_call(ebv, a, b, warmup=2, iters=7)

        fused = jax.jit(lambda a, b: kops.lu_solve(kops.lu(a, impl="pallas_fused", block=block), b))
        t_fused = time_call(fused, a, b, warmup=2, iters=7)

        a_np = np.asarray(a, np.float64)
        t_base = time_call(lambda: numpy_lu_baseline(a_np), iters=3 if n <= 512 else 1)

        rows[f"table2_dense_n{n}_ebv"] = t_ebv
        rows[f"table2_dense_n{n}_ebv_fused"] = t_fused
        rows[f"table2_dense_n{n}_baseline"] = t_base
        emit(f"table2_dense_n{n}_ebv", t_ebv, f"speedup={t_base / t_ebv:.1f}")
        emit(f"table2_dense_n{n}_ebv_fused", t_fused, f"speedup={t_base / t_fused:.1f}")
        emit(f"table2_dense_n{n}_baseline", t_base, "")
    return rows


if __name__ == "__main__":
    run()
