"""Benchmark driver — one module per paper table + the framework step bench.

Prints ``name,us_per_call,derived`` CSV (brief contract).  ``--full`` runs
the paper's full matrix sizes (up to 16000); default sizes keep the suite
CPU-friendly.  ``--smoke`` runs a fast CI subset (table2 at n=256, the LU
kernel-impl shootout at n∈{256, 1024}, the banded kernel shootout at the
paper's n=16384 / bw=16, the optimizer trajectory, and the serving rows —
decode host-sync before/after, ragged continuous batching, solve-service
cache speedup, plus the 8-device SPIKE substitution row timed in a
subprocess) and writes ``BENCH_kernels.json`` (name → us_per_call) at
the repo root, seeding the perf trajectory across PRs.  ``--smoke --full``
additionally runs the slow ``rand_lu_n2048_k256`` accuracy-tier rows.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys

SMOKE_LU_SIZES = (256, 1024)
SMOKE_LU_IMPLS = ("pallas_fused", "pallas_blocked", "xla")
SMOKE_BANDED_N = 16384
SMOKE_BANDED_BW = 16
SMOKE_BANDED_IMPLS = ("pallas_blocked", "pallas_tiled", "pallas_scalar")


def _spike_subprocess_row(n: int, bw: int, devices: int) -> float | None:
    """Time the multi-device SPIKE substitution at the paper shape.

    Runs in a child process with its own ``XLA_FLAGS`` because the host
    platform's device count is locked at backend init — forcing
    ``devices`` host devices in *this* process would change the timing
    environment of every single-device row above.  Returns seconds per
    call, or ``None`` when the child fails (row is then omitted and
    scripts/check.sh skips its gate with a note)."""
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = f"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={devices} "
    + os.environ.get("XLA_FLAGS", "")
)
import sys
sys.path.insert(0, {os.path.join(root, "src")!r})
sys.path.insert(0, {root!r})
import jax
from benchmarks.common import time_call
from repro.core.banded import make_banded_dd
from repro.kernels.spike import spike_lu_sharded, spike_solve_sharded
from repro.launch.mesh import make_mesh

mesh = make_mesh(({devices},), ("model",))
arow = make_banded_dd(jax.random.PRNGKey(0), {n}, {bw})
b = jax.random.normal(jax.random.PRNGKey(1), ({n},))
factors = spike_lu_sharded(arow, bw={bw}, mesh=mesh)  # untimed, factor-once
t = time_call(lambda: spike_solve_sharded(factors, b, mesh=mesh), iters=5)
print(f"SPIKE_US={{t * 1e6:.1f}}")
"""
    try:
        out = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True, text=True, timeout=900, check=True,
        )
    except (subprocess.SubprocessError, OSError) as e:
        detail = getattr(e, "stderr", "") or ""
        print(f"banded_solve_n{n}_spike_d{devices}_FAILED,0,"
              f"{type(e).__name__}:{detail.strip().splitlines()[-1:] or ''}",
              file=sys.stderr)
        return None
    for line in out.stdout.splitlines():
        if line.startswith("SPIKE_US="):
            return float(line.split("=", 1)[1]) / 1e6
    print(f"banded_solve_n{n}_spike_d{devices}_FAILED,0,no_marker_in_output",
          file=sys.stderr)
    return None


def smoke(out_path: str | None = None, full: bool = False) -> dict[str, float]:
    """Fast perf smoke: table2 at small size + per-impl LU kernel timings +
    the sparse (banded) trajectory at paper scale.

    Returns (and writes to ``out_path``) ``{name: us_per_call}``.  The
    ``lu_n1024_*`` entries are the tracked fused-vs-blocked wall-time
    comparison; the ``banded_*`` entries track the blocked band megakernel
    against the legacy scalar kernel and the sequential numpy baseline; the
    ``opt_*`` entries track the EbV-preconditioned optimizer's grouped
    batched solves against the per-leaf unrolled jnp reference it replaced.

    Every shootout is also *recorded into the repro.solvers autotune cache*
    (same keys, same harness), so the committed rows and the registry's
    dispatch decisions cannot silently disagree — scripts/check.sh asserts
    the agreement after this runs."""
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.core import make_diagonally_dominant
    from repro.core.banded import make_banded_dd
    from repro.kernels import ops as kops
    from repro.solvers import Problem
    from repro.solvers import cache as scache
    from . import table2_dense
    from .common import emit, numpy_banded_baseline, time_call, time_shootout

    rows_us: dict[str, float] = {}
    tune = scache.get_cache()  # seeded below so BENCH rows and dispatch agree
    for name, secs in table2_dense.run(sizes=[256]).items():
        rows_us[name] = secs * 1e6
    for n in SMOKE_LU_SIZES:
        a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
        # round-robin sampling: close races (fused vs its op-identical xla
        # mirror) must not be decided by measurement order / host drift
        fns = {impl: functools.partial(lambda impl, a: kops.lu(a, impl=impl), impl)
               for impl in SMOKE_LU_IMPLS}
        times = time_shootout(fns, a, iters=15 if n <= 256 else 5)
        tune.record(Problem(op="factor", structure="dense", n=n),
                    {impl: t * 1e6 for impl, t in times.items()})
        for impl, t in times.items():
            rows_us[f"lu_n{n}_{impl}"] = t * 1e6
            emit(f"lu_n{n}_{impl}", t)

    nb, bw = SMOKE_BANDED_N, SMOKE_BANDED_BW
    arow = make_banded_dd(jax.random.PRNGKey(0), nb, bw)
    fns = {impl: functools.partial(lambda impl, a: kops.banded_lu(a, bw=bw, impl=impl), impl)
           for impl in SMOKE_BANDED_IMPLS}
    banded_lu_times = time_shootout(fns, arow, iters=5)
    tune.record(Problem(op="factor", structure="banded", n=nb, bw=bw),
                {impl: t * 1e6 for impl, t in banded_lu_times.items()})
    for impl, t in banded_lu_times.items():
        rows_us[f"banded_lu_n{nb}_{impl}"] = t * 1e6
        emit(f"banded_lu_n{nb}_{impl}", t)
    arow_np = np.asarray(arow, np.float64)
    t = time_call(lambda: numpy_banded_baseline(arow_np, bw), warmup=0, iters=1)
    rows_us[f"banded_lu_n{nb}_numpy"] = t * 1e6
    emit(f"banded_lu_n{nb}_numpy", t)
    # factor ONCE with enrich=True: the diagonal-block inverses are a
    # factor-time cost, so the solve shootout times every impl against the
    # same solve-ready Factorization artifact (pallas/xla_scalar read only
    # its packed factors; pallas_inverted consumes the enrichments)
    lub = kops.banded_lu(arow, bw=bw, enrich=True)
    b = jax.random.normal(jax.random.PRNGKey(1), (nb,))
    fns = {impl: functools.partial(lambda impl, l, r: kops.banded_solve(l, r, bw=bw, impl=impl), impl)
           for impl in ("pallas", "xla_scalar", "pallas_inverted")}
    banded_solve_times = time_shootout(fns, lub, b, iters=5)
    tune.record(Problem(op="solve", structure="banded", n=nb, bw=bw, rhs=1),
                {impl: t * 1e6 for impl, t in banded_solve_times.items()})
    for impl, t in banded_solve_times.items():
        rows_us[f"banded_solve_n{nb}_{impl}"] = t * 1e6
        emit(f"banded_solve_n{nb}_{impl}", t)
    tune.save()  # dispatch decisions now provably follow the committed rows

    # --- multi-device SPIKE split substitution at the same paper shape,
    # timed under 8 forced host devices in a subprocess (see helper).
    # scripts/check.sh gates it <= SPIKE_MAX_RATIO x the best single-device
    # substitution above.
    t = _spike_subprocess_row(nb, bw, devices=8)
    if t is not None:
        rows_us[f"banded_solve_n{nb}_spike_d8"] = t * 1e6
        emit(f"banded_solve_n{nb}_spike_d8", t)

    # --- stacked-RHS dense substitution at transfer scale: one n=4096
    # artifact (factored+enriched once, untimed — the factor-once/solve-many
    # traffic shape) serving 64 coalesced RHS columns through the
    # inverted-diagonal trsm with equalized RHS tiling.  Tracks the wide
    # dispatches the solve service emits after RHS coalescing.
    nt, rt = 4096, 64
    at = make_diagonally_dominant(jax.random.PRNGKey(nt), nt)
    art = kops.lu(at, enrich=True)
    bt = jax.random.normal(jax.random.PRNGKey(2), (nt, rt))
    t = time_call(lambda: kops.lu_solve(art, bt), iters=5)
    rows_us[f"trsm_n{nt}_stacked_r{rt}"] = t * 1e6
    emit(f"trsm_n{nt}_stacked_r{rt}", t)

    # --- optimizer trajectory: the EbV-preconditioned step on a model of
    # (128, 128) parameter factors.  `opt_step_d128_registry` is the full
    # update (grouped batched solves through repro.solvers);
    # `opt_precond_*` isolates the preconditioner solves — registry batched
    # dispatch vs the per-leaf unrolled jnp reference the optimizer ran
    # before the registry rewire.
    from repro.core.blocked import blocked_lu
    from repro.core.solve import lu_solve as core_lu_solve
    from repro.train import optimizer as opt_lib

    d, nleaves = 128, 4
    params = {f"w{i}": 0.02 * jax.random.normal(jax.random.PRNGKey(10 + i), (d, d))
              for i in range(nleaves)}
    grads = {f"w{i}": jax.random.normal(jax.random.PRNGKey(20 + i), (d, d))
             for i in range(nleaves)}
    opt = opt_lib.ebv_preconditioned(opt_lib.constant_lr(1e-3))
    state = opt.init(params)
    step = jax.jit(lambda g, s, p: opt.update(g, s, p)[0])
    t = time_call(step, grads, state, params, iters=5)
    rows_us["opt_step_d128_registry"] = t * 1e6
    emit("opt_step_d128_registry", t)

    a3 = jnp.stack([make_diagonally_dominant(jax.random.PRNGKey(30 + i), d)
                    for i in range(nleaves)])
    r3 = jax.random.normal(jax.random.PRNGKey(40), (nleaves, d, d))
    fns = {
        "batched_registry": jax.jit(lambda a, r: kops.linear_solve(a, r)),
        "unrolled_jnp": jax.jit(lambda a, r: jnp.stack(
            [core_lu_solve(blocked_lu(a[i], block=d), r[i]) for i in range(nleaves)]
        )),
    }
    for impl, t in time_shootout(fns, a3, r3, iters=5).items():
        rows_us[f"opt_precond_b{nleaves}_n{d}_{impl}"] = t * 1e6
        emit(f"opt_precond_b{nleaves}_n{d}_{impl}", t)

    # --- serving trajectory: decode host-sync fix (before/after), ragged
    # continuous-batching throughput, the paged KV cache (capacity ratio +
    # shared-prefix warm/cold, gated in scripts/check.sh), and the solve
    # service's factorization cache (serve_solve_cache_cached must beat
    # _refactor >= 2x; gated in scripts/check.sh).
    from . import serve_bench

    for name, t in serve_bench.run().items():
        # *_capacity rows are dimensionless ratios, not seconds
        rows_us[name] = t if name.endswith("_capacity") else t * 1e6

    # --- accuracy tiers: the approximate backends' wall time AND measured
    # relative residual.  The ``*_residual`` companion rows are what
    # scripts/check.sh gates against the bounds the backends declare
    # (``BF16_IR_RESIDUAL_FLOOR`` / ``RAND_LU_RESIDUAL_BOUND``) — an
    # approximate tier that drifts past its advertised accuracy fails CI,
    # not just a unit test at toy sizes.
    from repro.solvers.backends import RAND_LU_RESIDUAL_BOUND

    n = 1024
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    ir_tol = 1e-5
    bf16_fn = functools.partial(kops.linear_solve, a, b, tolerance=ir_tol, impl="bf16_ir")
    t = time_call(bf16_fn, iters=5)
    x = bf16_fn()
    resid = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    rows_us["lu_n1024_bf16_ir"] = t * 1e6
    emit("lu_n1024_bf16_ir", t)
    rows_us["lu_n1024_bf16_ir_residual"] = resid
    print(f"lu_n1024_bf16_ir_residual,{resid:.3e},relative_residual", flush=True)

    if full:
        # ~2.7 s of the smoke wall clock for a row whose residual contract
        # the chaos drill (scenario 3) already exercises on every check.sh
        # run — so the timing row rides only with ``--smoke --full``.  The
        # residual gate in scripts/check.sh is present-conditional.
        nr, k = 2048, 256
        g1 = jax.random.normal(jax.random.PRNGKey(2), (nr, k))
        g2 = jax.random.normal(jax.random.PRNGKey(3), (k, nr))
        alr = (g1 @ g2) / k  # numerical rank k — the randomized tier's operand class
        xtrue = jax.random.normal(jax.random.PRNGKey(4), (nr,))
        blr = alr @ xtrue  # range-consistent RHS
        rand_fn = functools.partial(
            kops.linear_solve, alr, blr, rank=k, tolerance=RAND_LU_RESIDUAL_BOUND
        )
        t = time_call(rand_fn, iters=3)
        x = rand_fn()
        resid = float(jnp.linalg.norm(alr @ x - blr) / jnp.linalg.norm(blr))
        rows_us[f"rand_lu_n{nr}_k{k}"] = t * 1e6
        emit(f"rand_lu_n{nr}_k{k}", t)
        rows_us[f"rand_lu_n{nr}_k{k}_residual"] = resid
        print(f"rand_lu_n{nr}_k{k}_residual,{resid:.3e},relative_residual", flush=True)

    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_kernels.json")
    with open(out_path, "w") as f:
        # timing rows round to 0.1 µs; residual companion rows are ~1e-6
        # and must survive serialization un-flattened
        json.dump(
            {k: (round(v, 1) if abs(v) >= 1 else v) for k, v in rows_us.items()},
            f, indent=2, sort_keys=True,
        )
        f.write("\n")
    print(f"wrote {out_path}", file=sys.stderr)
    return rows_us


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-size matrices (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset; writes BENCH_kernels.json")
    ap.add_argument(
        "--only", default=None,
        choices=["table1", "table2", "table3", "lm_step"],
    )
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.smoke:
        smoke(full=args.full)
        return

    from . import table1_sparse, table2_dense, table3_transfer, lm_step

    mods = {
        "table1": table1_sparse,
        "table2": table2_dense,
        "table3": table3_transfer,
        "lm_step": lm_step,
    }
    for name, mod in mods.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(full=args.full)
        except Exception as e:  # keep the suite going; a failed table is a bug
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
