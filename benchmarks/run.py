"""Benchmark driver — one module per paper table + the framework step bench.

Prints ``name,us_per_call,derived`` CSV (brief contract).  ``--full`` runs
the paper's full matrix sizes (up to 16000); default sizes keep the suite
CPU-friendly.  ``--smoke`` runs a fast CI subset (table2 at n=256 plus the
LU kernel-impl shootout at n∈{256, 1024}) and writes ``BENCH_kernels.json``
(name → us_per_call) at the repo root, seeding the perf trajectory across
PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

SMOKE_LU_SIZES = (256, 1024)
SMOKE_LU_IMPLS = ("pallas_fused", "pallas_blocked", "xla")


def smoke(out_path: str | None = None) -> dict[str, float]:
    """Fast perf smoke: table2 at small size + per-impl LU kernel timings.

    Returns (and writes to ``out_path``) ``{name: us_per_call}``.  The
    ``lu_n1024_*`` entries are the tracked fused-vs-blocked wall-time
    comparison."""
    import jax

    from repro.core import make_diagonally_dominant
    from repro.kernels import ops as kops
    from . import table2_dense
    from .common import emit, time_call

    rows_us: dict[str, float] = {}
    for name, secs in table2_dense.run(sizes=[256]).items():
        rows_us[name] = secs * 1e6
    for n in SMOKE_LU_SIZES:
        a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
        for impl in SMOKE_LU_IMPLS:
            fn = lambda a: kops.lu(a, impl=impl)
            t = time_call(fn, a, iters=5)
            rows_us[f"lu_n{n}_{impl}"] = t * 1e6
            emit(f"lu_n{n}_{impl}", t)
    if out_path is None:
        out_path = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_kernels.json")
    with open(out_path, "w") as f:
        json.dump({k: round(v, 1) for k, v in rows_us.items()}, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {out_path}", file=sys.stderr)
    return rows_us


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-size matrices (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset; writes BENCH_kernels.json")
    ap.add_argument(
        "--only", default=None,
        choices=["table1", "table2", "table3", "lm_step"],
    )
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.smoke:
        smoke()
        return

    from . import table1_sparse, table2_dense, table3_transfer, lm_step

    mods = {
        "table1": table1_sparse,
        "table2": table2_dense,
        "table3": table3_transfer,
        "lm_step": lm_step,
    }
    for name, mod in mods.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(full=args.full)
        except Exception as e:  # keep the suite going; a failed table is a bug
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
