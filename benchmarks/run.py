"""Benchmark driver — one module per paper table + the framework step bench.

Prints ``name,us_per_call,derived`` CSV (brief contract).  ``--full`` runs
the paper's full matrix sizes (up to 16000); default sizes keep the suite
CPU-friendly.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-size matrices (slow)")
    ap.add_argument(
        "--only", default=None,
        choices=["table1", "table2", "table3", "lm_step"],
    )
    args = ap.parse_args()

    from . import table1_sparse, table2_dense, table3_transfer, lm_step

    print("name,us_per_call,derived")
    mods = {
        "table1": table1_sparse,
        "table2": table2_dense,
        "table3": table3_transfer,
        "lm_step": lm_step,
    }
    for name, mod in mods.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run(full=args.full)
        except Exception as e:  # keep the suite going; a failed table is a bug
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}", file=sys.stderr)
            raise


if __name__ == "__main__":
    main()
