"""Framework-level step benchmark: reduced-config train and decode steps
per architecture family (CPU wall-clock; tok/s derived)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import lm
from .common import emit, time_call

ARCHS = ["llama3_8b", "granite_moe_1b_a400m", "mamba2_1_3b", "hymba_1_5b"]
B, S = 2, 128


def run(full: bool = False):
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        batch = {"tokens": tokens}

        loss_fn = jax.jit(jax.grad(lambda p: lm.train_loss(p, batch, cfg)[0]))
        t_train = time_call(lambda: jax.tree.leaves(loss_fn(params))[0])
        emit(f"lm_step_{arch}_train", t_train, f"tok/s={B * S / t_train:,.0f}")

        caches, _ = jax.jit(lambda p: lm.prefill(p, batch, cfg, cache_len=S + 8))(params)
        dec = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, jnp.asarray(S, jnp.int32), cfg))
        t_dec = time_call(dec, params, caches, tokens[:, :1])
        emit(f"lm_step_{arch}_decode", t_dec, f"tok/s={B / t_dec:,.0f}")


if __name__ == "__main__":
    run()
