"""Serving-path benchmarks: decode host-sync fix, continuous-batching
throughput, and the solve service's factorization-cache speedup.

Rows (all ``us_per_call``):

* ``serve_gen_b4_hostsync`` / ``serve_gen_b4_buffered`` — the same
  prefill+decode workload driven two ways: the legacy loop that called
  ``np.asarray(tok)`` every decode step (blocking the host on every token)
  vs the engine's device-side token buffer with one transfer per request.
* ``serve_ragged_r8_s4`` — 8 ragged requests through the 4-slot
  continuous-batching scheduler (derived column: requests/s, tok/s).
* ``serve_solve_cache_refactor`` / ``serve_solve_cache_cached`` — one
  solve request against a cold vs warm factorization cache; the ratio is
  the factor-once/solve-many win and is gated (>= 2x) by scripts/check.sh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, time_call


def _legacy_hostsync_generate(eng, prompts: np.ndarray, max_new: int) -> np.ndarray:
    """The pre-scheduler decode loop: batched prefill, then lockstep decode
    with a host sync on EVERY token — ``np.asarray(tok)`` inside the loop
    blocks dispatch until the step lands.  Kept as the bench baseline the
    engine's device-side token buffer is measured against."""
    from repro.models import lm

    b, s0 = prompts.shape
    caches, logits = jax.jit(
        lambda p, t: lm.prefill(p, {"tokens": t}, eng.cfg, cache_len=eng.max_len)
    )(eng.params, jnp.asarray(prompts))
    tok = jnp.argmax(logits[:, -1, : eng.cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    out = [prompts]
    pos = jnp.full((b,), s0, jnp.int32)
    for _ in range(max_new - 1):
        out.append(np.asarray(tok))  # <-- the per-token host sync
        caches, logits = eng._decode(eng.params, caches, tok, pos)
        tok = jnp.argmax(logits[:, -1, : eng.cfg.vocab_size], -1).astype(jnp.int32)[:, None]
        pos = pos + 1
    out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)


def run(smoke: bool = True) -> dict[str, float]:
    """Returns {row_name: seconds_per_call} and emits CSV rows."""
    from repro.configs.base import get_config
    from repro.core import make_diagonally_dominant
    from repro.models import lm
    from repro.serve.engine import Engine, GenRequest
    from repro.serve.solve_service import SolveService

    rows: dict[str, float] = {}

    cfg = get_config("llama3_8b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch, s0, new = 4, 16, 24
    eng = Engine(params, cfg, max_len=s0 + new + 8, slots=batch, bucket=4)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (batch, s0)).astype(np.int32)

    t = time_call(lambda: _legacy_hostsync_generate(eng, prompts, new), iters=3)
    rows["serve_gen_b4_hostsync"] = t
    emit("serve_gen_b4_hostsync", t, f"{batch * new / t:.0f}tok/s")
    t = time_call(lambda: eng.generate(prompts, max_new_tokens=new), iters=3)
    rows["serve_gen_b4_buffered"] = t
    emit("serve_gen_b4_buffered", t, f"{batch * new / t:.0f}tok/s")

    rng = np.random.default_rng(1)
    lens = [3, 9, 5, 12, 2, 7, 4, 10]
    news = [9, 2, 5, 3, 11, 4, 6, 2]
    reqs = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                   max_new_tokens=n, seed=i)
        for i, (s, n) in enumerate(zip(lens, news))
    ]
    t = time_call(lambda: eng.serve(reqs), iters=3)
    rows["serve_ragged_r8_s4"] = t
    emit("serve_ragged_r8_s4", t, f"{len(reqs) / t:.1f}req/s;{sum(news) / t:.0f}tok/s")

    n = 1024
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    SolveService().solve(a, b)  # warm: compiles factor+solve once
    # iters higher than the generation rows: these calls are ~10-100x
    # shorter, so the cross-PR perf gate needs a steadier median
    t = time_call(lambda: SolveService().solve(a, b), iters=7)  # cold cache
    rows["serve_solve_cache_refactor"] = t
    emit("serve_solve_cache_refactor", t)
    svc = SolveService()
    svc.solve(a, b)  # prime the cache
    t = time_call(lambda: svc.solve(a, b), iters=7)
    rows["serve_solve_cache_cached"] = t
    emit("serve_solve_cache_cached", t,
         f"{rows['serve_solve_cache_refactor'] / t:.1f}x_vs_refactor")
    return rows
