"""Serving-path benchmarks: decode host-sync fix, continuous-batching
throughput, and the solve service's factorization-cache speedup.

Rows (all ``us_per_call``):

* ``serve_gen_b4_hostsync`` / ``serve_gen_b4_buffered`` — the same
  prefill+decode workload driven two ways: the legacy loop that called
  ``np.asarray(tok)`` every decode step (blocking the host on every token)
  vs the engine's device-side token buffer with one transfer per request.
* ``serve_ragged_r8_s4`` — 8 ragged requests through the 4-slot
  continuous-batching scheduler (derived column: requests/s, tok/s).
* ``serve_solve_cache_refactor`` / ``serve_solve_cache_cached`` — one
  solve request against a cold vs warm factorization cache; the ratio is
  the factor-once/solve-many win and is gated (>= 2x) by scripts/check.sh.
* ``serve_paged_capacity`` — DIMENSIONLESS (not µs): concurrent requests
  the paged engine sustains at the same KV-cache HBM budget as a 4-slot
  dense engine, divided by 4.  Short requests occupy pages, not max_len
  rows, so the ratio is >> 1; gated >= 2x by scripts/check.sh.
* ``serve_sharded_capacity`` — DIMENSIONLESS: the same workload through the
  4-shard paged engine (per-shard pools + slot pinning); partitioning the
  pool must not cost capacity, gated >= 2x by scripts/check.sh.
* ``serve_paged_prefix_cold`` / ``serve_paged_prefix_warm`` — one long
  -prompt request against a cold vs primed shared-prefix cache; warm
  admission maps the cached pages and prefills only the prompt tail.
  Gated (cold/warm >= 3x) by scripts/check.sh.

``python -m benchmarks.serve_bench --chaos`` runs :func:`run_chaos`
instead: a deterministic fault drill (poisoned flush group, crashed
preferred tiers) that asserts the failure-isolation contract end to end
and gates the *escalated*-path residuals against the same bounds
scripts/check.sh holds the default path to.  Chaos rows are printed but
never written to ``BENCH_kernels.json`` — they measure survival, not
speed, and must not participate in the cross-PR perf gate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, time_call


def _legacy_hostsync_generate(eng, prompts: np.ndarray, max_new: int) -> np.ndarray:
    """The pre-scheduler decode loop: batched prefill, then lockstep decode
    with a host sync on EVERY token — ``np.asarray(tok)`` inside the loop
    blocks dispatch until the step lands.  Kept as the bench baseline the
    engine's device-side token buffer is measured against."""
    from repro.models import lm

    b, s0 = prompts.shape
    caches, logits = jax.jit(
        lambda p, t: lm.prefill(p, {"tokens": t}, eng.cfg, cache_len=eng.max_len)
    )(eng.params, jnp.asarray(prompts))
    tok = jnp.argmax(logits[:, -1, : eng.cfg.vocab_size], -1).astype(jnp.int32)[:, None]
    out = [prompts]
    pos = jnp.full((b,), s0, jnp.int32)
    for _ in range(max_new - 1):
        out.append(np.asarray(tok))  # <-- the per-token host sync
        caches, logits = eng._decode(eng.params, caches, tok, pos)
        tok = jnp.argmax(logits[:, -1, : eng.cfg.vocab_size], -1).astype(jnp.int32)[:, None]
        pos = pos + 1
    out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)


def run(smoke: bool = True) -> dict[str, float]:
    """Returns {row_name: seconds_per_call} and emits CSV rows."""
    from repro.configs.base import get_config
    from repro.core import make_diagonally_dominant
    from repro.models import lm
    from repro.serve.engine import Engine, GenRequest
    from repro.serve.solve_service import SolveService

    rows: dict[str, float] = {}

    cfg = get_config("llama3_8b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch, s0, new = 4, 16, 24
    eng = Engine(params, cfg, max_len=s0 + new + 8, slots=batch, bucket=4)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (batch, s0)).astype(np.int32)

    t = time_call(lambda: _legacy_hostsync_generate(eng, prompts, new), iters=3)
    rows["serve_gen_b4_hostsync"] = t
    emit("serve_gen_b4_hostsync", t, f"{batch * new / t:.0f}tok/s")
    t = time_call(lambda: eng.generate(prompts, max_new_tokens=new), iters=3)
    rows["serve_gen_b4_buffered"] = t
    emit("serve_gen_b4_buffered", t, f"{batch * new / t:.0f}tok/s")

    rng = np.random.default_rng(1)
    lens = [3, 9, 5, 12, 2, 7, 4, 10]
    news = [9, 2, 5, 3, 11, 4, 6, 2]
    reqs = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                   max_new_tokens=n, seed=i)
        for i, (s, n) in enumerate(zip(lens, news))
    ]
    t = time_call(lambda: eng.serve(reqs), iters=3)
    rows["serve_ragged_r8_s4"] = t
    emit("serve_ragged_r8_s4", t, f"{len(reqs) / t:.1f}req/s;{sum(news) / t:.0f}tok/s")

    # --- paged KV cache: capacity at equal HBM, and prefix-reuse speedup.
    # Dense baseline: 4 slots x 48-token rows = 192 cache tokens.  Paged at
    # the same budget: 192 tokens = 12 pages of 16 (+1 scrap), and a
    # (12-token prompt, 4 new) request needs ONE page, so 12 run at once.
    dense_slots, dense_len = 4, 48
    pool = dense_slots * dense_len // 16 + 1
    peng = Engine(params, cfg, max_len=16, slots=12, bucket=4,
                  paged=True, page_size=16, pool_pages=pool,
                  prefix_reuse=False)
    short = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32),
                   max_new_tokens=4, seed=i)
        for i in range(12)
    ]
    peng.serve(short)
    ratio = peng.stats.peak_active / dense_slots
    rows["serve_paged_capacity"] = ratio  # dimensionless ratio, NOT seconds
    emit("serve_paged_capacity", ratio / 1e6,  # emit() multiplies by 1e6
         f"{peng.stats.peak_active}req@{pool - 1}pages_vs_{dense_slots}dense")

    # Mesh-sharded layout at the same *allocatable* page budget: the 12
    # usable pages split into 4 per-shard pools of 3 (+1 scrap page per
    # shard instead of one globally), slots pinned block-wise to shards.
    # Capacity must not shrink when the pool is partitioned — the scheduler
    # spreads admissions so no shard's 3 pages become the bottleneck.
    shards = 4
    seng = Engine(params, cfg, max_len=16, slots=12, bucket=4,
                  paged=True, page_size=16, pool_pages=4 * shards,
                  shards=shards, prefix_reuse=False)
    seng.serve(short)
    sratio = seng.stats.peak_active / dense_slots
    rows["serve_sharded_capacity"] = sratio  # dimensionless ratio, NOT seconds
    emit("serve_sharded_capacity", sratio / 1e6,
         f"{seng.stats.peak_active}req@{shards}x3pages_vs_{dense_slots}dense")

    # Long prompt + large pages: the cold admission is dominated by the
    # 1920-token prefill (~130 ms on this container) while the shared step
    # both rows pay — one paged decode dispatch — stays small because
    # page_size=128 keeps the in-kernel page walk at NP=16.  A 384-token
    # prompt at page_size=16 buries the prefill saving under the decode
    # floor and measures ~1x; this shape measures ~6x.
    s_long, pg = 1920, 128
    prompt_long = rng.integers(0, cfg.vocab_size, (s_long,)).astype(np.int32)
    long_req = [GenRequest(tokens=prompt_long, max_new_tokens=1, seed=0)]
    cold_eng = Engine(params, cfg, max_len=1936, slots=1, bucket=16,
                      paged=True, page_size=pg, pool_pages=40)
    warm_eng = Engine(params, cfg, max_len=1936, slots=1, bucket=16,
                      paged=True, page_size=pg, pool_pages=40)
    warm_eng.serve(long_req)  # prime the prefix cache

    def cold():
        cold_eng.prefix_cache.clear()
        return cold_eng.serve(long_req)

    t = time_call(cold, iters=3)
    rows["serve_paged_prefix_cold"] = t
    emit("serve_paged_prefix_cold", t, f"s0={s_long}")
    t = time_call(lambda: warm_eng.serve(long_req), iters=3)
    rows["serve_paged_prefix_warm"] = t
    emit("serve_paged_prefix_warm", t,
         f"{rows['serve_paged_prefix_cold'] / t:.1f}x_vs_cold;"
         f"hit={warm_eng.stats.prefix_hit_tokens}tok")

    n = 1024
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    SolveService().solve(a, b)  # warm: compiles factor+solve once
    # iters higher than the generation rows: these calls are ~10-100x
    # shorter, so the cross-PR perf gate needs a steadier median
    t = time_call(lambda: SolveService().solve(a, b), iters=7)  # cold cache
    rows["serve_solve_cache_refactor"] = t
    emit("serve_solve_cache_refactor", t)
    svc = SolveService()
    svc.solve(a, b)  # prime the cache
    t = time_call(lambda: svc.solve(a, b), iters=7)
    rows["serve_solve_cache_cached"] = t
    emit("serve_solve_cache_cached", t,
         f"{rows['serve_solve_cache_refactor'] / t:.1f}x_vs_refactor")
    return rows


def run_chaos() -> None:
    """Deterministic fault drill for the failure-isolating pipeline.

    Three scenarios, each asserting internally (a broken isolation
    contract fails the process, there is no row to gate):

    1. **Flush isolation** — one NaN-poisoned coalesced group among three
       in a single flush: the poisoned tickets resolve to structured
       :class:`~repro.solvers.SolveFailure` values, the healthy
       flush-mates stay bitwise-identical to an undisturbed service, the
       bad fingerprint is quarantined and never cached.
    2. **bf16_ir escalation residual** — the preferred mixed-precision
       tier crashes (injected) and the funnel serves via ``bf16_ir_xla``;
       the escalated answer must still meet the requested 1e-5 tolerance.
    3. **rand_lu escalation residual** — both bf16 tiers crash on a
       rank-k operand and the funnel bottoms out at the randomized tier;
       the answer must meet ``RAND_LU_RESIDUAL_BOUND``.

    The residual bounds are the same ones scripts/check.sh gates the
    default path's bench rows against — chaos proves the *degraded* path
    honours the tier contract too.
    """
    from repro import solvers
    from repro.core import make_diagonally_dominant, relative_residual
    from repro.kernels import ops as kops
    from repro.serve.solve_service import SolveService, fingerprint
    from repro.solvers.backends import RAND_LU_RESIDUAL_BOUND

    # --- 1. flush isolation: poisoned group among healthy flush-mates
    n1, n2, n3 = 192, 256, 320
    a1 = make_diagonally_dominant(jax.random.PRNGKey(1), n1)
    a2 = make_diagonally_dominant(jax.random.PRNGKey(2), n2).at[0, 0].set(jnp.nan)
    a3 = make_diagonally_dominant(jax.random.PRNGKey(3), n3)
    b1 = jax.random.normal(jax.random.PRNGKey(11), (n1,))
    b2 = jax.random.normal(jax.random.PRNGKey(12), (n2,))
    b3 = jax.random.normal(jax.random.PRNGKey(13), (n3,))

    ref = SolveService()
    ref1, ref3 = ref.solve(a1, b1), ref.solve(a3, b3)

    svc = SolveService()
    t1 = svc.submit(a1, b1)
    t2a, t2b = svc.submit(a2, b2), svc.submit(a2, b2 * 2.0)
    t3 = svc.submit(a3, b3)
    res = svc.flush()
    for t in (t2a, t2b):
        assert isinstance(res[t], solvers.SolveFailure), res[t]
        assert res[t].chain, "SolveFailure carries no escalation chain"
    np.testing.assert_array_equal(np.asarray(res[t1]), np.asarray(ref1))
    np.testing.assert_array_equal(np.asarray(res[t3]), np.asarray(ref3))
    assert fingerprint(a2) not in svc._lru, "unhealthy factor entered the cache"
    assert fingerprint(a2) in svc.quarantined_fingerprints()
    assert svc.stats.failed_requests == 2 and svc.stats.escalations > 0
    solvers.clear_demotions()
    emit("chaos_flush_isolation", 0.0,
         f"ok;failed={svc.stats.failed_requests};"
         f"escalations={svc.stats.escalations}")

    # --- 2. bf16_ir tier crash: bf16_ir_xla must serve within tolerance
    n = 1024
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    b = jax.random.normal(jax.random.PRNGKey(21), (n,))
    tol = 1e-5
    with solvers.record_escalations() as esc:
        with solvers.inject(backend_raises=True, backend="bf16_ir",
                            op="linear_solve"):
            x = kops.linear_solve(a, b, tolerance=tol)
    assert any(e[2] == "bf16_ir_xla" for e in esc), esc
    resid = float(relative_residual(a, b, x))
    assert resid <= tol, (
        f"escalated bf16_ir_xla path residual {resid:.3e} > {tol:.1e}")
    emit("chaos_bf16_ir_escalated_residual", 0.0, f"{resid:.3e}<= {tol:.1e}")

    # --- 3. both bf16 tiers crash on a rank-k operand: rand_lu serves.
    # No rank= here — an explicit rank forces impl="rand_lu" and bypasses
    # the funnel; instead the operand's numerical rank equals the tier's
    # default sketch rank (n // 8) so the auto-escalated path is in-class.
    nr = 1024
    k = nr // 8
    g1 = jax.random.normal(jax.random.PRNGKey(31), (nr, k))
    g2 = jax.random.normal(jax.random.PRNGKey(32), (k, nr))
    alr = (g1 @ g2) / k
    blr = alr @ jax.random.normal(jax.random.PRNGKey(33), (nr,))
    with solvers.record_escalations() as esc:
        with solvers.inject(backend_raises=True, backend="bf16_ir",
                            op="linear_solve"), \
             solvers.inject(backend_raises=True, backend="bf16_ir_xla",
                            op="linear_solve"):
            x = kops.linear_solve(alr, blr, tolerance=RAND_LU_RESIDUAL_BOUND)
    assert any(e[2] == "rand_lu" for e in esc), esc
    resid = float(jnp.linalg.norm(alr @ x - blr) / jnp.linalg.norm(blr))
    assert resid <= RAND_LU_RESIDUAL_BOUND, (
        f"escalated rand_lu path residual {resid:.3e} > "
        f"{RAND_LU_RESIDUAL_BOUND:.1e}")
    emit("chaos_rand_lu_escalated_residual", 0.0,
         f"{resid:.3e}<= {RAND_LU_RESIDUAL_BOUND:.1e}")
    print("chaos drill passed: isolation + escalated-path residual gates",
          flush=True)


if __name__ == "__main__":
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--chaos", action="store_true",
                        help="run the fault drill instead of the timing rows")
    if parser.parse_args().chaos:
        run_chaos()
    else:
        run()
