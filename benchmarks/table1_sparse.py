"""Paper Table 1: sparse (banded CFD-style) LU factorization+solve times and
vectorized-vs-sequential speedup across matrix sizes.

Three rows per size: the blocked band Pallas megakernel path
(``ops.banded_lu`` + ``ops.banded_solve``), the scalar-sequential jnp
reference, and the numpy loop baseline (the paper's "CPU" column).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import banded_lu, banded_solve, make_diagonally_dominant, to_banded
from repro.kernels import ops as kops
from .common import emit, numpy_banded_baseline, time_call

SIZES = [500, 1000, 2000, 4000]
FULL_SIZES = SIZES + [8000, 16000]
BW = 5  # CFD 5-point-stencil-like bandwidth


def run(full: bool = False):
    sizes = FULL_SIZES if full else SIZES
    for n in sizes:
        ad = make_diagonally_dominant(jax.random.PRNGKey(n), n, sparse_band=BW)
        arow = to_banded(ad, BW)
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))

        ebv = jax.jit(lambda a, b: banded_solve(banded_lu(a, bw=BW), b, bw=BW))
        t_ebv = time_call(ebv, arow, b)

        kernel = lambda a, b: kops.banded_solve(kops.banded_lu(a, bw=BW), b, bw=BW)
        t_kernel = time_call(kernel, arow, b)

        arow_np = np.asarray(arow, np.float64)
        t_base = time_call(lambda: numpy_banded_baseline(arow_np, BW), iters=1)

        emit(f"table1_sparse_n{n}_ebv", t_ebv, f"speedup={t_base / t_ebv:.1f}")
        emit(f"table1_sparse_n{n}_ebv_blocked", t_kernel, f"speedup={t_base / t_kernel:.1f}")
        emit(f"table1_sparse_n{n}_baseline", t_base, "")


if __name__ == "__main__":
    run()
