"""Paper Table 3: host↔device transfer times for the benchmark matrices."""
from __future__ import annotations

import jax
import numpy as np

from .common import emit, time_call

SIZES = [500, 1000, 2000, 4000]
FULL_SIZES = SIZES + [8000, 16000]


def run(full: bool = False):
    dev = jax.devices()[0]
    for n in FULL_SIZES if full else SIZES:
        host = np.random.default_rng(n).normal(size=(n, n)).astype(np.float32)

        def to_dev():
            return jax.device_put(host, dev).block_until_ready()

        t_to = time_call(to_dev)
        on_dev = jax.device_put(host, dev)

        def from_dev():
            return np.asarray(on_dev)

        t_from = time_call(from_dev)
        emit(f"table3_transfer_n{n}_to_device", t_to, f"GB/s={host.nbytes / t_to / 1e9:.2f}")
        emit(f"table3_transfer_n{n}_from_device", t_from, f"GB/s={host.nbytes / t_from / 1e9:.2f}")


if __name__ == "__main__":
    run()
