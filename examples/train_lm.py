"""End-to-end training driver: train a small LM for a few hundred steps with
checkpoint/resume and the optional EbV-preconditioned optimizer.

    PYTHONPATH=src python examples/train_lm.py --arch llama3_8b --size 20m --steps 200

``--size 100m`` builds a ~100M-parameter model (the brief's e2e target);
``20m``/``tiny`` keep the demo fast on 1 CPU core.  On a TPU mesh the same
driver runs through launch/train.py with the production sharding rules.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.train.loop import TrainConfig, train

SIZES = {
    # d_model, layers, heads, kv, d_ff, vocab  (≈ params with tied dims)
    "tiny": dict(d_model=64, num_layers=2, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16),
    "20m": dict(d_model=320, num_layers=8, num_heads=8, num_kv_heads=4, d_ff=896, vocab_size=8192, head_dim=40),
    "100m": dict(d_model=640, num_layers=12, num_heads=10, num_kv_heads=5, d_ff=1792, vocab_size=16384, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--size", choices=SIZES, default="20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", choices=["adamw", "ebv"], default="adamw")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(dtype="float32", **SIZES[args.size])
    if cfg.num_experts:
        cfg = cfg.replace(num_experts=4, experts_per_token=2)
    if cfg.mrope_sections:
        cfg = cfg.replace(mrope_sections=None)  # text-only demo sizes

    tc = TrainConfig(
        steps=args.steps, seq_len=args.seq_len, global_batch=args.batch,
        learning_rate=args.lr, warmup_steps=max(args.steps // 10, 5),
        optimizer=args.optimizer, ckpt_dir=args.ckpt_dir, log_every=10,
    )
    params, history = train(cfg, tc)
    first = sum(h["loss"] for h in history[:5]) / max(len(history[:5]), 1)
    last = sum(h["loss"] for h in history[-5:]) / max(len(history[-5:]), 1)
    n_params = sum(p.size for p in __import__("jax").tree.leaves(params))
    print(f"\narch={args.arch} size={args.size} params={n_params/1e6:.1f}M")
    print(f"loss: first-5 avg {first:.4f} → last-5 avg {last:.4f}  (Δ {first - last:+.4f})")


if __name__ == "__main__":
    main()
