"""Quickstart: solve a diagonally-dominant dense system with the EbV LU
solver (paper-faithful and blocked paths), validate against jnp.linalg.

    PYTHONPATH=src python examples/quickstart.py [--n 512]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (
    blocked_lu, ebv_lu, linear_solve, lu_solve, make_diagonally_dominant,
    equalized_pairing, pair_lengths,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=512)
    args = ap.parse_args()
    n = args.n

    key = jax.random.PRNGKey(0)
    a = make_diagonally_dominant(key, n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))

    print(f"EbV work units for n=8: {equalized_pairing(8)} lengths={pair_lengths(8)}")
    print(f"(every full pair sums to n — the paper's equalization invariant)\n")

    for name, fn in [
        ("paper-faithful (unblocked bi-vectorized)", lambda: lu_solve(ebv_lu(a), b)),
        ("TPU-adapted (blocked rank-k)", lambda: lu_solve(blocked_lu(a, block=128), b)),
        ("public API linear_solve", lambda: linear_solve(a, b, method="ebv_blocked")),
        ("registry auto (repro.solvers)", lambda: linear_solve(a, b, method="auto")),
        ("jnp.linalg.solve (reference)", lambda: jnp.linalg.solve(a, b)),
    ]:
        jitted = jax.jit(fn)
        x = jitted().block_until_ready()  # compile+run
        t0 = time.perf_counter()
        x = jitted().block_until_ready()
        dt = time.perf_counter() - t0
        res = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
        print(f"{name:42s} {dt * 1e3:8.2f} ms   residual={res:.2e}")


if __name__ == "__main__":
    main()
