"""Batched serving example: prefill + KV-cache decode with the Engine.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral_8x22b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import lm
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_len=args.prompt_len + args.new_tokens + cfg.num_prefix_embeds + 8)

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.int32)

    out = eng.generate(prompts, max_new_tokens=args.new_tokens)  # warm
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"arch={args.arch} (reduced) batch={args.batch}")
    print(f"generated {out.shape} in {dt*1e3:.1f} ms  ({tok_s:,.0f} tok/s decode)")
    print("sample continuation:", out[0, args.prompt_len:].tolist())


if __name__ == "__main__":
    main()
