"""Continuous-batching serving example: ragged requests through the
slot-based engine, plus the factor-once/solve-many solve service.

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral_8x22b \
        --temperature 0.7 --seed 11
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core import make_diagonally_dominant
from repro.models import lm
from repro.serve.engine import Engine, GenRequest
from repro.serve.solve_service import SolveService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--batch", type=int, default=8, help="request count")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--bucket", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache with shared-prefix reuse")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool size incl. scrap (0: derive from slots)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.bucket + args.new_tokens + cfg.num_prefix_embeds + 8
    paged_kw = {}
    if args.paged:
        if args.bucket > 1 and args.page_size % args.bucket != 0:
            ap.error(f"--page-size {args.page_size} must be a multiple of "
                     f"--bucket {args.bucket} for shared-prefix reuse")
        pages_per_req = -(-max_len // args.page_size)
        if args.pool_pages and args.slots > (args.pool_pages - 1) // pages_per_req:
            ap.error(
                f"--slots {args.slots} exceeds what --pool-pages "
                f"{args.pool_pages} can back (worst case {pages_per_req} "
                "pages per request); lower --slots or raise --pool-pages")
        paged_kw = dict(paged=True, page_size=args.page_size,
                        pool_pages=args.pool_pages or None)
    eng = Engine(
        params, cfg, slots=args.slots, bucket=args.bucket, max_len=max_len,
        **paged_kw,
    )

    rng = np.random.default_rng(0)
    reqs = [
        GenRequest(
            tokens=rng.integers(
                0, cfg.vocab_size, (int(rng.integers(4, args.prompt_len + 1)),)
            ).astype(np.int32),
            max_new_tokens=int(rng.integers(4, args.new_tokens + 1)),
            temperature=args.temperature,
            seed=args.seed + i,
        )
        for i in range(args.batch)
    ]

    eng.serve(reqs)  # warm (compiles one prefill per bucket + one decode)
    t0 = time.perf_counter()
    outs = eng.serve(reqs)
    dt = time.perf_counter() - t0
    st = eng.stats
    print(f"arch={args.arch} (reduced) requests={args.batch} slots={args.slots} "
          f"bucket={args.bucket} temperature={args.temperature}")
    print(f"served in {dt*1e3:.1f} ms: {args.batch/dt:.1f} req/s, "
          f"{st.generated_tokens/dt:,.0f} tok/s decode")
    print(f"dispatches: {st.prefill_dispatches} prefill + {st.decode_dispatches} decode "
          f"({st.tokens_per_dispatch:.2f} tok/dispatch); "
          f"padding waste {100*st.padding_frac:.1f}%")
    if args.paged:
        print(f"page pool: peak {st.pool_peak_pages}/{eng.pool.capacity} pages of "
              f"{eng.page_size}; page waste {100*st.page_frac:.1f}%; "
              f"prefix reuse {st.prefix_hits} hits / {st.prefix_hit_tokens} tokens "
              "(second serve is warm)")
    print("sample continuation:", outs[0][len(reqs[0].tokens):].tolist())

    # --- the other serving workload: one matrix, many right-hand sides ---
    n = 512
    a = make_diagonally_dominant(jax.random.PRNGKey(1), n)
    svc = SolveService()
    svc.solve(a, np.asarray(jax.random.normal(jax.random.PRNGKey(2), (n,))))  # warm+factor
    rhs = [np.asarray(jax.random.normal(jax.random.PRNGKey(10 + i), (n,))) for i in range(32)]
    t0 = time.perf_counter()
    tickets = [svc.submit(a, b) for b in rhs]
    svc.flush()
    dt = time.perf_counter() - t0
    sst = svc.stats
    print(f"solve service: {len(tickets)} RHS vs one {n}x{n} matrix in {dt*1e3:.1f} ms — "
          f"hit rate {100*sst.hit_rate:.0f}%, {sst.factor_dispatches} factor + "
          f"{sst.solve_dispatches} solve dispatches")


if __name__ == "__main__":
    main()
