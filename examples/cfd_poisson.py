"""CFD pressure-solve example — the paper authors' own domain.

A 2-D Poisson problem (5-point stencil) on an nx×ny grid is a banded system
with bandwidth nx: exactly the "sparse" matrices of paper Table 1.  Solved
with the banded EbV LU (naturally equalized vectors, DESIGN.md §4) and
validated against a dense solve.

    PYTHONPATH=src python examples/cfd_poisson.py [--nx 24 --ny 24]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import to_banded
from repro.kernels import ops as kops


def poisson_2d(nx, ny):
    """5-point Laplacian (Dirichlet), slightly regularized → diagonally
    dominant, matching the paper's no-pivot contract."""
    n = nx * ny
    a = np.zeros((n, n), np.float32)
    for j in range(ny):
        for i in range(nx):
            p = j * nx + i
            a[p, p] = 4.05
            if i > 0:
                a[p, p - 1] = -1.0
            if i < nx - 1:
                a[p, p + 1] = -1.0
            if j > 0:
                a[p, p - nx] = -1.0
            if j < ny - 1:
                a[p, p + nx] = -1.0
    return jnp.asarray(a)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=24)
    ap.add_argument("--ny", type=int, default=24)
    args = ap.parse_args()
    nx, ny = args.nx, args.ny
    n = nx * ny

    a = poisson_2d(nx, ny)
    # source term: point charge in the middle
    rhs = np.zeros((n,), np.float32)
    rhs[(ny // 2) * nx + nx // 2] = 1.0
    b = jnp.asarray(rhs)

    bw = nx  # stencil bandwidth
    arow = to_banded(a, bw)
    # registry-dispatched factor+solve: the `repro.solvers` auto path picks
    # the measured-best banded backends (blocked Pallas megakernel / jnp
    # sweeps) for this shape; pass impl=... to force one.
    solver = jax.jit(lambda ab, b: kops.banded_linear_solve(ab, b, bw=bw))
    x = solver(arow, b).block_until_ready()
    t0 = time.perf_counter()
    x = solver(arow, b).block_until_ready()
    dt = time.perf_counter() - t0

    res = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    x_ref = jnp.linalg.solve(a, b)
    err = float(jnp.abs(x - x_ref).max())
    print(f"grid {nx}x{ny} (n={n}, bandwidth={bw})")
    print(f"banded EbV solve: {dt * 1e3:.2f} ms   residual={res:.2e}   vs-dense max|Δ|={err:.2e}")
    field = np.asarray(x).reshape(ny, nx)
    print(f"pressure field: min={field.min():.4f} max={field.max():.4f} (peak at source ✓)")
    assert res < 1e-5


if __name__ == "__main__":
    main()
