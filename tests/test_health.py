"""Factor health screening: the bitwise twin contract.

Every Pallas kernel in the repo has a pure-jnp mirror producing
bitwise-identical packed factors, so the :class:`FactorHealth` records
computed from them must be bitwise-identical too — for healthy operands,
exactly singular ones, and near-singular (tiny-pivot) ones alike.  These
tests sweep every kernel/mirror pair (dense fused, banded blocked, batched
grid, the bf16 factor the bf16_ir tier refines from, and the randomized
rank-k tier) across n ∈ {8, 256, 1024} and assert record equality plus the
expected verdict per operand class.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DEFAULT_THRESHOLDS,
    HealthThresholds,
    PivotedFactors,
    factor_health,
    make_banded_dd,
    make_diagonally_dominant,
    pivoted_lu,
    pivoted_solve,
    relative_residual,
    to_banded,
    from_banded,
)
from repro.core import blocked as core_blocked
from repro.core import randomized as core_rand
from repro.kernels import ebv_lu as kfused
from repro.kernels import ops as kops

NS = [8, 256, 1024]
KINDS = ["healthy", "singular", "tiny"]
BW = 2


def dense_operand(n: int, kind: str) -> jax.Array:
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    if kind == "singular":
        return a.at[0, 0].set(0.0)
    if kind == "tiny":
        return a.at[0, 0].set(1e-12)
    return a


def banded_operand(n: int, kind: str) -> jax.Array:
    arow = make_banded_dd(jax.random.PRNGKey(n + 1), n, BW)
    if kind == "singular":
        return arow.at[0, BW].set(0.0)
    if kind == "tiny":
        return arow.at[0, BW].set(1e-12)
    return arow


def assert_identical_records(fa, fb, ref_max, bw=0):
    """The twin contract: same packed factors ⇒ bitwise-same health record
    (every field) and the same verdict."""
    ra = factor_health(fa, ref_max=ref_max, bw=bw)
    rb = factor_health(fb, ref_max=ref_max, bw=bw)
    for field, xa, xb in zip(ra._fields, ra, rb):
        # cast to f32 for the comparison: numpy's NaN-aware equality does
        # not recognise the bfloat16 extension dtype (bf16 → f32 is exact)
        np.testing.assert_array_equal(
            np.asarray(xa, np.float32), np.asarray(xb, np.float32),
            err_msg=f"FactorHealth.{field} differs between kernel and mirror",
        )
    assert ra.verdict() == rb.verdict()
    return ra


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", NS)
def test_dense_twins_identical_records(n, kind):
    a = dense_operand(n, kind)
    ref = jnp.max(jnp.abs(a))
    fa = kops.lu(a, impl="pallas_fused")
    fb = kops.lu(a, impl="xla")
    rec = assert_identical_records(fa, fb, ref)
    assert rec.verdict() == (kind == "healthy")


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", NS)
def test_banded_twins_identical_records(n, kind):
    arow = banded_operand(n, kind)
    ref = jnp.max(jnp.abs(arow))
    fa = kops.banded_lu(arow, bw=BW, impl="pallas_blocked")
    fb = kops.banded_lu(arow, bw=BW, impl="xla")
    rec = assert_identical_records(fa, fb, ref, bw=BW)
    assert rec.verdict() == (kind == "healthy")


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", NS)
def test_batched_twins_identical_records(n, kind):
    # one healthy member + one of the probed class: the batch record must
    # reduce to the worst member, so any poisoned member taints the verdict
    ab = jnp.stack([dense_operand(n, "healthy"), dense_operand(n, kind)])
    ref = jnp.max(jnp.abs(ab))
    fa = kops.lu(ab, impl="pallas")
    fb = kops.lu(ab, impl="xla")
    # the batched grid kernel and the vmapped mirror agree numerically but
    # not bitwise (different reduction order), so the contract here is the
    # verdict, not the raw record bits
    ra = factor_health(fa, ref_max=ref)
    rb = factor_health(fb, ref_max=ref)
    assert ra.verdict() == rb.verdict() == (kind == "healthy")
    if kind == "healthy":
        np.testing.assert_allclose(
            float(ra.min_pivot), float(rb.min_pivot), rtol=1e-5
        )
        np.testing.assert_allclose(float(ra.growth), float(rb.growth), rtol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", NS)
def test_bf16_tier_twins_identical_records(n, kind):
    # the factor the bf16_ir tier refines from: bf16 cast, factored by the
    # fused kernel vs its mirror (use_kernel True/False in the backend)
    a16 = dense_operand(n, kind).astype(jnp.bfloat16)
    ref = jnp.max(jnp.abs(a16)).astype(jnp.float32)
    fa = kfused.lu_fused(a16)
    fb = core_blocked.fused_blocked_lu(a16)
    assert_identical_records(fa, fb, ref)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("n", NS)
def test_rand_lu_tier_twins_identical_records(n, kind):
    a = dense_operand(n, kind)
    rank = max(2, n // 4)
    key = jax.random.PRNGKey(7)
    ref = jnp.max(jnp.abs(a))
    fa = core_rand.randomized_lu(a, rank=rank, key=key, lu_impl=kfused.lu_fused)
    fb = core_rand.randomized_lu(
        a, rank=rank, key=key, lu_impl=core_blocked.fused_blocked_lu
    )
    assert_identical_records(fa, fb, ref)


# ---------------------------------------------------------------------------
# verdict semantics
# ---------------------------------------------------------------------------
def test_thresholds_are_configurable():
    a = dense_operand(256, "healthy")
    _, rec = kops.lu(a, health=True)
    assert rec.verdict(DEFAULT_THRESHOLDS)
    # an absurdly strict pivot floor flips the same record to unhealthy
    assert not rec.verdict(HealthThresholds(min_pivot_ratio=10.0))
    assert not rec.verdict(HealthThresholds(max_growth=1e-6))


def test_nan_record_never_passes():
    a = dense_operand(64, "singular")
    packed = kops.lu(a, impl="xla")
    rec = factor_health(packed, ref_max=jnp.max(jnp.abs(a)))
    assert not rec.verdict()
    # even with finiteness forgiven, the NaN-poisoned growth/pivot fields
    # compare False against any threshold
    assert not rec.verdict(HealthThresholds(require_finite=False))
    assert "non-finite" in rec.report()


def test_pivoted_fallback_solves_what_no_pivot_cannot():
    n = 96
    a = dense_operand(n, "singular")  # a[0,0] == 0: no-pivot LU dies instantly
    b = jax.random.normal(jax.random.PRNGKey(3), (n,))
    f = pivoted_lu(a)
    assert isinstance(f, PivotedFactors)
    rec = factor_health(f, ref_max=jnp.max(jnp.abs(a)))
    assert rec.verdict()
    x = pivoted_solve(f, b)
    assert float(relative_residual(a, b, x)) < 1e-4


def test_relative_residual_banded_matches_dense():
    n = 64
    arow = banded_operand(n, "healthy")
    dense = from_banded(arow)
    b = jax.random.normal(jax.random.PRNGKey(5), (n,))
    x = jax.random.normal(jax.random.PRNGKey(6), (n,))
    rb = float(relative_residual(arow, b, x, bw=BW))
    rd = float(relative_residual(dense, b, x))
    np.testing.assert_allclose(rb, rd, rtol=1e-5)


def test_health_record_travels_with_batched_and_banded_ops():
    arow = banded_operand(128, "healthy")
    fb, rec_b = kops.banded_lu(arow, bw=BW, health=True)
    assert rec_b.verdict()
    np.testing.assert_array_equal(
        np.asarray(fb), np.asarray(kops.banded_lu(arow, bw=BW))
    )
    ab = jnp.stack([dense_operand(64, "healthy"), dense_operand(64, "healthy")])
    fd, rec_d = kops.lu(ab, health=True)
    assert rec_d.verdict()
    np.testing.assert_array_equal(np.asarray(fd), np.asarray(kops.lu(ab)))
