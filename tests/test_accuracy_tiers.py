"""Accuracy-tiered solver stack (ISSUE 6): the tolerance axis through
Problem → registry funnel → approximate backends → optimizer/serve
customers, plus cache-key integrity for both the autotune cache and the
solve service's tiered factorization cache."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_diagonally_dominant
from repro.core.randomized import RankKFactors, randomized_lu, randomized_solve
from repro.kernels import ops
from repro.solvers import Problem, candidates, record_dispatches, select
from repro.solvers import cache as scache
from repro.solvers.backends import (
    BF16_IR_RESIDUAL_FLOOR,
    IR_MAX_ITERS,
    RAND_LU_RESIDUAL_BOUND,
)


@pytest.fixture
def no_cache(monkeypatch, tmp_path):
    """Pin an absent cache file so selection is purely static."""
    monkeypatch.setenv("REPRO_SOLVERS_CACHE", str(tmp_path / "absent.json"))
    scache.invalidate()
    yield
    scache.invalidate()


def _env_cache(monkeypatch, tmp_path, entries):
    path = tmp_path / "cache.json"
    path.write_text(json.dumps({"version": 1, "entries": entries}))
    monkeypatch.setenv("REPRO_SOLVERS_CACHE", str(path))
    scache.invalidate()
    return path


def _dd(n, seed=0):
    return make_diagonally_dominant(jax.random.PRNGKey(seed), n)


def _lowrank(n, k, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = (jax.random.normal(k1, (n, k)) @ jax.random.normal(k2, (k, n))) / k
    xtrue = jax.random.normal(k3, (n,))
    return a, a @ xtrue, xtrue


def _rel_resid(a, x, b):
    return float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))


# ---------------------------------------------------------------------------
# funnel: tolerance gate
# ---------------------------------------------------------------------------
def test_default_tolerance_selects_exact_backends_only(no_cache):
    """tolerance=0.0 (the default) must preserve pre-tolerance selection:
    no approximate backend is even a candidate."""
    for op, structure in [
        ("factor", "dense"),
        ("solve", "dense"),
        ("linear_solve", "dense"),
        ("linear_solve", "batched_dense"),
    ]:
        p = Problem(op=op, structure=structure, n=256,
                    batch=4 if structure.startswith("batched") else 1)
        for b in candidates(p):
            assert b.residual_bound is None, (
                f"approximate backend {b.name} admitted at tolerance=0.0")
    # and the static winners are the historical ones
    assert select(Problem(op="factor", structure="dense", n=256)).name == "pallas_fused"


def test_tolerance_gate_admits_by_declared_bound(no_cache):
    loose = Problem(op="linear_solve", structure="dense", n=256, tolerance=1e-4)
    names = {b.name for b in candidates(loose)}
    assert "bf16_ir" in names and "bf16_ir_xla" in names
    # tighter than any approximate tier's guarantee: back to exact-only
    tight = Problem(op="linear_solve", structure="dense", n=256, tolerance=1e-9)
    for b in candidates(tight):
        assert b.residual_bound is None


def test_default_tolerance_results_bitwise_unchanged(no_cache):
    a, b = _dd(128), jax.random.normal(jax.random.PRNGKey(1), (128,))
    x_default = ops.linear_solve(a, b)
    x_explicit = ops.linear_solve(a, b, tolerance=0.0)
    np.testing.assert_array_equal(np.asarray(x_default), np.asarray(x_explicit))


# ---------------------------------------------------------------------------
# bf16 + iterative refinement
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [256, 1024])
def test_bf16_ir_converges_to_requested_residual(no_cache, n):
    """ISSUE 6 acceptance: bf16 factor + f32 refinement reaches the
    requested f32-level residual within the refinement cap."""
    from repro.core.refine import last_refinement

    a = _dd(n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    tol = 1e-5
    x = ops.linear_solve(a, b, tolerance=tol, impl="bf16_ir")
    jax.block_until_ready(x)
    assert _rel_resid(a, x, b) <= tol
    info = last_refinement()
    assert info["iterations"] is not None and info["iterations"] <= IR_MAX_ITERS


def test_bf16_ir_auto_selected_when_tolerance_permits(no_cache):
    a = _dd(256)
    b = jax.random.normal(jax.random.PRNGKey(1), (256,))
    with record_dispatches() as log:
        x = ops.linear_solve(a, b, tolerance=1e-5)
    names = [name for _, name in log]
    assert any(n.startswith("bf16_ir") for n in names), names
    assert _rel_resid(a, x, b) <= 1e-5


# ---------------------------------------------------------------------------
# randomized rank-k tier
# ---------------------------------------------------------------------------
def test_randomized_lu_factors_and_solve():
    n, k = 192, 24
    a, b, _ = _lowrank(n, k)
    f = randomized_lu(a, rank=k)
    assert isinstance(f, RankKFactors) and f.rank == k
    # near-orthonormal basis (lᵀl ≈ I; the Gram ridge blurs directions at
    # the operand's smallest kept singular value — the residual bound below
    # is the actual contract)
    np.testing.assert_allclose(np.asarray(f.l.T @ f.l), np.eye(k), atol=5e-2)
    x = randomized_solve(f, b)
    assert _rel_resid(a, x, b) <= RAND_LU_RESIDUAL_BOUND


def test_rand_lu_through_public_ops(no_cache):
    n, k = 256, 32
    a, b, _ = _lowrank(n, k)
    # rank= forces the randomized tier end to end
    x = ops.linear_solve(a, b, rank=k, tolerance=RAND_LU_RESIDUAL_BOUND)
    assert _rel_resid(a, x, b) <= RAND_LU_RESIDUAL_BOUND
    # factor/solve split: ops.lu(rank=) returns RankKFactors and
    # ops.lu_solve recognises the factor type
    f = ops.lu(a, rank=k, tolerance=RAND_LU_RESIDUAL_BOUND)
    assert isinstance(f, RankKFactors)
    x2 = ops.lu_solve(f, b, tolerance=RAND_LU_RESIDUAL_BOUND)
    assert _rel_resid(a, x2, b) <= RAND_LU_RESIDUAL_BOUND


# ---------------------------------------------------------------------------
# cache-key integrity (the regression the ISSUE names)
# ---------------------------------------------------------------------------
def test_loose_measured_win_never_serves_tight_problem(monkeypatch, tmp_path):
    """A measured autotune win recorded at a loose tolerance must be
    invisible to a tight/default-tolerance Problem: tolerance is an exact
    key field, and the tolerance gate prunes approximate backends before
    measured selection anyway."""
    entry = {
        "op": "linear_solve", "structure": "dense", "n": 256, "bw": 0,
        "dtype": "float32", "tolerance": 1e-3,
        "times_us": {"bf16_ir": 1.0, "xla": 9e9},
    }
    _env_cache(monkeypatch, tmp_path, [entry])
    try:
        # loose problem: the measured row steers selection
        loose = Problem(op="linear_solve", structure="dense", n=256, tolerance=1e-3)
        assert select(loose).name == "bf16_ir"
        # tight/default problem: measured row must NOT transfer — the cache
        # has nothing for it AND the gate prunes bf16_ir from candidacy, so
        # ops.linear_solve falls back to the exact factor+solve composition
        tight = Problem(op="linear_solve", structure="dense", n=256)
        assert scache.get_cache().lookup(tight) is None
        assert not any(b.name == "bf16_ir" for b in candidates(tight))
        a, b = _dd(256), jax.random.normal(jax.random.PRNGKey(1), (256,))
        with record_dispatches() as log:
            ops.linear_solve(a, b)
        assert [p.op for p, _ in log] == ["factor", "solve"]
        # ...and even a loose row naming an exact backend doesn't leak into
        # a different-dtype problem (dtype is a key field too)
        other_dtype = Problem(op="linear_solve", structure="dense", n=256,
                              dtype="bfloat16", tolerance=1e-3)
        assert scache.get_cache().lookup(other_dtype) is None
    finally:
        scache.invalidate()


def test_pre_tolerance_cache_rows_load_as_exact(monkeypatch, tmp_path):
    """Caches written before the tolerance axis (no tolerance field) must
    deserialize as exact rows, preserving old behaviour."""
    entry = {
        "op": "factor", "structure": "dense", "n": 256, "bw": 0,
        "dtype": "float32", "times_us": {"xla": 1.0, "pallas_fused": 9e9},
    }
    _env_cache(monkeypatch, tmp_path, [entry])
    try:
        assert select(Problem(op="factor", structure="dense", n=256)).name == "xla"
    finally:
        scache.invalidate()


# ---------------------------------------------------------------------------
# serve: tiered factorization cache + coalescing-width cap
# ---------------------------------------------------------------------------
def test_service_tier_never_reverse(no_cache):
    """An approximate-tier cached factor may serve looser requests but
    NEVER a tighter one; a tight factor serves looser requests."""
    from repro.serve.solve_service import SolveService

    n, k = 128, 16
    a, b, _ = _lowrank(n, k, seed=3)
    svc = SolveService()
    svc.solve(a, b, tolerance=RAND_LU_RESIDUAL_BOUND, rank=k)
    fp = next(iter(svc._lru))
    assert sorted(svc._lru[fp]) == [RAND_LU_RESIDUAL_BOUND]
    assert svc.stats.approx_solves >= 1

    # tolerance=0.0 request on the SAME matrix: must miss and refactor exact
    misses = svc.stats.cache_misses
    factors_before = svc.stats.factor_dispatches
    x = svc.solve(a, b)
    assert svc.stats.cache_misses == misses + 1
    assert svc.stats.factor_dispatches > factors_before
    assert sorted(svc._lru[fp]) == [0.0, RAND_LU_RESIDUAL_BOUND]
    assert _rel_resid(a, x, b) <= 1e-4  # exact answer, not the rank-k one

    # loose request now hits — and picks the TIGHTEST eligible tier (0.0)
    hits = svc.stats.cache_hits
    svc.solve(a, b, tolerance=5e-2)
    assert svc.stats.cache_hits == hits + 1


def test_service_rank_request_validates_tolerance(no_cache):
    from repro.serve.solve_service import SolveService

    svc = SolveService()
    a, b, _ = _lowrank(64, 8)
    with pytest.raises(ValueError):
        svc.submit(a, b, rank=8)  # tolerance 0.0 < the rank tier's bound
    with pytest.raises(ValueError):
        svc.submit(a, b, bw=1, rank=8, tolerance=1e-2)  # dense-only


def test_service_tolerance_in_scheduler_bucket(no_cache):
    """Same matrix, different tolerances: separate buckets, separate
    coalescing groups (group tolerance = tightest member's)."""
    from repro.serve.solve_service import SolveService

    n = 64
    a = _dd(n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    svc = SolveService()
    t1 = svc.submit(a, b)
    t2 = svc.submit(a, b, tolerance=1e-2)
    out = svc.flush()
    # exact group factors once; the loose group hits the tier-0 factor
    assert svc.stats.factor_dispatches == 1
    np.testing.assert_allclose(np.asarray(out[t1]), np.asarray(out[t2]), rtol=1e-5)


def test_service_coalescing_width_cap(monkeypatch, tmp_path):
    """A measured width sweep caps stacked-RHS dispatch width; the chunked
    results are bitwise-identical to the uncapped coalesced solve."""
    from repro.serve.solve_service import SolveService

    n = 512
    entry = {
        "op": "solve", "structure": "dense", "n": n, "bw": 0,
        "dtype": "float32", "tolerance": 0.0,
        "times_us": {"xla": 1.0},
        "width_us": {"8": 100.0, "32": 1000.0, "128": 5000.0},
    }
    _env_cache(monkeypatch, tmp_path, [entry])
    try:
        a = _dd(n)
        b = jax.random.normal(jax.random.PRNGKey(1), (n, 20))
        svc = SolveService()
        x = svc.solve(a, b)
        assert svc.stats.width_capped_dispatches == 2  # 20 cols → 8 + 8 + 4
        assert svc.stats.solve_dispatches == 3
        # uncapped reference (empty cache): identical columns
        monkeypatch.setenv("REPRO_SOLVERS_CACHE", str(tmp_path / "absent.json"))
        scache.invalidate()
        svc2 = SolveService()
        x_ref = svc2.solve(a, b)
        assert svc2.stats.width_capped_dispatches == 0
        np.testing.assert_array_equal(np.asarray(x), np.asarray(x_ref))
    finally:
        scache.invalidate()


# ---------------------------------------------------------------------------
# optimizer customer
# ---------------------------------------------------------------------------
def test_optimizer_auto_tolerance_dispatches_approx_tier(no_cache):
    """ISSUE 6 acceptance: a tolerance-carrying optimizer run dispatches at
    least one approximate-tier solve (the EMA noise floor at b2=0.95 admits
    the bf16+IR batched backend)."""
    from repro.train import optimizer as opt_lib

    d, nleaves = 64, 3
    params = {f"w{i}": 0.02 * jax.random.normal(jax.random.PRNGKey(10 + i), (d, d))
              for i in range(nleaves)}
    grads = {f"w{i}": jax.random.normal(jax.random.PRNGKey(20 + i), (d, d))
             for i in range(nleaves)}
    opt = opt_lib.ebv_preconditioned(opt_lib.constant_lr(1e-3), b2=0.95,
                                     solve_tolerance="auto")
    state = opt.init(params)
    with record_dispatches() as log:
        updates, state = opt.update(grads, state, params)
    approx = [name for p, name in log if name.startswith("bf16_ir")]
    assert approx, f"no approximate-tier dispatch in {[(p.op, n) for p, n in log]}"
    for leaf in jax.tree.leaves(updates):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_optimizer_default_stays_exact(no_cache):
    from repro.train import optimizer as opt_lib

    d = 32
    params = {"w": 0.02 * jax.random.normal(jax.random.PRNGKey(0), (d, d))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (d, d))}
    opt = opt_lib.ebv_preconditioned(opt_lib.constant_lr(1e-3))
    state = opt.init(params)
    with record_dispatches() as log:
        opt.update(grads, state, params)
    assert not any(name.startswith("bf16_ir") or name == "rand_lu"
                   for _, name in log)


# ---------------------------------------------------------------------------
# MoE tail-batch routing
# ---------------------------------------------------------------------------
def test_moe_tail_group_routes_like_full(no_cache):
    """A zero-padded underfull tail group must route its real rows exactly
    like a direct dispatch of just those rows: pad tokens consume no
    capacity, contribute nothing, and the aux loss matches."""
    from repro.configs.base import ModelConfig
    from repro.models.moe import _moe_local, init_moe

    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                      num_experts=8, experts_per_token=2, dtype="float32")
    p = {k: v[0] for k, v in init_moe(jax.random.PRNGKey(0), cfg).items()}
    t, r, d = 64, 23, 32
    x_real = jax.random.normal(jax.random.PRNGKey(1), (r, d))
    x_pad = jnp.concatenate([x_real, jnp.zeros((t - r, d))])
    out_direct, aux_direct = _moe_local(p, x_real, cfg)
    out_masked, aux_masked = _moe_local(p, x_pad, cfg, valid_count=jnp.int32(r))
    np.testing.assert_array_equal(np.asarray(out_masked[:r]), np.asarray(out_direct))
    assert float(jnp.max(jnp.abs(out_masked[r:]))) == 0.0
    np.testing.assert_allclose(float(aux_masked), float(aux_direct), rtol=1e-6)
    # full groups: the masked path is bitwise the unmasked body
    o_none, a_none = _moe_local(p, x_pad, cfg)
    o_full, a_full = _moe_local(p, x_pad, cfg, valid_count=jnp.int32(t))
    np.testing.assert_array_equal(np.asarray(o_none), np.asarray(o_full))


def test_moe_grouped_tail_under_jit(no_cache):
    from repro.configs.base import ModelConfig
    from repro.models.moe import _moe_grouped, init_moe

    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      num_experts=4, experts_per_token=2, dtype="float32")
    p = {k: v[0] for k, v in init_moe(jax.random.PRNGKey(0), cfg).items()}
    xt = jax.random.normal(jax.random.PRNGKey(2), (40, 16))
    out, aux = _moe_grouped(p, xt, cfg, group_tokens=16)  # tail group of 8
    out_j, aux_j = jax.jit(
        lambda x: _moe_grouped(p, x, cfg, group_tokens=16))(xt)
    assert out.shape == (40, 16) and np.isfinite(float(aux))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_j))
