"""SPIKE split banded solve (ISSUE 10): capability predicate and degenerate
shapes, devices=1 bitwise collapse onto the local blocked solver, the
shard_map Pallas path's bitwise identity with its pure-jnp mirror under the
8-host-device conftest, registry dispatch (spike vs replicated, escalation
funnel demotion), SpikeFactors substitution through the public ops, and
SolveService mesh routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import solvers
from repro.core import spike as cspike
from repro.core.banded import make_banded_dd
from repro.core.factorization import Factorization
from repro.core.spike import SpikeFactors, spike_supported
from repro.kernels import ops as kops
from repro.kernels import spike as kspike
from repro.launch.mesh import make_mesh
from repro.solvers import Problem, candidates, select
from repro.solvers import cache as scache


@pytest.fixture
def no_cache(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_SOLVERS_CACHE", str(tmp_path / "absent.json"))
    scache.invalidate()
    yield
    scache.invalidate()


@pytest.fixture(scope="module")
def mesh8():
    return make_mesh((8,), ("model",))


def _system(n, bw, rhs=0, seed=0):
    arow = make_banded_dd(jax.random.PRNGKey(seed), n, bw)
    shape = (n,) if rhs == 0 else (n, rhs)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), shape)
    return arow, b


def _local_solve(arow, b, bw):
    return kops.banded_solve(kops.banded_lu(arow, bw=bw), b, bw=bw)


# ---------------------------------------------------------------------------
# capability predicate + degenerate shapes (satellite: degenerate-shape tests)
# ---------------------------------------------------------------------------
def test_spike_supported_predicate():
    assert spike_supported(512, 8, 8)
    assert spike_supported(512, 8, 1)  # d=1: trivially one partition
    # 2*bw must fit the partition: ceil(64/8)=8 rows < 2*16
    assert not spike_supported(64, 16, 8)
    assert spike_supported(64, 4, 8)
    assert not spike_supported(64, 4, 0)  # nonsense device counts
    assert not spike_supported(64, 0, 4)  # pure diagonal: nothing to split
    assert not spike_supported(0, 4, 4)
    # boundary: 2*bw == m exactly is admitted; one row fewer is not
    assert spike_supported(64, 4, 8) and not spike_supported(56, 4, 8)


def test_wide_band_rejected_by_predicate_not_crash(mesh8, no_cache):
    """bw >= n/devices must route to the replicated fallback through the
    registry — never reach the SPIKE partition code."""
    n, bw, d = 64, 16, 8
    p = Problem(op="factor", structure="banded", n=n, bw=bw, devices=d)
    names = [b.name for b in candidates(p)]
    assert "spike" not in names and "replicated" in names
    arow, b = _system(n, bw)
    factors = kops.banded_lu(arow, bw=bw, mesh=mesh8)
    assert isinstance(factors, Factorization)  # replicated == local artifact
    x = kops.banded_solve(factors, b, bw=bw)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(_local_solve(arow, b, bw)))


def test_spike_devices1_collapses_bitwise(no_cache):
    """One partition == the local blocked factor/solve, bit for bit."""
    arow, b = _system(96, 4)
    x = cspike.spike_solve(cspike.spike_lu(arow, bw=4, devices=1), b)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(_local_solve(arow, b, 4)))


def test_spike_nondivisible_n(no_cache):
    """n % devices != 0 pads the last partition; answers stay accurate and
    the factors carry the true n."""
    n, bw, d = 100, 4, 3  # ceil(100/3)=34, last partition ragged
    arow, b = _system(n, bw, rhs=2)
    f = cspike.spike_lu(arow, bw=bw, devices=d)
    assert (f.n, f.devices, f.m) == (n, d, 34)
    x = cspike.spike_solve(f, b)
    assert x.shape == (n, 2)
    ref = _local_solve(arow, b, bw)
    assert float(jnp.max(jnp.abs(x - ref))) < 1e-4


# ---------------------------------------------------------------------------
# kernel path == pure-jnp mirror, bitwise (acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,bw,rhs", [(512, 8, 0), (512, 8, 3), (96, 4, 2)])
def test_spike_sharded_bitwise_vs_mirror(mesh8, no_cache, n, bw, rhs):
    arow, b = _system(n, bw, rhs=rhs)
    fk = kspike.spike_lu_sharded(arow, bw=bw, mesh=mesh8)
    fm = cspike.spike_lu(arow, bw=bw, devices=8)
    for ak, am in zip(jax.tree.leaves(fk), jax.tree.leaves(fm)):
        np.testing.assert_array_equal(np.asarray(ak), np.asarray(am))
    xk = kspike.spike_solve_sharded(fk, b, mesh=mesh8)
    xm = cspike.spike_solve(fm, b)
    np.testing.assert_array_equal(np.asarray(xk), np.asarray(xm))
    # fused linear_solve path too
    xl = kspike.spike_linear_solve_sharded(arow, b, bw=bw, mesh=mesh8)
    np.testing.assert_array_equal(np.asarray(xl), np.asarray(xm))


def test_spike_answer_close_to_local(mesh8, no_cache):
    arow, b = _system(512, 8, rhs=2)
    x = kspike.spike_linear_solve_sharded(arow, b, bw=8, mesh=mesh8)
    ref = _local_solve(arow, b, 8)
    assert float(jnp.max(jnp.abs(x - ref))) < 1e-4


# ---------------------------------------------------------------------------
# public ops + registry dispatch
# ---------------------------------------------------------------------------
def test_ops_mesh_dispatch_returns_spike_factors(mesh8, no_cache):
    arow, b = _system(512, 8, rhs=2)
    f = kops.banded_lu(arow, bw=8, mesh=mesh8)
    assert isinstance(f, SpikeFactors)
    x = kops.banded_solve(f, b, bw=8, mesh=mesh8)
    xm = cspike.spike_solve(cspike.spike_lu(arow, bw=8, devices=8), b)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(xm))
    # meshless substitution on SpikeFactors takes the mirror — same bits
    x2 = kops.banded_solve(f, b, bw=8)
    np.testing.assert_array_equal(np.asarray(x2), np.asarray(xm))


def test_ops_mesh_rejects_single_device_impl(mesh8, no_cache):
    arow, _ = _system(512, 8)
    with pytest.raises(ValueError, match="single-device"):
        kops.banded_lu(arow, bw=8, mesh=mesh8, impl="pallas_blocked")


def test_spike_health_screen_passes_well_conditioned(mesh8, no_cache):
    arow, _ = _system(512, 8)
    f, rec = kops.banded_lu(arow, bw=8, mesh=mesh8, health=True)
    assert isinstance(f, SpikeFactors) and rec.verdict


def test_spike_demotes_to_replicated_via_funnel(mesh8, no_cache):
    """A validator rejecting the SPIKE attempt must escalate to the
    replicated backend (PR-7 funnel), not fail the dispatch."""
    n, bw = 512, 8
    arow, _ = _system(n, bw)
    p = Problem(op="factor", structure="banded", n=n, bw=bw, devices=8)

    def reject_spike(problem, backend, result):
        if backend.name == "spike":
            return ("synthetic reject", None)
        return None

    with solvers.record_escalations() as log:
        factors = solvers.dispatch(p, arow, bw=bw, validate=reject_spike)
    assert [(f, nxt) for _, f, nxt, _ in log] == [("spike", "replicated")]
    assert not isinstance(factors, SpikeFactors)
    # the demotion is remembered for screened dispatches on this shape key
    with solvers.record_escalations() as log2:
        solvers.dispatch(p, arow, bw=bw, validate=reject_spike)
    assert log2 == []
    # ...but is keyed on devices: the single-device candidate set (disjoint
    # backends) is untouched by the mesh demotion
    p1 = Problem(op="factor", structure="banded", n=n, bw=bw)
    assert "spike" not in [b.name for b in candidates(p1)]
    solvers.registry._DEMOTIONS.clear()


# ---------------------------------------------------------------------------
# SolveService mesh routing (tentpole b)
# ---------------------------------------------------------------------------
def test_solve_service_routes_band_spanning_mesh_to_spike(mesh8, no_cache):
    from repro.serve.solve_service import SolveService

    n, bw = 512, 8
    arow, _ = _system(n, bw)
    bs = [jax.random.normal(jax.random.PRNGKey(10 + i), (n, 2)) for i in range(3)]
    svc = SolveService(mesh=mesh8)
    tix = [svc.submit(arow, b, bw=bw) for b in bs]
    out = svc.flush()
    tiers = next(iter(svc._lru.values()))
    assert any(isinstance(v, SpikeFactors) for v in tiers.values())
    assert svc.stats.factor_dispatches == 1  # coalesced: one SPIKE factor
    ref = SolveService()
    for t, b in zip(tix, bs):
        want = ref.solve(arow, b, bw=bw)
        assert float(jnp.max(jnp.abs(out[t] - want))) < 1e-4


def test_solve_service_wide_band_stays_local(mesh8, no_cache):
    from repro.serve.solve_service import SolveService

    n, bw = 64, 16  # 2*bw > ceil(n/8): spike_supported is False
    arow, b = _system(n, bw)
    svc = SolveService(mesh=mesh8)
    x = svc.solve(arow, b, bw=bw)
    tiers = next(iter(svc._lru.values()))
    assert all(not isinstance(v, SpikeFactors) for v in tiers.values())
    want = SolveService().solve(arow, b, bw=bw)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(want))


# ---------------------------------------------------------------------------
# measured selection weighs SPIKE against replication per (n, bw, devices)
# ---------------------------------------------------------------------------
def test_measured_selection_spike_vs_replicated(no_cache):
    from repro.solvers import AutotuneCache

    p = Problem(op="factor", structure="banded", n=512, bw=8, devices=8)
    prefer_spike = AutotuneCache(entries=[{
        "op": "factor", "structure": "banded", "dtype": "float32", "bw": 8,
        "n": 512, "devices": 8, "times_us": {"spike": 10.0, "replicated": 99.0},
    }])
    assert select(p, cache=prefer_spike).name == "spike"
    prefer_repl = AutotuneCache(entries=[{
        "op": "factor", "structure": "banded", "dtype": "float32", "bw": 8,
        "n": 512, "devices": 8, "times_us": {"spike": 99.0, "replicated": 10.0},
    }])
    assert select(p, cache=prefer_repl).name == "replicated"
    # no measurement: static priority prefers the split solve
    assert select(p, cache=AutotuneCache()).name == "spike"
