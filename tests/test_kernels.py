"""Pallas kernel sweeps (interpret mode) vs the numpy oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_diagonally_dominant, to_banded
from repro.kernels import ebv_lu as K
from repro.kernels import ops, ref
from repro.kernels.banded import banded_lu_kernelized
from repro.kernels.trsm import solve_vmem


def _tol(dtype, n):
    return 2e-2 * n if dtype == jnp.bfloat16 else 5e-5 * n


@pytest.mark.parametrize("n", [8, 32, 129, 256])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_lu_vmem_sweep(n, dtype):
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n, dtype=dtype)
    got = np.asarray(K.lu_vmem(a), np.float64)
    want = ref.lu_ref(np.asarray(a, np.float64))
    np.testing.assert_allclose(got, want, atol=_tol(dtype, n))


@pytest.mark.parametrize("m,b", [(32, 8), (64, 64), (96, 32), (128, 16)])
def test_panel_kernel_sweep(m, b):
    p = make_diagonally_dominant(jax.random.PRNGKey(m + b), m)[:, :b]
    # make the top block dominant so the no-pivot contract holds
    p = p.at[:b, :b].set(make_diagonally_dominant(jax.random.PRNGKey(1), b))
    got = np.asarray(K.panel(p))
    want = ref.panel_ref(np.asarray(p))
    np.testing.assert_allclose(got, want, atol=1e-3)


@pytest.mark.parametrize("n,block,ct", [(64, 16, 16), (128, 32, 32), (128, 64, 16), (96, 32, 32)])
def test_pallas_blocked_lu_sweep(n, block, ct):
    a = make_diagonally_dominant(jax.random.PRNGKey(n + block + ct), n)
    got = np.asarray(ops.lu(a, impl="pallas_blocked", block=block, col_tile=ct))
    want = ref.lu_ref(np.asarray(a))
    np.testing.assert_allclose(got, want, atol=5e-3)


@pytest.mark.parametrize("n,rhs", [(32, 1), (64, 8), (128, 32)])
def test_trsm_solve_sweep(n, rhs):
    a = make_diagonally_dominant(jax.random.PRNGKey(n + rhs), n)
    lu = ops.lu(a, impl="pallas_vmem")
    b = jax.random.normal(jax.random.PRNGKey(2), (n, rhs))
    got = np.asarray(solve_vmem(lu, b, rhs_tile=min(8, rhs)))
    want = ref.solve_ref(np.asarray(lu), np.asarray(b))
    np.testing.assert_allclose(got, want, atol=1e-3)
    # end-to-end residual
    res = np.linalg.norm(np.asarray(a, np.float64) @ got - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert res < 1e-4


@pytest.mark.parametrize("n,bw", [(32, 1), (64, 4), (200, 8)])
def test_banded_kernel_sweep(n, bw):
    ad = make_diagonally_dominant(jax.random.PRNGKey(n + bw), n, sparse_band=bw)
    arow = to_banded(ad, bw)
    got = np.asarray(banded_lu_kernelized(arow, bw=bw))
    want = ref.banded_lu_ref(np.asarray(arow), bw)
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("m,b,w,ct", [(64, 16, 48, 16), (128, 32, 96, 32)])
def test_fused_step_kernel(m, b, w, ct):
    key = jax.random.PRNGKey(m + w)
    pan = make_diagonally_dominant(key, m)[:, :b]
    pan = pan.at[:b, :b].set(make_diagonally_dominant(jax.random.PRNGKey(3), b))
    pan = K.panel(pan)
    a_top = jax.random.normal(jax.random.PRNGKey(4), (b, w))
    a_trail = jax.random.normal(jax.random.PRNGKey(5), (m - b, w))
    u12, trail = K.fused_step(pan, a_top, a_trail, col_tile=ct)
    u12_ref, trail_ref = ref.fused_step_ref(np.asarray(pan), np.asarray(a_top), np.asarray(a_trail))
    np.testing.assert_allclose(np.asarray(u12), u12_ref, atol=1e-3)
    np.testing.assert_allclose(np.asarray(trail), trail_ref, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_update_kernel_dtypes(dtype):
    m, b, w = 128, 32, 64
    l21 = jax.random.normal(jax.random.PRNGKey(6), (m, b)).astype(dtype)
    u12 = jax.random.normal(jax.random.PRNGKey(7), (b, w)).astype(dtype)
    a22 = jax.random.normal(jax.random.PRNGKey(8), (m, w)).astype(dtype)
    got = np.asarray(K.update(l21, u12, a22, row_tile=64, col_tile=32), np.float64)
    want = ref.update_ref(l21.astype(jnp.float32), u12.astype(jnp.float32), a22.astype(jnp.float32))
    atol = 0.5 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, atol=atol)


def test_pallas_vs_xla_impls_agree():
    n = 128
    a = make_diagonally_dominant(jax.random.PRNGKey(11), n)
    lu_p = np.asarray(ops.lu(a, impl="pallas_blocked", block=32, col_tile=32))
    lu_x = np.asarray(ops.lu(a, impl="xla", block=32))
    np.testing.assert_allclose(lu_p, lu_x, atol=2e-3)
