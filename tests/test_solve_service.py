"""Solve service tests: factorization cache hit/miss/evict, coalesced
multi-RHS parity, factor-once/solve-many dispatch accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_diagonally_dominant
from repro.core.banded import make_banded_dd
from repro.kernels import ops as kops
from repro.serve.solve_service import SolveService, fingerprint


@pytest.fixture()
def dense_system():
    n = 96
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    bs = [jax.random.normal(jax.random.PRNGKey(100 + i), (n,)) for i in range(8)]
    return a, bs


def test_factor_once_solve_many_coalesced(dense_system):
    """Acceptance: 1 matrix x 64 RHS arriving as separate requests triggers
    exactly one factorization dispatch plus ONE coalesced solve dispatch,
    bitwise-identical per request to per-request solves."""
    a, _ = dense_system
    n = a.shape[0]
    bs = [jax.random.normal(jax.random.PRNGKey(i), (n,)) for i in range(64)]
    svc = SolveService()
    tickets = [svc.submit(a, b) for b in bs]
    assert svc.pending() == 64
    results = svc.flush()
    st = svc.stats
    assert st.factor_dispatches == 1
    assert st.solve_dispatches == 1  # all 64 RHS in one stacked dispatch
    assert st.cache_misses == 1 and st.cache_hits == 63
    assert st.coalesced_requests == 64
    assert st.solved_columns == 64

    factors = kops.lu(a)
    for t, b in zip(tickets, bs):
        ref = kops.lu_solve(factors, b)
        np.testing.assert_array_equal(np.asarray(results[t]), np.asarray(ref))


def test_cache_hit_miss_evict(dense_system):
    a, bs = dense_system
    n = a.shape[0]
    a2 = make_diagonally_dominant(jax.random.PRNGKey(1), n)
    a3 = make_diagonally_dominant(jax.random.PRNGKey(2), n)
    svc = SolveService(cache_entries=2)
    svc.solve(a, bs[0])
    assert (svc.stats.cache_misses, svc.stats.cache_hits) == (1, 0)
    svc.solve(a, bs[1])  # hit
    assert (svc.stats.cache_misses, svc.stats.cache_hits) == (1, 1)
    svc.solve(a2, bs[2])  # miss, cache = {a, a2}
    svc.solve(a3, bs[3])  # miss, evicts a (LRU)
    assert svc.stats.cache_evictions == 1
    svc.solve(a, bs[4])  # miss again: a was evicted
    assert svc.stats.cache_misses == 4
    assert svc.stats.factor_dispatches == 4
    assert svc.stats.hit_rate == pytest.approx(1 / 5)


def test_mixed_matrices_grouped(dense_system):
    """Interleaved requests against two matrices coalesce into one solve
    dispatch per matrix, not per request."""
    a, bs = dense_system
    a2 = make_diagonally_dominant(jax.random.PRNGKey(7), a.shape[0])
    svc = SolveService()
    tickets = [
        svc.submit(a, bs[0]), svc.submit(a2, bs[1]),
        svc.submit(a, bs[2]), svc.submit(a2, bs[3]),
        svc.submit(a, bs[4]),
    ]
    results = svc.flush()
    assert svc.stats.factor_dispatches == 2
    assert svc.stats.solve_dispatches == 2
    f1, f2 = kops.lu(a), kops.lu(a2)
    for t, (m, b) in zip(tickets, [(f1, bs[0]), (f2, bs[1]), (f1, bs[2]), (f2, bs[3]), (f1, bs[4])]):
        np.testing.assert_array_equal(
            np.asarray(results[t]), np.asarray(kops.lu_solve(m, b))
        )


def test_matrix_rhs_requests_coalesce(dense_system):
    """(n, m) block RHS and (n,) vector RHS against one matrix stack into a
    single wide dispatch and split back with original shapes."""
    a, bs = dense_system
    n = a.shape[0]
    blk = jax.random.normal(jax.random.PRNGKey(50), (n, 5))
    svc = SolveService()
    t1 = svc.submit(a, bs[0])
    t2 = svc.submit(a, blk)
    out = svc.flush()
    assert out[t1].shape == (n,)
    assert out[t2].shape == (n, 5)
    assert svc.stats.solve_dispatches == 1
    assert svc.stats.solved_columns == 6
    factors = kops.lu(a)
    np.testing.assert_array_equal(np.asarray(out[t2]), np.asarray(kops.lu_solve(factors, blk)))


def test_banded_service_parity():
    n, bw = 128, 3
    arow = make_banded_dd(jax.random.PRNGKey(3), n, bw)
    bs = [jax.random.normal(jax.random.PRNGKey(200 + i), (n,)) for i in range(6)]
    svc = SolveService()
    tickets = [svc.submit(arow, b, bw=bw) for b in bs]
    results = svc.flush()
    assert svc.stats.factor_dispatches == 1
    assert svc.stats.solve_dispatches == 1
    lub = kops.banded_lu(arow, bw=bw)
    # per-request reference through the SAME multi-RHS-capable backend the
    # coalesced dispatch used (the scalar backend is vector-only and is
    # capability-filtered out of stacked dispatches)
    for t, b in zip(tickets, bs):
        ref = kops.banded_solve(lub, b[:, None], bw=bw)[:, 0]
        np.testing.assert_array_equal(np.asarray(results[t]), np.asarray(ref))


def test_fingerprint_sensitivity():
    a = np.eye(8, dtype=np.float32)
    assert fingerprint(a) == fingerprint(a.copy())
    b = a.copy()
    b[3, 4] = 1e-7
    assert fingerprint(a) != fingerprint(b)
    assert fingerprint(a) != fingerprint(a.astype(np.float64))
    assert fingerprint(a, bw=0) != fingerprint(a, bw=2)


def test_deadline_orders_flush_groups(dense_system):
    """The deadline-bearing matrix group flushes first (EDF over the shared
    scheduler), regardless of submission order."""
    a, bs = dense_system
    a2 = make_diagonally_dominant(jax.random.PRNGKey(9), a.shape[0])
    svc = SolveService()
    svc.submit(a, bs[0])
    svc.submit(a2, bs[1], deadline=1.0)
    order = []
    import repro.solvers as solvers

    hook = solvers.add_dispatch_hook(
        lambda p, be: order.append(p.op) if p.op == "factor" else None
    )
    try:
        fps = []
        orig = svc._factors_for

        def spy(req, tolerance):
            fps.append(req.fp)
            return orig(req, tolerance)

        svc._factors_for = spy
        svc.flush()
    finally:
        solvers.remove_dispatch_hook(hook)
    assert fps[0] == fingerprint(a2)  # deadline group factored first


def test_flush_requeues_unprocessed_on_error(dense_system):
    """An exception while serving one group must not drop the rest of the
    drained batch: unprocessed requests return to the queue and a later
    flush serves them."""
    a, bs = dense_system
    a2 = make_diagonally_dominant(jax.random.PRNGKey(21), a.shape[0])
    a3 = make_diagonally_dominant(jax.random.PRNGKey(22), a.shape[0])
    svc = SolveService()
    t1 = svc.submit(a, bs[0])
    t2 = svc.submit(a2, bs[1])
    t3 = svc.submit(a3, bs[2])
    bad_fp = fingerprint(a2)
    orig = svc._factors_for

    def boom(req, tolerance):
        if req.fp == bad_fp:
            raise RuntimeError("injected factor failure")
        return orig(req, tolerance)

    svc._factors_for = boom
    with pytest.raises(RuntimeError, match="injected factor failure"):
        svc.flush()
    # the failing group AND everything drained after it went back to the queue
    assert svc.pending() == 2
    # the group that completed before the failure stays redeemable
    np.testing.assert_array_equal(
        np.asarray(svc.result(t1)),
        np.asarray(kops.lu_solve(kops.lu(a), bs[0])),
    )
    svc._factors_for = orig
    results = svc.flush()
    assert set(results) == {t2, t3}
    np.testing.assert_array_equal(
        np.asarray(results[t3]), np.asarray(kops.lu_solve(kops.lu(a3), bs[2]))
    )


def test_solve_convenience_retains_other_results(dense_system):
    """solve() drains the whole queue; earlier submissions' answers stay
    redeemable via result() instead of being silently discarded."""
    a, bs = dense_system
    a2 = make_diagonally_dominant(jax.random.PRNGKey(11), a.shape[0])
    svc = SolveService()
    t_early = svc.submit(a, bs[0])
    x2 = svc.solve(a2, bs[1])
    np.testing.assert_array_equal(
        np.asarray(x2), np.asarray(kops.lu_solve(kops.lu(a2), bs[1]))
    )
    x_early = svc.result(t_early)
    np.testing.assert_array_equal(
        np.asarray(x_early), np.asarray(kops.lu_solve(kops.lu(a), bs[0]))
    )
    with pytest.raises(KeyError):
        svc.result(t_early)  # single redemption
