"""Fault injection, the escalation funnel, and serve-layer degradation.

The headline test is the end-to-end isolation proof: one poisoned
coalesced group among several in a single flush resolves to structured
:class:`SolveFailure` values while every healthy ticket's answer stays
bitwise-unchanged, the unhealthy factors never enter the LRU, and a
subsequent identical healthy run escalates zero times (asserted through
the registry's own hooks, never self-reporting).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import solvers
from repro.core import make_diagonally_dominant, relative_residual
from repro.core.pivoted import PivotedFactors
from repro.kernels import ops as kops
from repro.serve import DeadlineMiss, NotFlushed, SolveService, UnknownTicket
from repro.serve.solve_service import fingerprint


@pytest.fixture(autouse=True)
def _clean_demotions():
    solvers.clear_demotions()
    yield
    solvers.clear_demotions()


def dd(n, seed=0):
    return make_diagonally_dominant(jax.random.PRNGKey(seed), n)


def rhs(n, seed=100):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,))


# ---------------------------------------------------------------------------
# fault-plan mechanics
# ---------------------------------------------------------------------------
def test_plan_matching_and_budget():
    plan = solvers.FaultPlan(backend_raises=True, op="factor",
                             backend="pallas_fused", times=1)
    p_factor = solvers.Problem(op="factor", structure="dense", n=8)
    p_solve = solvers.Problem(op="solve", structure="dense", n=8, rhs=1)
    assert plan.matches(p_factor, "pallas_fused")
    assert not plan.matches(p_solve, "pallas_fused")
    assert not plan.matches(p_factor, "xla")
    with pytest.raises(solvers.InjectedFault):
        plan.before_call(p_factor, "pallas_fused")
    assert not plan.matches(p_factor, "pallas_fused")  # budget spent


def test_nan_pivot_poisons_dense_and_banded_factors():
    plan = solvers.FaultPlan(nan_pivot_at=2)
    p = solvers.Problem(op="factor", structure="dense", n=4)
    out = plan.after_call(p, "xla", jnp.ones((4, 4)))
    assert bool(jnp.isnan(out[2, 2])) and int(jnp.isnan(out).sum()) == 1
    pb = solvers.Problem(op="factor", structure="banded", n=6, bw=1)
    outb = plan.after_call(pb, "xla", jnp.ones((6, 3)))
    assert bool(jnp.isnan(outb[2, 1]))
    # solve results and non-array factor records pass through untouched
    assert plan.after_call(p, "xla", PivotedFactors(jnp.ones((2, 2)), jnp.arange(2))) is not None


def test_inject_is_scoped_and_clears_demotions():
    a = dd(48, 1)
    with solvers.inject(backend_raises=True, backend="pallas_fused", op="factor"):
        f = kops.lu(a)
        assert solvers.demotions()  # the injected crash demoted the winner
    assert not solvers.demotions()  # exit wiped the table
    np.testing.assert_array_equal(np.asarray(f), np.asarray(kops.lu(a, impl="xla")))
    # outside the context the default winner is back, bitwise
    with solvers.record_dispatches() as log:
        f2 = kops.lu(a)
    assert log[0][1] == "pallas_fused"
    np.testing.assert_array_equal(
        np.asarray(f2), np.asarray(kops.lu(a, impl="pallas_fused"))
    )


# ---------------------------------------------------------------------------
# escalation funnel
# ---------------------------------------------------------------------------
def test_escalation_chain_and_hooks():
    a = dd(48, 2)
    with solvers.inject(backend_raises=True, backend="pallas_fused", op="factor"):
        with solvers.record_escalations() as esc:
            f = kops.lu(a)
    assert [(e[1], e[2]) for e in esc] == [("pallas_fused", "xla")]
    assert "InjectedFault" in esc[0][3]
    np.testing.assert_array_equal(np.asarray(f), np.asarray(kops.lu(a, impl="xla")))


def test_all_backends_fail_raises_structured_solve_failure():
    a = dd(48, 3)
    with solvers.inject(backend_raises=True, op="factor"):
        with pytest.raises(solvers.SolveFailure) as ei:
            kops.lu(a, health=True)
    failure = ei.value
    assert failure.problem.op == "factor"
    assert len(failure.chain) >= 2  # every capable backend appears once
    assert all("InjectedFault" in c["reason"] for c in failure.chain)


def test_forced_impl_validation_failure_raises_not_escalates():
    a = dd(48, 4).at[0, 0].set(0.0)
    with solvers.record_escalations() as esc:
        with pytest.raises(solvers.SolveFailure) as ei:
            kops.lu(a, impl="xla", health=True)
    assert not esc  # forced impl has no escalation target
    assert ei.value.chain[0]["backend"] == "xla"
    assert ei.value.health is not None and not ei.value.health.verdict()


def test_health_escalation_reaches_pivoted_last_resort():
    n = 64
    a = dd(n, 5).at[0, 0].set(0.0)  # singular for no-pivot LU, fine with pivoting
    b = rhs(n)
    with solvers.record_escalations() as esc:
        f, rec = kops.lu(a, health=True)
    assert isinstance(f, PivotedFactors) and rec.verdict()
    assert esc and esc[-1][2] == "pivoted"
    x = kops.lu_solve(f, b)
    assert float(relative_residual(a, b, x)) < 1e-4


def test_demotion_never_reroutes_default_traffic():
    n = 72
    a = dd(n, 6)
    # what an undisturbed default dispatch picks for this shape (static
    # priority, or a measured-cache transfer — either way, the pre-demotion
    # choice is the reference the demoted dispatch must still make)
    undemoted = solvers.select(
        solvers.Problem(op="factor", structure="dense", n=n)).name
    bad = a.at[0, 0].set(0.0)
    kops.lu(bad, health=True)  # demotes the no-pivot backends for this shape
    assert solvers.demotions()
    with solvers.record_dispatches() as log:
        f = kops.lu(a)  # plain unscreened call, same shape
    assert log[0][1] == undemoted
    np.testing.assert_array_equal(
        np.asarray(f), np.asarray(kops.lu(a, impl=undemoted))
    )


def test_demotion_ttl_expires():
    n = 56
    bad = dd(n, 7).at[0, 0].set(0.0)
    a = dd(n, 7)
    kops.lu(bad, health=True)
    assert solvers.demotions()
    for _ in range(solvers.DEMOTION_TTL):
        kops.lu(a, health=True)  # screened same-shape dispatches age the table
    assert not solvers.demotions()
    with solvers.record_dispatches() as log:
        kops.lu(a, health=True)
    assert log[0][1] == "pallas_fused"  # original winner restored


def test_verify_residual_composed_path_escalates_to_pivoted():
    n = 64
    a = dd(n, 8).at[0, 0].set(0.0)
    b = rhs(n, 108)
    with solvers.record_escalations() as esc:
        x = kops.linear_solve(a, b, verify_residual=True)
    assert ("composed", "pivoted") in [(e[1], e[2]) for e in esc]
    assert float(relative_residual(a, b, x)) <= solvers.VERIFY_RESIDUAL_DEFAULT_BOUND


def test_verify_residual_fused_tier_escalates_between_twins():
    n = 128
    a = dd(n, 9)
    b = rhs(n, 109)
    with solvers.inject(backend_raises=True, backend="bf16_ir", op="linear_solve"):
        with solvers.record_escalations() as esc:
            x = kops.linear_solve(a, b, tolerance=1e-5)
    assert [(e[1], e[2]) for e in esc] == [("bf16_ir", "bf16_ir_xla")]
    assert float(relative_residual(a, b, x)) <= 1e-5


def test_verify_residual_default_path_is_untouched():
    n = 48
    a, b = dd(n, 10), rhs(n, 110)
    ref = kops.linear_solve(a, b)
    np.testing.assert_array_equal(
        np.asarray(kops.linear_solve(a, b, verify_residual=True)), np.asarray(ref)
    )


# ---------------------------------------------------------------------------
# serve-layer degradation
# ---------------------------------------------------------------------------
def test_flush_isolates_poisoned_group_end_to_end():
    """The ISSUE's acceptance proof: 1 poisoned group among 3, in one flush."""
    n1, n2, n3 = 48, 64, 80
    a1, a3 = dd(n1, 11), dd(n3, 13)
    a2 = dd(n2, 12).at[0, 0].set(jnp.nan)  # NaN operand: nothing can factor it
    b1, b2, b3 = rhs(n1, 111), rhs(n2, 112), rhs(n3, 113)

    ref = SolveService()
    ref1, ref3 = ref.solve(a1, b1), ref.solve(a3, b3)

    svc = SolveService()
    t1 = svc.submit(a1, b1)
    t2a = svc.submit(a2, b2)
    t2b = svc.submit(a2, b2 * 2.0)  # same poisoned group, coalesced
    t3 = svc.submit(a3, b3)
    res = svc.flush()

    # poisoned tickets: structured failure VALUES, not NaN arrays/exceptions
    for t in (t2a, t2b):
        assert isinstance(res[t], solvers.SolveFailure)
        assert res[t].chain
    # healthy tickets: bitwise-unchanged answers
    np.testing.assert_array_equal(np.asarray(res[t1]), np.asarray(ref1))
    np.testing.assert_array_equal(np.asarray(res[t3]), np.asarray(ref3))
    # the unhealthy factor never entered the LRU; the fingerprint is quarantined
    assert fingerprint(a2) not in svc._lru
    assert fingerprint(a2) in svc.quarantined_fingerprints()
    assert svc.stats.failed_requests == 2
    assert svc.stats.escalations > 0

    # identical healthy rerun: zero escalations, proven by the registry hook
    solvers.clear_demotions()
    with solvers.record_escalations() as esc:
        t5 = svc.submit(a1, b1)
        res2 = svc.flush()
    assert not esc
    np.testing.assert_array_equal(np.asarray(res2[t5]), np.asarray(ref1))


def test_quarantine_short_circuits_and_expires():
    n = 64
    bad = dd(n, 14).at[0, 0].set(jnp.nan)
    b = rhs(n, 114)
    svc = SolveService(quarantine_ttl=2)
    t = svc.submit(bad, b)
    first = svc.flush()[t]
    assert isinstance(first, solvers.SolveFailure)
    fd = svc.stats.factor_dispatches
    t2 = svc.submit(bad, b)
    again = svc.flush()[t2]
    assert again is first  # the cached failure value, no re-dispatch
    assert svc.stats.factor_dispatches == fd
    assert svc.stats.quarantined == 1
    svc.flush()  # ttl flush 2 of 2
    assert fingerprint(bad) in svc.quarantined_fingerprints()
    svc.flush()  # expired now
    assert fingerprint(bad) not in svc.quarantined_fingerprints()
    solvers.clear_demotions()


def test_deadline_shedding_with_clock():
    now = [0.0]
    svc = SolveService(clock=lambda: now[0])
    a, b = dd(48, 15), rhs(48, 115)
    t_late = svc.submit(a, b, deadline=1.0)
    t_fine = svc.submit(a, b * 2.0, deadline=100.0)
    now[0] = 10.0
    res = svc.flush()
    assert isinstance(res[t_late], DeadlineMiss)
    assert res[t_late].deadline == 1.0 and res[t_late].now == 10.0
    assert not isinstance(res[t_fine], DeadlineMiss)
    assert svc.stats.shed_deadline == 1
    # without a clock, deadlines only order (historical behaviour)
    svc2 = SolveService()
    t = svc2.submit(a, b, deadline=1.0)
    assert not isinstance(svc2.flush()[t], DeadlineMiss)


def test_result_distinguishes_unknown_and_unflushed():
    svc = SolveService()
    a, b = dd(32, 16), rhs(32, 116)
    t = svc.submit(a, b)
    with pytest.raises(NotFlushed):
        svc.result(t)
    svc.flush()
    svc.result(t)
    with pytest.raises(UnknownTicket):
        svc.result(t)  # already redeemed
    with pytest.raises(UnknownTicket):
        svc.result(10_000)  # never issued
    # both are KeyError subclasses (back-compat with existing callers)
    assert issubclass(UnknownTicket, KeyError)
    assert issubclass(NotFlushed, KeyError)


def test_solve_raises_terminal_failure():
    n = 48
    bad = dd(n, 17).at[0, 0].set(jnp.nan)
    svc = SolveService()
    with pytest.raises(solvers.SolveFailure):
        svc.solve(bad, rhs(n, 117))
    solvers.clear_demotions()


def test_slow_dispatch_fault_trips_deadline_on_reflush():
    """A straggler dispatch makes later queued work miss its deadline; the
    next flush sheds it instead of serving a stale answer."""
    import time as _time

    svc = SolveService(clock=_time.monotonic)
    a, b = dd(48, 18), rhs(48, 118)
    with solvers.inject(slow_dispatch_us=50_000, op="factor"):
        t1 = svc.submit(a, b, deadline=_time.monotonic() + 1000.0)
        svc.flush()
    t2 = svc.submit(a, b * 3.0, deadline=_time.monotonic() - 1.0)  # already late
    res = svc.flush()
    assert isinstance(res[t2], DeadlineMiss)
    svc.result(t1)  # the slow-but-served ticket still redeemable


def test_serve_quarantine_on_injected_solve_fault():
    """Faults on the solve op (factor healthy, substitution crashes on every
    backend) also degrade to per-ticket failures + quarantine."""
    n = 96
    a, b = dd(n, 19), rhs(n, 119)
    svc = SolveService()
    with solvers.inject(backend_raises=True, op="solve"):
        t = svc.submit(a, b)
        res = svc.flush()
    assert isinstance(res[t], solvers.SolveFailure)
    assert fingerprint(a) in svc.quarantined_fingerprints()


# ---------------------------------------------------------------------------
# cache hardening (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("payload", ['{"entries": "nope"}', "[1, 2, 3]", "{trunc"])
def test_corrupt_autotune_cache_warns_and_starts_empty(tmp_path, payload):
    p = tmp_path / "cache.json"
    p.write_text(payload)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        cache = solvers.AutotuneCache.load(str(p))
    assert cache.entries == []


def test_missing_cache_stays_silent(tmp_path):
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        cache = solvers.AutotuneCache.load(str(tmp_path / "absent.json"))
    assert cache.entries == []
