"""Single-dispatch fused EbV LU driver: correctness, dispatch-count and
equalized-schedule properties (ISSUE 2 acceptance criteria)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_diagonally_dominant
from repro.core.blocked import blocked_lu, fused_blocked_lu, sub_block_width
from repro.core.ebv import (
    equalized_pairing,
    equalized_tile_schedule,
    pair_lengths,
    reconstruct,
    tile_schedule_work,
)
from repro.kernels import ops, ref
from repro.kernels.ebv_lu import lu_fused
from repro.kernels.trsm import solve_tiled, solve_vmem
from repro.utils.hlo import primitive_count


# ---------------------------------------------------------------------------
# equivalence: fused kernel vs its pure-jnp mirror (bitwise) and oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [64, 257, 1024])
def test_fused_bitwise_identical_to_xla(n):
    """Acceptance: bitwise-identical packed LU vs impl="xla" in interpret
    mode for n in {64, 257, 1024}."""
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    got = np.asarray(ops.lu(a, impl="pallas_fused"))
    want = np.asarray(ops.lu(a, impl="xla"))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize(
    "n,block",
    [
        (40, 64),   # n < block
        (63, 32),   # odd, non-divisible
        (97, 32),   # prime
        (131, 64),  # prime > block
        (257, 64),  # prime, multi-step with padded tail
        (256, 64),  # exact multiple
    ],
)
def test_fused_nondivisible_sweep(n, block):
    a = make_diagonally_dominant(jax.random.PRNGKey(n + block), n)
    got = np.asarray(lu_fused(a, block=block))
    want = np.asarray(fused_blocked_lu(a, block=block))
    np.testing.assert_array_equal(got, want)
    oracle = ref.lu_ref(np.asarray(a, np.float64))
    np.testing.assert_allclose(got, oracle, atol=5e-5 * n)


@pytest.mark.parametrize("n,block", [(96, 32), (200, 64)])
def test_fused_reconstruct(n, block):
    """scipy-style check: L @ U (packed, unit-lower implicit) rebuilds A."""
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    lu = ops.lu(a, impl="pallas_fused", block=block)
    rebuilt = np.asarray(reconstruct(lu), np.float64)
    np.testing.assert_allclose(rebuilt, np.asarray(a, np.float64), atol=1e-3)


def test_fused_legacy_drivers_agree():
    """The legacy multi-launch drivers stay consistent with the fused one to
    factorization tolerance (their rank-1 ordering differs in last bits)."""
    n = 128
    a = make_diagonally_dominant(jax.random.PRNGKey(11), n)
    lu_f = np.asarray(ops.lu(a, impl="pallas_fused", block=32))
    lu_b = np.asarray(ops.lu(a, impl="pallas_blocked", block=32, col_tile=32))
    lu_legacy = np.asarray(blocked_lu(a, block=32))
    np.testing.assert_allclose(lu_f, lu_b, atol=2e-3)
    np.testing.assert_allclose(lu_f, lu_legacy, atol=2e-3)


def test_fused_is_default_impl():
    a = make_diagonally_dominant(jax.random.PRNGKey(3), 96)
    np.testing.assert_array_equal(
        np.asarray(ops.lu(a, block=32)), np.asarray(ops.lu(a, impl="pallas_fused", block=32))
    )


def test_fused_bf16_falls_back():
    a = make_diagonally_dominant(jax.random.PRNGKey(4), 64, dtype=jnp.bfloat16)
    out = ops.lu(a, block=32, col_tile=32)  # must not raise; xla-mirror fallback
    assert out.dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_fused_dtype_fallback_warns_once_and_uses_xla():
    """Regression: non-fp32 input used to drop silently to the ~9x-slower
    pallas_blocked driver; it now warns once (naming the dtype) and falls
    back to the op-identical xla mirror."""
    ops._FUSED_FALLBACK_WARNED.clear()
    n = 72  # unique shape so jit re-traces and the warning path runs
    a = make_diagonally_dominant(jax.random.PRNGKey(14), n, dtype=jnp.bfloat16)
    with pytest.warns(UserWarning, match="float32 only; got bfloat16"):
        got = ops.lu(a, block=32)
    # the fallback is the xla mirror, not the blocked driver
    jaxpr = jax.make_jaxpr(lambda x: ops.lu(x, block=32))(a)
    assert primitive_count(jaxpr, "pallas_call") == 0
    want = np.asarray(ops.lu(a, impl="xla", block=32), np.float32)
    np.testing.assert_array_equal(np.asarray(got, np.float32), want)
    # one-time: a second non-fp32 call does not warn again
    import warnings as _warnings
    with _warnings.catch_warnings(record=True) as rec:
        _warnings.simplefilter("always")
        ops.lu(make_diagonally_dominant(jax.random.PRNGKey(15), 76, dtype=jnp.bfloat16), block=32)
    assert not any("float32 only" in str(w.message) for w in rec)


# ---------------------------------------------------------------------------
# linear_solve impl routing (regression: solve phase used to drop `impl`)
# ---------------------------------------------------------------------------
def test_linear_solve_routes_impl_to_both_phases():
    """linear_solve(impl='xla') used to factor with XLA but silently solve
    with the default Pallas path; now both phases honour it."""
    n = 64
    a = make_diagonally_dominant(jax.random.PRNGKey(16), n)
    b = jax.random.normal(jax.random.PRNGKey(17), (n, 4))
    jaxpr = jax.make_jaxpr(lambda a, b: ops.linear_solve(a, b, impl="xla"))(a, b)
    assert primitive_count(jaxpr, "pallas_call") == 0
    jaxpr_p = jax.make_jaxpr(lambda a, b: ops.linear_solve(a, b, impl="pallas_fused"))(a, b)
    assert primitive_count(jaxpr_p, "pallas_call") == 2  # one factor + one solve


def test_linear_solve_solve_impl_mixing():
    """Deliberate phase mixing: Pallas factor + xla substitution."""
    n = 64
    a = make_diagonally_dominant(jax.random.PRNGKey(18), n)
    b = jax.random.normal(jax.random.PRNGKey(19), (n, 3))
    jaxpr = jax.make_jaxpr(
        lambda a, b: ops.linear_solve(a, b, impl="pallas_fused", solve_impl="xla")
    )(a, b)
    assert primitive_count(jaxpr, "pallas_call") == 1  # factor only
    got = np.asarray(ops.linear_solve(a, b, impl="pallas_fused", solve_impl="xla"))
    res = np.linalg.norm(np.asarray(a, np.float64) @ got - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert res < 1e-4


# ---------------------------------------------------------------------------
# dispatch count: the whole factorization is ONE pallas_call
# ---------------------------------------------------------------------------
def test_fused_single_dispatch():
    a = make_diagonally_dominant(jax.random.PRNGKey(0), 256)
    jaxpr = jax.make_jaxpr(lambda x: ops.lu(x, impl="pallas_fused", block=64))(a)
    assert primitive_count(jaxpr, "pallas_call") == 1
    # the legacy driver dispatches per block column (2S-1 launches)
    jaxpr_b = jax.make_jaxpr(lambda x: ops.lu(x, impl="pallas_blocked", block=64))(a)
    assert primitive_count(jaxpr_b, "pallas_call") == 7


# ---------------------------------------------------------------------------
# equalized fold schedule properties (paper eq. 7 at tile granularity)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("num_steps", [2, 3, 4, 5, 8, 9, 16, 33])
def test_tile_schedule_equal_work(num_steps):
    sched = equalized_tile_schedule(num_steps)
    work = tile_schedule_work(num_steps)
    # per-program lifetime work totals match the paper's pair lengths ...
    assert work == pair_lengths(num_steps)
    # ... which are all equal (to num_steps) except a possible middle singleton
    full = [w for unit, w in zip(sched, work) if len(unit) == 2]
    assert all(w == num_steps for w in full)
    assert sum(len(u) == 1 for u in sched) <= 1
    # every trailing tile is owned exactly once
    owned = sorted(t for unit in sched for t in unit)
    assert owned == list(range(1, num_steps))
    # and the kernel's closed-form (p+1, S-1-p) map realizes the schedule
    for p, unit in enumerate(sched):
        assert set(unit) == {p + 1, num_steps - 1 - p}


def test_tile_schedule_matches_pairing():
    for num_steps in range(2, 20):
        pairing = equalized_pairing(num_steps)
        sched = equalized_tile_schedule(num_steps)
        assert len(sched) == len(pairing)


def test_sub_block_width_divides():
    for b in [8, 16, 24, 32, 40, 64, 97, 128, 256]:
        assert b % sub_block_width(b) == 0


# ---------------------------------------------------------------------------
# solve phase: tiled driver + RHS-padding regression
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,m,block,rt", [(64, 8, 32, 8), (100, 7, 32, 4), (257, 33, 64, 16), (128, 1, 64, 8)])
def test_solve_tiled_matches_xla(n, m, block, rt):
    a = make_diagonally_dominant(jax.random.PRNGKey(n + m), n)
    lu = ops.lu(a, impl="xla", block=block)
    b = jax.random.normal(jax.random.PRNGKey(2), (n, m))
    got = np.asarray(solve_tiled(lu, b, block=block, rhs_tile=rt))
    want = ref.solve_ref(np.asarray(lu), np.asarray(b))
    np.testing.assert_allclose(got, want, atol=1e-3)
    res = np.linalg.norm(np.asarray(a, np.float64) @ got - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert res < 1e-4


def test_solve_tiled_1d_rhs():
    n = 96
    a = make_diagonally_dominant(jax.random.PRNGKey(5), n)
    lu = ops.lu(a, impl="xla", block=32)
    b = jax.random.normal(jax.random.PRNGKey(6), (n,))
    got = np.asarray(solve_tiled(lu, b, block=32))
    assert got.shape == (n,)
    want = ref.solve_ref(np.asarray(lu), np.asarray(b)[:, None])[:, 0]
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_solve_vmem_nondivisible_rhs():
    """Regression: m=300 with rhs_tile=256 used to trip the divisibility
    assert; now padded to the next tile multiple and sliced back."""
    n = 64
    a = make_diagonally_dominant(jax.random.PRNGKey(7), n)
    lu = ops.lu(a, impl="xla", block=32)
    b = jax.random.normal(jax.random.PRNGKey(8), (n, 300))
    got = np.asarray(solve_vmem(lu, b, rhs_tile=256))
    want = ref.solve_ref(np.asarray(lu), np.asarray(b))
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_solve_tiled_bf16():
    """Regression: the tiled solve used to crash on bf16 (scan-carry dtype
    promotion against the f32 scratch tile); it now solves in f32 and casts
    back, so the bf16 pipeline survives the large-n auto-dispatch."""
    n = 64
    a = make_diagonally_dominant(jax.random.PRNGKey(12), n, dtype=jnp.bfloat16)
    lu = ops.lu(a, block=32, col_tile=32)
    b = jax.random.normal(jax.random.PRNGKey(13), (n, 4)).astype(jnp.bfloat16)
    x = ops.lu_solve(lu, b, impl="pallas_tiled", block=32)
    assert x.dtype == jnp.bfloat16
    res = np.linalg.norm(
        np.asarray(a, np.float64) @ np.asarray(x, np.float64) - np.asarray(b, np.float64)
    ) / np.linalg.norm(np.asarray(b, np.float64))
    assert res < 0.05


def test_lu_solve_auto_dispatch_tiled():
    """Above the VMEM threshold lu_solve routes to the tiled driver and the
    whole pipeline still solves the system."""
    n = 160
    a = make_diagonally_dominant(jax.random.PRNGKey(9), n)
    b = jax.random.normal(jax.random.PRNGKey(10), (n, 4))
    lu = ops.lu(a, impl="pallas_fused", block=64)
    x_tiled = np.asarray(ops.lu_solve(lu, b, impl="pallas_tiled", block=64))
    x_vmem = np.asarray(ops.lu_solve(lu, b, impl="pallas_vmem"))
    np.testing.assert_allclose(x_tiled, x_vmem, atol=1e-4)


# ---------------------------------------------------------------------------
# legacy blocked driver: odd-trailing-width padding regression
# ---------------------------------------------------------------------------
def test_blocked_driver_odd_width_padding():
    """n=97/block=32 leaves a 65-wide trailing block; the driver used to
    halve the column tile down to 1 — now it pads to the tile multiple."""
    n = 97
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    got = np.asarray(ops.lu(a, impl="pallas_blocked", block=32, col_tile=32))
    want = ref.lu_ref(np.asarray(a))
    np.testing.assert_allclose(got, want, atol=5e-3)
