"""Unit tests for the core EbV LU library (paper's contribution)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    backward_substitution,
    banded_lu,
    banded_lu_solve,
    batched_linear_solve,
    blocked_lu,
    cyclic_owners,
    ebv_folded_owners,
    ebv_lu,
    equalized_pairing,
    fold_index,
    forward_substitution,
    from_banded,
    linear_solve,
    lu_solve,
    make_diagonally_dominant,
    pair_lengths,
    reconstruct,
    to_banded,
)
from repro.kernels import ref


@pytest.mark.parametrize("n", [4, 16, 65, 128])
def test_ebv_lu_matches_oracle(n):
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    got = np.asarray(ebv_lu(a))
    want = ref.lu_ref(np.asarray(a))
    np.testing.assert_allclose(got, want, rtol=0, atol=5e-4 * n)


@pytest.mark.parametrize("n,block", [(64, 16), (128, 32), (128, 128), (96, 40)])
def test_blocked_equals_unblocked(n, block):
    a = make_diagonally_dominant(jax.random.PRNGKey(n + block), n)
    np.testing.assert_allclose(
        np.asarray(blocked_lu(a, block=block)), np.asarray(ebv_lu(a)), atol=2e-3
    )


def test_reconstruction():
    a = make_diagonally_dominant(jax.random.PRNGKey(1), 96)
    rel = float(jnp.abs(reconstruct(ebv_lu(a)) - a).max() / jnp.abs(a).max())
    assert rel < 1e-5


@pytest.mark.parametrize("nrhs", [None, 1, 7])
def test_solve_residual(nrhs):
    n = 80
    a = make_diagonally_dominant(jax.random.PRNGKey(2), n)
    shape = (n,) if nrhs is None else (n, nrhs)
    b = jax.random.normal(jax.random.PRNGKey(3), shape)
    x = lu_solve(ebv_lu(a), b)
    res = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    assert res < 1e-5


def test_substitution_phases_vs_oracle():
    n = 48
    a = make_diagonally_dominant(jax.random.PRNGKey(4), n)
    lu = ebv_lu(a)
    b = jax.random.normal(jax.random.PRNGKey(5), (n,))
    y = forward_substitution(lu, b)
    np.testing.assert_allclose(np.asarray(y), ref.forward_ref(np.asarray(lu), np.asarray(b)), atol=1e-4)
    x = backward_substitution(lu, y)
    np.testing.assert_allclose(np.asarray(x), ref.backward_ref(np.asarray(lu), np.asarray(y)), atol=1e-4)


@pytest.mark.parametrize("method", ["ebv", "ebv_blocked", "jnp"])
def test_linear_solve_api(method):
    n = 64
    a = make_diagonally_dominant(jax.random.PRNGKey(6), n)
    b = jax.random.normal(jax.random.PRNGKey(7), (n,))
    x = linear_solve(a, b, method=method, block=32)
    assert float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b)) < 1e-5


# ---------------------------------------------------------------------------
# equalization schedule (the paper's core scheduling idea)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 3, 8, 9, 127, 128])
def test_equalized_pairing_invariants(n):
    units = equalized_pairing(n)
    covered = sorted(r for u in units for r in u)
    assert covered == list(range(n - 1)), "pairing must be a perfect matching"
    lengths = pair_lengths(n)
    pairs = [u for u in units if len(u) == 2]
    for u, l in zip(units, lengths):
        if len(u) == 2:
            assert l == n, "paired work units must have equal total length n"
    assert len(pairs) == (n - 1) // 2


@pytest.mark.parametrize("count", [4, 7, 16])
def test_fold_index_is_permutation(count):
    idx = [int(fold_index(i, count)) for i in range(count)]
    assert sorted(idx) == list(range(count))
    assert idx[0] == 0 and idx[1] == count - 1


@pytest.mark.parametrize("nb,p", [(16, 4), (32, 8), (8, 2)])
def test_owner_schedules_balanced(nb, p):
    for sched in (cyclic_owners(nb, p), ebv_folded_owners(nb, p)):
        counts = [sched.count(d) for d in range(p)]
        assert max(counts) - min(counts) <= 0
    # EbV-folded equalizes *work* (trailing size), not just counts:
    folded = ebv_folded_owners(nb, p)
    work = [0.0] * p
    for k, owner in enumerate(folded):
        work[owner] += nb - k  # panel k trailing work ∝ nb − k
    assert max(work) - min(work) <= 1.0, "folded schedule must equalize work"


# ---------------------------------------------------------------------------
# banded ("sparse") path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,bw", [(32, 1), (64, 4), (100, 9)])
def test_banded_matches_dense(n, bw):
    ad = make_diagonally_dominant(jax.random.PRNGKey(n), n, sparse_band=bw)
    arow = to_banded(ad, bw)
    assert float(jnp.abs(from_banded(arow) - ad).max()) == 0.0
    lub = banded_lu(arow, bw=bw)
    want = ref.banded_lu_ref(np.asarray(arow), bw)
    np.testing.assert_allclose(np.asarray(lub), want, atol=1e-4)
    b = jax.random.normal(jax.random.PRNGKey(8), (n,))
    x = banded_lu_solve(arow, b, bw=bw)
    assert float(jnp.linalg.norm(ad @ x - b) / jnp.linalg.norm(b)) < 1e-5


def test_batched_solver():
    nb, n = 5, 32
    keys = jax.random.split(jax.random.PRNGKey(9), nb)
    a = jnp.stack([make_diagonally_dominant(k, n) for k in keys])
    b = jax.random.normal(jax.random.PRNGKey(10), (nb, n))
    x = batched_linear_solve(a, b, method="ebv")
    res = jnp.linalg.norm(jnp.einsum("bij,bj->bi", a, x) - b, axis=-1) / jnp.linalg.norm(b, axis=-1)
    assert float(res.max()) < 1e-5
