"""Focused unit tests for the repro.dist sharding policy layer:
rules_for divisibility fallback, resolve_spec rank/axes edge cases,
constrain as a no-op outside any mesh context, pytree helpers, and the
pipeline bubble math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.dist import sharding as sh
from repro.dist.pipeline_par import bubble_fraction


class Mesh16:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class Mesh4:
    axis_names = ("data", "model")
    shape = {"data": 2, "model": 4}


class MeshPod:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 8, "model": 16}


# ---------------------------------------------------------------------------
# rules_for
# ---------------------------------------------------------------------------
def test_rules_for_divisibility_fallback_across_configs():
    # llama3: 32 heads / 8 kv on a 16-way model axis — q sharded, kv not
    r = sh.rules_for(get_config("llama3_8b"), Mesh16())
    assert r["heads_x_dim"] == "model" and r["kv_x_dim"] is None
    assert r["cache_kv"] is None
    # same config on a 4-way model axis: kv=8 divides — everything sharded
    r4 = sh.rules_for(get_config("llama3_8b"), Mesh4())
    assert r4["heads_x_dim"] == "model" and r4["kv_x_dim"] == "model"
    # mixtral: 8 experts; 48 heads / 8 kv behave like llama on 16-way
    r = sh.rules_for(get_config("mixtral_8x22b"), Mesh16())
    assert r["kv_x_dim"] is None and r["heads_x_dim"] == "model"
    # whisper_tiny: 6 heads — replicated on both mesh sizes
    assert sh.rules_for(get_config("whisper_tiny"), Mesh16())["heads_x_dim"] is None
    assert sh.rules_for(get_config("whisper_tiny"), Mesh4())["heads_x_dim"] is None


def test_rules_for_logs_fallbacks_and_respects_base():
    with sh.use_mesh_rules(None):
        sh._CTX.log = []
        sh.rules_for(get_config("nemotron_4_340b"), Mesh16())
        assert any(entry[0] == "kv_x_dim" for entry in sh._CTX.log)
    base = dict(sh.RULE_PRESETS["default"], heads_x_dim=None)
    r = sh.rules_for(get_config("llama3_8b"), Mesh16(), base)
    assert r["heads_x_dim"] is None  # base override survives


def test_rules_for_config_overrides():
    cfg = get_config("llama3_8b").replace(
        logical_rules_overrides=(("ff", None),)
    )
    assert sh.rules_for(cfg, Mesh4())["ff"] is None


# ---------------------------------------------------------------------------
# resolve_spec
# ---------------------------------------------------------------------------
def test_resolve_spec_basic_and_dim_fallback():
    rules = sh.RULE_PRESETS["default"]
    # (embed, ff): ff divisible -> sharded on model
    assert sh.resolve_spec((64, 128), ("embed", "ff"), mesh=Mesh4(), rules=rules) == P(None, "model")
    # indivisible dim replicates instead of padding
    assert sh.resolve_spec((64, 126), ("embed", "ff"), mesh=Mesh4(), rules=rules) == P()
    # scalar / empty axes
    assert sh.resolve_spec((), (), mesh=Mesh4(), rules=rules) == P()


def test_resolve_spec_rank_edge_cases():
    rules = sh.RULE_PRESETS["default"]
    # axes shorter than rank are padded with None
    assert sh.resolve_spec((8, 64, 32), ("act_batch",), mesh=Mesh4(), rules=rules) == P("data")
    # axes longer than rank is a caller bug
    with pytest.raises(ValueError):
        sh.resolve_spec((8,), ("act_batch", "act_seq"), mesh=Mesh4(), rules=rules)
    # no mesh anywhere -> fully replicated
    assert sh.resolve_spec((8, 8), ("act_batch", "act_seq")) == P()


def test_resolve_spec_multi_axis_rule_and_missing_axes():
    rules = sh.RULE_PRESETS["default"]
    # act_batch maps to ("pod", "data"); on a pod mesh both are used
    spec = sh.resolve_spec((32, 64, 16), ("act_batch", "act_seq", "act_embed"),
                           mesh=MeshPod(), rules=rules)
    assert spec == P(("pod", "data"), "model")
    # on a pod-less mesh the missing axis is silently dropped
    spec = sh.resolve_spec((32, 64, 16), ("act_batch", "act_seq", "act_embed"),
                           mesh=Mesh4(), rules=rules)
    assert spec == P("data", "model")
    # a mesh axis is never used twice in one spec
    rules2 = {"a": "model", "b": "model"}
    assert sh.resolve_spec((8, 8), ("a", "b"), mesh=Mesh4(), rules=rules2) == P("model")


# ---------------------------------------------------------------------------
# constrain / context
# ---------------------------------------------------------------------------
def test_constrain_noop_outside_mesh():
    assert sh.active_mesh() is None
    x = jnp.ones((4, 8))
    y = sh.constrain(x, ("act_batch", "act_seq"))
    assert y is x  # identity, not a copy


def test_use_mesh_rules_restores_and_keeps_log():
    class M:
        axis_names = ("model",)
        shape = {"model": 4}

    m = M()
    with sh.use_mesh_rules(m, {"ff": "model"}):
        assert sh.active_mesh() is m
        assert sh.active_rules()["ff"] == "model"
        sh.resolve_spec((6,), ("ff",), mesh=m)  # 6 % 4 != 0 -> logged
    assert sh.active_mesh() is None
    assert sh._CTX.log, "fallback log must survive context exit"


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------
def test_split_axes_and_prepend_axis():
    tree = {
        "w": (jnp.ones((2, 3)), ("embed", "ff")),
        "scale": (jnp.ones((3,)), ("ff",)),
        "bare": jnp.ones((4,)),
    }
    arrays, axes = sh.split_axes(tree)
    assert arrays["w"].shape == (2, 3) and axes["w"] == ("embed", "ff")
    assert axes["bare"] == (None,)
    stacked = sh.prepend_axis(axes, "layers")
    assert stacked["w"] == ("layers", "embed", "ff")
    assert stacked["scale"] == ("layers", "ff")


# ---------------------------------------------------------------------------
# pipeline math
# ---------------------------------------------------------------------------
def test_bubble_fraction():
    assert abs(bubble_fraction(4, 8) - 3 / 11) < 1e-12
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(8, 8) - 7 / 15) < 1e-12


def test_bubble_fraction_edges():
    # fewer microbatches than stages: the bubble dominates
    assert abs(bubble_fraction(4, 2) - 3 / 5) < 1e-12
    assert abs(bubble_fraction(4, 1) - 3 / 4) < 1e-12
    # single stage never bubbles, whatever M is
    assert bubble_fraction(1, 1) == 0.0
    assert bubble_fraction(1, 3) == 0.0


def _gpipe_system(num_stages, num_mb, layers_per=2, d=8):
    ws = jax.random.normal(
        jax.random.PRNGKey(0), (num_stages, layers_per, d, d)
    ) * (d ** -0.5)
    xs = jax.random.normal(jax.random.PRNGKey(1), (num_mb, 4, d))

    def stage_fn(w, x):
        for l in range(layers_per):
            x = jnp.tanh(x @ w[l])
        return x

    return ws, xs, stage_fn


def _gpipe_reference(ws, xs, stage_fn):
    want = xs
    for s in range(ws.shape[0]):
        want = jax.vmap(lambda x, w=ws[s]: stage_fn(w, x))(want)
    return want


@pytest.mark.parametrize("num_stages,num_mb", [(4, 2), (4, 1), (8, 3)])
def test_gpipe_fewer_microbatches_than_stages(num_stages, num_mb):
    """M < P runs the full (M + P − 1)-tick schedule correctly: every
    microbatch still crosses every stage even though most ticks idle."""
    from repro.dist.pipeline_par import gpipe_forward
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((num_stages,), ("pipe",))
    ws, xs, stage_fn = _gpipe_system(num_stages, num_mb)
    got = gpipe_forward(stage_fn, ws, xs, mesh=mesh, axis="pipe")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_gpipe_reference(ws, xs, stage_fn)), atol=1e-5
    )


def test_gpipe_single_stage_is_plain_forward():
    """P = 1 degenerates to a plain per-microbatch forward (no permute, no
    bubble) and matches the sequential reference exactly."""
    from repro.dist.pipeline_par import gpipe_forward
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("pipe",))
    ws, xs, stage_fn = _gpipe_system(1, 4)
    got = gpipe_forward(stage_fn, ws, xs, mesh=mesh, axis="pipe")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_gpipe_reference(ws, xs, stage_fn)), atol=1e-6
    )
