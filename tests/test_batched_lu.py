"""`repro.kernels.batched_lu` — the batched VMEM grid kernels (optimizer
path): bitwise parity with a vmapped jnp mirror, non-square-RHS solves, and
dispatch counts (one grid `pallas_call` per batch, not per system)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_diagonally_dominant
from repro.core.solve import lu_solve as core_lu_solve
from repro.kernels import ops
from repro.kernels.batched_lu import batched_lu_solve_vmem, batched_lu_vmem
from repro.kernels.ebv_lu import _lu_body
from repro.utils.hlo import primitive_count


def _stack(batch: int, n: int, seed: int = 0) -> jax.Array:
    return jnp.stack([
        make_diagonally_dominant(jax.random.PRNGKey(seed + i), n) for i in range(batch)
    ])


def _mirror_lu(a: jax.Array) -> jax.Array:
    """Vmapped pure-jnp mirror of the grid kernel body: the same
    ``_lu_body`` rank-1 step sequence per system, so parity is bitwise."""
    n = a.shape[-1]
    return jax.vmap(lambda m: jax.lax.fori_loop(0, n - 1, _lu_body(n, n), m))(a)


@pytest.mark.parametrize("batch", [1, 5])
@pytest.mark.parametrize("n", [8, 64, 128])
def test_batched_lu_bitwise_vs_vmapped_mirror(batch, n):
    a = _stack(batch, n, seed=batch * 100 + n)
    got = np.asarray(batched_lu_vmem(a))
    want = np.asarray(_mirror_lu(a))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("batch,n,m", [(1, 16, 3), (4, 64, 7), (3, 128, 1)])
def test_batched_solve_non_square_rhs(batch, n, m):
    """RHS width ≠ n (including a single column) solves each system in the
    batch to reference accuracy."""
    a = _stack(batch, n, seed=7)
    lu = batched_lu_vmem(a)
    b = jax.random.normal(jax.random.PRNGKey(1), (batch, n, m))
    x = np.asarray(batched_lu_solve_vmem(lu, b))
    assert x.shape == (batch, n, m)
    for i in range(batch):
        want = np.asarray(core_lu_solve(lu[i], b[i]))
        np.testing.assert_allclose(x[i], want, atol=1e-5)
        res = np.linalg.norm(np.asarray(a[i]) @ x[i] - np.asarray(b[i]))
        assert res / np.linalg.norm(np.asarray(b[i])) < 1e-4


def test_batched_is_one_grid_dispatch():
    a = _stack(5, 64)
    jx = jax.make_jaxpr(batched_lu_vmem)(a)
    assert primitive_count(jx, "pallas_call") == 1
    b = jax.random.normal(jax.random.PRNGKey(2), (5, 64, 3))
    jx = jax.make_jaxpr(batched_lu_solve_vmem)(_mirror_lu(a), b)
    assert primitive_count(jx, "pallas_call") == 1


def test_ops_route_matches_kernel_bitwise():
    """ops.lu with a forced Pallas impl on stacked input is the grid kernel
    verbatim (the registry's batched mapping), independent of any cache."""
    a = _stack(3, 64, seed=42)
    got = np.asarray(ops.lu(a, impl="pallas_fused"))  # batched analog: pallas_vmem
    np.testing.assert_array_equal(got, np.asarray(batched_lu_vmem(a)))
    jx = jax.make_jaxpr(functools.partial(ops.lu, impl="pallas_fused"))(a)
    assert primitive_count(jx, "pallas_call") == 1
    # leading batch dims beyond one fold and unfold
    a4 = a.reshape(3, 1, 64, 64)
    np.testing.assert_array_equal(np.asarray(ops.lu(a4, impl="pallas_fused")).reshape(3, 64, 64), got)
