import os
import sys

# Tests run on the single real CPU device (the 512-device XLA flag is only
# ever set inside launch/dryrun.py or in subprocesses spawned by
# test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def assert_tree_finite(tree):
    for leaf in jax.tree.leaves(tree):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float64)))
