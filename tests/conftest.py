import os
import sys

# Deterministic multi-device environment for tier-1: force 8 host devices
# centrally, BEFORE any jax import (the backend locks device count on first
# init).  test_distributed.py subprocesses strip XLA_FLAGS from their env
# and set their own count; launch/dryrun.py likewise sets 512 itself.
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
    ).strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Deterministic solver autotune cache: tests dispatch against the repo-local
# cache written by scripts/check.sh's autotune stage (absent = pure static
# heuristics), never against whatever ~/.cache/repro_solvers.json a developer
# machine has accumulated.
os.environ.setdefault(
    "REPRO_SOLVERS_CACHE",
    os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".autotune_cache.json")),
)

import jax
import numpy as np
import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_code_mappings():
    """Cap the live-executable footprint across the suite.

    XLA's CPU client never evicts compiled executables, and each one pins
    several JIT code mappings; at this suite's size (~400 tests x 8 forced
    host devices) the process crosses ``vm.max_map_count`` and LLVM
    segfaults on the next failed mmap, hundreds of tests after the modules
    that actually grew the footprint.  Dropping jax's caches after every
    test module keeps the mapping count bounded — they are pure perf
    caches, so behaviour (and every bitwise contract) is unaffected."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)


def assert_tree_finite(tree):
    for leaf in jax.tree.leaves(tree):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float64)))
