"""Serving engine tests: batched generation, greedy correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import lm
from repro.serve.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3_8b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_shapes_and_determinism(setup):
    cfg, params = setup
    eng = Engine(params, cfg, max_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    out1 = eng.generate(prompts, max_new_tokens=5)
    out2 = eng.generate(prompts, max_new_tokens=5)
    assert out1.shape == (3, 13)
    np.testing.assert_array_equal(out1, out2)  # greedy is deterministic
    assert np.all(out1[:, :8] == prompts)
    assert np.all((out1 >= 0) & (out1 < cfg.vocab_size))


def test_greedy_matches_teacher_forcing(setup):
    """Each greedy token equals argmax of a fresh full forward over the
    prefix — validates incremental decode against the stateless model."""
    cfg, params = setup
    eng = Engine(params, cfg, max_len=64)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=4)
    for t in range(4):
        prefix = jnp.asarray(out[:, : 6 + t])
        _, logits = lm.prefill(params, {"tokens": prefix}, cfg)
        expect = np.asarray(jnp.argmax(logits[:, -1, : cfg.vocab_size], -1))
        np.testing.assert_array_equal(out[:, 6 + t], expect)


def test_sampled_generation(setup):
    cfg, params = setup
    eng = Engine(params, cfg, max_len=32)
    prompts = np.zeros((2, 4), np.int32)
    out = eng.generate(prompts, max_new_tokens=4, temperature=1.0, seed=7)
    assert out.shape == (2, 8)


def test_sample_keys_distinct_from_root(setup):
    """Regression: the first _sample used to consume the root PRNG key that
    was then re-split for later steps, correlating the first token with the
    rest of the stream.  Every per-step key must differ from the root and
    from each other."""
    cfg, params = setup
    eng = Engine(params, cfg, max_len=32)
    seen = []
    orig = eng._sample

    def spy(logits, temperature, key):
        seen.append(np.asarray(key).copy())
        return orig(logits, temperature, key)

    eng._sample = spy
    eng.generate(np.zeros((1, 4), np.int32), max_new_tokens=4, temperature=1.0, seed=3)
    assert len(seen) == 4
    root = np.asarray(jax.random.PRNGKey(3))
    for k in seen:
        assert not np.array_equal(k, root)
    assert len({tuple(k.tolist()) for k in seen}) == len(seen)


def test_moe_engine_smoke():
    cfg = get_config("granite_moe_1b_a400m").reduced()
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    eng = Engine(params, cfg, max_len=32)
    out = eng.generate(np.ones((2, 4), np.int32), max_new_tokens=3)
    assert out.shape == (2, 7)
