"""Serving engine tests: continuous batching, ragged bitwise identity,
prefill insertion mid-decode, per-slot sampling independence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import lm
from repro.serve.engine import Engine, GenRequest
from repro.serve.scheduler import Scheduler, bucket_length


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3_8b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def ragged_engine(setup):
    """One shared engine for the ragged suite so every test reuses the same
    compiled _prefill/_decode (slots=4, bucket=4)."""
    cfg, params = setup
    return Engine(params, cfg, max_len=64, slots=4, bucket=4)


def _ragged_requests(cfg, *, temperature=0.0):
    rng = np.random.default_rng(42)
    lens = [3, 9, 5, 12, 2, 7, 4, 10]
    news = [9, 2, 5, 3, 11, 4, 6, 2]
    return [
        GenRequest(
            tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
            max_new_tokens=n, temperature=temperature, seed=100 + i,
        )
        for i, (s, n) in enumerate(zip(lens, news))
    ]


# ---------------------------------------------------------------------------
# lockstep-compatible generate()
# ---------------------------------------------------------------------------
def test_generate_shapes_and_determinism(setup):
    cfg, params = setup
    eng = Engine(params, cfg, max_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 8)).astype(np.int32)
    out1 = eng.generate(prompts, max_new_tokens=5)
    out2 = eng.generate(prompts, max_new_tokens=5)
    assert out1.shape == (3, 13)
    np.testing.assert_array_equal(out1, out2)  # greedy is deterministic
    assert np.all(out1[:, :8] == prompts)
    assert np.all((out1 >= 0) & (out1 < cfg.vocab_size))


def test_greedy_matches_teacher_forcing(setup):
    """Each greedy token equals argmax of a fresh full forward over the
    prefix — validates incremental slot decode against the stateless model."""
    cfg, params = setup
    eng = Engine(params, cfg, max_len=64)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=4)
    for t in range(4):
        prefix = jnp.asarray(out[:, : 6 + t])
        _, logits = lm.prefill(params, {"tokens": prefix}, cfg)
        expect = np.asarray(jnp.argmax(logits[:, -1, : cfg.vocab_size], -1))
        np.testing.assert_array_equal(out[:, 6 + t], expect)


def test_sampled_generation(setup):
    cfg, params = setup
    eng = Engine(params, cfg, max_len=32)
    prompts = np.zeros((2, 4), np.int32)
    out = eng.generate(prompts, max_new_tokens=4, temperature=1.0, seed=7)
    assert out.shape == (2, 8)
    # rows carry distinct per-request seeds -> independent draws
    assert not np.array_equal(out[0, 4:], out[1, 4:])


def test_sample_keys_distinct_from_root(setup):
    """Regression (lockstep engine): the first _sample used to consume the
    root PRNG key that was then re-split for later steps.  The slot engine
    keeps the discipline per request: every per-step subkey must differ from
    the root key and from each other."""
    cfg, params = setup
    eng = Engine(params, cfg, max_len=32)
    seen = []
    orig = eng._sample

    def spy(logits, temperature, key):
        seen.append(np.asarray(key).copy().reshape(-1, 2))
        return orig(logits, temperature, key)

    eng._sample = spy
    eng.generate(np.zeros((1, 4), np.int32), max_new_tokens=4, temperature=1.0, seed=3)
    assert len(seen) == 4  # 1 prefill sample + 3 decode samples
    root = np.asarray(jax.random.PRNGKey(3))
    flat = [tuple(k[0].tolist()) for k in seen]
    assert tuple(root.tolist()) not in flat
    assert len(set(flat)) == len(flat)


def test_moe_engine_smoke():
    cfg = get_config("granite_moe_1b_a400m").reduced()
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    eng = Engine(params, cfg, max_len=32)
    out = eng.generate(np.ones((2, 4), np.int32), max_new_tokens=3)
    assert out.shape == (2, 7)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------
def test_ragged_bitwise_identical_to_solo_and_fewer_dispatches(ragged_engine, setup):
    """Acceptance: mixed prompt lengths / new-token counts across 8 requests
    through the 4-slot scheduler produce per-request outputs bitwise equal
    to serving each request alone, with strictly fewer _decode dispatches
    than the lockstep engine would need."""
    cfg, _ = setup
    eng = ragged_engine
    reqs = _ragged_requests(cfg)
    outs = eng.serve(reqs)
    batched = eng.stats
    assert batched.prefill_dispatches == len(reqs)
    # prefill insertion happened mid-stream: some request was prefilled
    # AFTER the first decode dispatch (slots freed and were refilled)
    kinds = [k for k, _ in batched.events]
    assert "prefill" in kinds[kinds.index("decode"):]

    for r, out in zip(reqs, outs):
        assert out.shape == (len(r.tokens) + r.max_new_tokens,)
        solo = eng.serve([r])[0]
        np.testing.assert_array_equal(out, solo)  # bitwise

    # lockstep engine: groups of `slots` in arrival order, every group pays
    # its max new-token count, minus the token that comes from prefill
    slots = 4
    lockstep = sum(
        max(r.max_new_tokens for r in reqs[i : i + slots]) - 1
        for i in range(0, len(reqs), slots)
    )
    assert batched.decode_dispatches < lockstep
    assert batched.generated_tokens == sum(r.max_new_tokens for r in reqs)


def test_ragged_sampled_slot_independent(ragged_engine, setup):
    """temperature>0: a request's sampled stream depends only on its seed —
    not on which slot it lands in or what else is in flight."""
    cfg, _ = setup
    eng = ragged_engine
    reqs = _ragged_requests(cfg, temperature=1.0)
    outs = eng.serve(reqs)
    # same request alone (lands in slot 0 instead of wherever it was)
    for i in (1, 3, 6):
        solo = eng.serve([reqs[i]])[0]
        np.testing.assert_array_equal(outs[i], solo)
    # identical prompt, different seed -> different draw
    twin = GenRequest(
        tokens=reqs[0].tokens, max_new_tokens=reqs[0].max_new_tokens,
        temperature=1.0, seed=reqs[0].seed + 777,
    )
    solo0 = eng.serve([reqs[0]])[0]
    solo_twin = eng.serve([twin])[0]
    assert not np.array_equal(solo0, solo_twin)


def test_padding_stats(ragged_engine, setup):
    cfg, _ = setup
    eng = ragged_engine
    reqs = _ragged_requests(cfg)
    eng.serve(reqs)
    st = eng.stats
    want_real = sum(len(r.tokens) for r in reqs)
    want_pad = sum(bucket_length(len(r.tokens), 4) - len(r.tokens) for r in reqs)
    assert st.sched.real_tokens == want_real
    assert st.sched.padding_tokens == want_pad
    assert st.padding_frac == pytest.approx(want_pad / (want_real + want_pad))


@pytest.mark.parametrize("arch", ["qwen2_vl_2b", "whisper_tiny", "mamba2_1_3b", "hymba_1_5b"])
def test_families_serve_ragged_solo_identical(arch):
    """Every cache family (vlm prefix offset, encdec cross caches, ssm
    recurrent state, hybrid both) survives ragged slot serving, and the
    first request's output matches its solo serve bitwise."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=4)
    rng = np.random.default_rng(0)
    reqs = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                   max_new_tokens=n, seed=i)
        for i, (s, n) in enumerate([(5, 4), (8, 2), (3, 6)])
    ]
    outs = eng.serve(reqs)
    for r, o in zip(reqs, outs):
        assert o.shape == (len(r.tokens) + r.max_new_tokens,)
    np.testing.assert_array_equal(outs[0], eng.serve([reqs[0]])[0])


# ---------------------------------------------------------------------------
# scheduler unit behaviour
# ---------------------------------------------------------------------------
def test_scheduler_equalized_fill_mixes_heavy_and_light():
    sched = Scheduler()
    costs = [10, 11, 12, 13, 1, 2, 3, 4]
    for i, c in enumerate(costs):
        sched.submit(i, bucket=0, cost=c)
    picked = sched.take(4)
    got = sorted(r.cost for r in picked)
    # plain FIFO would take [10, 11, 12, 13]; the fold pick must mix ends
    assert got != [10, 11, 12, 13]
    assert max(got) >= 12 and min(got) <= 2
    # everything still drains
    assert len(sched.take(4, equalize=False)) == 4
    assert len(sched) == 0


def test_scheduler_deadline_beats_fifo():
    sched = Scheduler()
    for i in range(4):
        sched.submit(f"fifo{i}", bucket=0, cost=1)
    sched.submit("urgent", bucket=0, cost=100, deadline=1.0)
    picked = sched.take(2)
    assert picked[0].payload == "urgent"


def test_scheduler_fifo_window_bounds_overtaking():
    """A deadline-free request can be overtaken only within the 2k window —
    the front of the queue is always admitted."""
    sched = Scheduler()
    sched.submit("first", bucket=0, cost=1)  # lightest, oldest
    for i in range(10):
        sched.submit(f"r{i}", bucket=0, cost=5 + i)
    picked = sched.take(2)  # window = first 4 submissions
    payloads = {r.payload for r in picked}
    assert payloads <= {"first", "r0", "r1", "r2"}


def test_scheduler_shard_balanced_order():
    """With shards=/shard_load= the CHOICE of requests is unchanged; only
    the return order permutes so the heaviest pick lands on the
    lightest-loaded shard."""
    sched = Scheduler()
    for i, c in enumerate([10, 1, 7, 3]):
        sched.submit(i, bucket=0, cost=c)
    # 4 slots on shards [0, 0, 1, 1]; shard 0 already carries 20 cost
    picked = sched.take(4, shards=[0, 0, 1, 1], shard_load=[20.0, 0.0])
    assert sorted(r.cost for r in picked) == [1, 3, 7, 10]  # same picks
    # heaviest two go to shard 1's slots (positions 2 and 3)
    assert sorted(r.cost for r in picked[2:]) == [7, 10]
    assert sched.stats.shard_balanced == 4
    # without shards= the order is untouched and the stat stays zero
    sched2 = Scheduler()
    for i, c in enumerate([10, 1, 7, 3]):
        sched2.submit(i, bucket=0, cost=c)
    assert [r.cost for r in sched2.take(4, equalize=False)] == [10, 1, 7, 3]
    assert sched2.stats.shard_balanced == 0


def test_scheduler_shard_balance_spreads_evenly():
    """Heavy requests spread across shards instead of stacking on whichever
    shard's slots freed first."""
    sched = Scheduler()
    for i, c in enumerate([9, 9, 1, 1]):
        sched.submit(i, bucket=0, cost=c)
    picked = sched.take(4, equalize=False, shards=[0, 0, 1, 1], shard_load=[0.0, 0.0])
    load = [0.0, 0.0]
    for pos, r in enumerate(picked):
        load[[0, 0, 1, 1][pos]] += r.cost
    assert load == [10.0, 10.0]


def test_zero_token_budget_rejected(setup):
    cfg, params = setup
    eng = Engine(params, cfg, max_len=32)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.serve([GenRequest(tokens=np.zeros(4, np.int32), max_new_tokens=0)])


def test_sliding_window_bucket_never_evicts_real_kv():
    """Bucket pads must not roll real prompt K/V out of the sliding-window
    ring: past the window the engine prefills exact-length, and within it
    padded vs exact prompts decode identically."""
    cfg = get_config("mixtral_8x22b").reduced()  # window=32
    w = cfg.sliding_window
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, max_len=96, slots=1, bucket=8)
    assert eng._bucket_len(w + 3, None) == w + 3   # padding would evict -> exact
    assert eng._bucket_len(w - 6, None) == w       # pad to 32: still in-ring
    rng = np.random.default_rng(5)
    long_prompt = rng.integers(0, cfg.vocab_size, (w + 3,)).astype(np.int32)
    out = eng.serve([GenRequest(tokens=long_prompt, max_new_tokens=4)])[0]
    exact = Engine(params, cfg, max_len=96, slots=1, bucket=1)
    np.testing.assert_array_equal(
        out, exact.serve([GenRequest(tokens=long_prompt, max_new_tokens=4)])[0]
    )
