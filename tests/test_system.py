"""End-to-end system behaviour tests.

1. The paper's pipeline: build a CFD-style banded system → EbV LU solve →
   residual check (what the authors used the solver for).
2. Training: tiny LM trains, loss decreases, checkpoint-resume continues
   exactly (fault tolerance).
3. EbV-preconditioned optimizer end-to-end on a real model.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import banded_lu_solve, linear_solve, to_banded
from repro.train.loop import TrainConfig, train


def _poisson_1d(n):
    """Tridiagonal Poisson system (CFD pressure-solve stand-in)."""
    a = np.zeros((n, n), np.float32)
    idx = np.arange(n)
    a[idx, idx] = 2.1  # slightly dominant
    a[idx[:-1], idx[:-1] + 1] = -1.0
    a[idx[1:], idx[1:] - 1] = -1.0
    return jnp.asarray(a)


def test_cfd_style_solve_end_to_end():
    n = 512
    a = _poisson_1d(n)
    b = jnp.sin(jnp.linspace(0, 3.14, n))
    x_dense = linear_solve(a, b, method="ebv_blocked", block=64)
    x_band = banded_lu_solve(to_banded(a, 1), b, bw=1)
    for x in (x_dense, x_band):
        res = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
        assert res < 1e-5
    np.testing.assert_allclose(np.asarray(x_dense), np.asarray(x_band), atol=1e-3)


def test_training_loss_decreases_and_resumes(tmp_path):
    cfg = get_config("llama3_8b").reduced()
    tc = TrainConfig(steps=25, seq_len=64, global_batch=4, warmup_steps=5,
                     learning_rate=1e-3, ckpt_dir=str(tmp_path), ckpt_every=10,
                     log_every=100)
    params, hist = train(cfg, tc)
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]
    # resume: a fresh invocation continues from the checkpoint
    tc2 = TrainConfig(steps=27, seq_len=64, global_batch=4, warmup_steps=5,
                      learning_rate=1e-3, ckpt_dir=str(tmp_path), ckpt_every=100,
                      log_every=100)
    _, hist2 = train(cfg, tc2)
    assert hist2[0]["step"] == 25
    assert len(hist2) == 2


def test_ebv_optimizer_trains_model():
    cfg = get_config("starcoder2_3b").reduced()
    tc = TrainConfig(steps=10, seq_len=32, global_batch=2, warmup_steps=2,
                     learning_rate=1e-3, optimizer="ebv", log_every=100)
    params, hist = train(cfg, tc)
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.1


def test_microbatched_step_equivalence():
    from repro.train.loop import make_train_step
    from repro.train import optimizer as opt_lib
    from repro.models import lm

    cfg = get_config("llama3_8b").reduced()
    opt = opt_lib.adamw(opt_lib.constant_lr(1e-3))
    p0 = lm.init_params(jax.random.PRNGKey(1), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, cfg.vocab_size)}
    p1, _, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(p0, opt.init(p0), batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt, microbatches=2))(p0, opt.init(p0), batch)
    diff = max(float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert diff < 1e-4
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
