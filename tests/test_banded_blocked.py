"""Blocked banded megakernel suite: bitwise kernel/mirror agreement, edge
cases (non-divisible n, tridiagonal, bw ≥ n), single-dispatch counts, solve
coverage and the batched grid path (ISSUE 3 acceptance criteria)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_diagonally_dominant, to_banded, from_banded
from repro.core import banded as cband
from repro.kernels import banded as kband
from repro.kernels import ops, ref
from repro.utils.hlo import primitive_count


def _band_system(n, bw, *, key=0):
    ad = make_diagonally_dominant(jax.random.PRNGKey(key + n + bw), n, sparse_band=bw)
    return ad, to_banded(ad, bw)


# ---------------------------------------------------------------------------
# skewed layout: exact data movement
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("c,bw,blocks", [(8, 2, 3), (16, 5, 2), (4, 6, 5), (32, 1, 2)])
def test_skew_roundtrip_exact(c, bw, blocks):
    r, w = c * blocks, 2 * bw + 1
    ap = jnp.asarray(np.random.default_rng(0).normal(size=(r, w)).astype(np.float32))
    g = cband.band_to_skewed(ap, bw, c)
    apn = np.asarray(ap)
    expect = np.zeros((r, c + 2 * bw), np.float32)
    for i in range(r):
        r0 = i % c
        expect[i, r0 : r0 + w] = apn[i]
    np.testing.assert_array_equal(np.asarray(g), expect)
    np.testing.assert_array_equal(np.asarray(cband.skewed_to_band(g, bw, c)), apn)


# ---------------------------------------------------------------------------
# factorization: bitwise kernel/mirror sweep + oracle agreement
# ---------------------------------------------------------------------------
BANDED_SWEEP = [
    (64, 4, None),   # divisible, auto block
    (97, 3, 32),     # non-divisible n vs block (prime n)
    (33, 1, 16),     # bw=1 tridiagonal, non-divisible
    (16, 20, None),  # bw >= n: degenerate-to-dense
    (200, 8, 64),
    (128, 16, None),
    (60, 7, 13),     # odd block, non-divisible
]


@pytest.mark.parametrize("n,bw,block", BANDED_SWEEP)
def test_banded_blocked_bitwise_and_oracle(n, bw, block):
    """Acceptance: both blocked kernels produce band LU bitwise-identical to
    the core/banded.py mirror across the {n, bw} sweep, and match the dense
    numpy oracle."""
    _, arow = _band_system(n, bw)
    want = np.asarray(cband.banded_lu_blocked(arow, bw=bw, block=block))
    oracle = ref.banded_lu_ref(np.asarray(arow), bw)
    np.testing.assert_allclose(want, oracle, atol=1e-4 * max(n, 32))
    got_vmem = np.asarray(kband.banded_lu_blocked(arow, bw=bw, block=block))
    got_tiled = np.asarray(kband.banded_lu_tiled(arow, bw=bw, block=block))
    np.testing.assert_array_equal(got_vmem, want)
    np.testing.assert_array_equal(got_tiled, want)


def test_banded_blocked_matches_scalar_paths():
    """Blocked and legacy scalar paths factor the same band (to tolerance —
    their elimination orders differ in last bits)."""
    n, bw = 96, 5
    _, arow = _band_system(n, bw)
    blocked = np.asarray(ops.banded_lu(arow, bw=bw, impl="pallas_blocked"))
    scalar_k = np.asarray(ops.banded_lu(arow, bw=bw, impl="pallas_scalar"))
    scalar_x = np.asarray(ops.banded_lu(arow, bw=bw, impl="xla_scalar"))
    np.testing.assert_allclose(blocked, scalar_k, atol=1e-4)
    np.testing.assert_allclose(blocked, scalar_x, atol=1e-4)


def test_banded_degenerate_dense_equivalence():
    """bw >= n: the band covers the whole matrix, so the band LU must equal
    the dense no-pivot LU."""
    n, bw = 24, 30
    ad, arow = _band_system(n, bw)
    lub = ops.banded_lu(arow, bw=bw)
    dense_lu = ref.lu_ref(np.asarray(ad, np.float64))
    np.testing.assert_allclose(np.asarray(from_banded(lub)), dense_lu, atol=1e-4)


# ---------------------------------------------------------------------------
# solve: bitwise kernel/mirror + residuals + RHS shapes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,bw,block,m", [(64, 4, None, 5), (97, 3, 32, 1), (33, 1, 16, 7), (16, 20, None, 3)])
def test_banded_solve_bitwise_and_residual(n, bw, block, m):
    ad, arow = _band_system(n, bw)
    lub = cband.banded_lu_blocked(arow, bw=bw, block=block)
    b = jax.random.normal(jax.random.PRNGKey(2), (n, m))
    want = np.asarray(cband.banded_solve_blocked(lub, b, bw=bw, block=block))
    got = np.asarray(kband.banded_solve_kernelized(lub, b, bw=bw, block=block))
    np.testing.assert_array_equal(got, want)
    res = np.linalg.norm(np.asarray(ad, np.float64) @ got - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert res < 1e-5


def test_banded_solve_1d_rhs_and_scalar_agreement():
    n, bw = 80, 6
    _, arow = _band_system(n, bw)
    b = jax.random.normal(jax.random.PRNGKey(3), (n,))
    x = ops.banded_linear_solve(arow, b, bw=bw)
    assert x.shape == (n,)
    x_scalar = cband.banded_lu_solve(arow, b, bw=bw)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_scalar), atol=1e-5)


def test_banded_solve_nondivisible_rhs_tile():
    """RHS wider than one tile and not a multiple of it pads and slices back."""
    n, bw = 48, 3
    _, arow = _band_system(n, bw)
    lub = ops.banded_lu(arow, bw=bw)
    b = jax.random.normal(jax.random.PRNGKey(4), (n, 11))
    got = np.asarray(ops.banded_solve(lub, b, bw=bw, rhs_tile=4))
    want = np.asarray(ops.banded_solve(lub, b, bw=bw, impl="xla"))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# dispatch: one pallas_call per factor/solve (acceptance), impl routing
# ---------------------------------------------------------------------------
def test_banded_single_dispatch():
    n, bw = 96, 4
    _, arow = _band_system(n, bw)
    b = jax.random.normal(jax.random.PRNGKey(5), (n,))
    for impl in ("pallas_blocked", "pallas_tiled"):
        jx = jax.make_jaxpr(lambda a: ops.banded_lu(a, bw=bw, impl=impl))(arow)
        assert primitive_count(jx, "pallas_call") == 1, impl
    lub = ops.banded_lu(arow, bw=bw)
    jx = jax.make_jaxpr(lambda l, r: ops.banded_solve(l, r, bw=bw))(lub, b)
    assert primitive_count(jx, "pallas_call") == 1
    jx = jax.make_jaxpr(lambda a, r: ops.banded_linear_solve(a, r, bw=bw))(arow, b)
    assert primitive_count(jx, "pallas_call") == 2  # one factor + one solve


def test_banded_xla_impl_traces_no_pallas():
    """impl='xla' must route BOTH phases through the jnp mirrors."""
    n, bw = 64, 4
    _, arow = _band_system(n, bw)
    b = jax.random.normal(jax.random.PRNGKey(6), (n,))
    jx = jax.make_jaxpr(lambda a, r: ops.banded_linear_solve(a, r, bw=bw, impl="xla"))(arow, b)
    assert primitive_count(jx, "pallas_call") == 0
    got = np.asarray(ops.banded_linear_solve(arow, b, bw=bw, impl="xla"))
    want = np.asarray(ops.banded_linear_solve(arow, b, bw=bw))
    np.testing.assert_array_equal(got, want)  # mirrors are bitwise twins


def test_banded_auto_impl_thresholds():
    assert ops._banded_auto_impl(512, 4, None, 4) == "pallas_blocked"
    assert ops._banded_auto_impl(200_000, 16, None, 4) == "pallas_tiled"
    # dtype-aware: a float64 band twice the f32 footprint tips to streaming
    n_edge = 9000  # skewed f32 footprint ~5.9 MB: just under the 6 MB cap
    assert ops._banded_auto_impl(n_edge, 16, None, 4) == "pallas_blocked"
    assert ops._banded_auto_impl(n_edge, 16, None, 8) == "pallas_tiled"


def test_banded_unknown_impl_raises():
    _, arow = _band_system(32, 2)
    with pytest.raises(ValueError, match="unknown impl"):
        ops.banded_lu(arow, bw=2, impl="nope")


# ---------------------------------------------------------------------------
# batched grid path (optimizer workload)
# ---------------------------------------------------------------------------
def test_batched_banded_lu_and_solve():
    bw, n, bsz = 3, 40, 4
    bands = jnp.stack(
        [to_banded(make_diagonally_dominant(jax.random.PRNGKey(i), n, sparse_band=bw), bw)
         for i in range(bsz)]
    )
    lub = kband.batched_banded_lu_vmem(bands, bw=bw)
    b = jax.random.normal(jax.random.PRNGKey(9), (bsz, n, 2))
    x = kband.batched_banded_solve_vmem(lub, b, bw=bw)
    for i in range(bsz):
        want_lu = np.asarray(cband.banded_lu_blocked(bands[i], bw=bw))
        np.testing.assert_allclose(np.asarray(lub[i]), want_lu, atol=1e-6)
        want_x = np.asarray(cband.banded_solve_blocked(lub[i], b[i], bw=bw))
        np.testing.assert_allclose(np.asarray(x[i]), want_x, atol=1e-5)


def test_batched_banded_solve_1d_rhs():
    bw, n, bsz = 2, 24, 3
    bands = jnp.stack(
        [to_banded(make_diagonally_dominant(jax.random.PRNGKey(i + 50), n, sparse_band=bw), bw)
         for i in range(bsz)]
    )
    lub = kband.batched_banded_lu_vmem(bands, bw=bw)
    b = jax.random.normal(jax.random.PRNGKey(10), (bsz, n))
    x = kband.batched_banded_solve_vmem(lub, b, bw=bw)
    assert x.shape == (bsz, n)
    batched_single = jax.make_jaxpr(lambda a: kband.batched_banded_lu_vmem(a, bw=bw))(bands)
    assert primitive_count(batched_single, "pallas_call") == 1
