"""Multi-device tests.  Each test runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single-device backend (per the brief)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_in_subprocess(body: str, devices: int = 8, timeout: int = 900):
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        "import jax, jax.numpy as jnp, numpy as np\n" + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout, env=env
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_distributed_lu_both_placements():
    run_in_subprocess("""
    from repro.core import (make_diagonally_dominant, blocked_lu,
                            distributed_blocked_lu, distributed_lu_solve)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("model",))
    n = 256
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    want = np.asarray(blocked_lu(a, block=16))
    for placement in ("cyclic", "ebv_folded"):
        got = np.asarray(distributed_blocked_lu(a, mesh, block=16, placement=placement))
        np.testing.assert_allclose(got, want, atol=1e-3)
        x = distributed_lu_solve(a, b, mesh, block=16, placement=placement)
        res = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
        assert res < 1e-5, (placement, res)
    print("distributed LU OK")
    """)


def test_moe_shard_map_matches_local():
    run_in_subprocess("""
    from repro.configs.base import get_config
    from repro.models import moe as MOE
    from repro.dist import sharding as sh
    from repro.dist.sharding import split_axes
    from repro.launch.mesh import make_mesh
    cfg = get_config("granite_moe_1b_a400m").reduced().replace(dtype="float32")
    p, _ = split_axes(MOE.init_moe(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model), jnp.float32)
    out_local, aux_local = MOE._moe_local(p, x.reshape(-1, cfg.d_model), cfg)
    mesh = make_mesh((2, 4), ("data", "model"))
    with sh.use_mesh_rules(mesh):
        out_dist, aux_dist = jax.jit(lambda p, x: MOE.apply_moe(p, x, cfg))(p, x)
    # distributed capacity differs (per-shard): compare with generous tol on
    # outputs where no tokens dropped; aux must be close.
    assert np.isfinite(np.asarray(out_dist)).all()
    assert abs(float(aux_dist) - float(aux_local)) < 0.1
    # exact parity when capacity is non-binding (cf -> large)
    cfg2 = cfg.replace(moe_capacity_factor=8.0)
    out_local2, _ = MOE._moe_local(p, x.reshape(-1, cfg.d_model), cfg2)
    with sh.use_mesh_rules(mesh):
        out_dist2, _ = jax.jit(lambda p, x: MOE.apply_moe(p, x, cfg2))(p, x)
    np.testing.assert_allclose(np.asarray(out_dist2), np.asarray(out_local2).reshape(4, 32, -1),
                               atol=2e-4, rtol=2e-3)
    print("moe parity OK")
    """)


def test_sharded_train_loss_matches_single_device():
    run_in_subprocess("""
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.dist import sharding as sh
    from repro.launch.mesh import make_mesh
    from repro.launch import specs as S
    cfg = get_config("llama3_8b").reduced().replace(dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab_size)}
    loss_ref, _ = jax.jit(lambda p, b: lm.train_loss(p, b, cfg))(params, batch)
    mesh = make_mesh((2, 4), ("data", "model"))
    with sh.use_mesh_rules(mesh):
        fn = jax.jit(lambda p, b: lm.train_loss(p, b, cfg)[0])
        loss_sharded = fn(params, batch)
    np.testing.assert_allclose(float(loss_sharded), float(loss_ref), rtol=2e-5)
    print("sharded loss parity OK", float(loss_sharded))
    """)


def test_compressed_pod_psum():
    run_in_subprocess("""
    from repro.train import grad_compress as gc
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("pod", "data"))
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)}
    err = gc.init_error(grads)
    red, new_err = jax.jit(lambda g, e: gc.compressed_psum(g, e, mesh=mesh, axis="pod"))(grads, err)
    # grads replicated across pods -> mean == grads (up to int8 quantization)
    q, s = gc.quantize(grads["w"])
    np.testing.assert_allclose(np.asarray(red["w"]), np.asarray(gc.dequantize(q, s)), atol=1e-6)
    assert float(jnp.abs(new_err["w"]).max()) <= float(s) * 0.5 + 1e-7
    print("compressed psum OK")
    """)


def test_mini_dryrun_cells():
    """End-to-end dry-run machinery on an 8-device mesh with reduced
    configs: lower+compile train/prefill/decode and check analysis output."""
    run_in_subprocess("""
    import dataclasses
    from repro.configs.base import get_config, ShapeCell
    from repro.dist import sharding as sh
    from repro.launch import specs as S
    from repro.launch.mesh import make_mesh
    from repro.utils.hlo import collective_bytes, cost_analysis_dict

    mesh = make_mesh((2, 4), ("data", "model"))
    for arch in ("llama3_8b", "granite_moe_1b_a400m", "mamba2_1_3b", "whisper_tiny", "qwen2_vl_2b"):
        cfg = get_config(arch).reduced()
        for cell in (ShapeCell("t", 64, 4, "train"), ShapeCell("p", 64, 4, "prefill"),
                     ShapeCell("d", 64, 4, "decode")):
            with sh.use_mesh_rules(mesh):
                fn, args, axes = S.make_cell_fn(cfg, cell)
                in_sh = S.shardings_for_args(args, axes, mesh)
                compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
            mem = compiled.memory_analysis()
            cost = cost_analysis_dict(compiled)
            assert cost.get("flops", 0) > 0, (arch, cell.kind)
            cb = collective_bytes(compiled.as_text(), num_devices=8)
            print(arch, cell.kind, int(cost["flops"]), cb["total_wire"])
    print("mini dryrun OK")
    """, timeout=1500)


def test_elastic_restore_across_meshes():
    """Checkpoint saved from an 8-device sharded state restores onto a
    4-device mesh (elastic scaling)."""
    run_in_subprocess("""
    import tempfile
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt.manager import CheckpointManager
    from repro.launch.mesh import make_mesh
    mesh8 = make_mesh((8,), ("data",))
    w = jax.device_put(jnp.arange(32, dtype=jnp.float32), NamedSharding(mesh8, P("data")))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"w": w})
        mesh4 = make_mesh((4,), ("data",))
        sh4 = {"w": NamedSharding(mesh4, P("data"))}
        restored, _, _ = mgr.restore({"w": w}, shardings=sh4)
        assert restored["w"].sharding.num_devices == 4
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(32, dtype=np.float32))
    print("elastic restore OK")
    """)


def test_gpipe_pipeline_parallel():
    """GPipe over 4 stages == sequential layer application; bubble math."""
    run_in_subprocess("""
    from repro.dist.pipeline_par import gpipe_forward, bubble_fraction
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("pipe",))
    P_stages, L_per, M, D = 4, 2, 8, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (P_stages, L_per, D, D)) * (D ** -0.5)
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, 4, D))

    def stage_fn(w, x):
        for l in range(L_per):
            x = jnp.tanh(x @ w[l])
        return x

    got = gpipe_forward(stage_fn, ws, xs, mesh=mesh, axis="pipe")
    want = xs
    for s in range(P_stages):
        want = jax.vmap(lambda x: stage_fn(ws[s], x))(want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
    print("gpipe OK")
    """)


def test_ebv_attention_schedule_parity():
    """EbV fold-paired causal attention (shard_map) == rect baseline, and the
    per-rank work is provably uniform (2P+1 equal blocks — the paper's
    invariant)."""
    run_in_subprocess("""
    from repro.models.common import attention, ebv_attention_sharded
    from repro.dist import sharding as sh
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    b, s, h, kv, dh = 4, 64, 8, 4, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, dh), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    want = attention(q, k, v, q_positions=pos, kv_positions=pos,
                     causal=True, window=None, kv_chunk=16, schedule="rect")
    for window in (None, 24):
        want_w = attention(q, k, v, q_positions=pos, kv_positions=pos,
                           causal=True, window=window, kv_chunk=16, schedule="rect")
        with sh.use_mesh_rules(mesh):
            got = jax.jit(lambda q, k, v: ebv_attention_sharded(
                q, k, v, q_positions=pos, window=window))(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want_w), atol=3e-5, rtol=3e-5)
    print("ebv attention parity OK")
    """)
