"""Paged KV cache tests: page-pool bookkeeping, prefix fingerprint chains,
the Pallas gather-attention kernel's bitwise twin, paged-vs-dense serve
identity, shared-prefix warm admission, structural copy-on-write, pool
exhaustion queuing, and EOS early exit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels.paged_attn import paged_decode_attention, paged_decode_attention_ref
from repro.models import lm
from repro.serve.engine import Engine, GenRequest
from repro.serve.paged import SCRAP_PAGE, PagePool, PrefixCache, ShardedPagePool, prefix_chain
from repro.utils.hlo import primitive_count


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3_8b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def dense_engine(setup):
    cfg, params = setup
    return Engine(params, cfg, max_len=64, slots=4, bucket=4)


@pytest.fixture(scope="module")
def paged_engine(setup):
    cfg, params = setup
    return Engine(params, cfg, max_len=64, slots=4, bucket=4,
                  paged=True, page_size=8)


def _ragged_requests(cfg, *, temperature_odd=0.8):
    rng = np.random.default_rng(42)
    lens = [3, 9, 5, 12, 2, 7, 4, 10]
    news = [9, 2, 5, 3, 11, 4, 6, 2]
    return [
        GenRequest(
            tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
            max_new_tokens=n,
            temperature=0.0 if i % 2 else temperature_odd,
            seed=100 + i,
        )
        for i, (s, n) in enumerate(zip(lens, news))
    ]


# ---------------------------------------------------------------------------
# PagePool / prefix_chain / PrefixCache units
# ---------------------------------------------------------------------------
def test_page_pool_alloc_release_refcount():
    pool = PagePool(6, page_size=4)
    assert pool.capacity == 5 and pool.free == 5  # page 0 reserved scrap
    a = pool.alloc(3)
    assert sorted(a) == [1, 2, 3] and SCRAP_PAGE not in a
    assert pool.used == 3 and pool.peak_used == 3
    pool.retain([a[0]])
    assert pool.refcount(a[0]) == 2 and not pool.writable(a[0])
    pool.release(a)
    assert pool.refcount(a[0]) == 1 and pool.free == 4
    pool.release([a[0]])
    assert pool.free == 5
    # all-or-nothing: a short alloc takes nothing
    assert pool.alloc(6) is None
    assert pool.free == 5 and pool.failed_allocs == 1
    with pytest.raises(ValueError):
        pool.release([a[0]])  # already free
    with pytest.raises(ValueError):
        pool.retain([SCRAP_PAGE])


def test_prefix_chain_determinism_and_salt():
    toks = np.arange(20, dtype=np.int32)
    c1 = prefix_chain(toks, 8)
    c2 = prefix_chain(toks, 8)
    assert c1 == c2 and len(c1) == 2  # only FULL pages are fingerprinted
    # chain property: equal leading blocks -> equal chain prefix, and the
    # first divergent block breaks every later digest
    other = toks.copy()
    other[9] = 99
    c3 = prefix_chain(other, 8)
    assert c3[0] == c1[0] and c3[1] != c1[1]
    # the bucket-length salt separates otherwise-identical prompts: prefix
    # K/V is only bitwise-reproducible within one padded length
    assert prefix_chain(toks, 8, salt="lb=24") != prefix_chain(toks, 8, salt="lb=32")


def test_prefix_cache_lru_evicts_only_unpinned():
    pool = PagePool(5, page_size=4)
    cache = PrefixCache(pool)
    held = pool.alloc(2)
    cache.insert(["a", "b"], held)  # refcount 2 each (caller + index)
    assert len(cache) == 2 and pool.free == 2
    # pinned pages never evict
    assert cache.evict(need_free=4) == 0
    pool.release(held)  # caller drops; index still holds both
    got = cache.lookup(["a", "b", "c"])
    assert got == held  # longest-prefix hit, retained for us
    assert cache.hits == 1 and cache.hit_tokens == 8
    pool.release(got)
    assert cache.evict(need_free=4) == 2 and pool.free == 4
    assert cache.lookup(["a"]) == [] and cache.misses == 1


# ---------------------------------------------------------------------------
# kernel twin: bitwise + one pallas_call
# ---------------------------------------------------------------------------
def test_paged_attention_kernel_bitwise_and_single_call():
    key = jax.random.PRNGKey(0)
    b, h, kvh, dh, pool_pages, page, np_ = 3, 4, 2, 16, 9, 8, 4
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (pool_pages, page, kvh, dh), jnp.float32)
    vp = jax.random.normal(ks[2], (pool_pages, page, kvh, dh), jnp.float32)
    # ragged page tables with -1 holes past each row's allocation
    pt = np.full((b, np_), -1, np.int32)
    pt[0, :2] = [3, 7]
    pt[1, :4] = [1, 2, 5, 8]
    pt[2, :1] = [4]
    lengths = jnp.asarray([13, 32, 5], jnp.int32)
    pt = jnp.asarray(pt)
    out = paged_decode_attention(q, kp, vp, pt, lengths)
    ref = paged_decode_attention_ref(q, kp, vp, pt, lengths)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    jx = jax.make_jaxpr(
        lambda *a: paged_decode_attention(*a, interpret=True)
    )(q, kp, vp, pt, lengths)
    assert primitive_count(jx, "pallas_call") == 1


def test_paged_decode_step_single_pallas_call_per_layer(setup):
    cfg, params = setup
    caches = lm.init_paged_caches(cfg, 2, num_pages=9, page_size=8)
    pt = jnp.zeros((2, 4), jnp.int32)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    jx = jax.make_jaxpr(
        lambda p, c, t, s, g: lm.decode_step(p, c, t, s, cfg, page_table=g)
    )(params, caches, tok, pos, pt)
    # the layer stack is one lax.scan: the whole decode traces ONE
    # pallas_call (inside the scan body), not one per layer
    assert primitive_count(jx, "pallas_call") == 1


# ---------------------------------------------------------------------------
# serve-level bitwise identity
# ---------------------------------------------------------------------------
def test_paged_serve_bitwise_identical_to_dense(setup, dense_engine, paged_engine):
    cfg, _ = setup
    reqs = _ragged_requests(cfg)
    outs_d = dense_engine.serve(_ragged_requests(cfg))
    outs_p = paged_engine.serve(reqs)
    for i, (a, b) in enumerate(zip(outs_d, outs_p)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    st = paged_engine.stats
    assert st.peak_active <= 4
    assert st.pool_peak_pages <= paged_engine.pool.capacity
    # every retired page came back (only the prefix index may pin pages)
    pool = paged_engine.pool
    pinned = len(set(paged_engine.prefix_cache.pages.values()))
    assert pool.free == pool.capacity - pinned


def test_page_frac_accounting(setup, paged_engine):
    cfg, _ = setup
    paged_engine.serve(_ragged_requests(cfg))
    st = paged_engine.stats
    sched = st.sched
    assert sched.page_tokens >= sched.live_tokens > 0
    assert 0.0 <= st.page_frac < 1.0
    assert st.page_frac == pytest.approx(
        (sched.page_tokens - sched.live_tokens) / sched.page_tokens
    )


def test_warm_prefix_bitwise_identical_to_cold(setup):
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, (37,)).astype(np.int32)
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=8,
                 paged=True, page_size=8)
    cold = eng.serve([GenRequest(prompt, 6, seed=1)])[0]
    assert eng.stats.prefix_hits == 0
    warm = eng.serve([GenRequest(prompt, 6, seed=1)])[0]
    np.testing.assert_array_equal(cold, warm)
    # lookup stops strictly before the last prompt token: (37-1)//8 = 4
    # pages = 32 tokens reused, 5 suffix tokens re-prefilled
    assert eng.stats.prefix_hits == 1
    assert eng.stats.prefix_hit_tokens == 32
    dense = Engine(params, cfg, max_len=64, slots=2, bucket=8)
    np.testing.assert_array_equal(dense.serve([GenRequest(prompt, 6, seed=1)])[0], warm)


def test_copy_on_write_divergent_sharer_does_not_perturb(setup):
    """A prompt sharing a donor's prefix pages but diverging mid-prompt must
    (a) produce its own correct output and (b) leave the donor's shared
    pages untouched — CoW is structural: shared pages are never written."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    a = rng.integers(0, cfg.vocab_size, (37,)).astype(np.int32)
    b = a.copy()
    b[20] = (b[20] + 1) % cfg.vocab_size  # diverge inside page 2 of 8
    dense = Engine(params, cfg, max_len=64, slots=2, bucket=8)
    want_a = dense.serve([GenRequest(a, 6, seed=1)])[0]
    want_b = dense.serve([GenRequest(b, 6, seed=2)])[0]
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=8,
                 paged=True, page_size=8)
    eng.serve([GenRequest(a, 6, seed=1)])  # donor populates the prefix cache
    outs = eng.serve([GenRequest(a, 6, seed=1), GenRequest(b, 6, seed=2)])
    np.testing.assert_array_equal(outs[0], want_a)
    np.testing.assert_array_equal(outs[1], want_b)
    # after retirement only the index holds references — nothing leaked a
    # write-protecting refcount
    for page in set(eng.prefix_cache.pages.values()):
        assert eng.pool.refcount(page) == 1
    # and the donor still serves warm + bitwise
    np.testing.assert_array_equal(eng.serve([GenRequest(a, 6, seed=1)])[0], want_a)


def test_pool_exhaustion_queues_and_stays_bitwise(setup):
    """5 requests of 3 pages each against a 6-page pool: at most 2 fit at
    once, the rest re-queue (no crash, no corruption), outputs stay
    bitwise-identical to dense."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    reqs = [
        GenRequest(rng.integers(0, cfg.vocab_size, (20,)).astype(np.int32),
                   max_new_tokens=4, seed=10 + i)
        for i in range(5)
    ]
    eng = Engine(params, cfg, max_len=64, slots=8, bucket=4,
                 paged=True, page_size=8, pool_pages=7, prefix_reuse=False)
    outs = eng.serve(reqs)
    assert eng.stats.peak_active <= 2
    assert eng.pool.failed_allocs > 0
    assert eng.pool.free == eng.pool.capacity
    dense = Engine(params, cfg, max_len=64, slots=8, bucket=4)
    for a, b in zip(dense.serve(reqs), outs):
        np.testing.assert_array_equal(a, b)


def test_oversized_request_rejected_upfront(setup):
    cfg, params = setup
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=4,
                 paged=True, page_size=8, pool_pages=5)
    big = GenRequest(np.zeros((30,), np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="pool only holds"):
        eng.serve([big])


def test_unpageable_archs_rejected():
    for arch, err in [("mixtral_8x22b", "sliding-window"), ("mamba2_1_3b", "SSM"),
                      ("hymba_1_5b", "sliding-window")]:
        cfg = get_config(arch).reduced()
        with pytest.raises(ValueError, match=err):
            Engine(None, cfg, max_len=64, paged=True, page_size=8)


@pytest.mark.parametrize("arch", ["qwen2_vl_2b", "whisper_tiny"])
def test_families_paged_identical_to_dense(arch):
    """Every row-independent pageable family (vlm prefix offset, encdec
    cross caches) serves bitwise-identically paged vs dense.  MoE is
    excluded here exactly as in the dense ragged suite: expert capacity
    couples batch rows, and the *idle-slot* garbage rows differ between
    dense (stale cache) and paged (scrap page), so the coupled live rows
    can legitimately diverge.  Hymba/Mixtral are sliding-window (not
    pageable, rejected above); Mamba2 is pure SSM (no KV to page)."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                   max_new_tokens=n, seed=i)
        for i, (s, n) in enumerate([(5, 4), (8, 2), (3, 6)])
    ]
    dense = Engine(params, cfg, max_len=64, slots=2, bucket=4)
    paged = Engine(params, cfg, max_len=64, slots=2, bucket=4,
                   paged=True, page_size=16)
    for a, b in zip(dense.serve(reqs), paged.serve(reqs)):
        np.testing.assert_array_equal(a, b)


def test_moe_paged_smoke_and_deterministic():
    """MoE serves paged (shapes + repeatability); bitwise-vs-dense is not
    asserted because expert capacity couples rows with the idle-slot
    garbage, which differs by cache layout (see the families test)."""
    cfg = get_config("granite_moe_1b_a400m").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    reqs = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                   max_new_tokens=n, seed=i)
        for i, (s, n) in enumerate([(5, 4), (8, 2), (3, 6)])
    ]
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=4,
                 paged=True, page_size=16)
    outs1 = eng.serve(reqs)
    for r, o in zip(reqs, outs1):
        assert o.shape == (len(r.tokens) + r.max_new_tokens,)
    eng2 = Engine(params, cfg, max_len=64, slots=2, bucket=4,
                  paged=True, page_size=16)
    for a, b in zip(outs1, eng2.serve(reqs)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# EOS early exit
# ---------------------------------------------------------------------------
def test_eos_early_exit_truncates_and_saves_dispatches(setup, dense_engine):
    cfg, params = setup
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
    base = dense_engine.serve([GenRequest(prompt, 10, seed=1)])[0]
    n_base = dense_engine.stats.decode_dispatches
    eos_tok = int(base[len(prompt) + 2])  # the third generated token
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=4,
                 paged=True, page_size=8, eos_poll=2)
    out = eng.serve([GenRequest(prompt, 10, seed=1, eos_token=eos_tok)])[0]
    # output ends AT the eos token (included), budget unspent
    np.testing.assert_array_equal(out, base[: len(prompt) + 3])
    assert eng.stats.early_exits == 1
    assert eng.stats.decode_dispatches < n_base
    assert eng.stats.generated_tokens == 3
    # early retirement freed the pages
    assert eng.pool.free == eng.pool.capacity - len(
        set(eng.prefix_cache.pages.values())
    )


def test_eos_never_sampled_runs_full_budget(setup):
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    dense = Engine(params, cfg, max_len=64, slots=2, bucket=4)
    base = dense.serve([GenRequest(prompt, 6, seed=3)])[0]
    gen = base[len(prompt):]
    absent = int(next(t for t in range(cfg.vocab_size) if t not in set(gen.tolist())))
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=4)
    out = eng.serve([GenRequest(prompt, 6, seed=3, eos_token=absent)])[0]
    np.testing.assert_array_equal(out, base)
    assert eng.stats.early_exits == 0


def test_eos_works_in_dense_mode_mixed_batch(setup, dense_engine):
    """eos_token composes with the dense engine and with non-eos flight
    mates: the non-eos request's output is untouched."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    p1 = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    base = dense_engine.serve([GenRequest(p1, 8, seed=1), GenRequest(p2, 8, seed=2)])
    eos_tok = int(base[0][len(p1) + 1])  # second generated token of req 1
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=4, eos_poll=1)
    outs = eng.serve([GenRequest(p1, 8, seed=1, eos_token=eos_tok),
                      GenRequest(p2, 8, seed=2)])
    np.testing.assert_array_equal(outs[0], base[0][: len(p1) + 2])
    np.testing.assert_array_equal(outs[1], base[1])
    assert eng.stats.early_exits == 1


# ---------------------------------------------------------------------------
# mesh-sharded serving (ISSUE 10): per-shard pools, bitwise identity
# ---------------------------------------------------------------------------
def test_sharded_page_pool_disjoint_ranges():
    pool = ShardedPagePool(shards=4, pages_per_shard=4, page_size=8)
    assert pool.capacity == 12 and pool.shard_capacity == 3
    assert [pool.scrap(k) for k in range(4)] == [0, 4, 8, 12]
    a = pool.alloc(3, shard=1)
    assert a is not None and all(4 < p < 8 for p in a)
    assert pool.shard_used() == [0, 3, 0, 0]
    # all-or-nothing WITHIN the shard: shard 1 is full, shard 2 has room,
    # but pages are never borrowed across shards
    assert pool.alloc(1, shard=1) is None
    assert pool.failed_allocs == 1
    b = pool.alloc(2, shard=2)
    assert all(8 < p < 12 for p in b)
    # retain/release route by global id range
    pool.retain(a + b)
    assert pool.refcount(a[0]) == 2 and pool.refcount(b[0]) == 2
    pool.release(a + b)
    pool.release(a + b)
    assert pool.free == pool.capacity and pool.used == 0
    # scrap pages are never allocatable or releasable
    with pytest.raises(ValueError):
        pool.release([pool.scrap(2)])


def test_sharded_serve_bitwise_identical_to_single_device(setup):
    """Acceptance: per-request outputs of a shards>1 paged serve are
    bitwise-identical to the single-device paged serve (per-slot rows are
    computed independently, so shard placement must not change a bit)."""
    cfg, params = setup
    reqs = _ragged_requests(cfg)
    base = Engine(params, cfg, max_len=64, slots=4, bucket=4,
                  paged=True, page_size=8)
    want = base.serve(_ragged_requests(cfg))
    eng = Engine(params, cfg, max_len=64, slots=4, bucket=4,
                 paged=True, page_size=8, shards=4)
    got = eng.serve(reqs)
    assert len(got) == len(want)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    # per-shard accounting is live: peak cost was tracked for every shard
    assert len(eng.stats.shard_peak_cost) == 4
    assert all(c > 0 for c in eng.stats.shard_peak_cost)


def test_mesh_sharded_serve_bitwise_and_parked_pool(setup):
    """mesh= derives the shard count from the mesh axis; outputs stay
    bitwise-identical, and a SECOND serve() (which reuses the mesh-parked
    KV pool) is bitwise-identical too."""
    from repro.launch.mesh import make_mesh

    cfg, params = setup
    mesh = make_mesh((8,), ("model",))
    base = Engine(params, cfg, max_len=64, slots=8, bucket=4,
                  paged=True, page_size=8)
    want = base.serve(_ragged_requests(cfg))
    eng = Engine(params, cfg, max_len=64, slots=8, bucket=4,
                 paged=True, page_size=8, mesh=mesh)
    assert eng.shards == 8
    for w, g in zip(want, eng.serve(_ragged_requests(cfg))):
        np.testing.assert_array_equal(w, g)
    for w, g in zip(want, eng.serve(_ragged_requests(cfg))):
        np.testing.assert_array_equal(w, g)


def test_sharded_serve_balances_shard_cost(setup):
    """The shard-aware take() keeps per-shard peak cost closer together
    than the worst case (all heavy requests on one shard)."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    # two heavy + two light requests, admitted into 4 slots over 2 shards
    reqs = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                   max_new_tokens=n, temperature=0.0, seed=i)
        for i, (s, n) in enumerate([(12, 12), (12, 12), (2, 2), (2, 2)])
    ]
    eng = Engine(params, cfg, max_len=64, slots=4, bucket=4,
                 paged=True, page_size=8, shards=2)
    eng.serve(reqs)
    peak = eng.stats.shard_peak_cost
    assert len(peak) == 2
    # each shard got one heavy + one light request, not heavy+heavy
    assert max(peak) < 2 * 24 and min(peak) > 0
    assert abs(peak[0] - peak[1]) < 24


def test_sharded_prefix_cache_is_shard_local(setup):
    """Prefix reuse still works sharded — but an entry only hits for slots
    on its own shard (pages are never borrowed across shards)."""
    cfg, params = setup
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    reqs = [GenRequest(np.concatenate([shared, rng.integers(0, cfg.vocab_size, (2,)).astype(np.int32)]),
                       max_new_tokens=3, temperature=0.0, seed=50 + i)
            for i in range(4)]
    base = Engine(params, cfg, max_len=64, slots=2, bucket=4,
                  paged=True, page_size=8)
    want = base.serve(reqs)
    eng = Engine(params, cfg, max_len=64, slots=2, bucket=4,
                 paged=True, page_size=8, shards=2)
    got = eng.serve(reqs)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    hits = sum(c.hits for c in eng.prefix_caches if c is not None)
    assert hits >= 1  # same-shard reuse happened
    # every cached page lives on its cache's own shard
    for k, c in enumerate(eng.prefix_caches):
        for page in c.pages.values():
            assert eng.pool.shard_of(page) == k
