"""Factorization-artifact tests: the factor→solve contract.

* factor entry points return a solve-ready :class:`Factorization` (packed
  factors + factor-time diagonal-block inverses + layout/tier metadata);
* the Pallas inverted-diagonal kernels and their pure-jnp mirrors are
  bitwise twins across {n, bw, batch};
* legacy raw-ndarray operands still flow through every solve entry point
  (one-release shim);
* the solve service caches the artifact itself — a cache hit performs zero
  factor/health dispatches (asserted via registry dispatch hooks);
* stacked-RHS solves match per-request solves column-for-column.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import solvers
from repro.core import make_diagonally_dominant
from repro.core import factorization as fz
from repro.core.banded import make_banded_dd
from repro.kernels import banded as kbanded
from repro.kernels import ops as kops
from repro.kernels import trsm as ktrsm
from repro.serve.solve_service import SolveService, fingerprint


# ---------------------------------------------------------------------------
# artifact contract
# ---------------------------------------------------------------------------
def test_dense_factor_returns_artifact():
    a = make_diagonally_dominant(jax.random.PRNGKey(0), 96)
    art = kops.lu(a, enrich=True)
    assert isinstance(art, fz.Factorization)
    assert art.structure == "dense" and art.enriched and not art.batched
    assert art.linv is not None and art.uinv is not None
    # ndarray duck-typing shim: legacy consumers see the packed factors
    assert art.shape == (96, 96) and art.ndim == 2
    np.testing.assert_array_equal(np.asarray(art), np.asarray(art.packed))


def test_banded_factor_returns_artifact():
    n, bw = 256, 8
    g = make_banded_dd(jax.random.PRNGKey(0), n, bw)
    art = kops.banded_lu(g, bw=bw, enrich=True)
    assert isinstance(art, fz.Factorization)
    assert art.structure == "banded" and art.bw == bw and art.enriched
    assert art.tlo is not None and art.tup is not None


def test_unenriched_artifact_carries_no_inverses():
    a = make_diagonally_dominant(jax.random.PRNGKey(0), 96)
    art = kops.lu(a)
    assert isinstance(art, fz.Factorization) and not art.enriched
    assert art.linv is None
    # ensure-enriched shim upgrades it on demand, idempotently
    full = fz.dense_artifact(art)
    assert full.enriched and fz.dense_artifact(full) is full


# ---------------------------------------------------------------------------
# kernel ≡ mirror, bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,bw", [(128, 4), (384, 8), (256, 16)])
def test_banded_inverted_kernel_mirror_bitwise(n, bw):
    g = make_banded_dd(jax.random.PRNGKey(n), n, bw)
    art = kops.banded_lu(g, bw=bw, enrich=True)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    k = kbanded.banded_solve_inverted(
        art.linv, art.uinv, art.tlo, art.tup, b, n=n, bw=bw)
    m = fz.banded_inverted_solve(
        art.linv, art.uinv, art.tlo, art.tup, b, n=n, bw=bw)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(m))


@pytest.mark.parametrize("n", [96, 256])
def test_dense_inverted_kernel_mirror_bitwise(n):
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    art = kops.lu(a, enrich=True)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, 8))
    k = ktrsm.solve_inverted(art.packed, art.linv, art.uinv, b)
    m = fz.dense_inverted_solve(art.packed, art.linv, art.uinv, b,
                                block=art.block)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(m))


def test_batched_artifact_solve():
    bsz, n = 4, 96
    a3 = jnp.stack([make_diagonally_dominant(jax.random.PRNGKey(i), n)
                    for i in range(bsz)])
    art = kops.lu(a3, enrich=True)
    assert isinstance(art, fz.Factorization) and art.batched and art.enriched
    b3 = jax.random.normal(jax.random.PRNGKey(9), (bsz, n, 8))
    x3 = kops.lu_solve(art, b3)
    for i in range(bsz):
        resid = jnp.linalg.norm(a3[i] @ x3[i] - b3[i]) / jnp.linalg.norm(b3[i])
        assert float(resid) < 1e-5


# ---------------------------------------------------------------------------
# legacy-array shim (one release)
# ---------------------------------------------------------------------------
def test_lu_solve_accepts_legacy_packed_array():
    n = 96
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    art = kops.lu(a, enrich=True)
    raw = jnp.asarray(np.asarray(art.packed))  # a plain ndarray, no metadata
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    x_raw = kops.lu_solve(raw, b)
    resid = jnp.linalg.norm(a @ x_raw - b) / jnp.linalg.norm(b)
    assert float(resid) < 1e-5


def test_banded_solve_accepts_legacy_packed_array():
    n, bw = 256, 8
    g = make_banded_dd(jax.random.PRNGKey(0), n, bw)
    art = kops.banded_lu(g, bw=bw, enrich=True)
    raw = jnp.asarray(np.asarray(art.packed))
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    x_art = kops.banded_solve(art, b, bw=bw, impl="xla_scalar")
    x_raw = kops.banded_solve(raw, b, bw=bw, impl="xla_scalar")
    np.testing.assert_array_equal(np.asarray(x_art), np.asarray(x_raw))


def test_linear_solve_accepts_raw_operands():
    n = 96
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    x = kops.linear_solve(a, b)
    resid = jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b)
    assert float(resid) < 1e-5


# ---------------------------------------------------------------------------
# solve-service round trip: the cache payload is the artifact
# ---------------------------------------------------------------------------
def test_service_cache_stores_artifact_and_hits_skip_screening():
    n, bw = 512, 8
    g = make_banded_dd(jax.random.PRNGKey(0), n, bw)
    bs = [jax.random.normal(jax.random.PRNGKey(10 + i), (n,)) for i in range(3)]
    svc = SolveService()

    with solvers.record_dispatches() as cold:
        x0 = svc.solve(g, bs[0], bw=bw)
    assert sum(p.op == "factor" for p, _ in cold) == 1

    # cached payload is the enriched artifact, stamped with the fingerprint
    fp = fingerprint(g, bw=bw)
    cached = svc._lru[fp][0.0]
    assert isinstance(cached, fz.Factorization)
    assert cached.enriched and cached.fingerprint == fp

    # a hit re-derives NOTHING: no factor dispatch (health screening rides
    # the factor dispatch, so zero factor dispatches == zero re-screens)
    with solvers.record_dispatches() as warm:
        x1 = svc.solve(g, bs[1], bw=bw)
        x2 = svc.solve(g, bs[2], bw=bw)
    assert sum(p.op == "factor" for p, _ in warm) == 0
    assert sum(p.op == "solve" for p, _ in warm) == 2
    assert svc.stats.cache_hits == 2

    for b, x in zip(bs, (x0, x1, x2)):
        ref = kops.banded_solve(cached, b, bw=bw)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(ref))


# ---------------------------------------------------------------------------
# stacked-RHS ≡ per-request
# ---------------------------------------------------------------------------
def test_stacked_rhs_matches_per_request_solves():
    n, bw, r = 512, 8, 16
    g = make_banded_dd(jax.random.PRNGKey(0), n, bw)
    art = kops.banded_lu(g, bw=bw, enrich=True)
    bm = jax.random.normal(jax.random.PRNGKey(1), (n, r))
    stacked = kops.banded_solve(art, bm, bw=bw, impl="pallas_inverted")
    singles = jnp.stack(
        [kops.banded_solve(art, bm[:, i], bw=bw, impl="pallas_inverted")
         for i in range(r)], axis=1)
    # NOT bitwise by design: the equalized RHS tiling batches the GEMMs at a
    # width-dependent tile, which changes the reduction order in the last
    # bits.  The columns must still agree to solver accuracy.
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(singles),
                               rtol=2e-5, atol=1e-6)
