"""`repro.solvers` registry: measurement-driven selection (ISSUE 4
acceptance), capability filtering, static-fallback parity with the
historical dispatch, batched/vmap routing, and the multi-device backend."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_diagonally_dominant, to_banded
from repro.core.banded import make_banded_dd
from repro.kernels import ops
from repro.solvers import (
    AutotuneCache,
    Problem,
    backends_for,
    candidates,
    get_backend,
    select,
)
from repro.solvers import cache as scache
from repro.utils.hlo import primitive_count


@pytest.fixture
def no_cache(monkeypatch, tmp_path):
    """Pin an absent cache file so selection is purely static."""
    monkeypatch.setenv("REPRO_SOLVERS_CACHE", str(tmp_path / "absent.json"))
    scache.invalidate()
    yield
    scache.invalidate()


def _env_cache(monkeypatch, tmp_path, entries):
    path = tmp_path / "cache.json"
    path.write_text(json.dumps({"version": 1, "entries": entries}))
    monkeypatch.setenv("REPRO_SOLVERS_CACHE", str(path))
    scache.invalidate()
    return path


# ---------------------------------------------------------------------------
# Problem descriptor
# ---------------------------------------------------------------------------
def test_problem_from_arrays():
    a = jnp.zeros((64, 64))
    p = Problem.from_arrays("factor", a)
    assert (p.structure, p.n, p.batch, p.bw) == ("dense", 64, 1, 0)
    p = Problem.from_arrays("solve", jnp.zeros((5, 64, 64)), jnp.zeros((5, 64, 3)))
    assert (p.structure, p.batch, p.rhs) == ("batched_dense", 5, 3)
    p = Problem.from_arrays("solve", jnp.zeros((5, 64, 64)), jnp.zeros((5, 64)))
    assert p.rhs == 1
    p = Problem.from_arrays("factor", jnp.zeros((64, 9)), bw=4)
    assert (p.structure, p.n, p.bw) == ("banded", 64, 4)
    p = Problem.from_arrays("factor", jnp.zeros((3, 64, 9), jnp.bfloat16), bw=4)
    assert (p.structure, p.batch, p.dtype) == ("batched_banded", 3, "bfloat16")
    with pytest.raises(ValueError, match="unknown op"):
        Problem(op="nope", structure="dense", n=8)
    with pytest.raises(ValueError, match="leading batch axis"):
        Problem.from_arrays("factor", jnp.zeros((2, 2, 8, 8)))


# ---------------------------------------------------------------------------
# acceptance: selection is measurement-driven (synthetic cache A vs inverted
# vs no cache == today's static choices)
# ---------------------------------------------------------------------------
def test_registry_shootout_measured_and_inverted_and_static(no_cache):
    p = Problem(op="factor", structure="dense", n=256)
    prefer_a = AutotuneCache(entries=[{
        "op": "factor", "structure": "dense", "dtype": "float32", "bw": 0,
        "n": 256, "times_us": {"pallas_fused": 10.0, "xla": 99.0},
    }])
    assert select(p, cache=prefer_a).name == "pallas_fused"
    prefer_b = AutotuneCache(entries=[{
        "op": "factor", "structure": "dense", "dtype": "float32", "bw": 0,
        "n": 256, "times_us": {"pallas_fused": 99.0, "xla": 10.0},
    }])
    assert select(p, cache=prefer_b).name == "xla"
    # no cache → the historical static default
    assert select(p, cache=AutotuneCache()).name == "pallas_fused"
    assert select(p).name == "pallas_fused"  # env pinned to an absent file


def test_static_choices_reproduce_historical_dispatch(no_cache):
    # dense solve: VMEM driver to 2048, tiled beyond (the old threshold)
    assert select(Problem(op="solve", structure="dense", n=512, rhs=4)).name == "pallas_vmem"
    assert select(Problem(op="solve", structure="dense", n=4096, rhs=4)).name == "pallas_tiled"
    # banded factor: the old 6 MB skewed-band VMEM byte rule
    assert select(Problem(op="factor", structure="banded", n=512, bw=4)).name == "pallas_blocked"
    assert select(Problem(op="factor", structure="banded", n=200_000, bw=16)).name == "pallas_tiled"
    assert ops._banded_auto_impl(512, 4, None, 4) == "pallas_blocked"
    assert ops._banded_auto_impl(200_000, 16, None, 4) == "pallas_tiled"
    # banded solve: statically the blocked kernel (measurement may override)
    assert select(Problem(op="solve", structure="banded", n=96, bw=4, rhs=1)).name == "pallas"
    # batched dense: the VMEM grid kernel for small fp32 systems
    assert select(Problem(op="factor", structure="batched_dense", n=128, batch=8)).name == "pallas_vmem"


def test_capability_filter_and_forced_impl(no_cache):
    # fp32-only backends drop out for bf16; static fallback is the mirror
    p16 = Problem(op="factor", structure="dense", n=64, dtype="bfloat16")
    names = [b.name for b in candidates(p16)]
    assert "pallas_fused" not in names and "pallas_vmem" not in names
    assert select(p16).name == "xla"
    # devices>1 matches only the shard_map backend — and vice versa
    pd = Problem(op="factor", structure="dense", n=256, devices=8)
    assert [b.name for b in candidates(pd)] == ["distributed"]
    assert all(b.name != "distributed" for b in candidates(Problem(op="factor", structure="dense", n=256)))
    # forced-impl override bypasses auto; unknown names raise the old error
    assert select(p16, impl="pallas_blocked").name == "pallas_blocked"
    with pytest.raises(ValueError, match="unknown impl"):
        get_backend("factor", "dense", "nope")
    with pytest.raises(ValueError, match="unknown impl"):
        ops.lu(jnp.zeros((8, 8)), impl="nope")


def test_nearest_size_guard(no_cache):
    # a 16384-order measurement must not steer a 96-order dispatch (> 4x
    # away), but must steer an 8192-order one (2x away)
    cache = AutotuneCache(entries=[{
        "op": "solve", "structure": "banded", "dtype": "float32", "bw": 16,
        "n": 16384, "times_us": {"pallas": 8139.0, "xla_scalar": 2385.0},
    }])
    near = Problem(op="solve", structure="banded", n=8192, bw=16, rhs=1)
    far = Problem(op="solve", structure="banded", n=96, bw=4, rhs=1)
    assert select(near, cache=cache).name == "xla_scalar"
    assert select(far, cache=cache).name == "pallas"
    assert cache.best(far, ["pallas", "xla_scalar"]) is None


def test_cache_devices_exact_key_field(tmp_path):
    """`devices` is an exact-match key field (like `tolerance`): a mesh
    measurement never steers single-device dispatch, nor another mesh size,
    and pre-devices cache rows load as devices=1."""
    row8 = {
        "op": "factor", "structure": "banded", "dtype": "float32", "bw": 16,
        "n": 16384, "devices": 8, "times_us": {"spike": 10.0, "replicated": 99.0},
    }
    cache = AutotuneCache(entries=[dict(row8)])
    p8 = Problem(op="factor", structure="banded", n=16384, bw=16, devices=8)
    p1 = Problem(op="factor", structure="banded", n=16384, bw=16)
    p4 = Problem(op="factor", structure="banded", n=16384, bw=16, devices=4)
    assert cache.best(p8, ["spike", "replicated"]) == "spike"
    assert cache.best(p1, ["spike", "replicated"]) is None
    assert cache.best(p4, ["spike", "replicated"]) is None
    # recording the single-device shape keys a DISTINCT row, and both
    # round-trip with the devices field intact
    cache.record(p1, {"pallas_blocked": 5.0})
    assert len(cache.entries) == 2
    path = tmp_path / "c.json"
    cache.path = str(path)
    cache.save()
    loaded = AutotuneCache.load(str(path))
    assert loaded.best(p8, ["spike", "replicated"]) == "spike"
    assert loaded.best(p1, ["pallas_blocked", "spike"]) == "pallas_blocked"
    # a pre-devices row (field absent) deserializes as a devices=1 row
    legacy = dict(row8)
    del legacy["devices"]
    path.write_text(json.dumps({"version": 1, "entries": [legacy]}))
    legacy_cache = AutotuneCache.load(str(path))
    assert legacy_cache.best(p1, ["spike", "replicated"]) == "spike"
    assert legacy_cache.best(p8, ["spike", "replicated"]) is None


def test_cache_roundtrip_and_record_merge(tmp_path):
    path = tmp_path / "c.json"
    cache = AutotuneCache(path=str(path))
    p = Problem(op="factor", structure="dense", n=333)
    cache.record(p, {"pallas_fused": 7.0})
    cache.record(p, {"xla": 5.0})  # merges into the same entry
    cache.save()
    loaded = AutotuneCache.load(str(path))
    assert len(loaded.entries) == 1
    assert loaded.best(p, ["pallas_fused", "xla"]) == "xla"
    # candidates not in the entry are ignored; empty intersection -> None
    assert loaded.best(p, ["pallas_fused"]) == "pallas_fused"
    assert loaded.best(p, ["something_else"]) is None
    # corrupt file degrades to an empty cache, not an exception
    path.write_text("{not json")
    assert AutotuneCache.load(str(path)).entries == []


def test_env_cache_steers_public_ops(monkeypatch, tmp_path):
    """End-to-end: the persisted cache flips ops.banded_solve's auto path."""
    n, bw = 96, 4
    ad = make_diagonally_dominant(jax.random.PRNGKey(0), n, sparse_band=bw)
    arow = to_banded(ad, bw)
    lub = ops.banded_lu(arow, bw=bw, impl="pallas_blocked")
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    entry = {"op": "solve", "structure": "banded", "dtype": "float32",
             "bw": bw, "n": n, "times_us": {"pallas": 99.0, "xla_scalar": 1.0}}
    _env_cache(monkeypatch, tmp_path, [entry])
    jx = jax.make_jaxpr(lambda l, r: ops.banded_solve(l, r, bw=bw))(lub, b)
    assert primitive_count(jx, "pallas_call") == 0  # measured winner: jnp loop
    entry["times_us"] = {"pallas": 1.0, "xla_scalar": 99.0}
    _env_cache(monkeypatch, tmp_path, [entry])
    jx = jax.make_jaxpr(lambda l, r: ops.banded_solve(l, r, bw=bw))(lub, b)
    assert primitive_count(jx, "pallas_call") == 1  # measured winner: kernel
    scache.invalidate()


# ---------------------------------------------------------------------------
# batched + vmap routing through the public ops
# ---------------------------------------------------------------------------
def test_ops_lu_batched_and_vmap_route_to_grid_kernel(no_cache):
    from repro.kernels.batched_lu import batched_lu_vmem

    stack = jnp.stack([make_diagonally_dominant(jax.random.PRNGKey(i), 48) for i in range(4)])
    want = np.asarray(batched_lu_vmem(stack))
    np.testing.assert_array_equal(np.asarray(ops.lu(stack)), want)
    np.testing.assert_array_equal(np.asarray(jax.vmap(lambda m: ops.lu(m))(stack)), want)
    # ONE batched pallas_call, not 4 lifted unbatched kernels
    jx = jax.make_jaxpr(lambda s: ops.lu(s))(stack)
    assert primitive_count(jx, "pallas_call") == 1
    jx = jax.make_jaxpr(jax.vmap(lambda m: ops.lu(m)))(stack)
    assert primitive_count(jx, "pallas_call") == 1
    # forced xla names map to the vmapped mirror (no pallas)
    jx = jax.make_jaxpr(lambda s: ops.lu(s, impl="xla"))(stack)
    assert primitive_count(jx, "pallas_call") == 0


def test_ops_banded_batched_and_vmap(no_cache):
    from repro.kernels.banded import batched_banded_lu_vmem

    n, bw = 40, 3
    bands = jnp.stack([make_banded_dd(jax.random.PRNGKey(i), n, bw) for i in range(3)])
    want = np.asarray(batched_banded_lu_vmem(bands, bw=bw))
    np.testing.assert_array_equal(np.asarray(ops.banded_lu(bands, bw=bw)), want)
    np.testing.assert_array_equal(
        np.asarray(jax.vmap(lambda m: ops.banded_lu(m, bw=bw))(bands)), want
    )
    jx = jax.make_jaxpr(jax.vmap(lambda m: ops.banded_lu(m, bw=bw)))(bands)
    assert primitive_count(jx, "pallas_call") == 1
    # batched banded solve: vector and matrix RHS
    lub = ops.banded_lu(bands, bw=bw)
    bv = jax.random.normal(jax.random.PRNGKey(9), (3, n))
    xv = ops.banded_solve(lub, bv, bw=bw)
    assert xv.shape == (3, n)
    for i in range(3):
        x1 = ops.banded_solve(lub[i], bv[i], bw=bw, impl="pallas")
        np.testing.assert_allclose(np.asarray(xv[i]), np.asarray(x1), atol=1e-5)


def test_batched_impl_aliases(no_cache):
    """Forced impl names on batched inputs map to their batched analog —
    including the legacy 'pallas' auto alias on the banded ops (regression:
    the alias used to be pre-mapped to 'pallas_vmem' and then rejected by
    the unbatched slot's name validation)."""
    n, bw = 40, 3
    bands = jnp.stack([make_banded_dd(jax.random.PRNGKey(i), n, bw) for i in range(3)])
    want = np.asarray(ops.banded_lu(bands, bw=bw))
    for impl in ("pallas", "pallas_blocked", "pallas_tiled"):
        np.testing.assert_array_equal(np.asarray(ops.banded_lu(bands, bw=bw, impl=impl)), want)
    lub = ops.banded_lu(bands, bw=bw)
    bv = jax.random.normal(jax.random.PRNGKey(5), (3, n))
    np.testing.assert_array_equal(
        np.asarray(ops.banded_solve(lub, bv, bw=bw, impl="pallas")),
        np.asarray(ops.banded_solve(lub, bv, bw=bw)),
    )
    stack = jnp.stack([make_diagonally_dominant(jax.random.PRNGKey(i), 32) for i in range(2)])
    lus = ops.lu(stack)
    bs = jax.random.normal(jax.random.PRNGKey(6), (2, 32, 3))
    np.testing.assert_array_equal(
        np.asarray(ops.lu_solve(lus, bs, impl="pallas")),
        np.asarray(ops.lu_solve(lus, bs, impl="pallas_vmem")),
    )
    with pytest.raises(ValueError, match="unknown impl"):
        ops.banded_lu(bands, bw=bw, impl="nope")


def test_linear_solve_batched_end_to_end(no_cache):
    stack = jnp.stack([make_diagonally_dominant(jax.random.PRNGKey(i + 7), 64) for i in range(5)])
    b = jax.random.normal(jax.random.PRNGKey(3), (5, 64, 3))
    x = ops.linear_solve(stack, b)
    for i in range(5):
        res = np.linalg.norm(np.asarray(stack[i] @ x[i] - b[i])) / np.linalg.norm(np.asarray(b[i]))
        assert res < 1e-4
    from repro.core.batched import batched_linear_solve

    x_auto = batched_linear_solve(stack, b, method="auto")
    np.testing.assert_allclose(np.asarray(x_auto), np.asarray(x), atol=1e-5)
    # extra leading batch dims fold through BOTH phases (factor used to
    # fold while the solve phase rejected the 4-D factor it produced)
    x4 = ops.linear_solve(stack.reshape(5, 1, 64, 64), b.reshape(5, 1, 64, 3))
    np.testing.assert_array_equal(np.asarray(x4).reshape(5, 64, 3), np.asarray(x))


# ---------------------------------------------------------------------------
# multi-device backend (8 host devices forced by conftest)
# ---------------------------------------------------------------------------
def test_distributed_backend_registered_and_dispatches(no_cache):
    from repro.core.blocked import blocked_lu
    from repro.launch.mesh import make_mesh

    assert select(Problem(op="factor", structure="dense", n=256, devices=8)).name == "distributed"
    assert get_backend("linear_solve", "dense", "distributed").supports(
        Problem(op="linear_solve", structure="dense", n=256, rhs=1, devices=8)
    )
    mesh = make_mesh((8,), ("model",))
    n = 256
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    got = np.asarray(ops.lu(a, mesh=mesh, block=16))
    want = np.asarray(blocked_lu(a, block=16))
    np.testing.assert_allclose(got, want, atol=1e-3)
    b = jax.random.normal(jax.random.PRNGKey(1), (n,))
    x = ops.linear_solve(a, b, mesh=mesh, block=16)
    res = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    assert res < 1e-5
    # a forced single-device impl cannot silently ignore the mesh
    with pytest.raises(ValueError, match="cannot honour mesh"):
        ops.lu(a, mesh=mesh, impl="pallas_fused")
    with pytest.raises(ValueError, match="cannot honour mesh"):
        ops.linear_solve(a, b, mesh=mesh, impl="xla")


def test_every_slot_has_backends_and_a_static_winner(no_cache):
    """Registry completeness: every (op, structure) slot the shim can route
    to has at least one capable backend at a representative shape."""
    shapes = {
        "dense": dict(n=64),
        "banded": dict(n=64, bw=4),
        "batched_dense": dict(n=64, batch=2),
        "batched_banded": dict(n=64, bw=4, batch=2),
    }
    for op in ("factor", "solve"):
        for structure, kw in shapes.items():
            p = Problem(op=op, structure=structure, rhs=0 if op == "factor" else 1, **kw)
            assert backends_for(op, structure), (op, structure)
            assert select(p) is not None


# ---------------------------------------------------------------------------
# multi-RHS capability + dispatch hooks (serving-layer substrate)
# ---------------------------------------------------------------------------
def test_multi_rhs_capability_filters_scalar_banded_solve(no_cache, monkeypatch, tmp_path):
    """The scalar banded solve is vector-only: even when the measured cache
    says it wins, a stacked-RHS problem must never be steered to it."""
    vec = Problem(op="solve", structure="banded", n=512, bw=4, rhs=1)
    wide = Problem(op="solve", structure="banded", n=512, bw=4, rhs=32)
    assert get_backend("solve", "banded", "xla_scalar").supports(vec)
    assert not get_backend("solve", "banded", "xla_scalar").supports(wide)
    assert "xla_scalar" not in [b.name for b in candidates(wide)]
    # measured cache claiming xla_scalar is fastest: vector dispatch obeys,
    # stacked dispatch falls to the fastest *capable* backend
    _env_cache(monkeypatch, tmp_path, [{
        "op": "solve", "structure": "banded", "dtype": "float32", "bw": 4, "n": 512,
        "times_us": {"xla_scalar": 1.0, "pallas": 50.0, "xla": 80.0},
    }])
    assert select(vec).name == "xla_scalar"
    assert select(wide).name == "pallas"
    scache.invalidate()


def test_multi_rhs_capability_batched_vmem_solve(no_cache):
    """The batched VMEM solve holds its whole per-program RHS on-chip: a
    sufficiently wide coalesced stack overflows to the vmapped mirror."""
    ok = Problem(op="solve", structure="batched_dense", n=64, batch=4, rhs=64)
    wide = Problem(op="solve", structure="batched_dense", n=64, batch=4, rhs=64 * 5)
    assert select(ok).name == "pallas_vmem"
    assert select(wide).name == "xla"
    # and the end-to-end stacked solve still works past the cap
    a = jnp.stack([make_diagonally_dominant(jax.random.PRNGKey(i), 64) for i in range(2)])
    lu = ops.lu(a)
    b = jax.random.normal(jax.random.PRNGKey(9), (2, 64, 64 * 5))
    x = ops.lu_solve(lu, b)
    res = jnp.linalg.norm(jnp.einsum("bij,bjm->bim", a, x) - b) / jnp.linalg.norm(b)
    assert float(res) < 1e-4


def test_dispatch_hooks_observe_and_detach(no_cache):
    from repro.solvers import record_dispatches

    a = make_diagonally_dominant(jax.random.PRNGKey(0), 64)
    b = jax.random.normal(jax.random.PRNGKey(1), (64,))
    with record_dispatches() as log:
        ops.linear_solve(a, b)
    ops_seen = [p.op for p, _ in log]
    assert ops_seen.count("factor") == 1
    assert ops_seen.count("solve") == 1
    names = dict((p.op, name) for p, name in log)
    assert names["factor"] == select(Problem(op="factor", structure="dense", n=64)).name
    # hook detached: nothing recorded after the block
    before = len(log)
    ops.lu(a)
    assert len(log) == before


def test_stacked_rhs_helpers_roundtrip():
    from repro.core.solve import lu_solve_stacked, split_rhs, stack_rhs
    from repro.core.blocked import blocked_lu
    from repro.core.solve import lu_solve as core_lu_solve

    n = 48
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    bs = [
        jax.random.normal(jax.random.PRNGKey(1), (n,)),
        jax.random.normal(jax.random.PRNGKey(2), (n, 3)),
        jax.random.normal(jax.random.PRNGKey(3), (n,)),
    ]
    stacked, widths, squeezes = stack_rhs(bs)
    assert stacked.shape == (n, 5)
    back = split_rhs(stacked, widths, squeezes)
    for b, r in zip(bs, back):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(r))
    lu = blocked_lu(a, block=n)
    outs = lu_solve_stacked(lu, bs)
    for b, x in zip(bs, outs):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(core_lu_solve(lu, b)))


def test_linear_solve_many_variants():
    """The *_many stacked-RHS wrappers (factor once, split per request)
    match their per-RHS counterparts for every method vocabulary entry."""
    from repro.core.batched import batched_linear_solve, batched_linear_solve_many
    from repro.core.solve import linear_solve, linear_solve_many

    n = 48
    a = make_diagonally_dominant(jax.random.PRNGKey(0), n)
    bs = [
        jax.random.normal(jax.random.PRNGKey(1), (n,)),
        jax.random.normal(jax.random.PRNGKey(2), (n, 3)),
    ]
    for method in ("ebv", "ebv_blocked", "jnp", "auto"):
        outs = linear_solve_many(a, bs, method=method)
        for b, x in zip(bs, outs):
            assert x.shape == b.shape
            ref = linear_solve(a, b, method=method) if method != "auto" else ops.linear_solve(a, b)
            np.testing.assert_allclose(np.asarray(x), np.asarray(ref), atol=1e-5)

    ab = jnp.stack([make_diagonally_dominant(jax.random.PRNGKey(10 + i), n) for i in range(3)])
    bbs = [
        jax.random.normal(jax.random.PRNGKey(20), (3, n)),
        jax.random.normal(jax.random.PRNGKey(21), (3, n, 2)),
    ]
    outs = batched_linear_solve_many(ab, bbs, method="ebv")
    for b, x in zip(bbs, outs):
        assert x.shape == b.shape
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(batched_linear_solve(ab, b, method="ebv")), atol=1e-5
        )
