"""Substrate tests: optimizer, checkpoint manager, data pipeline,
gradient compression (single-device parts)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.train import grad_compress as gc
from repro.train import optimizer as opt_lib


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
def _quadratic_problem(n=16):
    a = jnp.asarray(np.random.default_rng(0).normal(size=(n, n)), jnp.float32)
    a = a @ a.T + n * jnp.eye(n)
    target = jnp.ones((n,))

    def loss(p):
        d = p["x"] - target
        return 0.5 * d @ a @ d

    return loss, {"x": jnp.zeros((n,))}


@pytest.mark.parametrize("name", ["adamw", "ebv"])
def test_optimizer_converges_on_quadratic(name):
    loss, params = _quadratic_problem()
    opt = opt_lib.get_optimizer(name, opt_lib.constant_lr(0.05), weight_decay=0.0)
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
        state.pop("gnorm", None)
    assert float(loss(params)) < 0.05 * l0


def test_ebv_preconditioner_uses_solver_on_2d():
    """The EbV optimizer must beat plain Adam on an ill-conditioned 2-D
    quadratic in equal steps (the solver whitens the curvature)."""
    rng = np.random.default_rng(1)
    n, m = 24, 8
    u = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    cond = u @ jnp.diag(jnp.logspace(0, 3, n)) @ u.T / 100.0
    target = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)

    def loss(p):
        d = p["w"] - target
        return 0.5 * jnp.sum(d.T @ cond @ d)

    losses = {}
    for name in ("adamw", "ebv"):
        params = {"w": jnp.zeros((n, m))}
        opt = opt_lib.get_optimizer(name, opt_lib.constant_lr(0.05), weight_decay=0.0)
        state = opt.init(params)
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
            state.pop("gnorm", None)
        losses[name] = float(loss(params))
    assert losses["ebv"] < losses["adamw"] * 1.05, losses


def test_ebv_optimizer_dispatches_kernel_backend(monkeypatch, tmp_path):
    """Regression (ISSUE 4): the EbV optimizer used to import the pure-jnp
    reference (core.blocked.blocked_lu / core.solve.lu_solve) directly and
    never touched a Pallas kernel.  The registry-routed step must trace to
    batched kernel dispatches — one factor + one solve pallas_call per
    order group — under static selection."""
    from repro.solvers import cache as scache
    from repro.utils.hlo import primitive_count

    monkeypatch.setenv("REPRO_SOLVERS_CACHE", str(tmp_path / "absent.json"))
    scache.invalidate()
    try:
        params = {"w": jnp.zeros((128, 128), jnp.float32),
                  "v": jnp.zeros((64, 200), jnp.float32),
                  "bias": jnp.zeros((128,), jnp.float32)}
        grads = {k: jax.random.normal(jax.random.PRNGKey(i), v.shape)
                 for i, (k, v) in enumerate(params.items())}
        opt = opt_lib.ebv_preconditioned(opt_lib.constant_lr(0.05))
        state = opt.init(params)
        jx = jax.make_jaxpr(lambda g, s, p: opt.update(g, s, p))(grads, state, params)
        # two order groups (n=128, n=64) x (batched factor + batched solve)
        assert primitive_count(jx, "pallas_call") == 4
        # forcing the vmapped-mirror backend traces no kernels but agrees
        opt_x = opt_lib.ebv_preconditioned(opt_lib.constant_lr(0.05), solver_impl="xla")
        jx = jax.make_jaxpr(lambda g, s, p: opt_x.update(g, s, p))(grads, state, params)
        assert primitive_count(jx, "pallas_call") == 0
        newp, _ = opt.update(grads, state, params)
        newp_x, _ = opt_x.update(grads, opt_x.init(params), params)
        for a, b in zip(jax.tree.leaves(newp), jax.tree.leaves(newp_x)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    finally:
        scache.invalidate()


def test_clip_and_schedule():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = opt_lib.clip_by_global_norm(tree, 1.0)
    assert abs(float(opt_lib.global_norm(clipped)) - 1.0) < 1e-5
    sched = opt_lib.warmup_cosine(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(100))) <= 0.11


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_ckpt_roundtrip_and_prune(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3), "nested": {"b": np.ones(4)}}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"data": {"step": step, "seed": 0}})
    assert mgr.all_steps() == [2, 3]  # pruned to keep=2
    restored, extra, step = mgr.restore(tree)
    assert step == 3 and extra["data"]["step"] == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])
    np.testing.assert_array_equal(restored["nested"]["b"], tree["nested"]["b"])


def test_ckpt_atomicity(tmp_path):
    """A leftover .tmp dir (simulated crash) must not corrupt restore."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": np.ones(3)}
    mgr.save(5, tree)
    os.makedirs(tmp_path / "step_000000006.tmp")  # crashed half-write
    assert mgr.latest_step() == 5
    restored, _, step = mgr.restore(tree)
    assert step == 5


def test_ckpt_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": np.zeros(10)}, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_ckpt_elastic_resharding(tmp_path):
    """Checkpoints are logical: restore onto a different sharding layout."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": np.arange(16, dtype=np.float32)}
    mgr.save(1, tree)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    restored, _, _ = mgr.restore(tree, shardings={"w": sh})
    assert isinstance(restored["w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_determinism_and_sharding():
    mk = lambda shard: TokenPipeline(
        vocab_size=100, seq_len=8, global_batch=4, shard_index=shard, num_shards=2, seed=3
    )
    a0, a1 = mk(0), mk(1)
    b0, b1 = next(a0)["tokens"], next(a1)["tokens"]
    assert b0.shape == (2, 8)
    assert not np.array_equal(b0, b1), "shards must generate distinct slices"
    # determinism: fresh pipeline reproduces the stream
    again = next(mk(0))["tokens"]
    np.testing.assert_array_equal(b0, again)


def test_pipeline_resume_exact():
    p = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2, seed=1)
    batches = [next(p)["tokens"] for _ in range(5)]
    state = p.state()
    later = [next(p)["tokens"] for _ in range(3)]
    q = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2, seed=1).restore(state)
    replay = [next(q)["tokens"] for _ in range(3)]
    for x, y in zip(later, replay):
        np.testing.assert_array_equal(x, y)


def test_pipeline_prefetch_thread():
    p = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2, seed=1).start()
    try:
        b = [next(p)["tokens"] for _ in range(3)]
        assert all(x.shape == (2, 4) for x in b)
        # matches the unthreaded stream
        q = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2, seed=1)
        for i in range(3):
            np.testing.assert_array_equal(b[i], next(q)["tokens"])
    finally:
        p.stop()


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_quantize_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(256,)), jnp.float32)
    q, s = gc.quantize(x)
    err = float(jnp.abs(gc.dequantize(q, s) - x).max())
    assert err <= float(s) * 0.5 + 1e-7


def test_error_feedback_accumulates():
    """With error feedback, the *running sum* of transported grads converges
    to the running sum of true grads (unbiased transport)."""
    rng = np.random.default_rng(3)
    g_true_sum = np.zeros(64, np.float32)
    g_sent_sum = np.zeros(64, np.float32)
    err = {"g": jnp.zeros(64, jnp.float32)}
    for _ in range(50):
        g = rng.normal(size=64).astype(np.float32)
        g_true_sum += g
        qs, scales, err_new = gc.compress_with_feedback({"g": jnp.asarray(g)}, err)
        g_sent_sum += np.asarray(gc.dequantize(qs["g"], scales["g"]))
        err = err_new
    residual = np.abs(g_true_sum - g_sent_sum).max()
    assert residual == pytest.approx(float(np.abs(np.asarray(err["g"])).max()), abs=1e-4)
    assert residual < 0.1  # bounded, non-accumulating


def test_compression_ratio():
    params = {"w": jnp.zeros((1000,)), "b": jnp.zeros((10,))}
    r = gc.compression_ratio(params)
    assert 0.24 < r < 0.27  # ≈4× transport reduction
