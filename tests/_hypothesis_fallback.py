"""Minimal deterministic stand-in for the tiny slice of `hypothesis` that
tests/test_property.py uses, so the property tests still run in containers
without the real package (which cannot be installed here).

Implements: ``given``/``settings`` decorators and the ``st.data()``,
``st.integers``, ``st.floats``, ``st.lists`` strategies with seeded random
sampling (first example minimal, then uniform draws).  NOT a general
hypothesis replacement — no shrinking, no database, no stateful testing.
"""
from __future__ import annotations

import numpy as np

DEFAULT_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def sample(self, rng, minimal=False):
        return self._draw(rng, minimal)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(None)


class _DataObject:
    """Mirrors hypothesis's interactive ``data.draw(strategy)``."""

    def __init__(self, rng, minimal):
        self._rng = rng
        self._minimal = minimal

    def draw(self, strategy):
        return strategy.sample(self._rng, self._minimal)


class st:
    @staticmethod
    def data():
        return _DataStrategy()

    @staticmethod
    def integers(min_value, max_value):
        def draw(rng, minimal):
            if minimal:
                return int(min_value)
            return int(rng.integers(min_value, max_value + 1))

        return _Strategy(draw)

    @staticmethod
    def floats(min_value=None, max_value=None, allow_nan=False, width=64):
        lo = -1e6 if min_value is None else float(min_value)
        hi = 1e6 if max_value is None else float(max_value)

        def draw(rng, minimal):
            if minimal:
                return 0.0 if lo <= 0.0 <= hi else lo
            return float(np.float32(rng.uniform(lo, hi)) if width == 32 else rng.uniform(lo, hi))

        return _Strategy(draw)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng, minimal):
            n = min_size if minimal else int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng, minimal) for _ in range(n)]

        return _Strategy(draw)


def settings(max_examples=DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        # zero-arg wrapper (like hypothesis) so pytest doesn't mistake the
        # strategy parameters for fixtures
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", DEFAULT_EXAMPLES)
            for example in range(n):
                rng = np.random.default_rng(example)
                minimal = example == 0
                drawn = [
                    _DataObject(rng, minimal)
                    if isinstance(s, _DataStrategy)
                    else s.sample(rng, minimal)
                    for s in strategies
                ]
                fn(*drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._fallback_max_examples = getattr(
            fn, "_fallback_max_examples", DEFAULT_EXAMPLES
        )
        return wrapper

    return deco
