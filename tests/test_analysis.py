"""Unit tests for the analysis machinery itself: HLO collective parser,
per-arch sharding-rule resolution, batched-LU kernel, reports helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.utils.hlo import collective_bytes, computation_multipliers


SYNTH_HLO = """HloModule jit_step

%body (p: (s32[], f32[16,64])) -> (s32[], f32[16,64]) {
  %ag = f32[16,64]{1,0} all-gather(%x), channel_id=1, replica_groups=[4,4]<=[16], dimensions={0}
  %ar = f32[16,64]{1,0} all-reduce(%ag), channel_id=2, replica_groups=[2,8]<=[16], to_apply=%add
}

%cond (p: (s32[], f32[16,64])) -> pred[] {
  %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (a: f32[16,64]) -> f32[16,64] {
  %w = (s32[], f32[16,64]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %rs = f32[4,64]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[4,4]<=[16], dimensions={0}
}
"""


def test_collective_parser_weighted():
    """While-body collectives multiply by known_trip_count; byte math per op
    kind matches the documented model."""
    out = collective_bytes(SYNTH_HLO, num_devices=16, weighted=True)
    b = 16 * 64 * 4  # f32[16,64]
    # all-gather in body: operand = result/g (g=4), ×10 trips
    assert out["operand_bytes"]["all-gather"] == (b // 4) * 10
    assert out["wire_bytes"]["all-gather"] == int(b * 3 / 4) * 10
    # all-reduce in body: operand = result, wire = 2·(g−1)/g·result (g=8)
    assert out["operand_bytes"]["all-reduce"] == b * 10
    assert out["wire_bytes"]["all-reduce"] == round(2 * b * 7 / 8 * 10)
    # reduce-scatter in entry (×1): operand = result·g
    rs = 4 * 64 * 4
    assert out["operand_bytes"]["reduce-scatter"] == rs * 4
    assert out["counts"]["all-gather"] == 10


def test_computation_multipliers():
    mult, comps = computation_multipliers(SYNTH_HLO)
    assert mult["body"] == 10.0 and mult["cond"] == 10.0
    assert mult["main"] == 1.0
    assert "body" in comps and len(comps["main"]) == 2


def test_rules_for_head_granularity():
    """Sub-head splits must fall back to replication (§Perf iteration 0)."""
    from repro.dist.sharding import rules_for

    # a real 16×16 mesh needs 256 devices; rules_for only reads
    # .axis_names/.shape, so a duck-typed stand-in is enough
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    r = rules_for(get_config("nemotron_4_340b"), m)  # kv=8 % 16 ≠ 0
    assert r["kv_x_dim"] is None and r["heads_x_dim"] == "model"
    r = rules_for(get_config("starcoder2_3b"), m)  # heads 24 % 16 ≠ 0
    assert r["heads_x_dim"] is None
    r = rules_for(get_config("mamba2_1_3b"), m)  # 64 ssd heads % 16 == 0
    assert r["ssm_inner"] == "model"
    r = rules_for(get_config("hymba_1_5b"), m)  # 50 ssd heads % 16 ≠ 0
    assert r["ssm_inner"] is None and r["state_heads"] is None


def test_batched_lu_kernel():
    from repro.core import make_diagonally_dominant
    from repro.kernels.batched_lu import batched_lu_vmem, batched_lu_solve_vmem
    from repro.kernels import ref

    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    a = jnp.stack([make_diagonally_dominant(k, 24) for k in keys])
    lu = batched_lu_vmem(a)
    for i in range(4):
        np.testing.assert_allclose(
            np.asarray(lu[i]), ref.lu_ref(np.asarray(a[i])), atol=1e-4
        )
    b = jax.random.normal(jax.random.PRNGKey(1), (4, 24, 3))
    x = batched_lu_solve_vmem(lu, b)
    res = jnp.linalg.norm(jnp.einsum("bij,bjk->bik", a, x) - b) / jnp.linalg.norm(b)
    assert float(res) < 1e-5


def test_model_flops_accounting():
    """MoE active-param accounting: granite top-8/32 ⇒ active ≪ total."""
    from repro.launch.roofline import param_counts

    total, active = param_counts(get_config("granite_moe_1b_a400m"))
    assert active < total
    # expert ffn is (total − non_expert); top-8 of 32 keeps 25% of it
    assert 0.2 < active / total < 0.9
    t2, a2 = param_counts(get_config("llama3_8b"))
    assert t2 == a2  # dense: all params active
    assert 7.5e9 < t2 < 9.5e9  # ≈8B + untied embeddings
