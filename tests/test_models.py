"""Per-architecture smoke tests (reduced configs, CPU) + decode consistency.

Every assigned architecture: one forward/train step asserting output shapes
and finiteness, plus prefill→decode logits matching the teacher-forced
forward (validates KV caches, ring buffers, SSD-vs-recurrent math).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPE_CELLS, get_config, cell_applicable
from repro.models import lm

B, S = 2, 48  # S divisible by reduced ssm_chunk (16); > reduced SWA window (32)


def _batch(cfg, tokens):
    if cfg.family == "vlm":
        p = cfg.num_prefix_embeds
        return {
            "tokens": tokens,
            "prefix_embeds": jax.random.normal(
                jax.random.PRNGKey(7), (tokens.shape[0], p, cfg.d_model), jnp.float32
            ),
        }
    if cfg.family == "encdec":
        return {
            "tokens": tokens,
            "frames": jax.random.normal(
                jax.random.PRNGKey(7), (tokens.shape[0], S // 4, cfg.d_model), jnp.float32
            ),
        }
    return {"tokens": tokens}


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_smoke(arch, arch_state):
    cfg, params = arch_state(arch)
    ntok = S - cfg.num_prefix_embeds if cfg.family == "vlm" else S
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, ntok), 0, cfg.vocab_size)
    loss, metrics = jax.jit(lambda p, b: lm.train_loss(p, b, cfg))(params, _batch(cfg, tokens))
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    grads = jax.grad(lambda p: lm.train_loss(p, _batch(cfg, tokens), cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, arch_state):
    """decode(prefill(t[:s]), t[s]) logits == prefill(t[:s+1]) last logits."""
    cfg, params = arch_state(arch)
    if cfg.num_experts:
        # exact consistency requires non-binding expert capacity: with
        # capacity drops, teacher-forcing and incremental decode legitimately
        # differ (different token populations per dispatch).
        cfg = cfg.replace(moe_capacity_factor=64.0)
    ntok = S - cfg.num_prefix_embeds if cfg.family == "vlm" else S
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, ntok), 0, cfg.vocab_size)

    _, logits_full = lm.prefill(params, _batch(cfg, tokens), cfg)

    caches, _ = lm.prefill(params, _batch(cfg, tokens[:, :-1]), cfg, cache_len=S + 4)
    pos = jnp.asarray(S - 1, jnp.int32)  # absolute position of the new token
    _, logits_dec = lm.decode_step(params, caches, tokens[:, -1:], pos, cfg)

    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]), atol=2e-3, rtol=2e-3
    )


@pytest.mark.parametrize("arch", ["mamba2_1_3b", "hymba_1_5b"])
def test_masked_prefill_pad_invariance(arch, arch_state):
    """Bucketed right-padding must not leak into the SSM state: ``lm.prefill``
    with ``last=`` masks dt to exactly 0 on pad rows and gathers conv tails
    at each row's true end.  Pure-SSM archs are bitwise-identical to the
    unpadded prompt across bucket widths; hybrid archs are bitwise
    pad-content-invariant at a fixed bucket (their attention sublayers
    compile per shape, the same per-bucket determinism dense archs have)."""
    cfg, params = arch_state(arch)
    s0, pad = 21, 11
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, s0), 0, cfg.vocab_size)
    last = jnp.asarray([s0 - 1], jnp.int32)

    zero_pad = jnp.pad(tokens, ((0, 0), (0, pad)))
    garbage = jax.random.randint(jax.random.PRNGKey(6), (1, pad), 0, cfg.vocab_size)
    garbage_pad = jnp.concatenate([tokens, garbage], axis=1)
    c1, l1 = lm.prefill(params, {"tokens": zero_pad}, cfg, last=last)
    c2, l2 = lm.prefill(params, {"tokens": garbage_pad}, cfg, last=last)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    if cfg.family == "ssm":
        # no attention sublayers → every cache leaf (conv tails, SSM state)
        # is pad-independent, and the unpadded prompt matches bitwise too
        for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        _, l0 = lm.prefill(params, {"tokens": tokens}, cfg, last=last)
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_output_shapes(arch, arch_state):
    cfg, params = arch_state(arch)
    ntok = S - cfg.num_prefix_embeds if cfg.family == "vlm" else S
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, ntok), 0, cfg.vocab_size)
    caches, logits = lm.prefill(params, _batch(cfg, tokens), cfg)
    vp = lm.padded_vocab_size(cfg)
    assert logits.shape == (B, 1, vp)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_cell_applicability_matrix():
    """33 live cells + 7 documented long_500k skips (DESIGN.md §6)."""
    live = skipped = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in SHAPE_CELLS.values():
            ok, reason = cell_applicable(cfg, cell)
            if ok:
                live += 1
            else:
                skipped += 1
                assert cell.name == "long_500k"
                assert reason
    assert live == 33 and skipped == 7
    for arch in ("mamba2_1_3b", "hymba_1_5b", "mixtral_8x22b"):
        ok, _ = cell_applicable(get_config(arch), SHAPE_CELLS["long_500k"])
        assert ok, f"{arch} must support long_500k (sub-quadratic)"


def test_full_configs_match_assignment():
    """Exact architecture hyper-parameters from the brief."""
    c = get_config("nemotron_4_340b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        96, 18432, 96, 8, 73728, 256000)
    c = get_config("llama3_8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        32, 4096, 32, 8, 14336, 128256)
    c = get_config("deepseek_67b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == (95, 8192, 64, 22016, 102400)
    c = get_config("starcoder2_3b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        30, 3072, 24, 2, 12288, 49152)
    c = get_config("whisper_tiny")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == (
        4, 4, 384, 6, 1536, 51865)
    c = get_config("mixtral_8x22b")
    assert (c.num_layers, c.d_model, c.num_experts, c.experts_per_token, c.sliding_window) == (
        56, 6144, 8, 2, 4096)
    c = get_config("granite_moe_1b_a400m")
    assert (c.num_layers, c.d_model, c.num_experts, c.experts_per_token, c.vocab_size) == (
        24, 1024, 32, 8, 49155)
    c = get_config("qwen2_vl_2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        28, 1536, 12, 2, 8960, 151936)
    assert c.mrope_sections == (16, 24, 24)
    c = get_config("mamba2_1_3b")
    assert (c.num_layers, c.d_model, c.ssm_state, c.vocab_size) == (48, 2048, 128, 50280)
    c = get_config("hymba_1_5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.ssm_state) == (
        32, 1600, 25, 5, 5504, 16)


def test_tri_attention_schedule_matches_rect():
    """§Perf optimization: triangular schedule must be numerically identical
    to the rectangular baseline (causal + sliding-window cases)."""
    import jax
    from repro.models.common import attention

    key = jax.random.PRNGKey(0)
    b, s, h, kv, dh = 2, 96, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, dh), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    for window in (None, 40):
        rect = attention(q, k, v, q_positions=pos, kv_positions=pos,
                         causal=True, window=window, kv_chunk=32, schedule="rect")
        tri = attention(q, k, v, q_positions=pos, kv_positions=pos,
                        causal=True, window=window, kv_chunk=32, schedule="tri")
        np.testing.assert_allclose(np.asarray(tri), np.asarray(rect), atol=2e-5, rtol=2e-5)
