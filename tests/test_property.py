"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: seeded deterministic shim
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    banded_lu_solve,
    blocked_lu,
    ebv_lu,
    equalized_pairing,
    fold_index,
    linear_solve,
    lu_solve,
    pair_lengths,
    reconstruct,
    to_banded,
)
from repro.core.blocked import ebv_folded_owners

SETTINGS = dict(max_examples=25, deadline=None)


def _dd_matrix(draw, n):
    """Diagonally dominant matrix from sampled entries (paper contract)."""
    elems = draw(
        st.lists(
            st.floats(-1, 1, allow_nan=False, width=32),
            min_size=n * n, max_size=n * n,
        )
    )
    a = np.array(elems, np.float32).reshape(n, n)
    np.fill_diagonal(a, np.abs(a).sum(1) + 1.0)
    return jnp.asarray(a)


@settings(**SETTINGS)
@given(st.data(), st.integers(2, 24))
def test_lu_reconstructs_input(data, n):
    a = _dd_matrix(data.draw, n)
    rel = float(jnp.abs(reconstruct(ebv_lu(a)) - a).max()) / max(float(jnp.abs(a).max()), 1e-6)
    assert rel < 1e-4


@settings(**SETTINGS)
@given(st.data(), st.integers(2, 24), st.integers(1, 24))
def test_solve_residual_bounded(data, n, block):
    a = _dd_matrix(data.draw, n)
    b = jnp.asarray(
        np.array(data.draw(st.lists(st.floats(-1, 1, width=32), min_size=n, max_size=n)), np.float32)
    )
    x = linear_solve(a, b, method="ebv_blocked", block=min(block, n))
    denom = max(float(jnp.linalg.norm(b)), 1e-6)
    assert float(jnp.linalg.norm(a @ x - b)) / denom < 1e-4


@settings(**SETTINGS)
@given(st.integers(2, 4096))
def test_equalization_invariants(n):
    units = equalized_pairing(n)
    covered = sorted(r for u in units for r in u)
    assert covered == list(range(n - 1))
    for u, l in zip(units, pair_lengths(n)):
        if len(u) == 2:
            assert l == n


@settings(**SETTINGS)
@given(st.integers(1, 2048))
def test_fold_index_bijection(count):
    seen = {int(fold_index(i, count)) for i in range(count)}
    assert seen == set(range(count))


@settings(**SETTINGS)
@given(st.integers(1, 32), st.integers(1, 8))
def test_folded_owner_work_equalized(pairs_per_dev, p):
    nb = 2 * pairs_per_dev * p
    owners = ebv_folded_owners(nb, p)
    work = [0.0] * p
    for k, o in enumerate(owners):
        work[o] += nb - k
    assert max(work) == min(work)


@settings(**SETTINGS)
@given(st.data(), st.integers(4, 24), st.integers(1, 3))
def test_banded_equals_dense_solve(data, n, bw):
    a = np.array(_dd_matrix(data.draw, n))
    i, j = np.indices(a.shape)
    a[np.abs(i - j) > bw] = 0.0
    np.fill_diagonal(a, np.abs(a).sum(1) + 1.0)
    a = jnp.asarray(a)
    b = jnp.asarray(
        np.array(data.draw(st.lists(st.floats(-1, 1, width=32), min_size=n, max_size=n)), np.float32)
    )
    xd = lu_solve(blocked_lu(a, block=min(8, n)), b)
    xb = banded_lu_solve(to_banded(a, bw), b, bw=bw)
    np.testing.assert_allclose(np.asarray(xb), np.asarray(xd), atol=1e-3, rtol=1e-3)
