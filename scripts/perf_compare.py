"""Compare dry-run artifact sets (§Perf before/after tables) and
BENCH_kernels.json snapshots (the cross-PR kernel-perf gate).

    python scripts/perf_compare.py artifacts/dryrun_v0_baseline artifacts/dryrun [--mesh single] [--cells a__b ...]
    python scripts/perf_compare.py --bench BENCH_prev.json BENCH_kernels.json [--max-ratio 1.5]

``--bench`` mode compares the ``name -> us_per_call`` rows of two smoke-bench
snapshots and **exits non-zero** when any key present in the previous file
regressed by more than ``--max-ratio`` (keys only in one file are reported
but never fail — new benches must be addable without tripping the gate).
"""
import argparse
import json
import os
import sys

from_dir = None

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def load(d, mesh):
    out = {}
    p = os.path.join(d, mesh)
    if not os.path.isdir(p):
        return out
    for f in os.listdir(p):
        r = json.load(open(os.path.join(p, f)))
        if r.get("status") == "ok":
            out[f"{r['arch']}__{r['cell']}"] = r
    return out


def terms(r):
    c = r["cost"]
    return {
        "compute_s": c["flops_per_device"] / PEAK,
        "memory_s": c["bytes_per_device"] / HBM,
        "collective_s": c["wire_bytes_per_device"] / ICI,
        "peak_gib": r["memory"]["peak_bytes_est"] / 2**30,
    }


def bench_compare(before_path: str, after_path: str, max_ratio: float, min_us: float = 0.0) -> int:
    with open(before_path) as f:
        before = json.load(f)
    with open(after_path) as f:
        after = json.load(f)
    regressions = []
    print(f"| bench | before us | after us | ratio |")
    print(f"|---|---|---|---|")
    for k in sorted(before):
        if k not in after:
            print(f"| {k} | {before[k]:.1f} | (dropped) | – |")
            continue
        ratio = after[k] / before[k] if before[k] else float("inf")
        gated = before[k] >= min_us
        flag = "  <-- REGRESSION" if ratio > max_ratio and gated else (
            "  (below noise floor, ungated)" if ratio > max_ratio else "")
        print(f"| {k} | {before[k]:.1f} | {after[k]:.1f} | {ratio:.2f}x |{flag}")
        if ratio > max_ratio and gated:
            regressions.append((k, ratio))
    for k in sorted(set(after) - set(before)):
        print(f"| {k} | (new) | {after[k]:.1f} | – |")
    if regressions:
        print(
            f"FAIL: {len(regressions)} bench(es) regressed past {max_ratio}x: "
            + ", ".join(f"{k} ({r:.2f}x)" for k, r in regressions),
            file=sys.stderr,
        )
        return 1
    print(f"OK: no key regressed past {max_ratio}x", file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("before")
    ap.add_argument("after")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--cells", nargs="*", default=None)
    ap.add_argument("--bench", action="store_true",
                    help="before/after are BENCH_kernels.json snapshots")
    ap.add_argument("--max-ratio", type=float, default=1.5,
                    help="--bench: fail when any shared key slows past this ratio")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="--bench: noise floor — rows whose BEFORE value is "
                         "under this many us are reported but never gated "
                         "(sub-5ms interpret-mode calls swing >1.5x "
                         "run-to-run on this container)")
    args = ap.parse_args()
    if args.bench:
        sys.exit(bench_compare(args.before, args.after, args.max_ratio, args.min_us))
    b = load(args.before, args.mesh)
    a = load(args.after, args.mesh)
    keys = args.cells or sorted(set(b) & set(a))
    print("| cell | compute s (b→a) | memory s (b→a) | collective s (b→a) | peak GiB (b→a) | dominant after |")
    print("|---|---|---|---|---|---|")
    for k in keys:
        if k not in b or k not in a:
            continue
        tb, ta = terms(b[k]), terms(a[k])
        dom = max(("compute_s", "memory_s", "collective_s"), key=lambda x: ta[x])
        fmt = lambda x, y: f"{x:.3g} → {y:.3g} ({'–' if x==0 else f'{(1 - y/x)*100:+.0f}%'[:6]})" if x != y else f"{x:.3g}"
        print(f"| {k} | {fmt(tb['compute_s'], ta['compute_s'])} | {fmt(tb['memory_s'], ta['memory_s'])} | "
              f"{fmt(tb['collective_s'], ta['collective_s'])} | {tb['peak_gib']:.1f} → {ta['peak_gib']:.1f} | {dom.split('_')[0]} |")


if __name__ == "__main__":
    main()
