"""Compare dry-run artifact sets (§Perf before/after tables).

    python scripts/perf_compare.py artifacts/dryrun_v0_baseline artifacts/dryrun [--mesh single] [--cells a__b ...]
"""
import argparse
import json
import os

from_dir = None

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def load(d, mesh):
    out = {}
    p = os.path.join(d, mesh)
    if not os.path.isdir(p):
        return out
    for f in os.listdir(p):
        r = json.load(open(os.path.join(p, f)))
        if r.get("status") == "ok":
            out[f"{r['arch']}__{r['cell']}"] = r
    return out


def terms(r):
    c = r["cost"]
    return {
        "compute_s": c["flops_per_device"] / PEAK,
        "memory_s": c["bytes_per_device"] / HBM,
        "collective_s": c["wire_bytes_per_device"] / ICI,
        "peak_gib": r["memory"]["peak_bytes_est"] / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("before")
    ap.add_argument("after")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--cells", nargs="*", default=None)
    args = ap.parse_args()
    b = load(args.before, args.mesh)
    a = load(args.after, args.mesh)
    keys = args.cells or sorted(set(b) & set(a))
    print("| cell | compute s (b→a) | memory s (b→a) | collective s (b→a) | peak GiB (b→a) | dominant after |")
    print("|---|---|---|---|---|---|")
    for k in keys:
        if k not in b or k not in a:
            continue
        tb, ta = terms(b[k]), terms(a[k])
        dom = max(("compute_s", "memory_s", "collective_s"), key=lambda x: ta[x])
        fmt = lambda x, y: f"{x:.3g} → {y:.3g} ({'–' if x==0 else f'{(1 - y/x)*100:+.0f}%'[:6]})" if x != y else f"{x:.3g}"
        print(f"| {k} | {fmt(tb['compute_s'], ta['compute_s'])} | {fmt(tb['memory_s'], ta['memory_s'])} | "
              f"{fmt(tb['collective_s'], ta['collective_s'])} | {tb['peak_gib']:.1f} → {ta['peak_gib']:.1f} | {dom.split('_')[0]} |")


if __name__ == "__main__":
    main()
