#!/usr/bin/env python
"""Autotune sweep: measure every autotune-flagged backend per problem shape
and persist the timings to the solvers cache.

Uses the same round-robin ``time_shootout`` harness as the smoke bench
(:mod:`benchmarks.common`), so the cache and ``BENCH_kernels.json`` can
never disagree about who won a shootout.  The cache path follows
``repro.solvers.cache`` resolution (``$REPRO_SOLVERS_CACHE`` >
``~/.cache/repro_solvers.json``) unless ``--out`` overrides it.

    python scripts/autotune.py --smoke            # CI: small sizes, seconds
    python scripts/autotune.py                    # default grid
    python scripts/autotune.py --full             # paper-scale sizes (slow)
    python scripts/autotune.py --devices 8        # + SPIKE-vs-replicated sweep
                                                  #   (forces 8 host devices)

Smoke sizes and the 4x nearest-size transfer window are chosen together so
that a seeded cache can never flip the *observable* behaviour the unit
tests assert at toy sizes: the banded sweep (n=2048) stays > 4x above every
banded test order (n ≤ 200) because the banded solve candidates are NOT
value-identical; the dense sweeps (n=256/512) may transfer into test sizes,
but the dense-factor autotune candidates are bitwise twins
(``pallas_fused`` ↔ ``xla``) and no test asserts dispatch counts or exact
values on a default-impl dense solve.  Tests that do assert static dispatch
(optimizer, batched routing) pin an empty cache explicitly.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _problem_grid(level: str):
    """(problem, kwargs) pairs to sweep.  ``kwargs`` are the backend-call
    kwargs (bw for banded slots)."""
    from repro.solvers import Problem

    if level == "smoke":
        dense_factor_ns = [256]
        dense_solve_ns = [512]
        banded = [(2048, 8)]
        batched = [(8, 128)]
    elif level == "full":
        dense_factor_ns = [256, 1024, 2048]
        dense_solve_ns = [512, 2048, 4096]
        banded = [(2048, 8), (16384, 16)]
        batched = [(8, 128), (32, 256)]
    else:  # default
        dense_factor_ns = [256, 1024]
        dense_solve_ns = [512, 2048]
        banded = [(2048, 8)]
        batched = [(8, 128)]

    grid = []
    for n in dense_factor_ns:
        grid.append(Problem(op="factor", structure="dense", n=n))
    for n in dense_solve_ns:
        grid.append(Problem(op="solve", structure="dense", n=n, rhs=8))
    for n, bw in banded:
        grid.append(Problem(op="factor", structure="banded", n=n, bw=bw))
        grid.append(Problem(op="solve", structure="banded", n=n, bw=bw, rhs=1))
    for b, n in batched:
        grid.append(Problem(op="factor", structure="batched_dense", n=n, batch=b))
        grid.append(Problem(op="solve", structure="batched_dense", n=n, batch=b, rhs=n))
    return grid


def _operands(problem):
    """Build concrete operand arrays for a problem (factored inputs for the
    solve ops come from the slot's pure-jnp reference backend)."""
    import jax
    import jax.numpy as jnp

    from repro.core import make_diagonally_dominant
    from repro.core.banded import make_banded_dd
    from repro.solvers import Problem, get_backend

    key = jax.random.PRNGKey(problem.n)
    if problem.structure == "dense":
        a = make_diagonally_dominant(key, problem.n)
    elif problem.structure == "banded":
        a = make_banded_dd(key, problem.n, problem.bw)
    elif problem.structure == "batched_dense":
        a = jnp.stack([
            make_diagonally_dominant(jax.random.PRNGKey(i), problem.n)
            for i in range(problem.batch)
        ])
    else:  # batched_banded
        a = jnp.stack([
            make_banded_dd(jax.random.PRNGKey(i), problem.n, problem.bw)
            for i in range(problem.batch)
        ])
    if problem.op == "factor":
        return (a,)
    fp = Problem(op="factor", structure=problem.structure, n=problem.n,
                 dtype=problem.dtype, bw=problem.bw, batch=problem.batch)
    lu = get_backend("factor", problem.structure, "xla").call(fp, a, bw=problem.bw)
    # hand the shootout a solve-ready Factorization artifact: enrichment
    # (diagonal-block inversion) is a factor-time cost, so the inverted
    # backends must be timed against pre-enriched operands — the legacy
    # backends unwrap ``.packed`` and are unaffected
    from repro.core import factorization as fz

    if problem.structure == "banded" and not problem.batched:
        lu = fz.banded_artifact(lu, bw=problem.bw)
    elif problem.structure == "dense" and not problem.batched:
        lu = fz.dense_artifact(lu)
    shape = ((problem.batch,) if problem.batched else ()) + (problem.n,)
    if problem.rhs > 1:
        shape = shape + (problem.rhs,)  # rhs == 1 stays a vector RHS
    b = jax.random.normal(jax.random.PRNGKey(1), shape)
    return (lu, b)


def _width_grid(level: str):
    """Stacked-RHS coalescing-width sweeps: (dense n, widths to measure).
    Consumed by ``AutotuneCache.best_width`` — the serve layer chunks wide
    coalesced solve dispatches at the most µs-per-column-efficient width."""
    if level == "full":
        return [(512, (8, 32, 128, 512)), (2048, (8, 32, 128, 512))]
    return [(512, (8, 32, 128))]


def run_width_sweep(cache, level: str, iters: int) -> dict:
    """Measure dense stacked-RHS substitution at each candidate width and
    persist per-width µs into the cache (``record_widths``)."""
    import jax

    from benchmarks.common import time_call
    from repro.core import make_diagonally_dominant
    from repro.kernels import ops as kops
    from repro.solvers import Problem

    measured = {}
    for n, widths in _width_grid(level):
        a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
        lu = kops.lu(a)
        width_us = {}
        for w in widths:
            b = jax.random.normal(jax.random.PRNGKey(1), (n, int(w)))
            width_us[int(w)] = time_call(kops.lu_solve, lu, b, iters=iters) * 1e6
        problem = Problem(op="solve", structure="dense", n=n, rhs=max(widths))
        cache.record_widths(problem, width_us)
        best = min(width_us, key=lambda w: width_us[w] / w)
        measured[n] = width_us
        print(
            f"solve/dense n={n} width sweep: "
            + "  ".join(f"w{w}={v:,.0f}us" for w, v in sorted(width_us.items()))
            + f"  -> cap {best}"
        )
    return measured


def run_page_size_sweep(cache, level: str, iters: int) -> dict:
    """Measure a small ragged paged-serve workload at each candidate KV page
    size and persist per-size µs into the cache (``record_page_sizes``) —
    consumed by ``Engine._default_page_size``.  Page size trades gather
    granularity (small pages: more page-table walks per decode) against
    internal fragmentation (large pages: partially-filled tails), so the
    optimum is container-specific and worth a measurement."""
    import numpy as np

    import jax

    from benchmarks.common import time_call
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.serve.engine import Engine, GenRequest
    from repro.solvers import Problem

    cfg = get_config("llama3_8b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    max_len = 64
    lens, news = [5, 11, 7, 14], [8, 3, 6, 4]
    reqs = [
        GenRequest(tokens=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                   max_new_tokens=n, seed=i)
        for i, (s, n) in enumerate(zip(lens, news))
    ]
    sizes = (8, 16, 32) if level != "full" else (4, 8, 16, 32)
    page_us = {}
    for pg in sizes:
        eng = Engine(params, cfg, max_len=max_len, slots=4, bucket=4,
                     paged=True, page_size=pg, prefix_reuse=False)
        # each sample is a whole serve() (multiple dispatches), so the
        # median steadies at fewer iters than the single-kernel shootouts
        page_us[int(pg)] = time_call(
            lambda e=eng: e.serve(reqs), iters=min(iters, 3)
        ) * 1e6
    problem = Problem(op="decode", structure="paged_kv", n=max_len,
                      dtype=jax.numpy.dtype(cfg.dtype).name)
    cache.record_page_sizes(problem, page_us)
    best = min(page_us, key=page_us.get)
    print(
        "decode/paged_kv page-size sweep: "
        + "  ".join(f"pg{p}={v:,.0f}us" for p, v in sorted(page_us.items()))
        + f"  -> {best}"
    )
    return page_us


def run_devices_sweep(cache, level: str, iters: int, devices: int) -> dict:
    """SPIKE-vs-replicated shootout for ``devices > 1`` banded problems.

    Runs both backends over a real ``(devices,)`` mesh (``mesh=`` routes the
    spike backend through its shard_map'd kernel entry, and the replicated
    backend through the same devices=1 re-dispatch the funnel falls back to)
    and records the timings under the exact ``(n, bw, devices)`` cache key —
    the measured selection ``repro.solvers`` consults before trusting
    spike's static priority."""
    import jax

    from benchmarks.common import time_shootout
    from repro.core.banded import make_banded_dd
    from repro.core.spike import spike_supported
    from repro.launch.mesh import make_mesh
    from repro.solvers import Problem, candidates

    if len(jax.devices()) < devices:
        print(
            f"devices sweep skipped: {len(jax.devices())} device(s) visible, "
            f"need {devices} (set --devices before jax initializes)",
            file=sys.stderr,
        )
        return {}
    mesh = make_mesh((devices,), ("model",))
    shapes = [(2048, 8), (16384, 16)] if level == "full" else [(2048, 8)]
    measured = {}
    for n, bw in shapes:
        if not spike_supported(n, bw, devices):
            print(f"devices sweep: n={n} bw={bw} devices={devices} "
                  f"unsupported (2*bw > ceil(n/devices)), skipped")
            continue
        arow = make_banded_dd(jax.random.PRNGKey(n), n, bw)
        b = jax.random.normal(jax.random.PRNGKey(1), (n,))
        for problem, arrays in (
            (Problem(op="factor", structure="banded", n=n, bw=bw,
                     devices=devices), (arow,)),
            (Problem(op="linear_solve", structure="banded", n=n, bw=bw,
                     rhs=1, devices=devices), (arow, b)),
        ):
            cands = [c for c in candidates(problem) if c.autotune]
            if len(cands) < 2:
                continue
            fns = {
                c.name: functools.partial(c.call, problem, bw=bw, mesh=mesh)
                for c in cands
            }
            times = time_shootout(fns, *arrays, iters=iters)
            times_us = {name: t * 1e6 for name, t in times.items()}
            cache.record(problem, times_us)
            winner = min(times_us, key=times_us.get)
            measured[problem] = times_us
            print(
                f"{problem.op}/banded n={n} bw={bw} devices={devices}: "
                + "  ".join(f"{k}={v:,.0f}us" for k, v in sorted(times_us.items()))
                + f"  -> {winner}"
            )
    return measured


def run(level: str, out: str | None, iters: int, devices: int = 1) -> dict:
    import jax

    from benchmarks.common import time_shootout
    from repro.solvers import candidates
    from repro.solvers.cache import AutotuneCache, cache_path

    path = out or cache_path()
    cache = AutotuneCache.load(path)
    measured = {}
    for problem in _problem_grid(level):
        cands = [b for b in candidates(problem) if b.autotune]
        if len(cands) < 2:
            continue
        arrays = _operands(problem)
        fns = {
            b.name: functools.partial(b.call, problem, bw=problem.bw)
            for b in cands
        }
        times = time_shootout(fns, *arrays, iters=iters)
        times_us = {name: t * 1e6 for name, t in times.items()}
        cache.record(problem, times_us)
        winner = min(times_us, key=times_us.get)
        measured[problem] = times_us
        print(
            f"{problem.op}/{problem.structure} n={problem.n} bw={problem.bw} "
            f"batch={problem.batch}: "
            + "  ".join(f"{k}={v:,.0f}us" for k, v in sorted(times_us.items()))
            + f"  -> {winner}"
        )
    run_width_sweep(cache, level, iters)
    run_page_size_sweep(cache, level, iters)
    if devices > 1:
        run_devices_sweep(cache, level, iters, devices)
    cache.save(path)
    print(f"wrote {len(cache.entries)} entries to {path}", file=sys.stderr)
    return measured


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small sizes (CI stage)")
    ap.add_argument("--full", action="store_true", help="paper-scale sizes (slow)")
    ap.add_argument("--out", default=None, help="cache file (default: resolved cache path)")
    ap.add_argument("--iters", type=int, default=5, help="shootout samples per backend")
    ap.add_argument("--devices", type=int, default=1,
                    help="also sweep SPIKE vs replicated over this many "
                         "devices (forces host devices when fewer are visible)")
    args = ap.parse_args()
    if args.devices > 1:
        # must land before the first jax import (all imports here are lazy):
        # the host platform's device count is locked at backend init
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", "")
        )
    level = "smoke" if args.smoke else ("full" if args.full else "default")
    run(level, args.out, args.iters, devices=args.devices)


if __name__ == "__main__":
    main()
