"""Quick numeric validation of the core EbV library (dev script)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ebv_lu, blocked_lu, reconstruct, lu_solve, linear_solve,
    make_diagonally_dominant, to_banded, from_banded, banded_lu, banded_lu_solve,
    distributed_blocked_lu, distributed_lu_solve, equalized_pairing, pair_lengths,
)

key = jax.random.PRNGKey(0)
n = 128
a = make_diagonally_dominant(key, n)
b = jax.random.normal(jax.random.PRNGKey(1), (n,))

lu1 = ebv_lu(a)
err = jnp.abs(reconstruct(lu1) - a).max() / jnp.abs(a).max()
print("ebv_lu reconstruct rel err:", err)

lu2 = blocked_lu(a, block=32)
print("blocked vs unblocked max diff:", jnp.abs(lu1 - lu2).max())

x = lu_solve(lu1, b)
print("solve residual:", jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
x2 = linear_solve(a, b, method="ebv_blocked", block=32)
print("linear_solve residual:", jnp.linalg.norm(a @ x2 - b) / jnp.linalg.norm(b))

# banded
bw = 5
ab_dense = make_diagonally_dominant(jax.random.PRNGKey(2), n, sparse_band=bw)
arow = to_banded(ab_dense, bw)
print("band roundtrip:", jnp.abs(from_banded(arow) - ab_dense).max())
xb = banded_lu_solve(arow, b, bw=bw)
print("banded solve residual:", jnp.linalg.norm(ab_dense @ xb - b) / jnp.linalg.norm(b))
lub = banded_lu(arow, bw=bw)
lud = blocked_lu(ab_dense, block=32)
print("banded vs dense LU diff:", jnp.abs(from_banded(lub) - jnp.where(jnp.abs(from_banded(to_banded(lud, bw))) > 0, from_banded(to_banded(lud, bw)), 0)).max())

# pairing invariants
for nn in (8, 9, 129):
    pl_ = pair_lengths(nn)
    covered = sorted(r for unit in equalized_pairing(nn) for r in unit)
    assert covered == list(range(nn - 1)), nn
    assert all(l == nn for l in pl_[: (nn - 1) // 2]), (nn, pl_)
print("pairing invariants ok")

# distributed
mesh = jax.make_mesh((4,), ("model",))
n2 = 256
a2 = make_diagonally_dominant(jax.random.PRNGKey(3), n2)
b2 = jax.random.normal(jax.random.PRNGKey(4), (n2,))
ref = blocked_lu(a2, block=32)
for placement in ("cyclic", "ebv_folded"):
    dlu = distributed_blocked_lu(a2, mesh, block=32, placement=placement)
    print(f"distributed[{placement}] vs blocked max diff:", jnp.abs(dlu - ref).max())
    dx = distributed_lu_solve(a2, b2, mesh, block=32, placement=placement)
    print(f"distributed[{placement}] solve residual:", jnp.linalg.norm(a2 @ dx - b2) / jnp.linalg.norm(b2))
print("OK")
