import jax, jax.numpy as jnp, numpy as np
from repro.core import make_diagonally_dominant, to_banded
from repro.kernels import ops, ref
from repro.kernels import ebv_lu as k

key = jax.random.PRNGKey(0)
for n in (32, 128, 257):
    a = make_diagonally_dominant(jax.random.PRNGKey(n), n)
    r = ref.lu_ref(np.asarray(a))
    got = ops.lu(a, impl="pallas_vmem")
    print(f"vmem n={n}:", np.abs(np.asarray(got) - r).max())
for n in (64, 256):
    a = make_diagonally_dominant(jax.random.PRNGKey(n + 1), n)
    r = ref.lu_ref(np.asarray(a))
    got = ops.lu(a, impl="pallas_blocked", block=32, col_tile=32)
    print(f"blocked n={n}:", np.abs(np.asarray(got) - r).max())
    b = jax.random.normal(jax.random.PRNGKey(2), (n, 4))
    x = ops.lu_solve(got, b)
    xr = ref.solve_ref(r, np.asarray(b))
    print(f"solve n={n}:", np.abs(np.asarray(x) - xr).max())
# banded
n, bw = 200, 4
ad = make_diagonally_dominant(jax.random.PRNGKey(9), n, sparse_band=bw)
arow = to_banded(ad, bw)
got = ops.banded_lu(arow, bw=bw)
r = ref.banded_lu_ref(np.asarray(arow), bw)
print("banded:", np.abs(np.asarray(got) - r).max())
print("OK")
