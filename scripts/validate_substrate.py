"""Dev smoke: optimizer/train-loop/ckpt/engine on a reduced config."""
import os, tempfile
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.train.loop import TrainConfig, train
from repro.serve.engine import Engine
from repro.models import lm

cfg = get_config("llama3_8b").reduced()
with tempfile.TemporaryDirectory() as d:
    tc = TrainConfig(steps=30, seq_len=64, global_batch=4, ckpt_dir=d, ckpt_every=16, log_every=10,
                     warmup_steps=5, learning_rate=1e-3)
    params, hist = train(cfg, tc)
    losses = [h["loss"] for h in hist]
    print("losses:", [f"{l:.3f}" for l in losses])
    assert losses[-1] < losses[0], "loss did not decrease"
    # resume path: new run continues from latest ckpt
    tc2 = TrainConfig(steps=32, seq_len=64, global_batch=4, ckpt_dir=d, ckpt_every=100, log_every=10,
                      warmup_steps=5, learning_rate=1e-3)
    params2, hist2 = train(cfg, tc2)
    assert hist2[0]["step"] == 30, hist2[0]["step"]

# EBV optimizer quick run
tc3 = TrainConfig(steps=4, seq_len=64, global_batch=4, optimizer="ebv", log_every=1)
params3, hist3 = train(cfg, tc3)
print("ebv-opt losses:", [f"{h['loss']:.3f}" for h in hist3])

# engine
eng = Engine(params, cfg, max_len=128)
out = eng.generate(np.ones((2, 8), np.int32), max_new_tokens=6)
print("generate:", out.shape, out[:, -6:])
assert out.shape == (2, 14)

# microbatch equivalence
from repro.train.loop import make_train_step
from repro.train import optimizer as opt_lib
opt = opt_lib.adamw(opt_lib.constant_lr(1e-3))
p0 = lm.init_params(jax.random.PRNGKey(1), cfg)
s0 = opt.init(p0)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, cfg.vocab_size)}
p1, _, m1 = jax.jit(make_train_step(cfg, opt, microbatches=1))(p0, s0, batch)
p2, _, m2 = jax.jit(make_train_step(cfg, opt, microbatches=2))(p0, opt.init(p0), batch)
diff = max(float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
print("microbatch param diff:", diff, "loss", float(m1["loss"]), float(m2["loss"]))
print("OK")
