"""§Perf hillclimb runner: compile tagged variants of the three chosen cells
and print before/after roofline terms.

    PYTHONPATH=src python scripts/hillclimb.py --step <name>
"""
import argparse
import json
import os
import sys

sys.path.insert(0, "src")

PEAK, HBM, ICI = 197e12, 819e9, 50e9
OUT = os.path.abspath("artifacts/dryrun")

STEPS = {
    # (arch, cell, tag, rules_preset, overrides)
    "llama_prefill_tri": ("llama3_8b", "prefill_32k", "_tri", "default", {"attention_schedule": "tri"}),
    "mixtral_train_tri": ("mixtral_8x22b", "train_4k", "_tri", "default", {"attention_schedule": "tri"}),
    "mixtral_train_cap1": ("mixtral_8x22b", "train_4k", "_cap1", "default", {"moe_capacity_factor": 1.0}),
    "mixtral_train_tricap": ("mixtral_8x22b", "train_4k", "_tricap", "default",
                             {"attention_schedule": "tri", "moe_capacity_factor": 1.0}),
    "llama_train_zero3": ("llama3_8b", "train_4k", "_zero3", "zero3", {}),
    "starcoder_train_zero3": ("starcoder2_3b", "train_4k", "_zero3", "zero3", {}),
    "llama_prefill_ebv": ("llama3_8b", "prefill_32k", "_ebv", "default",
                          {"attention_schedule": "ebv"}),
    "llama_train_ebv": ("llama3_8b", "train_4k", "_ebv", "default",
                        {"attention_schedule": "ebv"}),
    "mixtral_train_ebv": ("mixtral_8x22b", "train_4k", "_ebv", "default",
                          {"attention_schedule": "ebv"}),
    "mixtral_train_ebvcap": ("mixtral_8x22b", "train_4k", "_ebvcap", "default",
                             {"attention_schedule": "ebv", "moe_capacity_factor": 1.0}),
    "nemotron_train_ebv": ("nemotron_4_340b", "train_4k", "_ebv", "default",
                           {"attention_schedule": "ebv"}),
    "deepseek_prefill_ebv": ("deepseek_67b", "prefill_32k", "_ebv", "default",
                             {"attention_schedule": "ebv"}),
    "deepseek_train_zero3": ("deepseek_67b", "train_4k", "_zero3", "zero3", {}),
    "mixtral_train_dots": ("mixtral_8x22b", "train_4k", "_dots", "default", {"remat_policy": "dots"}),
    "deepseek_train_dots": ("deepseek_67b", "train_4k", "_dots", "default", {"remat_policy": "dots"}),
    "deepseek_train_ebv": ("deepseek_67b", "train_4k", "_ebv", "default",
                           {"attention_schedule": "ebv"}),
}


def terms(r):
    c = r["cost"]
    return dict(
        compute_s=c["flops_per_device"] / PEAK,
        memory_s=c["bytes_per_device"] / HBM,
        collective_s=c["wire_bytes_per_device"] / ICI,
        peak_gib=r["memory"]["peak_bytes_est"] / 2**30,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--step", choices=list(STEPS), required=True)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    arch, cell, tag, preset, overrides = STEPS[args.step]

    from repro.launch.dryrun import run_cell
    from repro.dist.sharding import RULE_PRESETS

    rec = run_cell(arch, cell, multi_pod=False, out_dir=OUT, force=args.force,
                   rules=RULE_PRESETS[preset], tag=tag, overrides=overrides or None)
    base = json.load(open(os.path.join(OUT, "single", f"{arch}__{cell}.json")))
    if rec["status"] != "ok":
        print("FAILED:", rec.get("error"))
        return
    tb, ta = terms(base), terms(rec)
    print(f"\n{args.step}: {arch} × {cell}  ({tag} vs baseline)")
    for k in tb:
        delta = "" if tb[k] == 0 else f"  ({(1 - ta[k] / tb[k]) * +100:+.1f}% better)" if ta[k] <= tb[k] else f"  ({(ta[k] / tb[k] - 1) * 100:+.1f}% WORSE)"
        print(f"  {k:14s} {tb[k]:10.4g} -> {ta[k]:10.4g}{delta}")
    dom_b = max(("compute_s", "memory_s", "collective_s"), key=lambda k: tb[k])
    dom_a = max(("compute_s", "memory_s", "collective_s"), key=lambda k: ta[k])
    print(f"  dominant: {dom_b} -> {dom_a}")


if __name__ == "__main__":
    main()
