"""Dev smoke: every arch (reduced) through train_loss / prefill / decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models import lm

key = jax.random.PRNGKey(0)
B, S = 2, 32

for arch in ARCH_IDS:
    cfg = get_config(arch).reduced()
    params = lm.init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.family == "vlm":
        batch = {"tokens": tok[:, : S - cfg.num_prefix_embeds],
                 "prefix_embeds": jax.random.normal(key, (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)}
    if cfg.family == "encdec":
        batch = {"tokens": tok, "frames": jax.random.normal(key, (B, S // 4, cfg.d_model), jnp.float32)}
    loss, m = jax.jit(lambda p, b: lm.train_loss(p, b, cfg))(params, batch)
    assert np.isfinite(loss), (arch, loss)
    # prefill + decode
    caches, logits = jax.jit(lambda p, b: lm.prefill(p, b, cfg))(params, batch)
    assert np.all(np.isfinite(logits)), arch
    nt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    pos = jnp.asarray(S, jnp.int32) if cfg.family != "vlm" else jnp.asarray(S, jnp.int32)
    caches2, logits2 = jax.jit(lambda p, c, t, q: lm.decode_step(p, c, t, q, cfg))(params, caches, nt, pos)
    assert np.all(np.isfinite(logits2)), arch
    print(f"{arch:24s} family={cfg.family:7s} params={n_params:8d} loss={float(loss):.3f} ok")
print("ALL OK")
