"""Generate EXPERIMENTS.md tables from dry-run artifacts (run at finish).

    PYTHONPATH=src python scripts/make_reports.py
"""
import json
import os
import sys

sys.path.insert(0, "src")

from repro.configs.base import ARCH_IDS, SHAPE_CELLS
from repro.launch.roofline import analyze, to_markdown  # noqa

OUT = "artifacts/dryrun"
V0 = "artifacts/dryrun_v0_baseline"


def load(mesh, base=OUT):
    rows = {}
    d = os.path.join(base, mesh)
    if not os.path.isdir(d):
        return rows
    for f in os.listdir(d):
        if "__" not in f or not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(d, f)))
        tag = f[:-5].split("__", 1)[1]
        rows[(r["arch"], tag)] = r
    return rows


def dryrun_table():
    out = ["| arch | cell | single: peak GiB / wire GiB / compile s | multi: peak GiB / wire GiB / compile s |",
           "|---|---|---|---|"]
    single, multi = load("single"), load("multi")
    for arch in ARCH_IDS:
        for cell in SHAPE_CELLS:
            def fmt(rows):
                r = rows.get((arch, cell))
                if r is None:
                    return "—"
                if r["status"] == "skipped":
                    return "skip (full attn)"
                if r["status"] != "ok":
                    return f"ERROR {r.get('error','')[:40]}"
                return (f"{r['memory']['peak_bytes_est']/2**30:.1f} / "
                        f"{r['cost']['wire_bytes_per_device']/2**30:.0f} / {r.get('compile_s',0):.0f}")
            out.append(f"| {arch} | {cell} | {fmt(single)} | {fmt(multi)} |")
    return "\n".join(out)


def iter0_table():
    v0, v1 = load("single", V0), load("single")
    cells = [("nemotron_4_340b", "train_4k"), ("llama3_8b", "train_4k"),
             ("starcoder2_3b", "train_4k"), ("mamba2_1_3b", "train_4k"),
             ("hymba_1_5b", "train_4k"), ("deepseek_67b", "train_4k"),
             ("qwen2_vl_2b", "train_4k"), ("whisper_tiny", "train_4k")]
    out = ["| cell | wire GiB/dev before | after | Δ |", "|---|---|---|---|"]
    for a, c in cells:
        b, n = v0.get((a, c)), v1.get((a, c))
        if not b or not n or b["status"] != "ok" or n["status"] != "ok":
            continue
        wb = b["cost"]["wire_bytes_per_device"] / 2**30
        wn = n["cost"]["wire_bytes_per_device"] / 2**30
        out.append(f"| {a} × {c} | {wb:,.0f} | {wn:,.0f} | {(1-wn/max(wb,1e-9))*100:+.0f}% |")
    return "\n".join(out)


def main():
    md = open("EXPERIMENTS.md").read()
    roof = to_markdown(analyze("single", os.path.abspath(OUT)), "single")
    md = md.replace("<!-- ROOFLINE_TABLE -->", roof + "\n### Dry-run summary (both meshes)\n\n" + dryrun_table() + "\n")
    md = md.replace("<!-- ITER0_TABLE -->", iter0_table())
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
