#!/usr/bin/env bash
# Tier-1 verification in one command (ROADMAP.md).  Runs the full test
# suite from the repo root, then the perf smoke (benchmarks/run.py --smoke,
# which writes BENCH_kernels.json for the cross-PR perf trajectory).
# tests/conftest.py forces the deterministic 8-host-device XLA environment.
# Extra pytest args pass through:
#
#     scripts/check.sh                 # everything
#     scripts/check.sh tests/test_distributed.py -k lu
#     SKIP_SMOKE=1 scripts/check.sh    # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
if [[ "${SKIP_SMOKE:-0}" != "1" ]]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run --smoke
    # the smoke bench must land the sparse trajectory: banded_* rows present
    python - <<'EOF'
import json
rows = json.load(open("BENCH_kernels.json"))
banded = sorted(k for k in rows if k.startswith("banded_"))
assert banded, "smoke bench wrote no banded_* rows to BENCH_kernels.json"
print(f"banded rows present: {len(banded)} ({', '.join(banded)})")
EOF
fi
