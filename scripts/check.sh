#!/usr/bin/env bash
# Tier-1 verification in one command (ROADMAP.md):
#   1. autotune smoke (scripts/autotune.py --smoke) — writes the measured
#      solver cache the test run dispatches against ($REPRO_SOLVERS_CACHE,
#      defaulting to the repo-local .autotune_cache.json that
#      tests/conftest.py also pins);
#   2. the full test suite;
#   3. the perf smoke (benchmarks/run.py --smoke → BENCH_kernels.json),
#      followed by a bench/dispatch consistency assert (the registry's auto
#      choice for the banded solve must equal the measured BENCH winner),
#      the serving gates (serve_* rows present; solve-service factorization
#      cache >= 2x over re-factorization; paged + sharded capacity ratios),
#      the multi-device SPIKE gate (spike_d8 vs replicated on the same
#      emulated mesh, SPIKE_MAX_RATIO) and the cross-PR perf gate
#      (scripts/perf_compare.py --bench: fail on >1.5x regression of any
#      key present in the previous snapshot).
# tests/conftest.py forces the deterministic 8-host-device XLA environment.
# Extra pytest args pass through:
#
#     scripts/check.sh                 # everything
#     scripts/check.sh tests/test_distributed.py -k lu
#     SKIP_SMOKE=1 scripts/check.sh    # tests only
#     SKIP_AUTOTUNE=1 scripts/check.sh # skip the cache-seeding stage
#     SKIP_CHAOS=1 scripts/check.sh    # skip the fault-injection drill
set -euo pipefail
cd "$(dirname "$0")/.."
export REPRO_SOLVERS_CACHE="${REPRO_SOLVERS_CACHE:-$PWD/.autotune_cache.json}"
if [[ "${SKIP_AUTOTUNE:-0}" != "1" ]]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python scripts/autotune.py --smoke
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
if [[ "${SKIP_CHAOS:-0}" != "1" ]]; then
    # fault-injection drill (benchmarks/serve_bench.py --chaos): a poisoned
    # flush group must be isolated, and the escalated backends — bf16_ir_xla
    # when bf16_ir crashes, rand_lu when both bf16 tiers crash — must still
    # meet the same residual bounds the accuracy gates below hold the
    # default path to.  Asserts internally; writes nothing to
    # BENCH_kernels.json (chaos measures survival, not speed).
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.serve_bench --chaos
fi
if [[ "${SKIP_SMOKE:-0}" != "1" ]]; then
    prev_bench=""
    if [[ -f BENCH_kernels.json ]]; then
        prev_bench="$(mktemp /tmp/BENCH_prev.XXXXXX.json)"
        trap 'rm -f "$prev_bench"' EXIT
        cp BENCH_kernels.json "$prev_bench"
    fi
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run --smoke
    # the smoke bench must land the sparse trajectory (banded_* rows), the
    # optimizer trajectory (opt_* rows), and the dispatch decisions must
    # agree with the measured rows it just wrote
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import json
rows = json.load(open("BENCH_kernels.json"))
banded = sorted(k for k in rows if k.startswith("banded_"))
assert banded, "smoke bench wrote no banded_* rows to BENCH_kernels.json"
print(f"banded rows present: {len(banded)} ({', '.join(banded)})")
opt = sorted(k for k in rows if k.startswith("opt_"))
assert opt, "smoke bench wrote no opt_* (optimizer) rows to BENCH_kernels.json"
print(f"optimizer rows present: {len(opt)} ({', '.join(opt)})")
serve = sorted(k for k in rows if k.startswith("serve_"))
assert serve, "smoke bench wrote no serve_* rows to BENCH_kernels.json"
print(f"serve rows present: {len(serve)} ({', '.join(serve)})")

# factor-once/solve-many acceptance: the warm factorization cache must beat
# re-factorization by >= 2x on the serve_solve_cache pair
speedup = rows["serve_solve_cache_refactor"] / rows["serve_solve_cache_cached"]
assert speedup >= 2.0, (
    f"solve-service cache speedup {speedup:.2f}x < 2x "
    f"(refactor {rows['serve_solve_cache_refactor']:.0f}us, "
    f"cached {rows['serve_solve_cache_cached']:.0f}us)")
print(f"solve-service cache speedup: {speedup:.1f}x")

# paged-KV acceptance: at the dense engine's HBM budget the paged engine
# must sustain >= 2x the concurrent requests (short requests hold pages,
# not max_len rows), and a primed shared-prefix cache must make long-prompt
# admission >= 3x faster than a cold prefill.  Env-overridable for noisy
# containers (capacity is deterministic; the warm ratio is wall time).
import os
cap_bound = float(os.environ.get("PAGED_CAPACITY_MIN_RATIO", "2.0"))
cap = rows["serve_paged_capacity"]
assert cap >= cap_bound, (
    f"paged capacity ratio {cap:.2f}x < {cap_bound}x the dense slot count")
print(f"paged capacity at equal HBM: {cap:.1f}x dense (bound {cap_bound}x)")
# sharded-serve acceptance: partitioning the pool into per-shard pools
# (disjoint page ranges, slot pinning, one scrap page per shard) must not
# cost concurrent capacity — the shard-balanced scheduler has to keep every
# shard's pages drawing even load
scap_bound = float(os.environ.get("SHARDED_CAPACITY_MIN_RATIO", "2.0"))
scap = rows["serve_sharded_capacity"]
assert scap >= scap_bound, (
    f"sharded capacity ratio {scap:.2f}x < {scap_bound}x the dense slot "
    f"count — per-shard pool partitioning is costing concurrency")
print(f"sharded capacity at equal pages: {scap:.1f}x dense (bound {scap_bound}x)")
warm_bound = float(os.environ.get("PAGED_WARM_MIN_RATIO", "3.0"))
warm = rows["serve_paged_prefix_cold"] / rows["serve_paged_prefix_warm"]
assert warm >= warm_bound, (
    f"shared-prefix warm admission {warm:.2f}x < {warm_bound}x cold "
    f"(cold {rows['serve_paged_prefix_cold']:.0f}us, "
    f"warm {rows['serve_paged_prefix_warm']:.0f}us)")
print(f"shared-prefix warm vs cold prefill: {warm:.1f}x (bound {warm_bound}x)")

# bench/dispatch consistency: the registry auto pick for the smoke banded
# solve shape must be the backend the bench just measured as fastest
from benchmarks.run import SMOKE_BANDED_N, SMOKE_BANDED_BW
from repro.solvers import Problem, select
prefix = f"banded_solve_n{SMOKE_BANDED_N}_"
# the spike_d8 row is a multi-device measurement — not a candidate for the
# single-device dispatch pick below
measured = {k[len(prefix):]: v for k, v in rows.items()
            if k.startswith(prefix) and not k[len(prefix):].startswith("spike")}
winner = min(measured, key=measured.get)
picked = select(Problem(op="solve", structure="banded",
                        n=SMOKE_BANDED_N, bw=SMOKE_BANDED_BW, rhs=1)).name
assert picked == winner, (
    f"banded_solve auto dispatch ({picked}) disagrees with the measured "
    f"BENCH winner ({winner}): {measured}")
print(f"banded_solve auto dispatch == measured winner: {winner}")

# solve-phase crown: the Pallas inverted-diagonal solve must stay within
# BANDED_SOLVE_MAX_RATIO (default 1.5) of the xla_scalar reference at the
# paper's sparse shape — it currently *beats* it ~3x, so this trips only
# on a genuine substitution-path regression, not timer noise
import os
ratio_bound = float(os.environ.get("BANDED_SOLVE_MAX_RATIO", "1.5"))
inv = rows[f"{prefix}pallas_inverted"]
ref = rows[f"{prefix}xla_scalar"]
assert inv <= ratio_bound * ref, (
    f"banded_solve pallas_inverted ({inv:.0f}us) > {ratio_bound}x "
    f"xla_scalar ({ref:.0f}us)")
print(f"banded_solve pallas_inverted/xla_scalar: {inv / ref:.2f}x "
      f"(bound {ratio_bound}x)")

# multi-device crown: the SPIKE split substitution against the replicated
# path on the same emulated 8-device mesh.  The bench times SPIKE under 8
# forced host devices on this container's single core, where the d
# per-device local solves serialize — so its wall clock is held to
# SPIKE_MAX_RATIO x (d x the best single-device substitution), which is
# exactly what the replicated path (every device substituting all n rows)
# costs on the same mesh.  The ratio therefore bounds SPIKE's
# reduced-system + tip-gather overhead over a perfect d-way split.
spike_devices = 8
spike_bound = float(os.environ.get("SPIKE_MAX_RATIO", "1.5"))
spike_row = f"{prefix}spike_d{spike_devices}"
assert spike_row in rows, (
    f"smoke bench wrote no {spike_row} row to BENCH_kernels.json "
    f"(the 8-device subprocess measurement failed)")
spike_budget = spike_bound * spike_devices * inv
assert rows[spike_row] <= spike_budget, (
    f"SPIKE split solve ({rows[spike_row]:.0f}us) > {spike_bound}x the "
    f"replicated cost on the same mesh ({spike_devices}x pallas_inverted "
    f"= {spike_devices * inv:.0f}us)")
print(f"banded_solve spike_d{spike_devices}/(d x pallas_inverted): "
      f"{rows[spike_row] / (spike_devices * inv):.2f}x (bound {spike_bound}x)")

# accuracy gate: every approximate tier's measured residual must stay
# within the bound its backend declares to the selection funnel — an
# accuracy drift past the advertised tier fails CI here, at bench scale,
# not just in toy-size unit tests
from repro.solvers.backends import RAND_LU_RESIDUAL_BOUND
accuracy_gates = {
    # (bound, required): the rand_lu rows ride only with --smoke --full —
    # the chaos drill above already holds that tier to the same bound on
    # every run, so its bench-scale gate is present-conditional
    "lu_n1024_bf16_ir_residual": (1e-5, True),  # the tolerance the bench requested
    "rand_lu_n2048_k256_residual": (RAND_LU_RESIDUAL_BOUND, False),
}
for row, (bound, required) in accuracy_gates.items():
    if row not in rows:
        assert not required, (
            f"smoke bench wrote no {row} row to BENCH_kernels.json")
        print(f"accuracy gate skipped: {row} absent "
              f"(--smoke --full row; chaos drill covers the tier)")
        continue
    assert rows[row] <= bound, (
        f"approximate tier exceeded its declared bound: "
        f"{row}={rows[row]:.3e} > {bound:.1e}")
    print(f"accuracy gate: {row}={rows[row]:.3e} <= {bound:.1e}")
EOF
    if [[ -n "$prev_bench" ]]; then
        # Gate calibration (measured on this container): sustained throttle
        # windows shift whole bench sections 1.2-1.7x between consecutive
        # quiet runs even with median-of-7 sampling, so the default ratio is
        # 2.0 (regressions this repo hunts are 3-9x design-level) and
        # sub-5ms rows — pure noise at this granularity — are reported but
        # not gated.  PERF_MAX_RATIO / PERF_MIN_US override both.
        python scripts/perf_compare.py --bench "$prev_bench" BENCH_kernels.json \
            --max-ratio "${PERF_MAX_RATIO:-2.0}" --min-us "${PERF_MIN_US:-5000}"
    fi
fi
