#!/usr/bin/env bash
# Tier-1 verification in one command (ROADMAP.md).  Runs the full test
# suite from the repo root; tests/conftest.py forces the deterministic
# 8-host-device XLA environment.  Extra pytest args pass through:
#
#     scripts/check.sh                 # everything
#     scripts/check.sh tests/test_distributed.py -k lu
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
