#!/usr/bin/env bash
# Tier-1 verification in one command (ROADMAP.md).  Runs the full test
# suite from the repo root, then the perf smoke (benchmarks/run.py --smoke,
# which writes BENCH_kernels.json for the cross-PR perf trajectory).
# tests/conftest.py forces the deterministic 8-host-device XLA environment.
# Extra pytest args pass through:
#
#     scripts/check.sh                 # everything
#     scripts/check.sh tests/test_distributed.py -k lu
#     SKIP_SMOKE=1 scripts/check.sh    # tests only
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
if [[ "${SKIP_SMOKE:-0}" != "1" ]]; then
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run --smoke
fi
